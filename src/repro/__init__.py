"""DistFlow-JAX: fully-distributed RL post-training framework.

Paper: "DistFlow: A Fully Distributed RL Framework for Scalable and
Efficient LLM Post-Training" (Wang et al., 2025). See DESIGN.md.

The top-level entry point is :class:`repro.api.ExperimentSpec` (re-exported
here lazily so ``import repro`` stays cheap).
"""


def __getattr__(name):
    if name == "ExperimentSpec":
        from repro.api import ExperimentSpec

        return ExperimentSpec
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
