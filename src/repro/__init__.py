"""DistFlow-JAX: fully-distributed RL post-training framework.

Paper: "DistFlow: A Fully Distributed RL Framework for Scalable and
Efficient LLM Post-Training" (Wang et al., 2025). See DESIGN.md.
"""
