"""Span tracing with Chrome trace-event export (docs/observability.md).

A :class:`Tracer` produces nested spans — name, category, host/process id,
start time, duration, ``key=value`` attributes — into a thread-safe
in-memory ring buffer, and exports them as Chrome trace-event JSON
(``chrome://tracing`` / Perfetto-loadable): one *process* track per host
(``pid``) and one *thread* track per subsystem category (``tid``), so a
2-host fleet run renders as two stacked host lanes with dag/rollout/fleet
sub-lanes each.

Disabled tracing is a true no-op: ``Tracer(enabled=False).span(...)``
returns a shared singleton context manager whose enter/exit/``set`` do
nothing and allocate nothing — instrumented code pays a dict-free function
call, not a span record (the overhead bound is test-asserted).

Instrumented call sites reach the tracer through the module-global
:func:`get_tracer`, which defaults to the disabled :data:`NULL_TRACER`;
``build_pipeline`` installs a live tracer via :func:`set_tracer` when
``ObsConfig.enabled`` is set. Timestamps are ``perf_counter`` deltas
anchored to the wall clock at tracer construction, so traces exported by
co-located host processes (the simulated-fleet harness) line up on one
Perfetto timeline.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional


class _NullSpan:
    """The shared do-nothing span: what a disabled tracer hands out."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records itself into the tracer's ring on exit."""

    __slots__ = ("_tracer", "name", "cat", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.attrs = attrs
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer.clock()
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._record(
            self.name, self.cat, self._t0,
            self._tracer.clock() - self._t0, self.attrs)
        return False

    def set(self, **attrs) -> None:
        """Attach/overwrite attributes mid-span (``args`` in the export)."""
        self.attrs.update(attrs)


class Tracer:
    """Span recorder with a bounded ring buffer and Chrome-trace export.

    ``host`` becomes the trace's ``pid`` (one track per host); each span's
    category becomes its ``tid`` (one sub-track per subsystem). ``capacity``
    bounds memory: the ring keeps the newest ``capacity`` events and
    overwrites the oldest (``dropped`` counts the overwritten ones).
    """

    def __init__(self, *, enabled: bool = False, host: int = 0,
                 capacity: int = 65536, clock=time.perf_counter):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.enabled = enabled
        self.host = int(host)
        self.capacity = int(capacity)
        self.clock = clock
        # wall-clock anchor: exported timestamps are wall0 + (t - perf0),
        # so independently exported host traces share one absolute timeline
        self._wall0 = time.time()
        self._perf0 = clock()
        self._lock = threading.Lock()
        self._buf: List[Optional[tuple]] = [None] * self.capacity
        self._n = 0  # total events ever recorded

    # ---------------- recording ---------------- #
    def span(self, name: str, cat: str = "default", **attrs):
        """A context manager timing one nested span. Zero-cost when the
        tracer is disabled (returns the shared no-op span)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, attrs)

    def instant(self, name: str, cat: str = "default", **attrs) -> None:
        """A zero-duration marker event."""
        if not self.enabled:
            return
        self._record(name, cat, self.clock(), None, attrs)

    def _record(self, name: str, cat: str, t0: float,
                dur: Optional[float], attrs: Dict[str, Any]) -> None:
        with self._lock:
            self._buf[self._n % self.capacity] = (name, cat, t0, dur, attrs)
            self._n += 1

    # ---------------- inspection / export ---------------- #
    @property
    def num_events(self) -> int:
        return min(self._n, self.capacity)

    @property
    def dropped(self) -> int:
        return max(self._n - self.capacity, 0)

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._n = 0

    def _snapshot(self) -> List[tuple]:
        with self._lock:
            n, cap = self._n, self.capacity
            if n <= cap:
                return [e for e in self._buf[:n]]
            start = n % cap
            return self._buf[start:] + self._buf[:start]

    def _ts_us(self, t: float) -> float:
        return (self._wall0 + (t - self._perf0)) * 1e6

    def to_events(self) -> List[dict]:
        """The ring's events in Chrome trace-event form (oldest first).
        Complete spans are ``"ph": "X"`` with ``ts``/``dur`` in µs;
        instants are ``"ph": "i"``. ``pid`` is the host id, ``tid`` the
        subsystem category's stable index."""
        snap = self._snapshot()
        cats = sorted({e[1] for e in snap})
        tid = {c: i + 1 for i, c in enumerate(cats)}
        out = []
        for name, cat, t0, dur, attrs in snap:
            ev = {
                "name": name,
                "cat": cat,
                "ph": "X" if dur is not None else "i",
                "ts": self._ts_us(t0),
                "pid": self.host,
                "tid": tid[cat],
            }
            if dur is not None:
                ev["dur"] = dur * 1e6
            else:
                ev["s"] = "p"  # instant scope: process
            if attrs:
                ev["args"] = {k: _jsonable(v) for k, v in attrs.items()}
            out.append(ev)
        return out

    def metadata_events(self) -> List[dict]:
        """Perfetto track naming: process_name per host, thread_name per
        subsystem category."""
        cats = sorted({e[1] for e in self._snapshot()})
        meta = [{
            "name": "process_name", "ph": "M", "pid": self.host, "tid": 0,
            "args": {"name": f"host{self.host}"},
        }]
        for i, c in enumerate(cats):
            meta.append({
                "name": "thread_name", "ph": "M", "pid": self.host,
                "tid": i + 1, "args": {"name": c},
            })
        return meta

    def to_chrome_trace(self) -> dict:
        return {
            "traceEvents": self.metadata_events() + self.to_events(),
            "displayTimeUnit": "ms",
        }

    def export_chrome(self, path: str) -> str:
        """Write the ring as a Chrome-trace JSON file; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    try:
        return float(v)  # numpy / jax scalars
    except (TypeError, ValueError):
        return str(v)


# ---------------------------------------------------------------------- #
# module-global tracer: instrumented call sites are always wired, and cost
# nothing until build_pipeline (or a test) installs an enabled tracer.
# ---------------------------------------------------------------------- #
NULL_TRACER = Tracer(enabled=False, capacity=1)
_GLOBAL: Tracer = NULL_TRACER


def get_tracer() -> Tracer:
    return _GLOBAL


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install ``tracer`` as the process-global tracer (``None`` restores
    the disabled default); returns the previous one so callers can
    save/restore."""
    global _GLOBAL
    prev = _GLOBAL
    _GLOBAL = NULL_TRACER if tracer is None else tracer
    return prev
