"""Typed metrics: Counter / Gauge / Histogram behind a MetricsRegistry.

The registry absorbs the repo's existing string-keyed ``Dict[str, float]``
metrics (``record_dict`` turns each key into a gauge) and reproduces them
*bitwise* through :meth:`MetricsRegistry.as_flat_dict` — gauges store the
recorded value verbatim, no float coercion — so every current test and
benchmark key survives the migration unchanged.

Histograms are fixed-boundary: ``boundaries`` of length K define K+1
buckets (underflow, K-1 interior, overflow), and a recorded value lands in
the bucket found by ``bisect_right``. Quantiles interpolate linearly inside
the rank's bucket, with the tracked min/max tightening the open-ended
underflow/overflow buckets. Because a quantile is a pure function of
(boundaries, counts, min, max) — and all of those combine exactly under
:meth:`Histogram.merge` — merged per-host histograms report *identical*
quantiles to one histogram fed the concatenated samples (test-asserted,
including as a hypothesis property).
"""
from __future__ import annotations

import math
from bisect import bisect_right
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


class Counter:
    """Monotonic accumulator."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-value-wins. Stores whatever it is handed, verbatim — the
    bitwise back-compat contract of ``as_flat_dict`` depends on it."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = None

    def set(self, v) -> None:
        self.value = v


def exponential_boundaries(lo: float, hi: float, n: int) -> Tuple[float, ...]:
    """``n`` geometrically spaced boundaries spanning [lo, hi]."""
    if not (lo > 0 and hi > lo and n >= 2):
        raise ValueError(f"bad boundary spec lo={lo} hi={hi} n={n}")
    r = math.log(hi / lo) / (n - 1)
    return tuple(lo * math.exp(r * i) for i in range(n))


# default latency boundaries: 100µs .. 100s, ~15% resolution per bucket
LATENCY_BOUNDARIES = exponential_boundaries(1e-4, 100.0, 100)


class Histogram:
    """Fixed-boundary histogram with interpolated quantiles, exact under
    merge. Bucket ``i`` covers ``[boundaries[i-1], boundaries[i])``; bucket
    0 is underflow, bucket ``len(boundaries)`` overflow."""

    __slots__ = ("name", "boundaries", "counts", "_count", "_sum",
                 "_min", "_max")

    def __init__(self, name: str,
                 boundaries: Sequence[float] = LATENCY_BOUNDARIES):
        b = tuple(float(x) for x in boundaries)
        if len(b) < 1 or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError("boundaries must be strictly increasing")
        self.name = name
        self.boundaries = b
        self.counts = [0] * (len(b) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    # ---------------- recording / merging ---------------- #
    def record(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_right(self.boundaries, v)] += 1
        self._count += 1
        self._sum += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v

    def merge(self, other: "Histogram") -> "Histogram":
        if other.boundaries != self.boundaries:
            raise ValueError(
                f"histogram {self.name!r}: cannot merge differing boundaries")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self._count += other._count
        self._sum += other._sum
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)
        return self

    # ---------------- stats ---------------- #
    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def quantile(self, q: float) -> float:
        """Interpolated quantile: find the bucket holding rank
        ``q * (count - 1)``, interpolate linearly within it. Underflow and
        overflow buckets borrow the tracked min/max as their missing edge,
        and the result is clamped to [min, max]."""
        if self._count == 0:
            return 0.0
        r = q * (self._count - 1)
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c > r:
                lo = self._min if i == 0 else self.boundaries[i - 1]
                hi = (self._max if i == len(self.boundaries)
                      else self.boundaries[i])
                est = lo + (hi - lo) * (r - cum) / c
                return min(max(est, self._min), self._max)
            cum += c
        return self._max

    def percentiles(self, ps: Iterable[int] = (50, 90, 99)
                    ) -> Dict[str, float]:
        return {f"p{p}": self.quantile(p / 100.0) for p in ps}

    # ---------------- (de)serialization ---------------- #
    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "boundaries": list(self.boundaries),
            "counts": list(self.counts),
            "count": self._count,
            "sum": self._sum,
            "min": self._min if self._count else None,
            "max": self._max if self._count else None,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Histogram":
        h = cls(d["name"], d["boundaries"])
        h.counts = [int(c) for c in d["counts"]]
        h._count = int(d["count"])
        h._sum = float(d["sum"])
        h._min = math.inf if d["min"] is None else float(d["min"])
        h._max = -math.inf if d["max"] is None else float(d["max"])
        return h


class MetricsRegistry:
    """Named instruments plus the flat-dict bridge the rest of the repo
    speaks. ``record_dict`` absorbs a per-iteration metrics dict (each key
    becomes a gauge holding the value verbatim); ``as_flat_dict`` emits
    gauges verbatim, counters as floats, and each histogram expanded to
    ``{name}/count|mean|p50|p90|p99``."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    # ---------------- instrument accessors (get-or-create) ------------- #
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  boundaries: Optional[Sequence[float]] = None) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(
                name, boundaries if boundaries is not None
                else LATENCY_BOUNDARIES)
        return h

    @property
    def histograms(self) -> Dict[str, Histogram]:
        return dict(self._hists)

    # ---------------- flat-dict bridge ---------------- #
    def record_dict(self, metrics: Dict[str, float]) -> None:
        for k, v in metrics.items():
            self.gauge(k).set(v)

    def as_flat_dict(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for k, g in self._gauges.items():
            out[k] = g.value
        for k, c in self._counters.items():
            out[k] = c.value
        for k, h in self._hists.items():
            out[f"{k}/count"] = float(h.count)
            out[f"{k}/mean"] = h.mean
            for pk, pv in h.percentiles((50, 90, 99)).items():
                out[f"{k}/{pk}"] = pv
        return out

    # ---------------- cross-host (de)serialization ---------------- #
    def to_dict(self) -> dict:
        return {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: g.value for k, g in self._gauges.items()},
            "histograms": {k: h.to_dict() for k, h in self._hists.items()},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MetricsRegistry":
        r = cls()
        for k, v in d.get("counters", {}).items():
            r.counter(k).value = v
        for k, v in d.get("gauges", {}).items():
            r.gauge(k).set(v)
        for k, hd in d.get("histograms", {}).items():
            r._hists[k] = Histogram.from_dict(hd)
        return r

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold another host's registry in: counters sum, histograms merge
        exactly, gauges last-write-wins."""
        for k, c in other._counters.items():
            self.counter(k).value += c.value
        for k, g in other._gauges.items():
            self.gauge(k).set(g.value)
        for k, h in other._hists.items():
            mine = self._hists.get(k)
            if mine is None:
                self._hists[k] = Histogram.from_dict(h.to_dict())
            else:
                mine.merge(h)
        return self
