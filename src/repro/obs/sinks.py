"""Metrics sinks: where per-iteration records go.

Three interchangeable sinks share one ``write(record)`` method:
:class:`JSONLSink` appends one JSON object per line to a file (what
``--obs-metrics`` and ``scripts/ci.sh`` use), :class:`StdoutSink` prints —
including a byte-compatible reproduction of the legacy
``[train] it=... {...}`` line — and :class:`MemorySink` accumulates records
in a list for tests.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional


def iteration_record(iteration: int, metrics: Dict[str, Any],
                     wall_s: float) -> dict:
    return {
        "kind": "iteration",
        "iteration": int(iteration),
        "wall_s": float(wall_s),
        "time": time.time(),
        "metrics": {k: _num(v) for k, v in metrics.items()},
    }


def _num(v):
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class JSONLSink:
    """Append-mode JSONL writer; the file opens lazily on first write and
    every record is flushed (crash-safe up to the last line)."""

    def __init__(self, path: str):
        self.path = path
        self._f = None

    def write(self, record: dict) -> None:
        if self._f is None:
            self._f = open(self.path, "a")
        self._f.write(json.dumps(record, sort_keys=True) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "JSONLSink":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class StdoutSink:
    """Line printer. ``emit_iteration`` reproduces launch/train.py's
    historical progress line byte-for-byte (same key filter, rounding, and
    json.dumps separators) — scripts grepping ``[train] it=`` keep working."""

    def write(self, record: dict) -> None:
        print(json.dumps(record, sort_keys=True), flush=True)

    def emit_iteration(self, iteration: int, metrics: Dict[str, Any],
                       wall_s: float) -> None:
        keep = {k: round(v, 4) for k, v in metrics.items()
                if not k.startswith("time/")}
        print(f"[train] it={iteration} {wall_s:.2f}s {json.dumps(keep)}",
              flush=True)

    def close(self) -> None:
        pass


class MemorySink:
    """Record list for tests."""

    def __init__(self):
        self.records: List[dict] = []

    def write(self, record: dict) -> None:
        self.records.append(record)

    def close(self) -> None:
        pass
