"""Fleet-wide metrics aggregation: snapshots → straggler report.

Each host publishes a per-iteration metrics snapshot through the
FleetContext file plane (``FleetContext.publish_metrics`` →
``<coord>/obs/host{h}/it{NNNNNN}.json``). This module reads them all back
and answers the question the DistFlow scaling pitch depends on: *which host
is slow, on which stage, and by how much* — per-host step-time skew,
slowest-node attribution, and exact cross-host histogram merge.

``launch/obs_report.py`` renders :func:`straggler_report` as a text
timeline plus table; tests assert the report's per-host step times
sum-match the hosts' own ``time/*`` metrics.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from .metrics import Histogram, exponential_boundaries

# step times span ~1ms..1000s in simulated fleets; ~7% bucket resolution
STEP_TIME_BOUNDARIES = exponential_boundaries(1e-3, 1e3, 200)


def collect_snapshots(root: str) -> Dict[int, Dict[int, dict]]:
    """Read every ``<root>/obs/host*/it*.json`` snapshot into
    ``{host: {iteration: payload}}``."""
    out: Dict[int, Dict[int, dict]] = {}
    for path in sorted(glob.glob(os.path.join(root, "obs", "host*",
                                              "it*.json"))):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue  # torn write from a dying host: skip, don't crash
        h = int(payload["host"])
        out.setdefault(h, {})[int(payload["iteration"])] = payload
    return out


def step_time(metrics: Dict[str, float]) -> float:
    """A host's step time for one iteration: the sum of its per-node
    ``time/*`` stage timings (deterministic key order)."""
    return sum(float(metrics[k]) for k in sorted(metrics)
               if k.startswith("time/"))


def straggler_report(snapshots: Dict[int, Dict[int, dict]]) -> dict:
    """Merge per-host snapshots into a straggler report.

    Returns a dict with per-host step-time stats and slowest-node
    attribution, per-iteration cross-host skew (max/mean), and a fleet-wide
    step-time histogram built by exact merge of per-host histograms.
    """
    hosts = sorted(snapshots)
    per_host: Dict[int, dict] = {}
    host_hists: Dict[int, Histogram] = {}
    for h in hosts:
        its = sorted(snapshots[h])
        steps = {it: step_time(snapshots[h][it]["metrics"]) for it in its}
        # mean time per node across iterations → slowest-stage attribution
        node_tot: Dict[str, float] = {}
        for it in its:
            for k, v in snapshots[h][it]["metrics"].items():
                if k.startswith("time/"):
                    node_tot[k[len("time/"):]] = (
                        node_tot.get(k[len("time/"):], 0.0) + float(v))
        hist = Histogram(f"fleet/step_s/host{h}", STEP_TIME_BOUNDARIES)
        for v in steps.values():
            hist.record(v)
        host_hists[h] = hist
        n = max(len(its), 1)
        per_host[h] = {
            "iterations": its,
            "step_times": steps,
            "total_s": sum(steps.values()),
            "mean_s": sum(steps.values()) / n,
            "slowest_node": (max(node_tot, key=node_tot.get)
                             if node_tot else None),
            "node_mean_s": {k: v / n for k, v in sorted(node_tot.items())},
        }

    # per-iteration skew: how much slower the worst host is than the mean
    all_its = sorted({it for h in hosts for it in per_host[h]["step_times"]})
    skew: Dict[int, dict] = {}
    for it in all_its:
        vals = {h: per_host[h]["step_times"][it] for h in hosts
                if it in per_host[h]["step_times"]}
        mean = sum(vals.values()) / len(vals)
        worst = max(vals, key=vals.get)
        skew[it] = {
            "per_host": vals,
            "mean_s": mean,
            "max_s": vals[worst],
            "slowest_host": worst,
            "skew": vals[worst] / mean if mean > 0 else 1.0,
        }

    fleet_hist = Histogram("fleet/step_s", STEP_TIME_BOUNDARIES)
    for h in hosts:
        fleet_hist.merge(host_hists[h])
    slowest_host = (max(hosts, key=lambda h: per_host[h]["total_s"])
                    if hosts else None)
    return {
        "hosts": hosts,
        "per_host": per_host,
        "per_iteration": skew,
        "slowest_host": slowest_host,
        "max_skew": max((s["skew"] for s in skew.values()), default=1.0),
        "step_hist": fleet_hist.to_dict(),
        "step_percentiles": fleet_hist.percentiles((50, 99)),
    }


def render_report(report: dict, width: int = 40) -> str:
    """The straggler report as a text timeline + table."""
    lines: List[str] = []
    hosts = report["hosts"]
    per_it = report["per_iteration"]
    if not hosts:
        return "no snapshots found\n"
    lines.append("== per-iteration step-time timeline "
                 "(one bar per host, * = slowest) ==")
    vmax = max((s["max_s"] for s in per_it.values()), default=0.0) or 1.0
    for it in sorted(per_it):
        s = per_it[it]
        lines.append(f"it {it:>4}  skew x{s['skew']:.2f}")
        for h in hosts:
            if h not in s["per_host"]:
                continue
            v = s["per_host"][h]
            bar = "#" * max(int(round(v / vmax * width)), 1)
            mark = " *" if h == s["slowest_host"] else ""
            lines.append(f"  host{h} |{bar:<{width}}| {v:8.3f}s{mark}")
    lines.append("")
    lines.append("== per-host summary ==")
    lines.append("| host | iters | total s | mean s | slowest node |")
    lines.append("|------|-------|---------|--------|--------------|")
    for h in hosts:
        ph = report["per_host"][h]
        star = " *" if h == report["slowest_host"] else ""
        lines.append(
            f"| host{h}{star} | {len(ph['iterations'])} "
            f"| {ph['total_s']:.3f} | {ph['mean_s']:.3f} "
            f"| {ph['slowest_node']} |")
    p = report["step_percentiles"]
    lines.append("")
    lines.append(f"fleet step-time p50 {p['p50']:.3f}s  p99 {p['p99']:.3f}s"
                 f"  (merged across {len(hosts)} hosts)"
                 f"  max skew x{report['max_skew']:.2f}")
    return "\n".join(lines) + "\n"


def merge_traces(paths_or_dicts: List, out_path: Optional[str] = None
                 ) -> dict:
    """Concatenate per-host Chrome traces into one multi-track trace.
    Host traces carry distinct ``pid``s, so concatenation *is* the merge."""
    events: List[dict] = []
    for item in paths_or_dicts:
        if isinstance(item, str):
            with open(item) as f:
                item = json.load(f)
        events.extend(item.get("traceEvents", []))
    merged = {"traceEvents": events, "displayTimeUnit": "ms"}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(merged, f)
    return merged
