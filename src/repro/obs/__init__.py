"""Unified telemetry: span tracing, typed metrics, sinks, fleet aggregation.

See docs/observability.md for the span model, the Chrome-trace export
walkthrough, and the metrics-key glossary.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    LATENCY_BOUNDARIES,
    MetricsRegistry,
    exponential_boundaries,
)
from .sinks import (  # noqa: F401
    JSONLSink,
    MemorySink,
    StdoutSink,
    iteration_record,
)
from .trace import (  # noqa: F401
    NULL_TRACER,
    Tracer,
    get_tracer,
    set_tracer,
)


@dataclasses.dataclass
class ObsState:
    """The per-pipeline observability runtime build_pipeline hangs on
    ``ctx.obs`` when ObsConfig is enabled: the config, the (installed)
    tracer, and the registry absorbing each iteration's metrics."""

    cfg: Any
    tracer: Tracer
    registry: MetricsRegistry


__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "JSONLSink",
    "LATENCY_BOUNDARIES",
    "MemorySink",
    "MetricsRegistry",
    "NULL_TRACER",
    "ObsState",
    "StdoutSink",
    "Tracer",
    "exponential_boundaries",
    "get_tracer",
    "iteration_record",
    "set_tracer",
]
