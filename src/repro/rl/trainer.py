"""Actor / critic update steps (the DAG's MODEL_TRAIN nodes).

Each step is a self-contained jit-able function: loss -> grad -> global-norm
clip -> AdamW. The DistFlow registry binds these to (ACTOR, MODEL_TRAIN) and
(CRITIC, MODEL_TRAIN) nodes; the launcher jits them with FSDP/TP shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.api import Model
from repro.optim import adamw
from repro.rl import critic as critic_mod
from repro.rl import loss as losses


@dataclasses.dataclass(frozen=True)
class RLConfig:
    # registered algorithm name (repro.rl.algorithms: grpo | ppo | rloo |
    # reinforce_pp | anything added via register_algorithm)
    algorithm: str = "grpo"
    lr: float = 1e-6
    critic_lr: float = 1e-5
    clip_eps: float = 0.2
    kl_coef: float = 0.001
    entropy_coef: float = 0.0
    max_grad_norm: float = 1.0
    gamma: float = 1.0
    gae_lambda: float = 0.95
    group_size: int = 8  # GRPO rollouts per prompt
    temperature: float = 1.0
    max_new_tokens: int = 16
    weight_decay: float = 0.0
    # truncation bound for the decoupled importance-ratio correction applied
    # to stale batches when the algorithm opts in (AlgorithmSpec.is_correction
    # == "truncated"; see docs/async_pipeline.md)
    is_rho_max: float = 2.0


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def init_state(params) -> TrainState:
    return TrainState(params=params, opt=adamw.init(params))


def _resolve_algorithm(rl: RLConfig, algorithm=None):
    if algorithm is not None:
        return algorithm
    from repro.rl import algorithms  # deferred: algorithms imports rl.loss

    return algorithms.get_algorithm(rl.algorithm)


def apply_is_correction(
    rl: RLConfig, spec, batch: Dict[str, jax.Array]
) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Decoupled truncated-IS correction for stale (off-policy) batches.

    When the async scheduler hands the trainer a batch generated under an
    older weight version AND the spec opted in (``is_correction ==
    "truncated"``), the batch carries ``behavior_logprob`` (gen-time policy)
    next to ``old_logprob`` (recomputed under the train-time proximal
    policy). The correction scales the advantages by the truncated ratio
    rho = min(exp(old - behaviour), rl.is_rho_max) — since rho > 0 this is
    exactly weighting the clipped surrogate, while the PPO clip keeps
    policing the proximal ratio. On-policy batches (no ``behavior_logprob``)
    pass through untouched, so the synchronous path is unchanged."""
    if spec.is_correction != "truncated" or "behavior_logprob" not in batch:
        return batch, {}
    w = losses.truncated_is_weights(
        batch["old_logprob"], batch["behavior_logprob"],
        batch["response_mask"], rho_max=rl.is_rho_max,
    )
    rho = w.pop("rho")
    batch = dict(batch, advantages=batch["advantages"] * rho)
    return batch, w


def actor_loss_fn(
    model: Model, rl: RLConfig, params, batch: Dict[str, jax.Array],
    *, algorithm=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    spec = _resolve_algorithm(rl, algorithm)
    lp, ent = model.logprobs(params, batch["tokens"], remat=True)
    mask = batch["response_mask"]
    batch, is_metrics = apply_is_correction(rl, spec, batch)
    out = spec.actor_loss(rl, lp, batch)
    out.update(is_metrics)
    loss = out.pop("loss")
    m = mask.astype(jnp.float32)
    out["entropy"] = jnp.sum(ent * m) / jnp.maximum(jnp.sum(m), 1.0)
    if rl.entropy_coef:
        loss = loss - rl.entropy_coef * out["entropy"]
    return loss, out


def make_actor_step(model: Model, rl: RLConfig, *, algorithm=None) -> Callable:
    spec = _resolve_algorithm(rl, algorithm)

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: actor_loss_fn(model, rl, p, batch, algorithm=spec),
            has_aux=True,
        )(state.params)
        grads, gnorm = adamw.clip_by_global_norm(grads, rl.max_grad_norm)
        params, opt = adamw.update(
            grads, state.opt, state.params, lr=rl.lr, weight_decay=rl.weight_decay
        )
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return TrainState(params, opt), metrics

    return step


def make_actor_grad_fn(model: Model, rl: RLConfig, *, algorithm=None) -> Callable:
    """The loss+grad half of :func:`make_actor_step`: ``(params, batch) ->
    (grads, metrics)``. Composed with :func:`make_actor_apply_fn` around a
    gradient exchange (``repro.distributed.fleet``), the split reproduces the
    fused step bitwise — grads leave the device, cross the DP wire, and come
    back before clip+AdamW, exactly where a multi-host psum sits."""
    spec = _resolve_algorithm(rl, algorithm)

    def grad_fn(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: actor_loss_fn(model, rl, p, batch, algorithm=spec),
            has_aux=True,
        )(params)
        return grads, dict(metrics, loss=loss)

    return grad_fn


def make_actor_apply_fn(rl: RLConfig) -> Callable:
    """The clip+update half of :func:`make_actor_step`: ``(state, grads) ->
    (state, metrics)``."""

    def apply_fn(state: TrainState, grads):
        grads, gnorm = adamw.clip_by_global_norm(grads, rl.max_grad_norm)
        params, opt = adamw.update(
            grads, state.opt, state.params, lr=rl.lr, weight_decay=rl.weight_decay
        )
        return TrainState(params, opt), {"grad_norm": gnorm}

    return apply_fn


def make_critic_step(cfg: ModelConfig, rl: RLConfig) -> Callable:
    def step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        def loss_fn(p):
            v = critic_mod.values_fn(cfg, p, batch["tokens"], remat=True)
            out = losses.value_loss(
                v,
                batch["old_values"],
                batch["returns"],
                batch["response_mask"],
                clip_eps=rl.clip_eps,
            )
            return out.pop("loss"), out

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        grads, gnorm = adamw.clip_by_global_norm(grads, rl.max_grad_norm)
        params, opt = adamw.update(
            grads, state.opt, state.params, lr=rl.critic_lr
        )
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return TrainState(params, opt), metrics

    return step


def make_actor_step_accumulated(model: Model, rl: RLConfig, *,
                                num_microbatches: int,
                                algorithm=None) -> Callable:
    """Gradient-accumulated actor update: the global batch is split into
    microbatches scanned sequentially (grads averaged), bounding activation
    memory at 1/num_microbatches while keeping the identical update — the
    standard large-global-batch trick for the paper's 1024-per-node batches."""
    spec = _resolve_algorithm(rl, algorithm)

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        B = batch["tokens"].shape[0]
        assert B % num_microbatches == 0, (B, num_microbatches)
        mb = B // num_microbatches

        def slice_mb(i):
            return jax.tree.map(
                lambda t: jax.lax.dynamic_slice_in_dim(t, i * mb, mb, 0), batch
            )

        def body(carry, i):
            grads_acc, loss_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: actor_loss_fn(model, rl, p, slice_mb(i),
                                        algorithm=spec),
                has_aux=True,
            )(state.params)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / num_microbatches,
                grads_acc, grads)
            return (grads_acc, loss_acc + loss / num_microbatches), metrics

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        (grads, loss), metrics = jax.lax.scan(
            body, (zeros, jnp.zeros(())), jnp.arange(num_microbatches))
        metrics = jax.tree.map(lambda m: m[-1], metrics)  # last microbatch
        grads, gnorm = adamw.clip_by_global_norm(grads, rl.max_grad_norm)
        params, opt = adamw.update(
            grads, state.opt, state.params, lr=rl.lr,
            weight_decay=rl.weight_decay)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return TrainState(params, opt), metrics

    return step


def make_lm_train_step(model: Model, *, lr: float = 3e-4, max_grad_norm: float = 1.0,
                       unroll: bool = False):
    """Plain LM CE train step — the dry-run's ``train_step`` workload and the
    supervised arm of the framework."""

    def step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, unroll=unroll), has_aux=True
        )(state.params)
        grads, gnorm = adamw.clip_by_global_norm(grads, max_grad_norm)
        params, opt = adamw.update(grads, state.opt, state.params, lr=lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return TrainState(params, opt), metrics

    return step
