"""Continuous-batching rollout engine: slot-refill generation for the
GENERATE stage.

The lockstep path (:func:`repro.rl.rollout.generate`) pads every prompt to a
common length and scans all ``max_new`` decode steps even after every
sequence has emitted EOS — so real token throughput collapses as
response-length variance grows, exactly the failure mode AsyncFlow / LlamaRL
attribute their largest wins to fixing with in-flight batching. This module
is that fix on the DistFlow GENERATE stage:

  * a fixed pool of ``num_slots`` decode slots shares ONE persistent KV-cache
    arena (``model.init_caches(num_slots, smax)``); slot *i* is batch row *i*
    of every cache leaf, and each slot carries its own ``cache_len`` (the
    decode kernels already take per-sequence valid lengths);
  * when a slot's sequence hits EOS (or its token budget) the slot is freed
    and immediately refilled with the next prompt from the
    :class:`PromptQueue` — a fresh prefill is scattered over the slot's cache
    rows (``lm.scatter_cache_rows``, the slot-reset path) while the other
    slots' in-flight state is untouched;
  * refills are length-bucketed (prompts grouped by true length rounded up
    to ``prefill_bucket``) so a refill batch prefills at its bucket length
    instead of the global padded max, and optionally chunked
    (``lm.prefill_chunk``) so one long prefill is split into bounded pieces;
  * the decode loop is a ``lax.while_loop`` that early-exits on ``all(done)``
    once the prompt queue drains — the engine never pays lockstep's
    "scan to max_new regardless" tax.

Determinism / equivalence contract: under a *fixed slot schedule* — one
length bucket, ``num_slots >= batch`` (every prompt prefilled at once, no
mid-stream refill) — the engine consumes the exact key schedule of lockstep
``generate`` (``k0`` for the prefill sample, ``split(k2, max_new-1)`` for
decode steps) and computes the same prefill/decode math on the same shapes,
so it is token-for-token identical to lockstep (asserted by
``tests/test_rollout_engine.py``). Decode steps past ``max_new - 1`` (which
only exist once refill has happened) derive keys by ``fold_in(k2, t)``.

Metrics (``engine.last_stats``, surfaced by the GENERATE stage as
``rollout/*``): tokens/sec, padding-waste %, slot occupancy, decode steps,
refill counts. ``docs/rollout_engine.md`` has the slot lifecycle diagram and
the metrics glossary.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.rl.rollout import RolloutResult, sample_token


def _true_lengths(prompts: np.ndarray, pad_id: int) -> np.ndarray:
    """Per-row count of tokens up to and including the last non-pad token
    (right-padded prompts; a fully-pad row counts 1 so it still prefills)."""
    nonpad = prompts != pad_id
    rev = nonpad[:, ::-1]
    last = prompts.shape[1] - np.argmax(rev, axis=1)  # index after last non-pad
    return np.where(nonpad.any(axis=1), last, 1).astype(np.int64)


class PromptQueue:
    """Length-bucketed FIFO over one iteration's prompts.

    Each prompt's true (non-pad) length is rounded up to a multiple of
    ``bucket`` (0 = a single bucket at the batch's padded length — the
    lockstep-equivalent schedule); refills pop from one bucket at a time so
    every prefill batch shares a padded length. Within a bucket, dataset
    order is preserved.
    """

    def __init__(self, prompts: np.ndarray, *, pad_id: int, bucket: int = 0,
                 order=None):
        self.prompts = prompts
        B, Lp = prompts.shape
        self.true_len = _true_lengths(prompts, pad_id)
        if bucket <= 0:
            blens = np.full(B, Lp, np.int64)
        else:
            blens = np.minimum(-(-self.true_len // bucket) * bucket, Lp)
        self.bucket_len = blens
        self._buckets: Dict[int, deque] = {}
        for i in (range(B) if order is None else order):
            self._buckets.setdefault(int(blens[i]), deque()).append(i)

    def __len__(self) -> int:
        return sum(len(q) for q in self._buckets.values())

    def pop(self, n: int) -> Tuple[int, List[int]]:
        """Pop up to ``n`` prompt indices from the fullest bucket (ties break
        toward the shorter bucket length). Returns (bucket_len, indices)."""
        lb = max(self._buckets, key=lambda b: (len(self._buckets[b]), -b))
        q = self._buckets[lb]
        take = [q.popleft() for _ in range(min(n, len(q)))]
        if not q:
            del self._buckets[lb]
        return lb, take


class ContinuousRolloutEngine:
    """Slot-based continuous-batching generation engine.

    Drop-in for the jitted lockstep engine at the GENERATE stage: callable as
    ``engine(params, prompts, key) -> RolloutResult`` with identical output
    contract (tokens / response_mask / old_logprob / lengths in dataset
    order). Host code orchestrates slot bookkeeping; the two hot paths — the
    per-bucket refill prefill and the early-exiting decode burst — are jitted
    once per shape and reused across iterations.
    """

    def __init__(
        self,
        model: Model,
        *,
        max_new: int,
        temperature: float = 1.0,
        eos_id: Optional[int] = None,
        pad_id: int = 0,
        num_slots: int = 0,
        prefill_chunk: int = 0,
        prefill_bucket: int = 0,
        refill_threshold: int = 1,
    ):
        if model.is_encdec or model.cfg.num_prefix_embeds:
            raise ValueError(
                "the continuous engine supports text decoder-only archs; "
                "use engine='lockstep' for enc-dec / prefix-modality models"
            )
        self.model = model
        self.max_new = max_new
        self.temperature = temperature
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.num_slots = num_slots
        self.prefill_bucket = prefill_bucket
        # minimum count of newly-freed slots before a burst hands control
        # back for refill (while prompts pend). 1 = refill eagerly (maximum
        # occupancy); higher values trade a little slot idleness for fewer
        # host round-trips — useful when dispatch overhead is comparable to
        # a decode step, as on CPU hosts
        self.refill_threshold = max(1, refill_threshold)
        # chunked prefill is attention-only (SSM state doesn't carry between
        # chunks), needs an unwrapped cache (no SWA ring), and excludes
        # int8 caches: a chunk would attend the quantize->dequantized K/V
        # of its own prefix, diverging from whole-prompt prefill by far
        # more than float reassociation (~3e-2 in behaviour logprobs)
        kinds = model.cfg.layer_kinds()
        self._can_chunk = (
            prefill_chunk > 0
            and all(k[0] == "attn" for k in kinds)
            and model.cfg.sliding_window is None
            and not model.cfg.kv_quant
        )
        self.prefill_chunk = prefill_chunk if self._can_chunk else 0
        self.last_stats: Dict[str, float] = {}
        self._refill_jit: Dict[Tuple[int, int, int], callable] = {}
        self._burst_jit: Dict[Tuple[int, int], callable] = {}

    # ------------------------------------------------------------------ #
    # jitted halves
    # ------------------------------------------------------------------ #
    def _make_refill(self, R: int, Lb: int, smax: int):
        """Refill ``R`` lanes with a (padded) prompt batch of width ``Lb``:
        prefill, scatter the fresh cache rows over the arena at ``slots``
        (out-of-range ids = padding lanes, dropped), sample each lane's first
        response token, and reset the per-slot output rows. ``R`` is the
        refill batch width — the caller rounds the actual refill count up to
        a power of two so late-stream single-slot refills don't pay a
        full-pool prefill (and the compile count stays log-bounded)."""
        model, temp = self.model, self.temperature
        eos, pad, max_new = self.eos_id, self.pad_id, self.max_new
        chunk = self.prefill_chunk

        def refill(params, caches, prompts, slots, lane_budget, key,
                   cur_tok, cache_len, resp_len, done, budget,
                   out_tok, out_lp):
            if chunk > 0:
                rows = model.init_caches(R, smax)
                logits = None
                for off in range(0, Lb, chunk):
                    logits, rows = model.prefill_chunk(
                        params, prompts[:, off:off + chunk], rows, offset=off
                    )
            else:
                logits, rows, _ = model.prefill(params, prompts, smax=smax)
            caches = model.scatter_cache_rows(caches, rows, slots)
            tok0 = sample_token(logits, key, temp)
            lane = jnp.arange(R)
            lp0 = jax.nn.log_softmax(logits, axis=-1)[lane, tok0]
            done0 = (tok0 == eos) if eos is not None else jnp.zeros((R,), bool)
            row_tok = jnp.full((R, max_new), pad, out_tok.dtype).at[:, 0].set(tok0)
            row_lp = jnp.zeros((R, max_new), out_lp.dtype).at[:, 0].set(lp0)
            cur_tok = cur_tok.at[slots].set(tok0, mode="drop")
            cache_len = cache_len.at[slots].set(Lb, mode="drop")
            resp_len = resp_len.at[slots].set(1, mode="drop")
            done = done.at[slots].set(
                done0 | (lane_budget <= 1), mode="drop")
            budget = budget.at[slots].set(lane_budget, mode="drop")
            out_tok = out_tok.at[slots].set(row_tok, mode="drop")
            out_lp = out_lp.at[slots].set(row_lp, mode="drop")
            return (caches, cur_tok, cache_len, resp_len, done, budget,
                    out_tok, out_lp)

        return jax.jit(refill)

    def _make_burst(self, S: int):
        """The decode loop: a ``lax.while_loop`` stepping every slot, exiting
        as soon as (a) every slot is done — the early-exit on a drained
        queue — or (b) any slot *newly* finishes while prompts are pending,
        handing control back to the host for an immediate refill."""
        model, temp = self.model, self.temperature
        eos, pad, max_new = self.eos_id, self.pad_id, self.max_new
        T = max_new - 1  # lockstep's decode-step count (key schedule length)
        threshold = self.refill_threshold

        def burst(params, caches, cur_tok, cache_len, resp_len, done, budget,
                  out_tok, out_lp, t, occ, step_keys, k2, has_pending):
            n_done_entry = jnp.sum(done)
            lane = jnp.arange(S)

            def cond(st):
                done = st[4]
                any_active = ~jnp.all(done)
                below_threshold = (jnp.sum(done) - n_done_entry) < threshold
                return any_active & (below_threshold | ~has_pending)

            def body(st):
                (caches, cur_tok, cache_len, resp_len, done, budget,
                 out_tok, out_lp, t, occ) = st
                occ = occ + jnp.sum(~done)
                logits, caches, cache_len = model.decode_step(
                    params, cur_tok, caches, cache_len
                )
                # lockstep's exact key schedule for the first T steps
                # (jax.random.split is NOT prefix-stable, so the array is
                # sized exactly T); steps beyond T — which only exist after
                # a refill — fold the step index into k2
                kt = jax.lax.select(
                    t < T,
                    step_keys[jnp.minimum(t, T - 1)],
                    jax.random.fold_in(k2, t),
                )
                nxt = sample_token(logits, kt, temp)
                lp = jax.nn.log_softmax(logits, axis=-1)[lane, nxt]
                nxt = jnp.where(done, pad, nxt)
                lp = jnp.where(done, 0.0, lp)
                wr = (~done) & (resp_len < max_new)
                idx = jnp.where(wr, resp_len, max_new)  # OOB -> dropped
                out_tok = out_tok.at[lane, idx].set(nxt, mode="drop")
                out_lp = out_lp.at[lane, idx].set(lp, mode="drop")
                resp_len = resp_len + wr
                new_done = done
                if eos is not None:
                    new_done = new_done | (nxt == eos)
                new_done = new_done | (resp_len >= budget)
                return (caches, nxt, cache_len,
                        resp_len, new_done, budget, out_tok, out_lp,
                        t + 1, occ)

            st = (caches, cur_tok, cache_len, resp_len, done, budget,
                  out_tok, out_lp, t, occ)
            return jax.lax.while_loop(cond, body, st)

        return jax.jit(burst)

    # ------------------------------------------------------------------ #
    def __call__(self, params, prompts, key,
                 budgets: Optional[np.ndarray] = None) -> RolloutResult:
        """``budgets`` (B,) caps each sequence's response length at
        ``min(budgets[b], max_new)`` — same semantics as lockstep
        ``generate(budgets=...)``, but here a capped sequence *frees its
        slot* instead of padding out the scan."""
        t_start = time.perf_counter()
        prompts_np = np.asarray(jax.device_get(prompts), np.int32)
        B, Lp = prompts_np.shape
        max_new = self.max_new
        if budgets is None:
            budgets_np = np.full(B, max_new, np.int32)
        else:
            budgets_np = np.clip(
                np.asarray(jax.device_get(budgets), np.int32), 1, max_new)
        S = self.num_slots if self.num_slots > 0 else B
        S = max(1, min(S, B))
        smax = Lp + max_new
        # known budgets + a real queue (S < B) -> longest-first (LPT) slot
        # packing: long sequences start first instead of draining alone at
        # the tail (the same policy as the coordinator's length-aware
        # balancing). With S == B there is no queue, and dataset order is
        # kept — that's the lockstep-equivalent fixed schedule.
        order = (np.argsort(-budgets_np, kind="stable")
                 if budgets is not None and S < B else None)
        queue = PromptQueue(prompts_np, pad_id=self.pad_id,
                            bucket=self.prefill_bucket, order=order)
        prefill_true_tokens = int(queue.true_len.sum())

        k0, k2 = jax.random.split(key)
        T = max_new - 1
        step_keys = (jax.random.split(k2, T) if T > 0
                     else jnp.zeros((1, 2), jnp.uint32))

        # slot state (device) -------------------------------------------- #
        caches = self.model.init_caches(S, smax)
        cur_tok = jnp.zeros((S,), jnp.int32)
        cache_len = jnp.zeros((S,), jnp.int32)
        resp_len = jnp.zeros((S,), jnp.int32)
        done = jnp.ones((S,), bool)  # every slot starts free/idle
        budget = jnp.full((S,), max_new, jnp.int32)
        out_tok = jnp.full((S, max_new), self.pad_id, jnp.int32)
        out_lp = jnp.zeros((S, max_new), jnp.float32)
        t = jnp.zeros((), jnp.int32)
        occ = jnp.zeros((), jnp.int32)

        # host bookkeeping ------------------------------------------------ #
        slot_seq = np.full(S, -1, np.int64)  # dataset row held by each slot
        res_tok = np.full((B, max_new), self.pad_id, np.int32)
        res_lp = np.zeros((B, max_new), np.float32)
        res_len = np.zeros((B,), np.int32)
        completed = 0
        refills = 0
        prefill_lane_tokens = 0
        bursts = 0

        burst = self._burst_jit.get((S, smax))
        if burst is None:
            burst = self._burst_jit[(S, smax)] = self._make_burst(S)

        while completed < B:
            # one bundled host sync per visit: flush state for every slot
            done_h, resp_len_h, out_tok_h, out_lp_h = jax.device_get(
                (done, resp_len, out_tok, out_lp))
            # flush finished slots into the per-sequence results
            for s in range(S):
                if done_h[s] and slot_seq[s] >= 0:
                    row = slot_seq[s]
                    res_tok[row] = out_tok_h[s]
                    res_lp[row] = out_lp_h[s]
                    res_len[row] = resp_len_h[s]
                    slot_seq[s] = -1
                    completed += 1
            if completed >= B:
                break
            # refill every free slot, one jitted prefill per length bucket
            free = [s for s in range(S) if slot_seq[s] < 0]
            while free and len(queue):
                lb, idxs = queue.pop(len(free))
                lanes, free = free[: len(idxs)], free[len(idxs):]
                # pad the refill batch to the next power of two (capped at
                # the pool size), not the full pool: a late-stream
                # single-slot refill prefills 1 lane, not num_slots — and a
                # full-pool fill keeps the exact pool shape, which is what
                # the lockstep-equivalence schedule runs
                R = 1
                while R < len(idxs):
                    R *= 2
                R = min(R, S)
                batch = np.zeros((R, lb), np.int32)
                batch[: len(idxs)] = prompts_np[idxs][:, :lb]
                slots_arr = jnp.asarray(
                    np.concatenate([lanes, np.full(R - len(lanes), S)])
                    .astype(np.int32)
                )
                lane_budget = np.full(R, max_new, np.int32)
                lane_budget[: len(idxs)] = budgets_np[idxs]
                rk = k0 if refills == 0 else jax.random.fold_in(k0, refills)
                rf = self._refill_jit.get((R, lb, smax))
                if rf is None:
                    rf = self._refill_jit[(R, lb, smax)] = self._make_refill(
                        R, lb, smax)
                (caches, cur_tok, cache_len, resp_len, done, budget,
                 out_tok, out_lp) = rf(
                    params, caches, jnp.asarray(batch), slots_arr,
                    jnp.asarray(lane_budget), rk,
                    cur_tok, cache_len, resp_len, done, budget,
                    out_tok, out_lp,
                )
                for lane, seq in zip(lanes, idxs):
                    slot_seq[lane] = seq
                refills += 1
                # count the lanes the prefill actually executed (incl. the
                # pow2 padding lanes) so prefill_waste reflects real compute
                prefill_lane_tokens += R * lb
            if not any(slot_seq[s] >= 0 for s in range(S)):
                break  # queue drained and nothing in flight
            # a lane refilled immediately-done (EOS at its first token /
            # budget 1) is counted in the burst's n_done_entry, so the loop
            # below won't mistake it for a fresh completion; it flushes on
            # the next visit
            has_pending = jnp.asarray(len(queue) > 0)
            (caches, cur_tok, cache_len, resp_len, done, budget,
             out_tok, out_lp, t, occ) = burst(
                params, caches, cur_tok, cache_len, resp_len, done, budget,
                out_tok, out_lp, t, occ, step_keys, k2, has_pending,
            )
            bursts += 1

        # assemble RolloutResult in dataset order ------------------------- #
        tokens = np.concatenate([prompts_np, res_tok], axis=1)
        mask = np.zeros((B, Lp + max_new), bool)
        for b in range(B):
            mask[b, Lp: Lp + res_len[b]] = True
        old_lp = np.concatenate(
            [np.zeros((B, Lp), np.float32), res_lp], axis=1)

        wall = time.perf_counter() - t_start
        steps = int(jax.device_get(t))
        occ_steps = int(jax.device_get(occ))
        gen_tokens = int(res_len.sum())
        decode_tokens = gen_tokens - B  # first tokens come from prefill
        lane_steps = S * steps
        self.last_stats = {
            "tokens": float(gen_tokens),
            "wall_s": wall,
            "tokens_per_s": gen_tokens / wall if wall > 0 else 0.0,
            "decode_steps": float(steps),
            "bursts": float(bursts),
            "refills": float(refills),
            "num_slots": float(S),
            "slot_occupancy": occ_steps / lane_steps if lane_steps else 1.0,
            "padding_waste": (
                1.0 - decode_tokens / lane_steps if lane_steps else 0.0),
            "prefill_lane_tokens": float(prefill_lane_tokens),
            "prefill_true_tokens": float(prefill_true_tokens),
            "prefill_waste": (
                1.0 - prefill_true_tokens / prefill_lane_tokens
                if prefill_lane_tokens else 0.0),
        }
        return RolloutResult(
            jnp.asarray(tokens),
            jnp.asarray(mask),
            jnp.asarray(old_lp),
            jnp.asarray(res_len.astype(np.int32)),
        )


def lockstep_waste(lengths: np.ndarray, max_new: int) -> float:
    """Padding-waste of the lockstep schedule for the same responses: the
    fraction of decode lane-steps (B x (max_new-1)) that produced no counted
    token. The benchmark arm reports this next to the engine's measured
    waste."""
    lengths = np.asarray(lengths)
    B = len(lengths)
    lane_steps = B * max(max_new - 1, 1)
    decode_tokens = int(lengths.sum()) - B
    return 1.0 - decode_tokens / lane_steps if lane_steps else 0.0
