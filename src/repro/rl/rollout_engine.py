"""Continuous-batching rollout engine: slot-refill generation for the
GENERATE stage, with a multi-turn episode loop for agentic environments.

The lockstep path (:func:`repro.rl.rollout.generate`) pads every prompt to a
common length and scans all ``max_new`` decode steps even after every
sequence has emitted EOS — so real token throughput collapses as
response-length variance grows, exactly the failure mode AsyncFlow / LlamaRL
attribute their largest wins to fixing with in-flight batching. This module
is that fix on the DistFlow GENERATE stage:

  * a fixed pool of ``num_slots`` decode slots shares ONE persistent KV-cache
    arena (``model.init_caches(num_slots, smax)``); slot *i* is batch row *i*
    of every cache leaf, and each slot carries its own ``cache_len`` (the
    decode kernels already take per-sequence valid lengths);
  * when a slot's sequence hits EOS (or its token budget) the slot is freed
    and immediately refilled with the next prompt from the
    :class:`PromptQueue` — a fresh prefill is scattered over the slot's cache
    rows (``lm.scatter_cache_rows``, the slot-reset path) while the other
    slots' in-flight state is untouched;
  * refills are length-bucketed (prompts grouped by true length rounded up
    to ``prefill_bucket``) so a refill batch prefills at its bucket length
    instead of the global padded max, and optionally chunked
    (``lm.prefill_chunk``) so one long prefill is split into bounded pieces;
  * the decode loop is a ``lax.while_loop`` that early-exits on ``all(done)``
    once the prompt queue drains — the engine never pays lockstep's
    "scan to max_new regardless" tax.

Multi-turn episodes (``env=`` an :class:`repro.rl.envs.EnvRuntime`): a slot
whose sequence finishes a *turn* hands its response to the environment; if
the episode continues, it **re-enters the PromptQueue** as a continuation
item carrying its saved KV rows (``lm.gather_cache_rows``) and the feed
tokens ``[last response token] + observation``. When the continuation is
scheduled, the rows are scattered back over a free slot's arena rows
(``lm.scatter_cache_rows``) and ONLY the feed tokens are run through the
decode path — the shared prompt/response prefix is never re-prefilled, so
``last_stats["prefill_tokens_turn2plus"]`` counts observation tokens (plus
one carried response token per turn), not prefixes. Observation tokens are
excluded from ``response_mask`` and tagged 2 in the emitted ``role_mask``,
so losses/advantages never train on env tokens (docs/environments.md).

Determinism / equivalence contract: under a *fixed slot schedule* — one
length bucket, ``num_slots >= batch`` (every prompt prefilled at once, no
mid-stream refill) — the engine consumes the exact key schedule of lockstep
``generate`` (``k0`` for the prefill sample, ``split(k2, max_new-1)`` for
decode steps) and computes the same prefill/decode math on the same shapes,
so it is token-for-token identical to lockstep (asserted by
``tests/test_rollout_engine.py``). Decode steps past ``max_new - 1`` (which
only exist once refill has happened) derive keys by ``fold_in(k2, t)``.
Single-turn runs — env off, or a single-turn env, which only scores — take
this exact path (asserted by ``tests/test_envs.py``).

Metrics (``engine.last_stats``, surfaced by the GENERATE stage as
``rollout/*``): tokens/sec, padding-waste %, slot occupancy, decode steps,
refill counts, per-turn prefill token accounting. ``docs/rollout_engine.md``
has the slot lifecycle diagram and the metrics glossary.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.obs.trace import get_tracer
from repro.rl.rollout import RolloutResult, sample_token


def _true_lengths(prompts: np.ndarray, pad_id: int) -> np.ndarray:
    """Per-row count of tokens up to and including the last non-pad token
    (right-padded prompts; a fully-pad row counts 1 so it still prefills)."""
    nonpad = prompts != pad_id
    rev = nonpad[:, ::-1]
    last = prompts.shape[1] - np.argmax(rev, axis=1)  # index after last non-pad
    return np.where(nonpad.any(axis=1), last, 1).astype(np.int64)


class _Continuation:
    """A continuing episode waiting for a slot: the dataset row, the feed
    tokens (last response token + clipped observation), the saved KV rows,
    and the cache offset the feed starts at."""

    __slots__ = ("row", "feed", "cache_rows", "cache_len")

    def __init__(self, row: int, feed: np.ndarray, cache_rows, cache_len: int):
        self.row = row
        self.feed = np.asarray(feed, np.int32)
        self.cache_rows = cache_rows
        self.cache_len = int(cache_len)


class PromptQueue:
    """Length-bucketed FIFO over one iteration's pending work.

    Fresh prompts: each prompt's true (non-pad) length is rounded up to a
    multiple of ``bucket`` (0 = a single bucket at the batch's padded
    length — the lockstep-equivalent schedule); refills pop from one bucket
    at a time so every prefill batch shares a padded length. Within a
    bucket, dataset order is preserved.

    Continuations (:meth:`push`): continuing episodes re-enter the queue in
    exact-feed-length buckets (a continuation batch must share its feed
    width; feeds are short — an observation plus one carried token — so the
    bucket count stays small). ``pop_work`` prefers continuations —
    finishing in-flight episodes bounds the number of saved KV-row sets
    held off-arena — but only for ``STARVATION_LIMIT`` consecutive pops
    while fresh prompts wait, so sustained continuation pressure (an env
    that re-queues a continuation per finished turn, i.e. exactly as fast
    as slots free) cannot defer fresh prompts indefinitely.

    Both lanes pick the *fullest* bucket (maximal batch of one shape), which
    on its own would let a small bucket's head wait out every larger bucket;
    a pass counter ages each non-empty bucket that loses the selection and
    force-serves any bucket passed over ``STARVATION_LIMIT`` times. Every
    pending item is therefore served within a bounded number of pops, while
    schedules too short to trip the limits are untouched.
    """

    STARVATION_LIMIT = 4  # max times a non-empty lane/bucket is passed over

    def __init__(self, prompts: np.ndarray, *, pad_id: int, bucket: int = 0,
                 order=None):
        self.prompts = prompts
        B, Lp = prompts.shape
        self.true_len = _true_lengths(prompts, pad_id)
        if bucket <= 0:
            blens = np.full(B, Lp, np.int64)
        else:
            blens = np.minimum(-(-self.true_len // bucket) * bucket, Lp)
        self.bucket_len = blens
        self._buckets: Dict[int, deque] = {}
        self._cont: Dict[int, deque] = {}
        self._passes: Dict[int, int] = {}  # fresh-bucket aging
        self._cont_passes: Dict[int, int] = {}  # cont-bucket aging
        self._cont_streak = 0  # cont pops in a row while fresh waited
        for i in (range(B) if order is None else order):
            self._buckets.setdefault(int(blens[i]), deque()).append(i)

    def __len__(self) -> int:
        return (sum(len(q) for q in self._buckets.values())
                + sum(len(q) for q in self._cont.values()))

    def push(self, cont: _Continuation) -> None:
        """Re-enqueue a continuing episode (multi-turn env path)."""
        self._cont.setdefault(len(cont.feed), deque()).append(cont)

    @staticmethod
    def _select(buckets: Dict[int, deque], passes: Dict[int, int],
                limit: int) -> int:
        """Fullest bucket, unless one has been passed over ``limit`` times
        (then the oldest-starved, shortest-length one). Losing non-empty
        buckets age by one pass; the winner's counter resets."""
        aged = [b for b in buckets if passes.get(b, 0) >= limit]
        if aged:
            sel = min(aged, key=lambda b: (-passes[b], b))
        else:
            sel = max(buckets, key=lambda b: (len(buckets[b]), -b))
        for b in buckets:
            if b != sel:
                passes[b] = passes.get(b, 0) + 1
        passes.pop(sel, None)
        return sel

    def pop(self, n: int) -> Tuple[int, List[int]]:
        """Pop up to ``n`` fresh-prompt indices from the fullest bucket
        (ties break toward the shorter bucket length), except that a bucket
        passed over ``STARVATION_LIMIT`` times is served first. Returns
        (bucket_len, indices); FIFO within the bucket."""
        lb = self._select(self._buckets, self._passes, self.STARVATION_LIMIT)
        q = self._buckets[lb]
        take = [q.popleft() for _ in range(min(n, len(q)))]
        if not q:
            del self._buckets[lb]
        return lb, take

    def pop_work(self, n: int):
        """Pop up to ``n`` homogeneous work items: ``("cont", feed_len,
        [_Continuation, ...])`` or ``("prefill", bucket_len, [row, ...])``.
        Continuations go first — bounding off-arena KV — until they have
        monopolized ``STARVATION_LIMIT`` consecutive pops with fresh
        prompts waiting; then one fresh bucket is served. With no
        continuations this is exactly :meth:`pop` — the single-turn refill
        schedule is untouched."""
        serve_cont = self._cont and (
            not self._buckets or self._cont_streak < self.STARVATION_LIMIT)
        if serve_cont:
            self._cont_streak = self._cont_streak + 1 if self._buckets else 0
            K = self._select(self._cont, self._cont_passes,
                             self.STARVATION_LIMIT)
            q = self._cont[K]
            take = [q.popleft() for _ in range(min(n, len(q)))]
            if not q:
                del self._cont[K]
            return "cont", K, take
        self._cont_streak = 0
        lb, idxs = self.pop(n)
        return "prefill", lb, idxs


class _Episode:
    """Host-side record of one multi-turn episode (dataset row)."""

    __slots__ = ("env", "toks", "roles", "lps", "reward", "turn", "infos")

    def __init__(self, env):
        self.env = env
        self.toks: List[int] = []   # tokens after the prompt region
        self.roles: List[int] = []  # 1 = action, 2 = observation
        self.lps: List[float] = []  # behaviour logprobs (0 on observations)
        self.reward = 0.0
        self.turn = 0
        self.infos: List[dict] = []

    def record_turn(self, resp: np.ndarray, lps: np.ndarray) -> None:
        self.toks.extend(int(t) for t in resp)
        self.roles.extend([1] * len(resp))
        self.lps.extend(float(v) for v in lps)

    def record_obs(self, obs: np.ndarray) -> None:
        self.toks.extend(int(t) for t in obs)
        self.roles.extend([2] * len(obs))
        self.lps.extend([0.0] * len(obs))


class ContinuousRolloutEngine:
    """Slot-based continuous-batching generation engine.

    Drop-in for the jitted lockstep engine at the GENERATE stage: callable as
    ``engine(params, prompts, key) -> RolloutResult`` with identical output
    contract (tokens / response_mask / old_logprob / lengths in dataset
    order). Host code orchestrates slot bookkeeping; the three hot paths —
    the per-bucket refill prefill, the continuation feed, and the
    early-exiting decode burst — are jitted once per shape and reused across
    iterations.

    ``env`` (an :class:`repro.rl.envs.EnvRuntime`) switches the slot loop to
    the episode loop: one environment per sequence, up to ``max_turns``
    turns, observations appended via KV-preserving continuations. With
    ``env=None`` (default) the engine is the PR-4 single-turn engine,
    token-for-token.
    """

    def __init__(
        self,
        model: Model,
        *,
        max_new: int,
        temperature: float = 1.0,
        top_p: float = 1.0,
        eos_id: Optional[int] = None,
        pad_id: int = 0,
        num_slots: int = 0,
        prefill_chunk: int = 0,
        prefill_bucket: int = 0,
        refill_threshold: int = 1,
        env=None,
        max_turns: int = 1,
        turn_budget: int = 0,
        obs_budget: int = 16,
    ):
        if model.is_encdec or model.cfg.num_prefix_embeds:
            raise ValueError(
                "the continuous engine supports text decoder-only archs; "
                "use engine='lockstep' for enc-dec / prefix-modality models"
            )
        self.model = model
        self.max_new = max_new
        self.temperature = temperature
        self.top_p = top_p
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.num_slots = num_slots
        self.prefill_bucket = prefill_bucket
        # minimum count of newly-freed slots before a burst hands control
        # back for refill (while prompts pend). 1 = refill eagerly (maximum
        # occupancy); higher values trade a little slot idleness for fewer
        # host round-trips — useful when dispatch overhead is comparable to
        # a decode step, as on CPU hosts
        self.refill_threshold = max(1, refill_threshold)
        # multi-turn episode loop (None = single-turn slot loop)
        self.env = env
        self.max_turns = max(1, max_turns)
        if env is not None and self.max_turns > 1 and any(
                k[0] == "ssm" for k in model.cfg.layer_kinds()):
            # a done slot keeps executing decode steps (fed PAD) until the
            # burst exits; attention tolerates that — the garbage KV sits
            # past the valid cache_len and is sequentially overwritten
            # before it can be attended — but SSM recurrent state absorbs
            # every update irreversibly, so the rows saved at turn end
            # would resume the next turn from a corrupted state
            raise ValueError(
                "multi-turn environments support attention-only archs; "
                f"{model.cfg.name!r} has SSM mixer layers whose recurrent "
                "state cannot be preserved across turns (use max_turns=1 "
                "or an attention arch)"
            )
        # per-turn response cap (0 = max_new); observation clip per turn
        self.turn_budget = min(turn_budget, max_new) if turn_budget else max_new
        self.obs_budget = max(1, obs_budget)
        # chunked prefill is attention-only (SSM state doesn't carry between
        # chunks), needs an unwrapped cache (no SWA ring), and excludes
        # int8 caches: a chunk would attend the quantize->dequantized K/V
        # of its own prefix, diverging from whole-prompt prefill by far
        # more than float reassociation (~3e-2 in behaviour logprobs)
        kinds = model.cfg.layer_kinds()
        self._can_chunk = (
            prefill_chunk > 0
            and all(k[0] == "attn" for k in kinds)
            and model.cfg.sliding_window is None
            and not model.cfg.kv_quant
        )
        self.prefill_chunk = prefill_chunk if self._can_chunk else 0
        self.last_stats: Dict[str, float] = {}
        # per-episode env outputs of the last call (None when env is off):
        # {"rewards": (B,), "turns": (B,), "tool_calls": int}
        self.last_env: Optional[Dict[str, np.ndarray]] = None
        self._refill_jit: Dict[Tuple[int, int, int], callable] = {}
        self._burst_jit: Dict[Tuple[int, int], callable] = {}
        self._cont_jit: Dict[Tuple[int, int, int], callable] = {}

    # ------------------------------------------------------------------ #
    # jitted halves
    # ------------------------------------------------------------------ #
    def _seed_slots(self, R, logits, key, slots, lane_budget, new_len,
                    cur_tok, cache_len, resp_len, done, budget,
                    out_tok, out_lp):
        """Shared epilogue of the refill and continuation closures (traced
        inside their jits): sample each lane's first response token from
        ``logits``, reset the per-slot output rows, and scatter the lane
        state into the slot arrays (out-of-range slot ids = padding lanes,
        dropped). ``new_len`` is the lanes' cache length after the fill — a
        scalar bucket width for refills, a per-lane vector for
        continuations."""
        eos, pad, max_new = self.eos_id, self.pad_id, self.max_new
        tok0 = sample_token(logits, key, self.temperature, self.top_p)
        lane = jnp.arange(R)
        lp0 = jax.nn.log_softmax(logits, axis=-1)[lane, tok0]
        done0 = (tok0 == eos) if eos is not None else jnp.zeros((R,), bool)
        row_tok = jnp.full((R, max_new), pad, out_tok.dtype).at[:, 0].set(tok0)
        row_lp = jnp.zeros((R, max_new), out_lp.dtype).at[:, 0].set(lp0)
        cur_tok = cur_tok.at[slots].set(tok0, mode="drop")
        cache_len = cache_len.at[slots].set(new_len, mode="drop")
        resp_len = resp_len.at[slots].set(1, mode="drop")
        done = done.at[slots].set(done0 | (lane_budget <= 1), mode="drop")
        budget = budget.at[slots].set(lane_budget, mode="drop")
        out_tok = out_tok.at[slots].set(row_tok, mode="drop")
        out_lp = out_lp.at[slots].set(row_lp, mode="drop")
        return cur_tok, cache_len, resp_len, done, budget, out_tok, out_lp

    def _make_refill(self, R: int, Lb: int, smax: int):
        """Refill ``R`` lanes with a (padded) prompt batch of width ``Lb``:
        prefill, scatter the fresh cache rows over the arena at ``slots``
        (out-of-range ids = padding lanes, dropped), sample each lane's first
        response token, and reset the per-slot output rows. ``R`` is the
        refill batch width — the caller rounds the actual refill count up to
        a power of two so late-stream single-slot refills don't pay a
        full-pool prefill (and the compile count stays log-bounded)."""
        model = self.model
        chunk = self.prefill_chunk

        def refill(params, caches, prompts, slots, lane_budget, key,
                   cur_tok, cache_len, resp_len, done, budget,
                   out_tok, out_lp):
            if chunk > 0:
                rows = model.init_caches(R, smax)
                logits = None
                for off in range(0, Lb, chunk):
                    logits, rows = model.prefill_chunk(
                        params, prompts[:, off:off + chunk], rows, offset=off
                    )
            else:
                logits, rows, _ = model.prefill(params, prompts, smax=smax)
            caches = model.scatter_cache_rows(caches, rows, slots)
            (cur_tok, cache_len, resp_len, done, budget, out_tok,
             out_lp) = self._seed_slots(
                R, logits, key, slots, lane_budget, Lb,
                cur_tok, cache_len, resp_len, done, budget, out_tok, out_lp)
            return (caches, cur_tok, cache_len, resp_len, done, budget,
                    out_tok, out_lp)

        return jax.jit(refill)

    def _make_continue(self, R: int, K: int, smax: int):
        """Resume ``R`` continuing episodes on free slots: scatter each
        episode's saved KV rows over the arena at ``slots``, teacher-force
        the ``K`` feed tokens (last response token + observation) through the
        decode path — per-row cache offsets differ, which
        ``model.decode_step`` already supports — and sample each lane's
        first next-turn token from the final feed position's logits. Only
        the feed is processed: the shared prompt/response prefix is reused
        from the saved rows, never re-prefilled."""
        model = self.model
        V = model.cfg.padded_vocab

        def cont(params, caches, rows, slots, feed, start_len, lane_budget,
                 key, cur_tok, cache_len, resp_len, done, budget,
                 out_tok, out_lp):
            def body(carry, tok):
                rows, clen, _ = carry
                logits, rows, clen = model.decode_step(params, tok, rows, clen)
                return (rows, clen, logits), None

            init = (rows, start_len, jnp.zeros((R, V), jnp.float32))
            (rows, clen, logits), _ = jax.lax.scan(
                body, init, jnp.moveaxis(feed, 1, 0))
            caches = model.scatter_cache_rows(caches, rows, slots)
            (cur_tok, cache_len, resp_len, done, budget, out_tok,
             out_lp) = self._seed_slots(
                R, logits, key, slots, lane_budget, clen,
                cur_tok, cache_len, resp_len, done, budget, out_tok, out_lp)
            return (caches, cur_tok, cache_len, resp_len, done, budget,
                    out_tok, out_lp)

        return jax.jit(cont)

    def _make_burst(self, S: int):
        """The decode loop: a ``lax.while_loop`` stepping every slot, exiting
        as soon as (a) every slot is done — the early-exit on a drained
        queue — or (b) any slot *newly* finishes while prompts are pending,
        handing control back to the host for an immediate refill."""
        model, temp, top_p = self.model, self.temperature, self.top_p
        eos, pad, max_new = self.eos_id, self.pad_id, self.max_new
        T = max_new - 1  # lockstep's decode-step count (key schedule length)
        threshold = self.refill_threshold

        def burst(params, caches, cur_tok, cache_len, resp_len, done, budget,
                  out_tok, out_lp, t, occ, step_keys, k2, has_pending):
            n_done_entry = jnp.sum(done)
            lane = jnp.arange(S)

            def cond(st):
                done = st[4]
                any_active = ~jnp.all(done)
                below_threshold = (jnp.sum(done) - n_done_entry) < threshold
                return any_active & (below_threshold | ~has_pending)

            def body(st):
                (caches, cur_tok, cache_len, resp_len, done, budget,
                 out_tok, out_lp, t, occ) = st
                occ = occ + jnp.sum(~done)
                # lockstep's exact key schedule for the first T steps
                # (jax.random.split is NOT prefix-stable, so the array is
                # sized exactly T); steps beyond T — which only exist after
                # a refill — fold the step index into k2
                kt = jax.lax.select(
                    t < T,
                    step_keys[jnp.minimum(t, T - 1)],
                    jax.random.fold_in(k2, t),
                )
                # fused decode+sample: logits never materialize outside the
                # kernel dispatch (ref mode is bitwise the old sequence)
                nxt, lp, caches, cache_len = model.decode_step_sample(
                    params, cur_tok, caches, cache_len, kt, temp, top_p=top_p
                )
                nxt = jnp.where(done, pad, nxt)
                lp = jnp.where(done, 0.0, lp)
                wr = (~done) & (resp_len < max_new)
                idx = jnp.where(wr, resp_len, max_new)  # OOB -> dropped
                out_tok = out_tok.at[lane, idx].set(nxt, mode="drop")
                out_lp = out_lp.at[lane, idx].set(lp, mode="drop")
                resp_len = resp_len + wr
                new_done = done
                if eos is not None:
                    new_done = new_done | (nxt == eos)
                new_done = new_done | (resp_len >= budget)
                return (caches, nxt, cache_len,
                        resp_len, new_done, budget, out_tok, out_lp,
                        t + 1, occ)

            st = (caches, cur_tok, cache_len, resp_len, done, budget,
                  out_tok, out_lp, t, occ)
            return jax.lax.while_loop(cond, body, st)

        return jax.jit(burst)

    # ------------------------------------------------------------------ #
    @staticmethod
    def _stack_cont_rows(items: List[_Continuation], R: int):
        """Stack the saved per-episode cache rows (leaves (N, 1, ...)) into
        an (N, R, ...) tree, zero-padding the unused lanes."""
        stacked = jax.tree.map(
            lambda *leaves: jnp.concatenate(leaves, axis=1),
            *[c.cache_rows for c in items])
        pad_n = R - len(items)
        if pad_n:
            stacked = jax.tree.map(
                lambda a: jnp.pad(
                    a, [(0, 0), (0, pad_n)] + [(0, 0)] * (a.ndim - 2)),
                stacked)
        return stacked

    # ------------------------------------------------------------------ #
    def __call__(self, params, prompts, key,
                 budgets: Optional[np.ndarray] = None) -> RolloutResult:
        """``budgets`` (B,) caps each sequence's response length at
        ``min(budgets[b], max_new)`` — same semantics as lockstep
        ``generate(budgets=...)``, but here a capped sequence *frees its
        slot* instead of padding out the scan. Under an env, the cap applies
        per turn (jointly with ``turn_budget``)."""
        t_start = time.perf_counter()
        prompts_np = np.asarray(jax.device_get(prompts), np.int32)
        B, Lp = prompts_np.shape
        max_new = self.max_new
        env_on = self.env is not None
        max_turns = self.max_turns if env_on else 1
        turn_cap = min(self.turn_budget, max_new) if env_on else max_new
        if budgets is None:
            budgets_np = np.full(B, turn_cap, np.int32)
        else:
            budgets_np = np.clip(
                np.asarray(jax.device_get(budgets), np.int32), 1, turn_cap)
        S = self.num_slots if self.num_slots > 0 else B
        S = max(1, min(S, B))
        # the arena must hold the longest possible episode: prompt + every
        # turn's response + every inter-turn feed (observation + 1 carried
        # response token)
        smax = Lp + max_turns * max_new + (max_turns - 1) * (self.obs_budget + 1)

        # episode setup: one env per dataset row; reset() supplies the
        # turn-1 context (built-ins return the prompt unchanged, so the
        # single-turn schedule — and its tokens — are untouched)
        episodes: List[Optional[_Episode]] = [None] * B
        if env_on:
            true0 = _true_lengths(prompts_np, self.pad_id)
            first_rows = np.full((B, Lp), self.pad_id, np.int32)
            for b in range(B):
                ep = _Episode(self.env.make_episode())
                obs0 = np.asarray(
                    ep.env.reset(prompts_np[b, : true0[b]]), np.int32).ravel()
                if len(obs0) > Lp:
                    raise ValueError(
                        f"env reset() returned {len(obs0)} tokens > prompt "
                        f"width {Lp}")
                first_rows[b, : len(obs0)] = obs0
                episodes[b] = ep
            queue_rows = first_rows
        else:
            queue_rows = prompts_np

        # known budgets + a real queue (S < B) -> longest-first (LPT) slot
        # packing: long sequences start first instead of draining alone at
        # the tail (the same policy as the coordinator's length-aware
        # balancing). With S == B there is no queue, and dataset order is
        # kept — that's the lockstep-equivalent fixed schedule.
        order = (np.argsort(-budgets_np, kind="stable")
                 if budgets is not None and S < B else None)
        queue = PromptQueue(queue_rows, pad_id=self.pad_id,
                            bucket=self.prefill_bucket, order=order)
        prefill_true_tokens = int(queue.true_len.sum())

        k0, k2 = jax.random.split(key)
        T = max_new - 1
        step_keys = (jax.random.split(k2, T) if T > 0
                     else jnp.zeros((1, 2), jnp.uint32))

        # slot state (device) -------------------------------------------- #
        caches = self.model.init_caches(S, smax)
        cur_tok = jnp.zeros((S,), jnp.int32)
        cache_len = jnp.zeros((S,), jnp.int32)
        resp_len = jnp.zeros((S,), jnp.int32)
        done = jnp.ones((S,), bool)  # every slot starts free/idle
        budget = jnp.full((S,), max_new, jnp.int32)
        out_tok = jnp.full((S, max_new), self.pad_id, jnp.int32)
        out_lp = jnp.zeros((S, max_new), jnp.float32)
        t = jnp.zeros((), jnp.int32)
        occ = jnp.zeros((), jnp.int32)

        # host bookkeeping ------------------------------------------------ #
        slot_seq = np.full(S, -1, np.int64)  # dataset row held by each slot
        row_cache_pos = np.zeros(B, np.int64)  # cache offset per episode
        res_tok = np.full((B, max_new), self.pad_id, np.int32)
        res_lp = np.zeros((B, max_new), np.float32)
        res_len = np.zeros((B,), np.int32)
        completed = 0
        refills = 0
        cont_refills = 0
        cont_feed_tokens = 0
        obs_tokens = 0
        total_turns = 0
        tool_calls = 0
        prefill_lane_tokens = 0
        bursts = 0

        burst = self._burst_jit.get((S, smax))
        if burst is None:
            burst = self._burst_jit[(S, smax)] = self._make_burst(S)

        while completed < B:
            # one bundled host sync per visit: flush state for every slot
            done_h, resp_len_h, out_tok_h, out_lp_h = jax.device_get(
                (done, resp_len, out_tok, out_lp))
            # flush finished slots: single-turn -> results; env -> step the
            # episode and either finalize or re-enqueue a continuation
            # (KV rows for every continuing slot are gathered in ONE device
            # call after the loop, then sliced per episode)
            pending_conts: List[Tuple[int, int, np.ndarray]] = []
            for s in range(S):
                if not (done_h[s] and slot_seq[s] >= 0):
                    continue
                row = slot_seq[s]
                slot_seq[s] = -1
                if not env_on:
                    res_tok[row] = out_tok_h[s]
                    res_lp[row] = out_lp_h[s]
                    res_len[row] = resp_len_h[s]
                    completed += 1
                    continue
                ep = episodes[row]
                n = int(resp_len_h[s])
                rtoks = out_tok_h[s, :n].copy()
                ep.record_turn(rtoks, out_lp_h[s, :n])
                row_cache_pos[row] += n - 1  # decode steps this turn
                obs, r, ep_done, info = ep.env.step(rtoks)
                ep.reward += float(r)
                ep.turn += 1
                ep.infos.append(info or {})
                total_turns += 1
                if info and info.get("tool_call"):
                    tool_calls += 1
                if ep_done or ep.turn >= max_turns:
                    completed += 1
                    continue
                obs = np.asarray(obs, np.int32).ravel()[: self.obs_budget]
                ep.record_obs(obs)
                # the last response token's KV was never written (it was
                # sampled, not fed), so it leads the feed; the saved rows
                # carry the whole shared prefix — nothing is re-prefilled
                feed = np.concatenate([rtoks[-1:], obs])
                pending_conts.append((s, row, feed))
                cont_feed_tokens += len(feed)
                obs_tokens += len(obs)
            if pending_conts:
                gathered = self.model.gather_cache_rows(
                    caches,
                    jnp.asarray([s for s, _, _ in pending_conts], jnp.int32))
                for j, (s, row, feed) in enumerate(pending_conts):
                    saved = jax.tree.map(
                        lambda a, j=j: a[:, j:j + 1], gathered)
                    queue.push(_Continuation(
                        row, feed, saved, row_cache_pos[row]))
                    row_cache_pos[row] += len(feed)
            if completed >= B:
                break
            # refill every free slot, one jitted call per homogeneous batch
            # (continuations first, then fresh-prompt length buckets)
            free = [s for s in range(S) if slot_seq[s] < 0]
            while free and len(queue):
                kind, L, items = queue.pop_work(len(free))
                lanes, free = free[: len(items)], free[len(items):]
                # pad the batch to the next power of two (capped at the
                # pool size), not the full pool: a late-stream single-slot
                # refill runs 1 lane, not num_slots — and a full-pool fill
                # keeps the exact pool shape, which is what the lockstep-
                # equivalence schedule runs
                R = 1
                while R < len(items):
                    R *= 2
                R = min(R, S)
                slots_arr = jnp.asarray(
                    np.concatenate([lanes, np.full(R - len(lanes), S)])
                    .astype(np.int32)
                )
                lane_budget = np.full(R, max_new, np.int32)
                if kind == "prefill":
                    idxs = items
                    batch = np.zeros((R, L), np.int32)
                    batch[: len(idxs)] = queue.prompts[idxs][:, :L]
                    lane_budget[: len(idxs)] = budgets_np[idxs]
                    rk = (k0 if refills == 0
                          else jax.random.fold_in(k0, refills))
                    rf = self._refill_jit.get((R, L, smax))
                    if rf is None:
                        rf = self._refill_jit[(R, L, smax)] = \
                            self._make_refill(R, L, smax)
                    with get_tracer().span("rollout/prefill", cat="rollout",
                                           lanes=R, width=L,
                                           seqs=len(idxs)):
                        (caches, cur_tok, cache_len, resp_len, done, budget,
                         out_tok, out_lp) = rf(
                            params, caches, jnp.asarray(batch), slots_arr,
                            jnp.asarray(lane_budget), rk,
                            cur_tok, cache_len, resp_len, done, budget,
                            out_tok, out_lp,
                        )
                    for lane, seq in zip(lanes, idxs):
                        slot_seq[lane] = seq
                        row_cache_pos[seq] = L
                    refills += 1
                    # count the lanes the prefill actually executed (incl.
                    # the pow2 padding lanes) so prefill_waste reflects
                    # real compute
                    prefill_lane_tokens += R * L
                else:  # continuation: feed tokens only, saved KV reused
                    feed = np.zeros((R, L), np.int32)
                    start_len = np.zeros(R, np.int64)
                    for j, c in enumerate(items):
                        feed[j] = c.feed
                        start_len[j] = c.cache_len
                        lane_budget[j] = budgets_np[c.row]
                    rows = self._stack_cont_rows(items, R)
                    ck = jax.random.fold_in(k0, 1_000_000 + cont_refills)
                    cf = self._cont_jit.get((R, L, smax))
                    if cf is None:
                        cf = self._cont_jit[(R, L, smax)] = \
                            self._make_continue(R, L, smax)
                    with get_tracer().span("rollout/refill", cat="rollout",
                                           lanes=R, width=L,
                                           conts=len(items)):
                        (caches, cur_tok, cache_len, resp_len, done, budget,
                         out_tok, out_lp) = cf(
                            params, caches, rows, slots_arr, jnp.asarray(feed),
                            jnp.asarray(start_len.astype(np.int32)),
                            jnp.asarray(lane_budget), ck,
                            cur_tok, cache_len, resp_len, done, budget,
                            out_tok, out_lp,
                        )
                    for lane, c in zip(lanes, items):
                        slot_seq[lane] = c.row
                    cont_refills += 1
            if not any(slot_seq[s] >= 0 for s in range(S)):
                break  # queue drained and nothing in flight
            # a lane refilled immediately-done (EOS at its first token /
            # budget 1) is counted in the burst's n_done_entry, so the loop
            # below won't mistake it for a fresh completion; it flushes on
            # the next visit.
            # "pending" must also count in-flight episodes that may re-enter
            # the queue as continuations — otherwise a drained fresh-prompt
            # queue would hold every finished slot at a global barrier until
            # the slowest turn completes (lockstep turns, zero overlap).
            # Conservative: an episode below its turn cap counts as pending
            # even if its env ends up finishing it (costs one extra host
            # visit). Single-turn runs (env off or max_turns == 1) never
            # have such episodes, so their burst schedule is untouched.
            cont_possible = env_on and max_turns > 1 and any(
                slot_seq[s] >= 0
                and episodes[slot_seq[s]].turn + 1 < max_turns
                for s in range(S)
            )
            has_pending = jnp.asarray(len(queue) > 0 or cont_possible)
            with get_tracer().span("rollout/decode", cat="rollout",
                                   burst=bursts, completed=completed):
                (caches, cur_tok, cache_len, resp_len, done, budget,
                 out_tok, out_lp, t, occ) = burst(
                    params, caches, cur_tok, cache_len, resp_len, done,
                    budget, out_tok, out_lp, t, occ, step_keys, k2,
                    has_pending,
                )
            bursts += 1

        # assemble RolloutResult in dataset order ------------------------- #
        if not env_on:
            Lmax = Lp + max_new
            tokens = np.concatenate([prompts_np, res_tok], axis=1)
            mask = np.zeros((B, Lmax), bool)
            for b in range(B):
                mask[b, Lp: Lp + res_len[b]] = True
            old_lp = np.concatenate(
                [np.zeros((B, Lp), np.float32), res_lp], axis=1)
            roles = None
            total_turns = completed  # one turn per sequence
            self.last_env = None
        else:
            Lmax = Lp + max_turns * max_new + (max_turns - 1) * self.obs_budget
            tokens = np.full((B, Lmax), self.pad_id, np.int32)
            tokens[:, :Lp] = queue_rows
            roles = np.zeros((B, Lmax), np.int8)
            old_lp = np.zeros((B, Lmax), np.float32)
            rewards = np.zeros(B, np.float32)
            turns = np.zeros(B, np.int32)
            for b, ep in enumerate(episodes):
                n = len(ep.toks)
                tokens[b, Lp: Lp + n] = ep.toks
                roles[b, Lp: Lp + n] = ep.roles
                old_lp[b, Lp: Lp + n] = ep.lps
                rewards[b] = ep.reward
                turns[b] = ep.turn
            mask = roles == 1
            old_lp = np.where(mask, old_lp, 0.0)
            res_len = mask.sum(axis=1).astype(np.int32)
            self.last_env = {
                "rewards": rewards,
                "turns": turns,
                "tool_calls": tool_calls,
            }

        wall = time.perf_counter() - t_start
        steps = int(jax.device_get(t))
        occ_steps = int(jax.device_get(occ))
        gen_tokens = int(res_len.sum())
        # each turn's first token comes from a refill/continuation sample,
        # not a decode step (single-turn: total_turns == B)
        decode_tokens = gen_tokens - total_turns
        lane_steps = S * steps
        self.last_stats = {
            "tokens": float(gen_tokens),
            "wall_s": wall,
            "tokens_per_s": gen_tokens / wall if wall > 0 else 0.0,
            "decode_steps": float(steps),
            "bursts": float(bursts),
            "refills": float(refills),
            "num_slots": float(S),
            "slot_occupancy": occ_steps / lane_steps if lane_steps else 1.0,
            "padding_waste": (
                1.0 - decode_tokens / lane_steps if lane_steps else 0.0),
            "prefill_lane_tokens": float(prefill_lane_tokens),
            "prefill_true_tokens": float(prefill_true_tokens),
            "prefill_waste": (
                1.0 - prefill_true_tokens / prefill_lane_tokens
                if prefill_lane_tokens else 0.0),
            # per-turn prefill accounting: turn 1 prefills true prompt
            # tokens; every later turn feeds ONLY the observation plus one
            # carried response token through the decode path (KV reuse —
            # the acceptance metric for the episode loop)
            "prefill_tokens": float(prefill_true_tokens + cont_feed_tokens),
            "prefill_tokens_turn1": float(prefill_true_tokens),
            "prefill_tokens_turn2plus": float(cont_feed_tokens),
            "obs_tokens": float(obs_tokens),
            "cont_refills": float(cont_refills),
            "turns": float(total_turns),
        }
        if env_on:
            self.last_stats["turns_mean"] = (
                total_turns / B if B else 0.0)
            self.last_stats["tool_calls"] = float(tool_calls)
        return RolloutResult(
            jnp.asarray(tokens),
            jnp.asarray(mask),
            jnp.asarray(old_lp),
            jnp.asarray(res_len.astype(np.int32)),
            None if roles is None else jnp.asarray(roles),
        )


def lockstep_waste(lengths: np.ndarray, max_new: int) -> float:
    """Padding-waste of the lockstep schedule for the same responses: the
    fraction of decode lane-steps (B x (max_new-1)) that produced no counted
    token. The benchmark arm reports this next to the engine's measured
    waste."""
    lengths = np.asarray(lengths)
    B = len(lengths)
    lane_steps = B * max(max_new - 1, 1)
    decode_tokens = int(lengths.sum()) - B
    return 1.0 - decode_tokens / lane_steps if lane_steps else 0.0
