from repro.rl.trainer import RLConfig, TrainState, init_state
