from repro.rl.trainer import RLConfig, TrainState, init_state
from repro.rl.algorithms import (
    AlgorithmSpec,
    get_algorithm,
    list_algorithms,
    register_algorithm,
)
