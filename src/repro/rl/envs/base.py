"""Environment / reward plugin contracts + registries.

DistFlow's usability claim is that the DAG executes "complex execution
flows" beyond the fixed single-turn PPO loop; HybridFlow makes the same case
for RLHF dataflows as composable programs. This module is the *workload*
half of that contract, mirroring :mod:`repro.rl.algorithms`: an
:class:`EnvSpec` names a factory for per-episode :class:`Environment`
instances (multi-turn tool use, dialog, or a plain single-turn function
reward), a :class:`RewardSpec` names the scoring functions the REWARD/ENV
stages call, and both live in register/get/list registries with the same
nearest-match ``KeyError`` messages as the algorithm registry.

Episode lifecycle (driven by the continuous rollout engine, host side)::

    env = runtime.make_episode()
    obs = env.reset(prompt_tokens)          # turn-1 context (prefilled)
    while True:
        response = <engine decodes one turn from the policy>
        obs, reward, done, info = env.step(response)
        if done: break
        # `obs` re-enters the prompt queue appended to the episode's KV rows

Environments are *host-side* and token-native: ``reset``/``step`` take and
return 1-D ``np.ndarray`` token ids (the engine never decodes text; envs own
their tokenizer use). See ``docs/environments.md`` for the full lifecycle,
KV-reuse, and masking contracts.
"""
from __future__ import annotations

import dataclasses
import difflib
from typing import Any, Callable, Dict, List, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.data.tokenizer import ByteTokenizer


@runtime_checkable
class Environment(Protocol):
    """One episode. ``reset`` returns the turn-1 observation tokens (usually
    the prompt itself, at most the prompt's padded length); ``step`` consumes
    the policy's turn response and returns ``(obs_tokens, reward, done,
    info)`` — ``obs_tokens`` is the next turn's appended context (ignored
    when ``done``)."""

    def reset(self, prompt: np.ndarray) -> np.ndarray: ...

    def step(
        self, response: np.ndarray
    ) -> Tuple[np.ndarray, float, bool, Dict[str, Any]]: ...


# --------------------------------------------------------------------------- #
# specs
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class EnvSpec:
    """A registered environment: ``factory(tok, env_cfg)`` builds one fresh
    per-episode :class:`Environment`. ``multi_turn`` declares whether the env
    ever continues past turn 1 (single-turn envs run on either generation
    engine; multi-turn needs the continuous engine's episode loop)."""

    name: str
    factory: Callable[[ByteTokenizer, Any], Environment]
    multi_turn: bool = False
    description: str = ""


@dataclasses.dataclass(frozen=True)
class RewardSpec:
    """A registered function reward, in both execution forms: ``host_fn(texts,
    answers) -> np.ndarray`` for host-side env scoring, and ``token_fn(tokens,
    mask, answers, tok) -> jax.Array`` for the jitted REWARD stage. The two
    must agree on well-formed (EOS-terminated) rollouts — property-tested in
    ``tests/test_kernels_hypothesis.py``."""

    name: str
    host_fn: Callable[[List[str], np.ndarray], np.ndarray]
    token_fn: Callable
    description: str = ""


# --------------------------------------------------------------------------- #
# registries (mirroring rl/algorithms.py)
# --------------------------------------------------------------------------- #
_ENVS: Dict[str, EnvSpec] = {}
_REWARDS: Dict[str, RewardSpec] = {}


def register_env(spec: EnvSpec, *, override: bool = False) -> EnvSpec:
    if spec.name in _ENVS and not override:
        raise KeyError(
            f"environment {spec.name!r} already registered "
            f"(pass override=True to replace). Registered: {list_envs()}"
        )
    _ENVS[spec.name] = spec
    return spec


def get_env(name: str) -> EnvSpec:
    try:
        return _ENVS[name]
    except KeyError:
        near = difflib.get_close_matches(name, _ENVS, n=1)
        hint = f"; did you mean {near[0]!r}?" if near else ""
        raise KeyError(
            f"unknown environment {name!r}. Registered: {list_envs()}{hint}"
        ) from None


def list_envs() -> List[str]:
    return sorted(_ENVS)


def register_reward(spec: RewardSpec, *, override: bool = False) -> RewardSpec:
    if spec.name in _REWARDS and not override:
        raise KeyError(
            f"reward {spec.name!r} already registered "
            f"(pass override=True to replace). Registered: {list_rewards()}"
        )
    _REWARDS[spec.name] = spec
    return spec


def get_reward(name: str) -> RewardSpec:
    try:
        return _REWARDS[name]
    except KeyError:
        near = difflib.get_close_matches(name, _REWARDS, n=1)
        hint = f"; did you mean {near[0]!r}?" if near else ""
        raise KeyError(
            f"unknown reward {name!r}. Registered: {list_rewards()}{hint}"
        ) from None


def list_rewards() -> List[str]:
    return sorted(_REWARDS)


# --------------------------------------------------------------------------- #
# DAG transform
# --------------------------------------------------------------------------- #
def with_env_stage(dag):
    """Swap every (REWARD, COMPUTE) node in ``dag`` for an (ENV, COMPUTE)
    node named ``env_compute``, rewiring dependents. This is how an enabled
    :class:`~repro.configs.base.EnvConfig` retargets an algorithm's built-in
    DAG template: the env stage satisfies the algorithm's REWARD role
    (:meth:`~repro.rl.algorithms.AlgorithmSpec.validate_dag` treats ENV as
    providing REWARD) and writes the same ``rewards`` buffer key."""
    from repro.core.dag import DAG, Node, NodeType, Role

    renames = {
        n.node_id: "env_compute"
        for n in dag.nodes.values()
        if n.role == Role.REWARD and n.type == NodeType.COMPUTE
    }
    if not renames:
        return dag
    if len(renames) > 1:
        raise ValueError(
            f"cannot retarget a DAG with multiple REWARD/COMPUTE nodes "
            f"({sorted(renames)}) to an environment stage"
        )
    nodes = []
    for n in dag.nodes.values():
        deps = tuple(renames.get(d, d) for d in n.deps)
        if n.node_id in renames:
            nodes.append(Node(renames[n.node_id], Role.ENV, NodeType.COMPUTE,
                              deps=deps, parallelism=dict(n.parallelism)))
        else:
            nodes.append(Node(n.node_id, n.role, n.type, deps=deps,
                              parallelism=dict(n.parallelism)))
    return DAG.from_nodes(nodes)


# --------------------------------------------------------------------------- #
# runtime binding
# --------------------------------------------------------------------------- #
class EnvRuntime:
    """A bound (EnvSpec, EnvConfig, tokenizer) triple — what the pipeline
    threads through ``WorkerContext.env`` and hands the rollout engine.

    ``make_episode`` builds one fresh env per rollout sequence per iteration;
    ``score_single_turn`` is the lockstep-engine path for single-turn envs
    (the ENV stage steps each episode post-hoc over the finished rollout)."""

    def __init__(self, spec: EnvSpec, cfg, tok: ByteTokenizer):
        if cfg.max_turns > 1 and not spec.multi_turn:
            multi = [n for n in list_envs() if _ENVS[n].multi_turn]
            raise ValueError(
                f"environment {spec.name!r} is single-turn; max_turns="
                f"{cfg.max_turns} needs a multi_turn env ({multi})"
            )
        self.spec = spec
        self.cfg = cfg
        self.tok = tok

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def max_turns(self) -> int:
        return self.cfg.max_turns

    def make_episode(self) -> Environment:
        return self.spec.factory(self.tok, self.cfg)

    def score_single_turn(
        self, tokens: np.ndarray, mask: np.ndarray
    ) -> np.ndarray:
        """Step every episode once over a finished lockstep rollout: row b's
        prompt is the (non-pad) prefix before its first response token, its
        response the masked tokens. Returns per-sequence rewards."""
        tokens = np.asarray(tokens)
        mask = np.asarray(mask, bool)
        B = tokens.shape[0]
        rewards = np.zeros(B, np.float32)
        for b in range(B):
            m = mask[b]
            first = int(np.argmax(m)) if m.any() else tokens.shape[1]
            prompt = tokens[b, :first]
            prompt = prompt[: int(np.max(np.nonzero(
                prompt != self.tok.pad_id)[0])) + 1] if (
                prompt != self.tok.pad_id).any() else prompt[:1]
            env = self.make_episode()
            env.reset(prompt)
            _, r, _, _ = env.step(tokens[b][m])
            rewards[b] = r
        return rewards
