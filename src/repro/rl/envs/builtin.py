"""Built-in environments over the synthetic math task + the ``math`` reward.

All three parse their task from the prompt itself (``"<aa>+<bb>="`` byte
tokens), so no answer side-channel flows through the engine — an env owns
its task end to end, exactly the contract a real tool-use environment needs.

* :class:`FunctionRewardEnv` (``function_reward``) — single turn: the
  response is the answer, scored by the registered :class:`RewardSpec`.
  Wraps the pre-PR reward path; generation is untouched, so a run with this
  env is token-identical to one without (test-asserted).
* :class:`CalculatorToolEnv` (``calculator``) — multi-turn tool use: a turn
  beginning ``CALL`` invokes the calculator (the env evaluates the called
  expression — or the prompt's own on a malformed call — and appends the
  result digits + ``=`` as observation tokens); a turn beginning with a
  digit is the final answer, scored and terminal; anything else is treated
  as a malformed tool exchange — the env re-asks by appending the original
  expression and the episode burns a turn.
* :class:`MultiTurnDialogEnv` (``dialog``) — fixed ``max_turns`` rounds of
  the same question with per-turn partial rewards: every turn's response is
  scored (earlier turns at half credit), and the env re-asks between turns.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Tuple

import numpy as np

from repro.data.tokenizer import ByteTokenizer
from repro.rl import reward as reward_mod
from repro.rl.envs.base import (
    EnvSpec,
    RewardSpec,
    get_reward,
    register_env,
    register_reward,
)

_EXPR = re.compile(r"(\d+)\s*([+\-*])\s*(\d+)")


def _parse_expr(text: str):
    """First `<int><op><int>` expression in ``text``, or None."""
    m = _EXPR.search(text)
    if not m:
        return None
    a, op, b = int(m.group(1)), m.group(2), int(m.group(3))
    return a + b if op == "+" else a - b if op == "-" else a * b


class _MathEnvBase:
    """Shared prompt parsing / scoring for the math-task envs."""

    def __init__(self, tok: ByteTokenizer, cfg):
        self.tok = tok
        self.cfg = cfg
        self.answer = 0
        self.prompt_text = ""

    def reset(self, prompt: np.ndarray) -> np.ndarray:
        self.prompt_text = self.tok.decode(prompt)
        ans = _parse_expr(self.prompt_text)
        self.answer = 0 if ans is None else int(ans)
        return np.asarray(prompt, np.int32)

    def _score(self, response: np.ndarray) -> float:
        text = self.tok.decode(response)
        host = get_reward(self.cfg.reward).host_fn
        return float(host([text], np.asarray([self.answer]))[0])

    def _reask(self) -> np.ndarray:
        """Observation that re-poses the question (`;` separates turns)."""
        expr = self.prompt_text if self.prompt_text.endswith("=") else (
            self.prompt_text + "=")
        return self.tok.encode(";" + expr)


class FunctionRewardEnv(_MathEnvBase):
    """Single-turn function reward (the pre-PR path as an Environment)."""

    def step(
        self, response: np.ndarray
    ) -> Tuple[np.ndarray, float, bool, Dict[str, Any]]:
        return np.zeros(0, np.int32), self._score(response), True, {}


class CalculatorToolEnv(_MathEnvBase):
    """Multi-turn tool use: CALL -> tool result observation; leading digit ->
    final answer; junk -> re-ask. The engine truncates at ``max_turns``, so
    an episode that never answers is scored by its last turn (0 unless it
    answered)."""

    def __init__(self, tok: ByteTokenizer, cfg):
        super().__init__(tok, cfg)
        self.turn = 0
        self.tool_calls = 0

    def step(
        self, response: np.ndarray
    ) -> Tuple[np.ndarray, float, bool, Dict[str, Any]]:
        self.turn += 1
        text = self.tok.decode(response)
        if text and text[0].isdigit():
            # final answer turn
            return np.zeros(0, np.int32), self._score(response), True, {
                "answered": True, "tool_calls": self.tool_calls}
        if text.startswith("CALL"):
            result = _parse_expr(text[4:])
            if result is None:  # malformed call: evaluate the prompt's expr
                result = self.answer
            self.tool_calls += 1
            obs = self.tok.encode(f"{int(result)}=")
            return obs, 0.0, False, {"tool_call": True}
        # junk: the env re-asks; the episode burns the turn
        return self._reask(), 0.0, False, {"malformed": True}


class MultiTurnDialogEnv(_MathEnvBase):
    """Fixed-round dialog with per-turn partial rewards: every turn's
    response is scored against the answer — earlier turns at half credit,
    the final turn at full — and the env re-asks between turns. Always runs
    ``cfg.max_turns`` turns (the deterministic multi-turn workload for the
    engine's continuation path)."""

    def __init__(self, tok: ByteTokenizer, cfg):
        super().__init__(tok, cfg)
        self.turn = 0

    def step(
        self, response: np.ndarray
    ) -> Tuple[np.ndarray, float, bool, Dict[str, Any]]:
        self.turn += 1
        last = self.turn >= self.cfg.max_turns
        reward = self._score(response) * (1.0 if last else 0.5)
        obs = np.zeros(0, np.int32) if last else self._reask()
        return obs, reward, last, {"turn": self.turn}


# --------------------------------------------------------------------------- #
# registrations
# --------------------------------------------------------------------------- #
MATH_REWARD = register_reward(RewardSpec(
    name="math",
    host_fn=reward_mod.math_reward,
    token_fn=reward_mod.math_reward_tokens,
    description="Exact-match digits -> 1.0; digit-prefix partial credit "
                "0.1/digit (the paper's function reward).",
))

FUNCTION_REWARD = register_env(EnvSpec(
    name="function_reward",
    factory=FunctionRewardEnv,
    multi_turn=False,
    description="Single-turn function reward over the synthetic math task "
                "(token-identical generation to the env-off path).",
))

CALCULATOR = register_env(EnvSpec(
    name="calculator",
    factory=CalculatorToolEnv,
    multi_turn=True,
    description="Multi-turn tool use: CALL <expr> invokes the calculator, a "
                "digit-leading turn is the scored final answer.",
))

DIALOG = register_env(EnvSpec(
    name="dialog",
    factory=MultiTurnDialogEnv,
    multi_turn=True,
    description="Fixed-round dialog: per-turn partial rewards, env re-asks "
                "between turns.",
))
