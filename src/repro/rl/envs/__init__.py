"""Multi-turn agentic environments + pluggable rewards (docs/environments.md).

Importing the package registers the built-ins: envs ``function_reward`` /
``calculator`` / ``dialog`` and reward ``math``.
"""
from repro.rl.envs.base import (
    Environment,
    EnvRuntime,
    EnvSpec,
    RewardSpec,
    get_env,
    get_reward,
    list_envs,
    list_rewards,
    register_env,
    register_reward,
    with_env_stage,
)
from repro.rl.envs.builtin import (
    CalculatorToolEnv,
    FunctionRewardEnv,
    MultiTurnDialogEnv,
)

__all__ = [
    "Environment",
    "EnvRuntime",
    "EnvSpec",
    "RewardSpec",
    "get_env",
    "get_reward",
    "list_envs",
    "list_rewards",
    "register_env",
    "register_reward",
    "with_env_stage",
    "CalculatorToolEnv",
    "FunctionRewardEnv",
    "MultiTurnDialogEnv",
]
