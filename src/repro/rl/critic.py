"""Critic (value) model for PPO: shares the LM backbone machinery with a
scalar value head — the paper's Critic Model (same size as the actor)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm


def init(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    backbone = lm.init(cfg, ks[0])
    backbone.pop("lm_head", None)  # value model has no token head
    return {
        "backbone": backbone,
        "v_head": (jax.random.normal(ks[1], (cfg.d_model, 1), jnp.float32) * 0.01),
    }


def values_fn(cfg: ModelConfig, params, tokens: jax.Array, *, remat: bool = False):
    """Token values (B, S) fp32."""
    h = lm.embed_tokens(cfg, params["backbone"], tokens)
    positions = jnp.arange(h.shape[1])[None, :]
    h, _, _ = lm.backbone(cfg, params["backbone"], h, positions, mode="full", remat=remat)
    v = (h.astype(jnp.float32) @ params["v_head"])[..., 0]
    return v
