"""Advantage estimators: GAE (PPO), group-relative (GRPO), leave-one-out
(RLOO), and global-batch-normalized (REINFORCE++) — paper Fig. 1 plus the
critic-free family registered in :mod:`repro.rl.algorithms`."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gae(
    rewards: jax.Array,  # (B, T) per-token rewards (sparse terminal + KL shaping)
    values: jax.Array,  # (B, T) value estimates
    mask: jax.Array,  # (B, T) 1 on generated tokens
    *,
    gamma: float = 1.0,
    lam: float = 0.95,
):
    """Generalized advantage estimation over the response tokens.

    Returns (advantages (B,T), returns (B,T)). The sequence terminates at the
    last masked position; bootstrap value beyond it is 0.
    """
    B, T = rewards.shape
    mask = mask.astype(jnp.float32)
    # v_{t+1} masked: 0 beyond the response
    v_next = jnp.concatenate([values[:, 1:], jnp.zeros((B, 1))], axis=1) * mask
    deltas = (rewards + gamma * v_next - values) * mask

    def scan_fn(carry, x):
        delta_t, m_t = x
        carry = delta_t + gamma * lam * m_t * carry
        return carry, carry

    # right-to-left scan
    _, adv_rev = jax.lax.scan(
        scan_fn,
        jnp.zeros((B,)),
        (jnp.moveaxis(deltas, 1, 0)[::-1], jnp.moveaxis(mask, 1, 0)[::-1]),
    )
    adv = jnp.moveaxis(adv_rev[::-1], 0, 1) * mask
    returns = adv + values * mask
    return adv, returns


def grpo(
    rewards: jax.Array,  # (B,) scalar reward per sequence
    mask: jax.Array,  # (B, T)
    *,
    group_size: int,
    eps: float = 1e-6,
):
    """Group-relative advantages: normalize each sequence's reward by its
    prompt-group statistics, broadcast over response tokens."""
    B = rewards.shape[0]
    assert B % group_size == 0, (B, group_size)
    g = rewards.reshape(B // group_size, group_size)
    mean = jnp.mean(g, axis=1, keepdims=True)
    std = jnp.std(g, axis=1, keepdims=True)
    adv = ((g - mean) / (std + eps)).reshape(B)
    return adv[:, None] * mask.astype(jnp.float32)


def rloo(
    rewards: jax.Array,  # (B,) scalar reward per sequence
    mask: jax.Array,  # (B, T)
    *,
    group_size: int,
):
    """Leave-one-out baseline (RLOO): each rollout's baseline is the mean
    reward of the *other* ``group_size - 1`` members of its prompt group —
    an unbiased, critic-free REINFORCE baseline. Requires group_size >= 2."""
    B = rewards.shape[0]
    assert B % group_size == 0, (B, group_size)
    assert group_size >= 2, "rloo needs >= 2 rollouts per prompt"
    g = rewards.reshape(B // group_size, group_size)
    baseline = (jnp.sum(g, axis=1, keepdims=True) - g) / (group_size - 1)
    adv = (g - baseline).reshape(B)
    return adv[:, None] * mask.astype(jnp.float32)


def reinforce_pp(
    rewards: jax.Array,  # (B,) scalar reward per sequence
    mask: jax.Array,  # (B, T)
    *,
    eps: float = 1e-6,
):
    """REINFORCE++ advantages: sequence-level rewards normalized over the
    *global batch* (mean/std across all rollouts, not per prompt group),
    broadcast over response tokens. No critic, no per-group statistics."""
    adv = (rewards - jnp.mean(rewards)) / (jnp.std(rewards) + eps)
    return adv[:, None] * mask.astype(jnp.float32)


def whiten(adv: jax.Array, mask: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Batch-whiten advantages over masked positions (PPO stabilizer)."""
    m = mask.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(m), 1.0)
    mean = jnp.sum(adv * m) / n
    var = jnp.sum(jnp.square(adv - mean) * m) / n
    return (adv - mean) * jax.lax.rsqrt(var + eps) * m
