"""Function rewards (the paper's PPO setup uses a function reward in place of
a reward model) + the synthetic math task used by examples/benchmarks.

Task: prompts are byte-tokenized "<a>+<b>=" strings; a correct completion is
the decimal digits of a+b followed by EOS. Reward 1.0 on exact match, partial
credit for digit prefix matches (keeps early training signal dense).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import ByteTokenizer


def make_math_prompts(
    rng: np.random.Generator, n: int, tok: ByteTokenizer, *, max_operand: int = 99
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (prompt_tokens (n, Lp), answers (n,)) with fixed prompt length."""
    a = rng.integers(0, max_operand + 1, size=n)
    b = rng.integers(0, max_operand + 1, size=n)
    prompts = [f"{x:02d}+{y:02d}=" for x, y in zip(a, b)]
    ids = np.stack([tok.encode(p) for p in prompts])
    return ids.astype(np.int32), (a + b).astype(np.int32)


def math_reward(
    response_text: List[str], answers: np.ndarray
) -> np.ndarray:
    """Host-side function reward: exact answer -> 1.0; prefix digits -> 0.1/digit."""
    out = np.zeros(len(response_text), np.float32)
    for i, (text, ans) in enumerate(zip(response_text, answers)):
        want = str(int(ans))
        got = ""
        for ch in text:
            if ch.isdigit():
                got += ch
            else:
                break
        if got == want:
            out[i] = 1.0
        else:
            match = 0
            for c1, c2 in zip(got, want):
                if c1 == c2:
                    match += 1
                else:
                    break
            out[i] = 0.1 * match
    return out


def math_reward_tokens(
    tokens: jax.Array,  # (B, L) full sequences
    mask: jax.Array,  # (B, L) response mask
    answers: jax.Array,  # (B,)
    tok: ByteTokenizer,
) -> jax.Array:
    """Pure-jnp reward (usable inside jit / inside the DAG REWARD node):
    compares the first response digits against the decimal answer."""
    B, L = tokens.shape
    digits0 = tok.encode("0")[0]
    # answer digits (up to 3): hundreds, tens, ones — drop leading zeros
    h = answers // 100
    t = (answers // 10) % 10
    o = answers % 10
    n_digits = jnp.where(answers >= 100, 3, jnp.where(answers >= 10, 2, 1))
    d0 = jnp.where(n_digits == 3, h, jnp.where(n_digits == 2, t, o))
    d1 = jnp.where(n_digits == 3, t, o)
    d2 = o
    # first response token index per row
    first = jnp.argmax(mask, axis=1)
    idx = jnp.arange(B)

    def tok_at(off):
        pos = jnp.clip(first + off, 0, L - 1)
        return tokens[idx, pos]

    ok0 = tok_at(0) == d0 + digits0
    ok1 = jnp.where(n_digits >= 2, tok_at(1) == d1 + digits0, True)
    ok2 = jnp.where(n_digits >= 3, tok_at(2) == d2 + digits0, True)
    # token after the digits must be EOS (or masked out)
    after = tok_at(n_digits)
    eos_ok = after == tok.eos_id
    exact = ok0 & ok1 & ok2 & eos_ok
    partial = 0.1 * (
        ok0.astype(jnp.float32)
        + (ok0 & ok1 & (n_digits >= 2)).astype(jnp.float32)
        + (ok0 & ok1 & ok2 & (n_digits >= 3)).astype(jnp.float32)
    )
    return jnp.where(exact, 1.0, partial)
