"""RL objectives: PPO clipped policy loss, GRPO loss, clipped value loss.

All losses are token-level means over the response mask, matching the verl /
DistFlow conventions (Fig. 1 nodes ACTOR_TRAIN / CRITIC_TRAIN).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def _masked_mean(x, mask):
    m = mask.astype(jnp.float32)
    return jnp.sum(x * m) / jnp.maximum(jnp.sum(m), 1.0)


def ppo_policy_loss(
    logprob: jax.Array,  # (B,T) under the current policy
    old_logprob: jax.Array,  # (B,T) behaviour policy (rollout)
    advantages: jax.Array,  # (B,T)
    mask: jax.Array,  # (B,T)
    *,
    clip_eps: float = 0.2,
) -> Dict[str, jax.Array]:
    ratio = jnp.exp(logprob - old_logprob)
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    surrogate = jnp.minimum(ratio * advantages, clipped * advantages)
    loss = -_masked_mean(surrogate, mask)
    clipfrac = _masked_mean((jnp.abs(ratio - 1.0) > clip_eps).astype(jnp.float32), mask)
    approx_kl = _masked_mean(old_logprob - logprob, mask)
    return {"loss": loss, "clipfrac": clipfrac, "approx_kl": approx_kl,
            "ratio_mean": _masked_mean(ratio, mask)}


def kl_penalty(
    logprob: jax.Array, ref_logprob: jax.Array, mask: jax.Array, *, kind: str = "k3"
) -> jax.Array:
    """Per-token KL(π‖π_ref) estimator. k3 (Schulman) is low-variance and
    non-negative: exp(Δ) - Δ - 1 with Δ = ref - π."""
    delta = ref_logprob - logprob
    if kind == "k1":
        kl = -delta
    elif kind == "k2":
        kl = 0.5 * jnp.square(delta)
    else:  # k3
        kl = jnp.exp(delta) - delta - 1.0
    return _masked_mean(kl, mask)


def grpo_loss(
    logprob,
    old_logprob,
    ref_logprob,
    advantages,
    mask,
    *,
    clip_eps: float = 0.2,
    kl_coef: float = 0.001,
) -> Dict[str, jax.Array]:
    out = ppo_policy_loss(logprob, old_logprob, advantages, mask, clip_eps=clip_eps)
    kl = kl_penalty(logprob, ref_logprob, mask, kind="k3")
    out["kl"] = kl
    out["loss"] = out["loss"] + kl_coef * kl
    return out


def truncated_is_weights(
    proximal_logprob: jax.Array,  # (B,T) policy at batch receipt (train time)
    behavior_logprob: jax.Array,  # (B,T) policy that generated the batch
    mask: jax.Array,  # (B,T)
    *,
    rho_max: float = 2.0,
) -> Dict[str, jax.Array]:
    """Decoupled off-policy correction (AsyncFlow / IMPALA-style): the
    per-token importance ratio between the train-time (proximal) policy and
    the stale behaviour policy, truncated at ``rho_max`` to bound gradient
    variance. Both inputs are data (no gradients flow through them); the
    weight multiplies the surrogate — equivalently the advantages, since
    rho > 0 — leaving the PPO clip to police the proximal ratio alone."""
    rho = jnp.exp(proximal_logprob - behavior_logprob)
    truncated = jnp.minimum(rho, rho_max)
    m = mask.astype(jnp.float32)
    return {
        "rho": truncated * m,
        "rho_mean": _masked_mean(truncated, mask),
        "rho_clipfrac": _masked_mean((rho > rho_max).astype(jnp.float32), mask),
    }


def value_loss(
    values,  # (B,T) current critic
    old_values,  # (B,T) rollout-time critic
    returns,  # (B,T) GAE returns
    mask,
    *,
    clip_eps: float = 0.2,
) -> Dict[str, jax.Array]:
    v_clip = old_values + jnp.clip(values - old_values, -clip_eps, clip_eps)
    l1 = jnp.square(values - returns)
    l2 = jnp.square(v_clip - returns)
    loss = 0.5 * _masked_mean(jnp.maximum(l1, l2), mask)
    return {"loss": loss, "value_err": _masked_mean(jnp.abs(values - returns), mask)}
