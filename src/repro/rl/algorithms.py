"""First-class algorithm plugins: :class:`AlgorithmSpec` + registry.

The paper's usability claim (§4.1) is that researchers define their entire RL
workflow declaratively and the framework executes it without modification.
This module is the algorithm half of that contract: an ``AlgorithmSpec``
bundles everything that used to be ``if rl.algorithm == ...`` branches spread
over four layers — the DAG template, the advantage estimator, the actor loss,
rollout group semantics, and the roles the DAG must provide. The core layers
(pipeline / stages / worker / trainer) only ever see the spec's callables;
adding an algorithm is one ``register_algorithm`` call (see
``docs/algorithms.md``).

Built-ins: ``grpo`` and ``ppo`` (compiled from the exact pre-redesign code
paths — bitwise-identical numerics), plus ``rloo`` (REINFORCE with a
leave-one-out baseline) and ``reinforce_pp`` (REINFORCE++: global-batch
advantage normalization, no critic).
"""
from __future__ import annotations

import dataclasses
import difflib
from typing import Any, Callable, Dict, FrozenSet, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.dag import DAG, DAGError, Node, NodeType, Role
from repro.rl import advantage as adv_mod
from repro.rl import loss as losses


# --------------------------------------------------------------------------- #
# built-in DAG templates (paper Fig. 1)
# --------------------------------------------------------------------------- #
def grpo_dag() -> DAG:
    return DAG.from_nodes(
        [
            Node("actor_generation", Role.ACTOR, NodeType.GENERATE),
            Node("reference_inference", Role.REFERENCE, NodeType.MODEL_INFERENCE,
                 deps=("actor_generation",)),
            Node("reward_compute", Role.REWARD, NodeType.COMPUTE,
                 deps=("actor_generation",)),
            Node("advantage_compute", Role.ADVANTAGE, NodeType.COMPUTE,
                 deps=("reward_compute",)),
            Node("actor_train", Role.ACTOR, NodeType.MODEL_TRAIN,
                 deps=("reference_inference", "advantage_compute")),
        ]
    )


def ppo_dag() -> DAG:
    return DAG.from_nodes(
        [
            Node("actor_generation", Role.ACTOR, NodeType.GENERATE),
            Node("reference_inference", Role.REFERENCE, NodeType.MODEL_INFERENCE,
                 deps=("actor_generation",)),
            Node("reward_compute", Role.REWARD, NodeType.COMPUTE,
                 deps=("actor_generation",)),
            Node("critic_inference", Role.CRITIC, NodeType.MODEL_INFERENCE,
                 deps=("actor_generation",)),
            Node("advantage_compute", Role.ADVANTAGE, NodeType.COMPUTE,
                 deps=("reward_compute", "critic_inference",
                       "reference_inference")),
            Node("actor_train", Role.ACTOR, NodeType.MODEL_TRAIN,
                 deps=("advantage_compute",)),
            Node("critic_train", Role.CRITIC, NodeType.MODEL_TRAIN,
                 deps=("advantage_compute",)),
        ]
    )


def critic_free_dag() -> DAG:
    """Reference-free, critic-free chain (REINFORCE-family algorithms)."""
    return DAG.from_nodes(
        [
            Node("actor_generation", Role.ACTOR, NodeType.GENERATE),
            Node("reward_compute", Role.REWARD, NodeType.COMPUTE,
                 deps=("actor_generation",)),
            Node("advantage_compute", Role.ADVANTAGE, NodeType.COMPUTE,
                 deps=("reward_compute",)),
            Node("actor_train", Role.ACTOR, NodeType.MODEL_TRAIN,
                 deps=("advantage_compute",)),
        ]
    )


# --------------------------------------------------------------------------- #
# the spec
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """Everything the framework needs to run one RL algorithm.

    ``make_advantage(rl)`` returns the jit-able advantage engine; its
    positional signature is ``(rewards, mask, *advantage_inputs)`` where
    ``advantage_inputs`` names the extra databuffer keys it consumes, and it
    returns one array per ``advantage_outputs`` entry (a single array when
    there is exactly one output).

    ``actor_loss(rl, logprob, batch)`` returns a metrics dict containing
    ``"loss"`` — the pre-entropy policy objective (the trainer adds the
    entropy bonus and metric uniformly).
    """

    name: str
    dag_factory: Callable[[], DAG]
    make_advantage: Callable[[Any], Callable]
    actor_loss: Callable[[Any, jax.Array, Dict[str, jax.Array]], Dict]
    # extra buffer keys the advantage engine reads after (rewards, mask)
    advantage_inputs: Tuple[str, ...] = ()
    # buffer keys the advantage engine writes, in return order
    advantage_outputs: Tuple[str, ...] = ("advantages",)
    # roles a DAG must contain to run this algorithm
    required_roles: FrozenSet[Role] = frozenset(
        {Role.ACTOR, Role.REWARD, Role.ADVANTAGE}
    )
    # rollouts are sampled in prompt groups of rl.group_size (GRPO semantics)
    grouped_rollouts: bool = False
    # actor batch carries ref_logprob (falls back to old_logprob when the DAG
    # has no reference node — the zero-KL variant)
    needs_reference: bool = False
    # off-policy correction under the async pipeline (docs/async_pipeline.md):
    #   "none"      — train stale batches as-is (the PPO/GRPO ratio vs the
    #                 behaviour logprobs absorbs the staleness);
    #   "truncated" — decoupled truncated importance sampling: the scheduler
    #                 recomputes old_logprob under the train-time (proximal)
    #                 policy and the trainer weights the surrogate by
    #                 min(exp(proximal - behaviour), rl.is_rho_max).
    # Only consulted for batches whose staleness is >= 1; the synchronous
    # path and max_staleness=0 never see it.
    is_correction: str = "none"
    description: str = ""

    def __post_init__(self):
        if self.is_correction not in ("none", "truncated"):
            raise ValueError(
                f"is_correction must be 'none' or 'truncated', "
                f"got {self.is_correction!r}"
            )

    @property
    def uses_critic(self) -> bool:
        return Role.CRITIC in self.required_roles

    def group_size(self, rl) -> int:
        """Rollouts per prompt for this algorithm under ``rl``."""
        return rl.group_size if self.grouped_rollouts else 1

    def validate_dag(self, dag: DAG) -> None:
        """Raise :class:`DAGError` if ``dag`` lacks a role this algorithm
        requires (e.g. a PPO run on a DAG without a critic node)."""
        have = {n.role for n in dag.nodes.values()}
        if Role.ENV in have:
            # an environment stage writes the same `rewards` buffer key the
            # REWARD stage would (repro.rl.envs.with_env_stage)
            have.add(Role.REWARD)
        missing = self.required_roles - have
        if missing:
            raise DAGError(
                f"DAG is missing required roles for algorithm {self.name!r}: "
                f"{sorted(r.value for r in missing)} "
                f"(DAG roles: {sorted(r.value for r in have)})"
            )


# --------------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------------- #
_ALGORITHMS: Dict[str, AlgorithmSpec] = {}


def register_algorithm(spec: AlgorithmSpec, *, override: bool = False) -> AlgorithmSpec:
    if spec.name in _ALGORITHMS and not override:
        raise KeyError(
            f"algorithm {spec.name!r} already registered "
            f"(pass override=True to replace). Registered: {list_algorithms()}"
        )
    _ALGORITHMS[spec.name] = spec
    return spec


def get_algorithm(name: str) -> AlgorithmSpec:
    try:
        return _ALGORITHMS[name]
    except KeyError:
        near = difflib.get_close_matches(name, _ALGORITHMS, n=1)
        hint = f"; did you mean {near[0]!r}?" if near else ""
        raise KeyError(
            f"unknown algorithm {name!r}. Registered: {list_algorithms()}{hint}"
        ) from None


def list_algorithms() -> List[str]:
    return sorted(_ALGORITHMS)


def resolve(ctx) -> AlgorithmSpec:
    """The spec for a worker context: the bound spec if the pipeline attached
    one, else the registry entry for ``ctx.rl.algorithm``."""
    spec = getattr(ctx, "algorithm", None)
    return spec if spec is not None else get_algorithm(ctx.rl.algorithm)


# --------------------------------------------------------------------------- #
# built-in actor losses (exactly the pre-redesign trainer branches)
# --------------------------------------------------------------------------- #
def _grpo_actor_loss(rl, logprob, batch):
    return losses.grpo_loss(
        logprob,
        batch["old_logprob"],
        batch["ref_logprob"],
        batch["advantages"],
        batch["response_mask"],
        clip_eps=rl.clip_eps,
        kl_coef=rl.kl_coef,
    )


def _clip_actor_loss(rl, logprob, batch):
    return losses.ppo_policy_loss(
        logprob, batch["old_logprob"], batch["advantages"],
        batch["response_mask"], clip_eps=rl.clip_eps,
    )


# public aliases: reusable loss building blocks for custom specs
grpo_actor_loss = _grpo_actor_loss
clip_actor_loss = _clip_actor_loss


# --------------------------------------------------------------------------- #
# built-in advantage engines (exactly the pre-redesign pipeline branches)
# --------------------------------------------------------------------------- #
def _make_grpo_advantage(rl):
    return lambda rewards, mask: adv_mod.grpo(
        rewards, mask, group_size=rl.group_size
    )


def _make_ppo_advantage(rl):
    def _ppo_adv(rewards, mask, old_lp, ref_lp, values):
        B, T = mask.shape
        kl = old_lp - ref_lp  # per-token KL estimate (k1)
        m = mask.astype(jnp.float32)
        # terminal reward at the last response token
        last = jnp.maximum(jnp.sum(m, axis=1) - 1, 0).astype(jnp.int32)
        first = jnp.argmax(mask, axis=1)
        pos = jnp.clip(first + last, 0, T - 1)
        tok_rewards = -rl.kl_coef * kl * m
        tok_rewards = tok_rewards.at[jnp.arange(B), pos].add(rewards)
        adv, ret = adv_mod.gae(
            tok_rewards, values * m, m, gamma=rl.gamma, lam=rl.gae_lambda
        )
        return adv_mod.whiten(adv, m), ret

    return _ppo_adv


def _make_rloo_advantage(rl):
    return lambda rewards, mask: adv_mod.rloo(
        rewards, mask, group_size=rl.group_size
    )


def _make_reinforce_pp_advantage(rl):
    return lambda rewards, mask: adv_mod.reinforce_pp(rewards, mask)


# --------------------------------------------------------------------------- #
# built-in specs
# --------------------------------------------------------------------------- #
GRPO = register_algorithm(AlgorithmSpec(
    name="grpo",
    dag_factory=grpo_dag,
    make_advantage=_make_grpo_advantage,
    actor_loss=_grpo_actor_loss,
    grouped_rollouts=True,
    needs_reference=True,
    description="Group-relative policy optimization: per-prompt-group "
                "normalized advantages, clipped surrogate + k3 KL penalty.",
))

PPO = register_algorithm(AlgorithmSpec(
    name="ppo",
    dag_factory=ppo_dag,
    make_advantage=_make_ppo_advantage,
    actor_loss=_clip_actor_loss,
    advantage_inputs=("old_logprob", "ref_logprob", "old_values"),
    advantage_outputs=("advantages", "returns"),
    required_roles=frozenset(
        {Role.ACTOR, Role.REWARD, Role.ADVANTAGE, Role.CRITIC, Role.REFERENCE}
    ),
    description="PPO with a same-size critic: KL-shaped token rewards, GAE, "
                "whitened advantages, clipped policy + value losses.",
))

RLOO = register_algorithm(AlgorithmSpec(
    name="rloo",
    dag_factory=grpo_dag,
    make_advantage=_make_rloo_advantage,
    actor_loss=_grpo_actor_loss,
    grouped_rollouts=True,
    needs_reference=True,
    description="REINFORCE leave-one-out: each rollout's baseline is the mean "
                "reward of the other group members; clipped surrogate + KL.",
))

REINFORCE_PP = register_algorithm(AlgorithmSpec(
    name="reinforce_pp",
    dag_factory=critic_free_dag,
    make_advantage=_make_reinforce_pp_advantage,
    actor_loss=_clip_actor_loss,
    grouped_rollouts=True,
    description="REINFORCE++: global-batch-normalized sequence advantages, "
                "clipped surrogate, no critic and no reference model.",
))
