"""Autoregressive rollout engine (the paper's vLLM-equivalent generation
stage, as a first-class JAX engine).

Generation = prefill(prompt) + ``lax.scan`` over decode steps with temperature
sampling; finished rows (EOS) keep emitting pad but stop counting. Returns the
full sequences, the response mask, and the behaviour-policy logprobs used as
``old_logprob`` by PPO/GRPO.

Fixed-shape by construction (prompt_len and max_new are static), so one
compiled executable serves every iteration — and the *iteration* cost is
max-len bounded, which is the straggler-mitigation story of DESIGN.md §9.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.api import Model


class RolloutResult(NamedTuple):
    tokens: jax.Array  # (B, Lp + T) prompt + response (pad after EOS)
    response_mask: jax.Array  # (B, Lp + T) 1 on counted response tokens
    old_logprob: jax.Array  # (B, Lp + T) behaviour logprobs (0 on prompt)
    lengths: jax.Array  # (B,) response lengths
    # per-token roles for multi-turn episodes (repro.rl.envs): 0 = prompt /
    # pad, 1 = model action, 2 = environment observation. None on the
    # single-turn paths (every non-prompt token is an action there);
    # response_mask == (role_mask == 1) whenever role_mask is present, so
    # losses/advantages already exclude observation tokens.
    role_mask: Optional[jax.Array] = None


def sample_token(
    logits: jax.Array, key, temperature: float, top_p: float = 1.0
) -> jax.Array:
    """Temperature (then nucleus) sampling; ``temperature == 0`` is greedy.
    ``top_p`` filters AFTER temperature scaling, keeping the smallest
    prefix of the sorted distribution whose mass reaches ``top_p`` (the
    top-1 token is always kept). The default ``top_p=1.0`` is bitwise the
    historical behaviour — the filter is skipped at the Python level."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    scaled = logits / temperature
    if top_p < 1.0:
        from repro.kernels import ref as _kref
        scaled = _kref.top_p_filter(scaled, top_p)
    return jax.random.categorical(key, scaled, axis=-1)


def generate(
    model: Model,
    params,
    prompt: jax.Array,  # (B, Lp) fixed-length prompts
    key: jax.Array,
    *,
    max_new: int,
    temperature: float = 1.0,
    top_p: float = 1.0,
    eos_id: Optional[int] = None,
    pad_id: int = 0,
    budgets: Optional[jax.Array] = None,  # (B,) per-sequence response caps
    frames: Optional[jax.Array] = None,
    prefix_embeds: Optional[jax.Array] = None,
) -> RolloutResult:
    """``budgets`` caps each sequence's counted response length at
    ``min(budgets[b], max_new)`` (>=1; the first sampled token always
    counts) — per-sample truncation for mixed-task batches. Lockstep still
    scans all ``max_new`` steps regardless; only the continuous engine turns
    short budgets into freed decode slots."""
    B, Lp = prompt.shape
    smax = Lp + max_new
    kw = {}
    if frames is not None:
        kw["frames"] = frames
    if prefix_embeds is not None:
        kw["prefix_embeds"] = prefix_embeds
    logits, caches, cache_len = model.prefill(params, prompt, smax=smax, **kw)

    k0, key = jax.random.split(key)
    tok0 = sample_token(logits, k0, temperature, top_p)
    lp0 = jax.nn.log_softmax(logits, axis=-1)[jnp.arange(B), tok0]

    def body(carry, xs):
        step_key, j = xs  # j: 0-based scan step, emitting response pos j+2
        tok, caches, cache_len, done = carry
        # fused decode+sample: the (B, vocab) logits stay behind the kernel
        # dispatch (ref mode is bitwise the old decode_step + sample_token +
        # log_softmax-gather sequence)
        nxt, lp, caches, cache_len = model.decode_step_sample(
            params, tok, caches, cache_len, step_key, temperature, top_p=top_p
        )
        nxt = jnp.where(done, pad_id, nxt)
        lp = jnp.where(done, 0.0, lp)
        new_done = done | ((nxt == eos_id) if eos_id is not None else False)
        if budgets is not None:
            new_done = new_done | (j + 2 >= budgets)
        return (nxt, caches, cache_len, new_done), (nxt, lp, done)

    done0 = (tok0 == eos_id) if eos_id is not None else jnp.zeros((B,), bool)
    if budgets is not None:
        done0 = done0 | (budgets <= 1)
    step_keys = jax.random.split(key, max_new - 1)
    (_, _, _, _), (toks, lps, dones) = jax.lax.scan(
        body, (tok0, caches, cache_len, done0),
        (step_keys, jnp.arange(max_new - 1)),
    )
    # assemble (B, T)
    resp = jnp.concatenate([tok0[:, None], jnp.moveaxis(toks, 0, 1)], axis=1)
    resp_lp = jnp.concatenate([lp0[:, None], jnp.moveaxis(lps, 0, 1)], axis=1)
    was_done = jnp.concatenate(
        [jnp.zeros((B, 1), bool), jnp.moveaxis(dones, 0, 1)], axis=1
    )
    resp_mask = ~was_done  # token emitted while not yet done counts (incl. EOS)

    tokens = jnp.concatenate([prompt, resp], axis=1)
    mask = jnp.concatenate([jnp.zeros((B, Lp), bool), resp_mask], axis=1)
    old_lp = jnp.concatenate([jnp.zeros((B, Lp)), resp_lp * resp_mask], axis=1)
    lengths = jnp.sum(resp_mask, axis=1)
    return RolloutResult(tokens, mask, old_lp, lengths)
