"""Top-level declarative API: :class:`ExperimentSpec`.

An ExperimentSpec is the single serializable description of an RL
post-training run — model architecture, algorithm + hyperparameters, data
coordinator flags, async-pipeline flags, mesh/parallelism, and (optionally)
a custom DAG in its JSON-dict form. ``compile()`` turns it into a runnable
:class:`~repro.core.pipeline.Pipeline`; ``to_dict``/``from_dict`` (and the
JSON string forms) round-trip losslessly, so a whole experiment can live in a
config file, travel over the wire, or be diffed between runs.

    from repro.api import ExperimentSpec
    from repro.configs import ARCHS, reduced
    from repro.rl import RLConfig

    exp = ExperimentSpec(
        model=reduced(ARCHS["qwen2.5-7b"], vocab_size=260),
        rl=RLConfig(algorithm="rloo", group_size=4),
        prompts_per_iter=8,
    )
    pipe = exp.compile()
    pipe.run(10)
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

from repro.configs.base import (
    AsyncPipelineConfig,
    DataCoordinatorConfig,
    DistributedConfig,
    EnvConfig,
    ModelConfig,
    ObsConfig,
    RolloutEngineConfig,
)
from repro.rl.trainer import RLConfig


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """Declarative description of one RL experiment.

    ``dag`` is the DAG's dict form (``DAG.to_spec()`` / the JSON config
    schema), not a live DAG object, so the spec stays JSON-serializable;
    ``None`` means "use the algorithm's built-in template".
    ``mesh_shape=None`` compiles onto a local 1x1 mesh (or whatever mesh is
    passed to ``compile``).
    """

    model: ModelConfig
    rl: RLConfig = dataclasses.field(default_factory=RLConfig)
    coordinator: DataCoordinatorConfig = dataclasses.field(
        default_factory=DataCoordinatorConfig
    )
    async_pipeline: AsyncPipelineConfig = dataclasses.field(
        default_factory=AsyncPipelineConfig
    )
    rollout: RolloutEngineConfig = dataclasses.field(
        default_factory=RolloutEngineConfig
    )
    env: EnvConfig = dataclasses.field(default_factory=EnvConfig)
    # multi-host fleet (docs/multihost.md); None = single-host, the default
    distributed: Optional[DistributedConfig] = None
    # telemetry (docs/observability.md); disabled by default
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)
    mesh_shape: Optional[Tuple[int, ...]] = None
    mesh_axes: Tuple[str, ...] = ("data", "model")
    prompts_per_iter: int = 8
    centralized: bool = False
    seed: int = 0
    dag: Optional[Dict] = None

    # ------------------------------------------------------------------ #
    @property
    def algorithm(self):
        """The resolved :class:`~repro.rl.algorithms.AlgorithmSpec`."""
        from repro.rl import algorithms

        return algorithms.get_algorithm(self.rl.algorithm)

    # ------------------------------------------------------------------ #
    # serialization
    # ------------------------------------------------------------------ #
    def to_dict(self) -> Dict[str, Any]:
        return {
            "model": dataclasses.asdict(self.model),
            "rl": dataclasses.asdict(self.rl),
            "coordinator": dataclasses.asdict(self.coordinator),
            "async_pipeline": dataclasses.asdict(self.async_pipeline),
            "rollout": dataclasses.asdict(self.rollout),
            "env": dataclasses.asdict(self.env),
            "distributed": (
                dataclasses.asdict(self.distributed)
                if self.distributed is not None else None
            ),
            "obs": dataclasses.asdict(self.obs),
            "mesh_shape": list(self.mesh_shape) if self.mesh_shape else None,
            "mesh_axes": list(self.mesh_axes),
            "prompts_per_iter": self.prompts_per_iter,
            "centralized": self.centralized,
            "seed": self.seed,
            "dag": self.dag,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        mesh_shape = d.get("mesh_shape")
        return cls(
            model=ModelConfig(**d["model"]),
            rl=RLConfig(**d.get("rl", {})),
            coordinator=DataCoordinatorConfig(**d.get("coordinator", {})),
            async_pipeline=AsyncPipelineConfig(**d.get("async_pipeline", {})),
            rollout=RolloutEngineConfig(**d.get("rollout", {})),
            env=EnvConfig(**d.get("env", {})),
            distributed=(
                DistributedConfig(**d["distributed"])
                if d.get("distributed") else None
            ),
            obs=ObsConfig(**d.get("obs", {})),
            mesh_shape=tuple(mesh_shape) if mesh_shape else None,
            mesh_axes=tuple(d.get("mesh_axes", ("data", "model"))),
            prompts_per_iter=d.get("prompts_per_iter", 8),
            centralized=d.get("centralized", False),
            seed=d.get("seed", 0),
            dag=d.get("dag"),
        )

    def to_json(self, *, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    # ------------------------------------------------------------------ #
    # compilation
    # ------------------------------------------------------------------ #
    def compile(self, *, mesh=None, dataset=None, registry=None):
        """Compile the spec into a runnable Pipeline.

        ``mesh`` overrides ``mesh_shape`` (useful when the caller already
        holds a device mesh); ``dataset``/``registry`` are the non-serializable
        escape hatches for custom data sources and stage functions.
        """
        from repro.core.dag import DAG
        from repro.core.pipeline import build_pipeline

        if mesh is None and self.mesh_shape is not None:
            from repro.utils.jax_compat import make_compat_mesh

            mesh = make_compat_mesh(tuple(self.mesh_shape),
                                    tuple(self.mesh_axes))
        dag = DAG.from_spec(self.dag) if self.dag is not None else None
        return build_pipeline(
            self.model,
            self.rl,
            mesh=mesh,
            dag=dag,
            dataset=dataset,
            prompts_per_iter=self.prompts_per_iter,
            centralized=self.centralized,
            coordinator=self.coordinator,
            async_pipeline=self.async_pipeline,
            rollout=self.rollout,
            env=self.env,
            distributed=self.distributed,
            obs=self.obs,
            registry=registry,
            algorithm=self.algorithm,
            seed=self.seed,
        )
