from repro.optim.adamw import (
    AdamWState,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    init,
    update,
)
