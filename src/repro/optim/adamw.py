"""AdamW with fully-sharded optimizer state.

State mirrors the param pytree (m, v each with the *same* PartitionSpecs as
params), so under FSDP the optimizer memory scales 1/devices — the JAX
equivalent of ZeRO. Master weights stay in the params' own dtype (bf16 params
with fp32 m/v); grads are cast up for the moment updates.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    """Returns (new_params, new_state). ``lr`` may be a scalar or a schedule
    value computed by the caller."""
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, base_lr * cos)
