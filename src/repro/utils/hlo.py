"""HLO text analysis: collective operand bytes + cost-analysis plumbing.

``cost_analysis()`` has no collective accounting, so §Roofline's collective
term comes from parsing the post-SPMD stablehlo/HLO text: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op's operand
sizes are summed, bucketed by op kind.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "i8": 1, "ui8": 1,
    "s16": 2, "u16": 2, "i16": 2, "ui16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "i32": 4, "ui32": 4, "f32": 4,
    "s64": 8, "u64": 8, "i64": 8, "ui64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
    # stablehlo spellings
    "all_gather", "all_reduce", "reduce_scatter", "all_to_all",
    "collective_permute",
)

# matches e.g. "bf16[16,512,128]{...}" or "f32[256]"  (HLO text)
_HLO_SHAPE = re.compile(r"\b(\w+)\[([\d,]*)\]")
# matches stablehlo "tensor<16x512x128xbf16>"
_MLIR_SHAPE = re.compile(r"tensor<([0-9x]*?)x?(\w+)>")


def _shape_bytes_hlo(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _shape_bytes_mlir(dims: str, dtype: str) -> int:
    dt = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "i1": 1, "i8": 1,
          "i16": 2, "i32": 4, "i64": 8, "ui8": 1, "ui32": 4}.get(dtype, 0)
    n = 1
    if dims:
        for d in dims.split("x"):
            if d:
                n *= int(d)
    return n * dt


def collective_bytes(text: str) -> Dict:
    """Sum *output* operand bytes of every collective op in HLO/MLIR text.

    Output bytes approximate wire volume per device program: all-gather
    output = full gathered tensor; all-reduce output = reduced tensor (2x on
    wire for ring, we report raw and let the roofline apply the algo factor).
    """
    per_kind: Dict[str, int] = {}
    count: Dict[str, int] = {}
    for line in text.splitlines():
        stripped = line.strip()
        kind = None
        for op in COLLECTIVE_OPS:
            # HLO: "%x = bf16[...] all-gather(...)" / MLIR: "stablehlo.all_gather"
            if f" {op}(" in stripped or f".{op}" in stripped or stripped.startswith(op):
                kind = op.replace("_", "-")
                break
        if kind is None:
            continue
        nbytes = 0
        m = _HLO_SHAPE.search(stripped)
        if m and m.group(1) in _DTYPE_BYTES:
            nbytes = _shape_bytes_hlo(m.group(1), m.group(2))
        else:
            mm = _MLIR_SHAPE.search(stripped)
            if mm:
                nbytes = _shape_bytes_mlir(mm.group(1), mm.group(2))
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        count[kind] = count.get(kind, 0) + 1
    return {
        "per_kind_bytes": per_kind,
        "per_kind_count": count,
        "total_bytes": sum(per_kind.values()),
        "total_count": sum(count.values()),
    }


def cost_summary(cost) -> Dict:
    """Normalize compiled.cost_analysis() across jax versions."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds"):
        if k in cost:
            out[k.replace(" ", "_")] = float(cost[k])
    # per-memory-space bytes if present
    for k, v in cost.items():
        if isinstance(k, str) and k.startswith("bytes accessed"):
            out[k.replace(" ", "_")] = float(v)
    return out
