"""Version-tolerance shims for the jax APIs this repo uses.

The codebase targets current jax (explicit ``AxisType.Auto`` meshes, the
``jax.sharding.set_mesh`` ambient-mesh context, ``get_abstract_mesh``), but
must also run on older 0.4.x releases where none of those exist. Every
call site goes through these helpers instead of feature-testing jax inline:

* :func:`auto_axis_types` / :func:`make_compat_mesh` — mesh construction.
* :func:`use_mesh` — ambient-mesh context manager: ``set_mesh`` when
  available, else the legacy ``with mesh:`` context plus a module-local
  stack so :func:`ambient_mesh` still answers.
* :func:`ambient_mesh` — the mesh model code should resolve logical axis
  names against, or None (-> sharding constraints no-op, keeping model code
  mesh-agnostic exactly as before).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

_LEGACY_AMBIENT: list = []  # fallback ambient-mesh stack for pre-set_mesh jax


def auto_axis_types(n: int):
    """(AxisType.Auto,) * n on jax versions that have AxisType, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return None if axis_type is None else (axis_type.Auto,) * n


def make_compat_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis_types when the running jax supports
    them, plain otherwise — the two spell the same mesh."""
    types = auto_axis_types(len(axes))
    if types is not None:
        try:
            return jax.make_mesh(shape, axes, axis_types=types)
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axes)


@contextlib.contextmanager
def use_mesh(mesh):
    """Enter ``mesh`` as the ambient mesh for sharding constraints."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        with set_mesh(mesh):
            yield mesh
        return
    _LEGACY_AMBIENT.append(mesh)
    try:
        with mesh:  # legacy context: enables with_sharding_constraint(x, P)
            yield mesh
    finally:
        _LEGACY_AMBIENT.pop()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` on current jax; ``jax.experimental.shard_map`` (with
    its ``check_rep`` spelling of ``check_vma``) on older releases."""
    native = getattr(jax, "shard_map", None)
    if native is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        try:
            return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
        except TypeError:
            pass  # a jax with jax.shard_map but the old check_rep kwarg
    from jax.experimental.shard_map import shard_map as legacy

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def peak_memory_bytes(stats) -> int:
    """``CompiledMemoryStats.peak_memory_in_bytes`` where jaxlib provides it;
    the temp+argument+output sum (the dominant contributors) on older
    releases that only expose the per-category sizes."""
    peak = getattr(stats, "peak_memory_in_bytes", None)
    if peak is not None:
        return int(peak)
    return int(
        stats.temp_size_in_bytes
        + stats.argument_size_in_bytes
        + stats.output_size_in_bytes
    )


def ambient_mesh():
    """The mesh logical-axis constraints should resolve against, or None.

    None also when the ambient mesh has explicit (non-Auto) axis types —
    with_sharding_constraint only accepts Auto axes, so callers must no-op
    inside shard_map manual regions.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is None:
        return _LEGACY_AMBIENT[-1] if _LEGACY_AMBIENT else None
    mesh = get_abstract()
    if mesh.empty:
        return None
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None and any(t != axis_type.Auto for t in mesh.axis_types):
        return None
    return mesh
