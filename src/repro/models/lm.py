"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layer stacks are *pattern-compressed*: the per-layer kind sequence
(cfg.layer_kinds()) is reduced to its smallest repeating pattern P, params are
stacked over the N = num_layers / P repetitions, and the forward pass is a
``lax.scan`` over the N groups with the P positions unrolled inside the body.
Homogeneous archs get P=1 (pure scan over layers, e.g. 95-layer deepseek);
jamba gets P=8 / N=4. This keeps compile time and HLO size flat in depth —
essential when lowering for 512 devices.

Three execution modes share one backbone:
  full     — whole sequence, no cache (training loss / RL logprobs)
  prefill  — whole sequence, emits decode caches
  decode   — one token per sequence against the caches

Decode caches (per pattern position, stacked over groups):
  attn  {"k","v"} (N,B,W,KVH,hd) — W = min(Smax, sliding_window): SWA archs get
        a ring buffer bounded at the window (the long_500k enabler for mixtral)
  ssm   {"ssm","conv_x","conv_bc"} — constant-size Mamba2 state
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.constraint import constrain, residual_entries
from repro.kernels import ops
from repro.models import layers, moe, ssm

Params = Dict[str, Any]

LOSS_CHUNK = 1024  # sequence chunking for the CE/logprob loss (memory bound)
IGNORE = -1  # label id excluded from the loss


# --------------------------------------------------------------------------- #
# pattern compression
# --------------------------------------------------------------------------- #
def pattern_length(cfg: ModelConfig) -> int:
    kinds = cfg.layer_kinds()
    L = len(kinds)
    for p in range(1, L + 1):
        if L % p == 0 and all(kinds[i] == kinds[i % p] for i in range(L)):
            return p
    return L


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #
def _init_block_pos(cfg: ModelConfig, key, kind: Tuple[str, str]) -> Params:
    mixer_kind, mlp_kind = kind
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": layers.init_norm(cfg)}
    if mixer_kind == "attn":
        p["attn"] = layers.init_attention(cfg, ks[0])
    else:
        p["ssm"] = ssm.init_ssm(cfg, ks[0])
    if mlp_kind != "none" and not cfg.parallel_block:
        p["norm2"] = layers.init_norm(cfg)
    if mlp_kind == "dense":
        p["mlp"] = layers.init_mlp(cfg, ks[1])
    elif mlp_kind == "moe":
        p["moe"] = moe.init_moe(cfg, ks[1])
    return p


def init(cfg: ModelConfig, key) -> Params:
    P = pattern_length(cfg)
    N = cfg.num_layers // P
    kinds = cfg.layer_kinds()[:P]
    ks = jax.random.split(key, P + 2)

    blocks: List[Params] = []
    for pos in range(P):
        group_keys = jax.random.split(ks[pos], N)
        blocks.append(jax.vmap(lambda k: _init_block_pos(cfg, k, kinds[pos]))(group_keys))

    v, d = cfg.padded_vocab, cfg.d_model
    params: Params = {
        "embed": (jax.random.normal(ks[P], (v, d), jnp.float32) * 0.02).astype(
            jnp.bfloat16
        ),
        "blocks": blocks,
        "final_norm": layers.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(ks[P + 1], (d, v), jnp.float32) / (d**0.5)
        ).astype(jnp.bfloat16)
    return params


# --------------------------------------------------------------------------- #
# mixers with cache plumbing
# --------------------------------------------------------------------------- #
def quant_kv(x: jax.Array):
    """(…, KVH, hd) -> (int8 values, f32 scales over the hd dim)."""
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(m, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequant_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(jnp.bfloat16)


def _ring_width(cfg: ModelConfig, smax: int) -> int:
    if cfg.sliding_window is not None:
        return min(smax, cfg.sliding_window)
    return smax


def _attn_mixer(
    cfg: ModelConfig,
    p: Params,
    h: jax.Array,
    positions: jax.Array,
    mode: str,
    cache: Optional[Params],
    cache_len: Optional[jax.Array],
    smax: int,
    chunk_offset: Optional[int] = None,
    page_tables: Optional[jax.Array] = None,
    write_enable: Optional[jax.Array] = None,
):
    if mode == "full":
        return layers.self_attention(cfg, p, h, positions), None

    if mode == "prefill_chunk":
        # one chunk of a chunked prefill: write this chunk's K/V into the
        # existing cache at [offset, offset+C) and attend the chunk's queries
        # against the (static-width) prefix [0, offset+C). ``chunk_offset``
        # is a Python int, so every slice below is static. Ring (SWA-bounded)
        # caches are unsupported — the engine falls back to whole-prompt
        # prefill for those archs.
        assert cache is not None and chunk_offset is not None
        C = h.shape[1]
        pos = (chunk_offset + jnp.arange(C))[None, :]
        q, k, v = layers.qkv_proj(cfg, p, h, pos)
        hi = chunk_offset + C
        if cfg.kv_quant:
            kq, vq = cache["k"], cache["v"]
            ks, vs = cache["k_scale"], cache["v_scale"]
            assert hi <= kq.shape[1], "chunked prefill past the cache width"
            kq_new, ks_new = quant_kv(k)
            vq_new, vs_new = quant_kv(v)
            kq = jax.lax.dynamic_update_slice(kq, kq_new, (0, chunk_offset, 0, 0))
            vq = jax.lax.dynamic_update_slice(vq, vq_new, (0, chunk_offset, 0, 0))
            ks = jax.lax.dynamic_update_slice(ks, ks_new, (0, chunk_offset, 0))
            vs = jax.lax.dynamic_update_slice(vs, vs_new, (0, chunk_offset, 0))
            o = ops.flash_attention(
                q, dequant_kv(kq[:, :hi], ks[:, :hi]),
                dequant_kv(vq[:, :hi], vs[:, :hi]),
                causal=True, window=cfg.sliding_window, q_offset=chunk_offset,
            )
            return layers.out_proj(cfg, p, o), {
                "k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        kc, vc = cache["k"], cache["v"]
        assert hi <= kc.shape[1], "chunked prefill past the cache width"
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, chunk_offset, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, chunk_offset, 0, 0))
        o = ops.flash_attention(
            q, kc[:, :hi], vc[:, :hi],
            causal=True, window=cfg.sliding_window, q_offset=chunk_offset,
        )
        return layers.out_proj(cfg, p, o), {"k": kc, "v": vc}

    if mode == "prefill":
        q, k, v = layers.qkv_proj(cfg, p, h, positions)
        o = ops.flash_attention(q, k, v, causal=True, window=cfg.sliding_window)
        B, S = h.shape[0], h.shape[1]
        W = _ring_width(cfg, smax)
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        kc = jnp.zeros((B, W, kvh, hd), k.dtype)
        vc = jnp.zeros((B, W, kvh, hd), v.dtype)
        if S >= W:  # keep the last W tokens (ring-aligned slots pos % W)
            slot = jnp.arange(S - W, S) % W
            kc = kc.at[:, slot].set(k[:, S - W :])
            vc = vc.at[:, slot].set(v[:, S - W :])
        else:
            kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
        if cfg.kv_quant:
            kq, ks = quant_kv(kc)
            vq, vs = quant_kv(vc)
            return layers.out_proj(cfg, p, o), {
                "k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        return layers.out_proj(cfg, p, o), {"k": kc, "v": vc}

    # decode
    assert cache is not None and cache_len is not None
    B = h.shape[0]
    q, k_new, v_new = layers.qkv_proj(cfg, p, h, cache_len[:, None])
    if page_tables is not None:
        return _decode_paged(
            cfg, p, q, k_new, v_new, cache, cache_len, page_tables,
            write_enable,
        )
    if cfg.kv_quant:
        return _decode_quant(cfg, p, q, k_new, v_new, cache, cache_len)
    kc, vc = cache["k"], cache["v"]
    W = kc.shape[1]
    ring = cfg.sliding_window is not None and W <= cfg.sliding_window
    slot = cache_len % W if ring else cache_len
    # masked write instead of a dynamic scatter: elementwise select keeps
    # the seq-sharded cache fully in place under GSPMD (a scatter at a
    # traced index made the partitioner all-gather the cache every step —
    # §Perf A-it2); costs one cache read+write of HBM locally, zero wire.
    sel = (jax.lax.broadcasted_iota(jnp.int32, (B, W), 1)
           == slot[:, None])[..., None, None]
    kc = jnp.where(sel, k_new[:, 0][:, None], kc)
    vc = jnp.where(sel, v_new[:, 0][:, None], vc)
    # pin the updated cache to its resident layout (batch x seq-over-model)
    kc = constrain(kc, "dp", "tp", None, None)
    vc = constrain(vc, "dp", "tp", None, None)
    if ring:
        eff_len = jnp.minimum(cache_len + 1, W)
        o, _ = ops.decode_attention(q[:, 0], kc, vc, eff_len, window=None)
    else:
        o, _ = ops.decode_attention(
            q[:, 0], kc, vc, cache_len + 1, window=cfg.sliding_window
        )
    return layers.out_proj(cfg, p, o)[:, None], {"k": kc, "v": vc}


def _decode_quant(cfg, p, q, k_new, v_new, cache, cache_len):
    """int8-cache decode step: quantize the new slot and attend with the
    fused int8 decode kernel (``ops.decode_attention_quant``) — the cache
    stays int8 in HBM; dequantization happens per tile inside the kernel
    (the ref path dequantizes up front, bitwise-identical to the pre-fusion
    full-cache dequantize)."""
    B = q.shape[0]
    kq, vq = cache["k"], cache["v"]
    ks, vs = cache["k_scale"], cache["v_scale"]
    W = kq.shape[1]
    ring = cfg.sliding_window is not None and W <= cfg.sliding_window
    slot = cache_len % W if ring else cache_len
    kq_new, ks_new = quant_kv(k_new[:, 0])
    vq_new, vs_new = quant_kv(v_new[:, 0])
    sel = (jax.lax.broadcasted_iota(jnp.int32, (B, W), 1)
           == slot[:, None])
    sel4 = sel[..., None, None]
    kq = jnp.where(sel4, kq_new[:, None], kq)
    vq = jnp.where(sel4, vq_new[:, None], vq)
    ks = jnp.where(sel[..., None], ks_new[:, None], ks)
    vs = jnp.where(sel[..., None], vs_new[:, None], vs)
    if ring:
        eff_len = jnp.minimum(cache_len + 1, W)
        o, _ = ops.decode_attention_quant(
            q[:, 0], kq, vq, ks, vs, eff_len, window=None)
    else:
        o, _ = ops.decode_attention_quant(
            q[:, 0], kq, vq, ks, vs, cache_len + 1, window=cfg.sliding_window)
    new_cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    return layers.out_proj(cfg, p, o)[:, None], new_cache


def _decode_paged(cfg, p, q, k_new, v_new, cache, cache_len, page_tables,
                  write_enable):
    """Paged decode step: the cache leaves ARE the serving engine's shared
    page pool (``(num_pages, page_size, kvh, hd)``); each lane's KV lives in
    the pages its ``page_tables`` row names. The new token's K/V is written
    straight into the lane's current page (no staging rows), and the paged
    flash-decode kernel gathers pages through the table — the burst never
    materializes contiguous per-slot KV.

    ``write_enable`` (bool (B,), optional) routes retired lanes' writes to
    an out-of-range page that ``mode="drop"`` discards: a finished slot can
    keep stepping in the fixed-shape burst without corrupting pool pages it
    no longer owns. Attention-only, full-window, bf16 caches (the serving
    engine's admission gate); SWA rings and int8 pools are rejected here."""
    assert cfg.sliding_window is None, "paged decode: SWA unsupported"
    assert not cfg.kv_quant, "paged decode: int8 pool unsupported"
    kc, vc = cache["k"], cache["v"]
    P, ps = kc.shape[0], kc.shape[1]
    T = page_tables.shape[1]
    pidx = jnp.clip(cache_len // ps, 0, T - 1)
    page = jnp.take_along_axis(page_tables, pidx[:, None], axis=1)[:, 0]
    off = cache_len % ps
    if write_enable is not None:
        page = jnp.where(write_enable, page, P)  # OOB -> dropped below
    kc = kc.at[page, off].set(k_new[:, 0].astype(kc.dtype), mode="drop")
    vc = vc.at[page, off].set(v_new[:, 0].astype(vc.dtype), mode="drop")
    o, _ = ops.paged_decode_attention(q[:, 0], kc, vc, page_tables,
                                      cache_len + 1)
    return layers.out_proj(cfg, p, o)[:, None], {"k": kc, "v": vc}


def _ssm_mixer(cfg, p, h, mode, cache):
    if mode == "prefill_chunk":
        raise NotImplementedError(
            "chunked prefill needs SSM state carried between chunks; "
            "use whole-prompt prefill (prefill_chunk=0) for SSM/hybrid archs"
        )
    if mode == "full":
        return ssm.apply_ssm(cfg, p, h), None
    if mode == "prefill":
        out, state = ssm.apply_ssm(cfg, p, h, return_state=True)
        return out, state
    out, state = ssm.apply_ssm_decode(cfg, p, h, cache)
    return out, state


def _apply_block(
    cfg: ModelConfig,
    p: Params,
    kind: Tuple[str, str],
    h: jax.Array,
    positions: Optional[jax.Array],
    mode: str,
    cache: Optional[Params],
    cache_len: Optional[jax.Array],
    smax: int,
    chunk_offset: Optional[int] = None,
    page_tables: Optional[jax.Array] = None,
    write_enable: Optional[jax.Array] = None,
):
    mixer_kind, mlp_kind = kind
    hn = layers.apply_norm(cfg, p["norm1"], h)
    if mixer_kind == "attn":
        mix_out, new_cache = _attn_mixer(
            cfg, p["attn"], hn, positions, mode, cache, cache_len, smax,
            chunk_offset, page_tables, write_enable)
    else:
        assert page_tables is None, "paged decode: attention-only archs"
        mix_out, new_cache = _ssm_mixer(cfg, p["ssm"], hn, mode, cache)

    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        if mlp_kind == "dense":
            mlp_out = layers.apply_mlp(cfg, p["mlp"], hn)
        elif mlp_kind == "moe":
            mlp_out, aux = moe.apply_moe(cfg, p["moe"], hn)
        else:
            mlp_out = 0.0
        return h + mix_out + mlp_out, aux, new_cache

    h = h + mix_out
    if mlp_kind != "none":
        hn2 = layers.apply_norm(cfg, p["norm2"], h)
        if mlp_kind == "dense":
            h = h + layers.apply_mlp(cfg, p["mlp"], hn2)
        else:
            mlp_out, aux = moe.apply_moe(cfg, p["moe"], hn2)
            h = h + mlp_out
    return h, aux, new_cache


# --------------------------------------------------------------------------- #
# backbone: scan over groups, pattern positions unrolled in the body
# --------------------------------------------------------------------------- #
def backbone(
    cfg: ModelConfig,
    params: Params,
    h: jax.Array,
    positions: Optional[jax.Array],
    *,
    mode: str = "full",
    caches: Optional[List[Any]] = None,
    cache_len: Optional[jax.Array] = None,
    smax: int = 0,
    remat: bool = False,
    unroll: bool = False,
    chunk_offset: Optional[int] = None,
    page_tables: Optional[jax.Array] = None,
    write_enable: Optional[jax.Array] = None,
):
    """Returns (h, aux_sum, new_caches).

    ``unroll=True`` replaces the layer-group scan with a Python loop: same
    math, explicit per-layer HLO. Used by the dry-run so cost_analysis()
    counts every layer (XLA prices a while-loop body once) — and by perf
    variants trading compile time for scheduling freedom."""
    P = pattern_length(cfg)
    kinds = cfg.layer_kinds()[:P]
    blocks = params["blocks"]  # list over positions, each stacked over groups

    def body(carry, xs):
        h, aux = carry
        group_params, group_caches = xs
        new_caches = []
        for pos in range(P):
            c_in = None if group_caches is None else group_caches[pos]
            h, a, c_out = _apply_block(
                cfg, group_params[pos], kinds[pos],
                h, positions, mode, c_in, cache_len, smax, chunk_offset,
                page_tables, write_enable,
            )
            # sequence-parallel residual stream (Megatron-SP): between
            # blocks the seq dim shards over `model`, so the out-proj's TP
            # all-reduce lowers to a reduce-scatter (+ all-gather at the next
            # block's QKV). REPRO_SP=0 restores the baseline arm.
            h = constrain(h, *residual_entries())
            aux = aux + a
            new_caches.append(c_out)
        if all(c is None for c in new_caches):
            return (h, aux), None
        return (h, aux), new_caches

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    carry0 = (h, jnp.zeros((), jnp.float32))
    if unroll:
        N = cfg.num_layers // P
        carry = carry0
        ys = []
        for i in range(N):
            xs_i = jax.tree.map(lambda t: t[i], (blocks, caches))
            carry, y = body(carry, xs_i)
            ys.append(y)
        (h, aux) = carry
        if ys[0] is None:
            new_caches = None
        else:
            new_caches = jax.tree.map(lambda *ts: jnp.stack(ts), *ys)
    else:
        (h, aux), new_caches = jax.lax.scan(body, carry0, (blocks, caches))
    h = layers.apply_norm(cfg, params["final_norm"], h)
    return h, aux, new_caches


def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model**0.5, h.dtype)
    return constrain(h, "dp", None, None)


def _head_matrix(cfg: ModelConfig, params: Params) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def assemble_input(
    cfg: ModelConfig, params: Params, tokens: jax.Array,
    prefix_embeds: Optional[jax.Array],
) -> jax.Array:
    """Token embeddings, with modality prefix embeddings concatenated ahead
    (VLM patches / audio frames per the assignment's frontend stub)."""
    h = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None and cfg.num_prefix_embeds > 1:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    return h


# --------------------------------------------------------------------------- #
# chunked CE loss / logprobs (never materializes (B,S,V))
# --------------------------------------------------------------------------- #
def _chunked_head_scan(h, w_head, labels, chunk, vocab_size=None, unroll=False):
    """scan over sequence chunks; returns per-position (logprob, entropy, mask)."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=IGNORE)
    nc = h.shape[1] // chunk
    hc = jnp.moveaxis(h.reshape(B, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    vpad = w_head.shape[1]
    vmask = None
    if vocab_size is not None and vocab_size < vpad:
        vmask = jnp.arange(vpad) < vocab_size
    # gather the FSDP-sharded head once, keep it vocab-TP for the chunk loop
    w_head = constrain(w_head, None, "tp")

    @jax.checkpoint
    def body(_, xs):
        hx, lx = xs
        logits = (hx @ w_head).astype(jnp.float32)  # (B, chunk, V)
        logits = constrain(logits, "dp", None, "tp")
        if vmask is not None:  # exclude padded vocab slots (match sampling)
            logits = jnp.where(vmask, logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tok = jnp.take_along_axis(
            logits, jnp.maximum(lx, 0)[..., None], axis=-1
        )[..., 0]
        logprob = tok - logz
        probs = jax.nn.softmax(logits, axis=-1)
        entropy = logz - jnp.sum(probs * logits, axis=-1)
        return (), (logprob, entropy, (lx != IGNORE))

    if unroll:
        outs = [body((), (hc[i], lc[i]))[1] for i in range(nc)]
        lp, ent, mask = (jnp.stack(ts) for ts in zip(*outs))
    else:
        _, (lp, ent, mask) = jax.lax.scan(body, (), (hc, lc))
    fix = lambda t: jnp.moveaxis(t, 0, 1).reshape(B, -1)[:, :S]
    return fix(lp), fix(ent), fix(mask)


def token_stats(cfg, params, h, labels, chunk=LOSS_CHUNK, unroll=False):
    return _chunked_head_scan(
        h, _head_matrix(cfg, params), labels, chunk, vocab_size=cfg.vocab_size,
        unroll=unroll,
    )


def ce_loss(cfg, params, h, labels, unroll=False):
    lp, ent, mask = token_stats(cfg, params, h, labels, unroll=unroll)
    denom = jnp.maximum(jnp.sum(mask), 1)
    loss = -jnp.sum(lp * mask) / denom
    return loss, {"ce": loss, "entropy": jnp.sum(ent * mask) / denom}


# --------------------------------------------------------------------------- #
# public entry points
# --------------------------------------------------------------------------- #
def loss_fn(
    cfg: ModelConfig,
    params: Params,
    batch: Dict[str, jax.Array],
    *,
    remat: bool = True,
    unroll: bool = False,
):
    """LM training loss. batch: tokens (B,St) [, prefix_embeds (B,P,d)],
    labels (B, P+St) with IGNORE at non-predicted positions."""
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeds")
    h = assemble_input(cfg, params, tokens, prefix)
    positions = jnp.arange(h.shape[1])[None, :]
    h, aux, _ = backbone(cfg, params, h, positions, mode="full", remat=remat,
                         unroll=unroll)
    loss, metrics = ce_loss(cfg, params, h, batch["labels"], unroll=unroll)
    if cfg.num_experts:
        loss = loss + 0.01 * aux
        metrics["moe_aux"] = aux
    return loss, metrics


def logprobs_fn(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    *,
    prefix_embeds: Optional[jax.Array] = None,
    remat: bool = False,
    unroll: bool = False,
):
    """Per-token logprob + entropy of ``tokens`` under the model (RL eval).

    Returns (logprob, entropy) each (B, S): position i scores tokens[:, i]
    given tokens[:, :i] (position 0 gets 0)."""
    h = assemble_input(cfg, params, tokens, prefix_embeds)
    positions = jnp.arange(h.shape[1])[None, :]
    h, _, _ = backbone(cfg, params, h, positions, mode="full", remat=remat,
                       unroll=unroll)
    offset = h.shape[1] - tokens.shape[1]  # prefix length
    labels = tokens[:, 1:]
    h_pred = h[:, offset : offset + tokens.shape[1] - 1]
    lp, ent, _ = token_stats(cfg, params, h_pred, labels)
    zero = jnp.zeros((tokens.shape[0], 1), lp.dtype)
    return (
        jnp.concatenate([zero, lp], axis=1),
        jnp.concatenate([zero, ent], axis=1),
    )


def init_caches(cfg: ModelConfig, batch: int, smax: int):
    """Zero caches (one entry per pattern position, stacked over groups)."""
    P = pattern_length(cfg)
    N = cfg.num_layers // P
    kinds = cfg.layer_kinds()[:P]
    W = _ring_width(cfg, smax)
    caches = []
    for pos in range(P):
        if kinds[pos][0] == "attn":
            kvh, hd = cfg.num_kv_heads, cfg.head_dim
            if cfg.kv_quant:
                caches.append(
                    {
                        "k": jnp.zeros((N, batch, W, kvh, hd), jnp.int8),
                        "v": jnp.zeros((N, batch, W, kvh, hd), jnp.int8),
                        "k_scale": jnp.zeros((N, batch, W, kvh), jnp.float32),
                        "v_scale": jnp.zeros((N, batch, W, kvh), jnp.float32),
                    }
                )
            else:
                caches.append(
                    {
                        "k": jnp.zeros((N, batch, W, kvh, hd), jnp.bfloat16),
                        "v": jnp.zeros((N, batch, W, kvh, hd), jnp.bfloat16),
                    }
                )
        else:
            shapes = ssm.ssm_state_shapes(cfg, batch)
            caches.append(
                {k: jnp.zeros((N,) + s.shape, s.dtype) for k, s in shapes.items()}
            )
    return caches


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    *,
    smax: int,
    prefix_embeds: Optional[jax.Array] = None,
    unroll: bool = False,
):
    """Run the prompt, return (last-position logits, caches, cache_len)."""
    h = assemble_input(cfg, params, tokens, prefix_embeds)
    positions = jnp.arange(h.shape[1])[None, :]
    h, _, caches = backbone(
        cfg, params, h, positions, mode="prefill", smax=smax, unroll=unroll
    )
    logits = (h[:, -1] @ _head_matrix(cfg, params)).astype(jnp.float32)
    logits = mask_padded_vocab(cfg, logits)
    cache_len = jnp.full((tokens.shape[0],), h.shape[1], jnp.int32)
    return logits, caches, cache_len


def _decode_hidden(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,  # (B,) or (B,1)
    caches,
    cache_len: jax.Array,  # (B,)
    *,
    unroll: bool = False,
    page_tables: Optional[jax.Array] = None,
    write_enable: Optional[jax.Array] = None,
):
    """Shared decode-step body: embed -> backbone -> last hidden (B, d).
    With ``page_tables``, ``caches`` is the serving page pool and attention
    runs through the block table (see :func:`_decode_paged`)."""
    token = token.reshape(-1, 1)
    h = embed_tokens(cfg, params, token)
    h, _, new_caches = backbone(
        cfg, params, h, None, mode="decode", caches=caches, cache_len=cache_len,
        smax=0, unroll=unroll, page_tables=page_tables,
        write_enable=write_enable,
    )
    return h[:, 0], new_caches


def decode_step(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,  # (B,) or (B,1)
    caches,
    cache_len: jax.Array,  # (B,)
    unroll: bool = False,
):
    """One decode step. Returns (logits (B,V), new_caches, cache_len+1)."""
    h, new_caches = _decode_hidden(
        cfg, params, token, caches, cache_len, unroll=unroll)
    logits = (h @ _head_matrix(cfg, params)).astype(jnp.float32)
    logits = mask_padded_vocab(cfg, logits)
    return logits, new_caches, cache_len + 1


def decode_step_sample(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,  # (B,) or (B,1)
    caches,
    cache_len: jax.Array,  # (B,)
    key: jax.Array,
    temperature: float,  # static; 0.0 = greedy
    *,
    top_p: float = 1.0,  # static; < 1.0 routes dispatch to the ref path
    unroll: bool = False,
):
    """One decode step with the sampler fused behind the kernel dispatch:
    the (B, padded_vocab) logits never leave the op (``ops.fused_sample``).
    Returns (sampled token (B,), behaviour logprob (B,) under the untempered
    masked distribution, new_caches, cache_len+1). The ref dispatch path is
    bitwise-identical to ``decode_step`` + ``rollout.sample_token`` +
    ``log_softmax`` gather."""
    h, new_caches = _decode_hidden(
        cfg, params, token, caches, cache_len, unroll=unroll)
    tok, lp = ops.fused_sample(
        h, _head_matrix(cfg, params), key, temperature,
        vocab_size=cfg.vocab_size, top_p=top_p,
    )
    return tok, lp, new_caches, cache_len + 1


def decode_step_paged(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,  # (B,) or (B,1)
    pool,  # init_caches(num_pages, page_size) tree — the shared page pool
    cache_len: jax.Array,  # (B,)
    page_tables: jax.Array,  # (B, T) int32 pool-page ids per lane
    *,
    write_enable: Optional[jax.Array] = None,  # bool (B,); False = retired
    unroll: bool = False,
):
    """Paged decode step over the serving page pool. Returns
    (logits (B,V), new_pool, cache_len+1)."""
    h, new_pool = _decode_hidden(
        cfg, params, token, pool, cache_len, unroll=unroll,
        page_tables=page_tables, write_enable=write_enable)
    logits = (h @ _head_matrix(cfg, params)).astype(jnp.float32)
    return mask_padded_vocab(cfg, logits), new_pool, cache_len + 1


def decode_step_paged_sample(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,  # (B,) or (B,1)
    pool,
    cache_len: jax.Array,  # (B,)
    page_tables: jax.Array,  # (B, T) int32
    keys: jax.Array,  # (B, 2) uint32 per-row PRNG keys
    temps: jax.Array,  # (B,) f32; <= 0 means greedy
    *,
    write_enable: Optional[jax.Array] = None,
    unroll: bool = False,
):
    """Paged decode + fused per-row sampling (the serving burst step).
    Returns (sampled token (B,), new_pool, cache_len+1)."""
    h, new_pool = _decode_hidden(
        cfg, params, token, pool, cache_len, unroll=unroll,
        page_tables=page_tables, write_enable=write_enable)
    tok = ops.fused_sample_rows(
        h, _head_matrix(cfg, params), keys, temps, vocab_size=cfg.vocab_size)
    return tok, new_pool, cache_len + 1


def prefill_chunk(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,  # (B, C) one chunk of the prompts
    caches,  # per-slot caches being filled (width >= offset + C)
    *,
    offset: int,  # static: absolute position of tokens[:, 0]
    unroll: bool = False,
):
    """One chunk of a chunked prefill into existing decode caches.

    The continuous-batching rollout engine uses this to break a refill
    prompt's prefill into bounded pieces (so a long prefill never stalls
    in-flight decodes for its full length): chunk c writes K/V into
    ``caches`` at ``[offset, offset+C)`` and attends against the prefix
    ``[0, offset+C)``. For bf16 caches, calling it over consecutive chunks
    is numerically equivalent to one whole-prompt :func:`prefill` (same
    masked softmax, up to float reassociation); with ``kv_quant`` the chunk
    attends its prefix's quantize->dequantized K/V, which whole-prompt
    prefill never does — the rollout engine excludes that combination.
    Returns (last-position logits, new caches);
    the caller owns ``cache_len`` (set it to the prompt length after the
    final chunk). Attention-only paths; SSM mixers raise (state would need
    to carry between chunks) and ring-bounded SWA caches are rejected by
    width asserts."""
    h = embed_tokens(cfg, params, tokens)
    h, _, new_caches = backbone(
        cfg, params, h, None, mode="prefill_chunk", caches=caches,
        chunk_offset=offset, unroll=unroll,
    )
    logits = (h[:, -1] @ _head_matrix(cfg, params)).astype(jnp.float32)
    return mask_padded_vocab(cfg, logits), new_caches


def gather_cache_rows(caches, slots: jax.Array):
    """Pull the per-slot cache rows at ``slots`` (batch axis 1 of every
    leaf: leaves are stacked (N, B, ...) over layer groups)."""
    return jax.tree.map(lambda a: jnp.take(a, slots, axis=1), caches)


def scatter_cache_rows(caches, rows, slots: jax.Array):
    """Slot-reset path: overwrite the arena's rows at ``slots`` with freshly
    prefilled ``rows`` (same tree structure, batch axis 1). Out-of-range
    slot ids are dropped — the engine pads refill batches to a fixed lane
    count and parks the padding lanes at an out-of-range slot."""
    return jax.tree.map(
        lambda a, r: a.at[:, slots].set(r.astype(a.dtype), mode="drop"),
        caches, rows,
    )


def gather_cache_pages(caches, slots: jax.Array, *, num_pages: int,
                       page_size: int):
    """Page-granular generalization of :func:`gather_cache_rows`: pull the
    first ``num_pages`` fixed-size KV pages (``page_size``-token spans along
    the token axis) of the rows at ``slots``. Leaves come back shaped
    ``(N, R, num_pages, page_size, *rest)`` — one block-table row per lane —
    ready to be stored into a page pool (``repro.serving.paged_arena``).

    Attention caches only: every leaf must carry the token axis at index 2
    (``(N, B, W, ...)``); SSM recurrent state has no token axis to page.
    """
    span = num_pages * page_size

    def g(a):
        rows = jnp.take(a, slots, axis=1)[:, :, :span]
        return rows.reshape(
            rows.shape[:2] + (num_pages, page_size) + rows.shape[3:])

    return jax.tree.map(g, caches)


def scatter_cache_pages(caches, pages, slots: jax.Array):
    """Inverse of :func:`gather_cache_pages`: write per-lane page stacks
    (leaves ``(N, R, k, page_size, *rest)``) contiguously into the arena
    rows at ``slots``, covering token positions ``[0, k * page_size)``.
    Out-of-range slot ids are dropped (padding lanes), mirroring
    :func:`scatter_cache_rows`."""

    def s(a, p):
        span = p.shape[2] * p.shape[3]
        flat = p.reshape(p.shape[:2] + (span,) + p.shape[4:])
        return a.at[:, slots, :span].set(flat.astype(a.dtype), mode="drop")

    return jax.tree.map(s, caches, pages)


def mask_padded_vocab(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    v = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(v, logits, -1e30)
