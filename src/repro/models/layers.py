"""Shared model layers: norms, RoPE, MLP flavours, attention mixer.

Pure-functional: every layer is (params-pytree, inputs) -> outputs. Params are
nested dicts of jax.Arrays so sharding rules (distributed/sharding.py) can be
expressed as a matching pytree of PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.constraint import constrain
from repro.kernels import ops

Params = Dict[str, Any]


def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / (fan_in**0.5)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(jnp.bfloat16)


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #
def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    if cfg.norm_type == "rmsnorm":
        return {"w": jnp.zeros((d,), jnp.float32)}
    return {"w": jnp.zeros((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm_type == "rmsnorm":
        return ops.rmsnorm(x, p["w"])
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    return ((1.0 + p["w"]) * y + p["b"]).astype(x.dtype)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, D) or (..., H, D) with matching positions (..., S) / (...,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------- #
# MLP flavours
# --------------------------------------------------------------------------- #
def init_mlp(cfg: ModelConfig, key) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    gated = cfg.mlp_type in ("swiglu", "geglu")
    p: Params = {"w_in": _dense_init(ks[0], (d, f)), "w_out": _dense_init(ks[1], (f, d))}
    if gated:
        p["w_gate"] = _dense_init(ks[2], (d, f))
    if cfg.use_bias:
        p["b_in"] = jnp.zeros((f,), jnp.float32)
        p["b_out"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    h = x @ p["w_in"]
    h = constrain(h, "dp", None, "tp")
    if cfg.use_bias:
        h = h + p["b_in"].astype(h.dtype)
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    elif cfg.mlp_type == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * h
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(h))
    elif cfg.mlp_type == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    else:
        raise ValueError(cfg.mlp_type)
    out = h @ p["w_out"]
    if cfg.use_bias:
        out = out + p["b_out"].astype(out.dtype)
    return out


# --------------------------------------------------------------------------- #
# attention mixer
# --------------------------------------------------------------------------- #
def init_attention(cfg: ModelConfig, key, *, cross: bool = False) -> Params:
    """Q/K/V/O projections. Q/O use padded_heads (zero-padded heads are exact:
    their W_o columns are zero)."""
    d, hd = cfg.d_model, cfg.head_dim
    hp, kvh = cfg.padded_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    wq = _dense_init(ks[0], (d, hp * hd))
    wo = _dense_init(ks[3], (hp * hd, d))
    if cfg.padded_heads != cfg.num_heads:
        # zero the padded head slots
        mask = (jnp.arange(hp * hd) < cfg.num_heads * hd)
        wq = wq * mask[None, :].astype(wq.dtype)
        wo = wo * mask[:, None].astype(wo.dtype)
    p: Params = {
        "w_q": wq,
        "w_k": _dense_init(ks[1], (d, kvh * hd)),
        "w_v": _dense_init(ks[2], (d, kvh * hd)),
        "w_o": wo,
    }
    if cfg.use_bias:
        p["b_q"] = jnp.zeros((hp * hd,), jnp.float32)
        p["b_k"] = jnp.zeros((kvh * hd,), jnp.float32)
        p["b_v"] = jnp.zeros((kvh * hd,), jnp.float32)
        p["b_o"] = jnp.zeros((d,), jnp.float32)
    return p


def qkv_proj(
    cfg: ModelConfig, p: Params, x: jax.Array, positions: Optional[jax.Array]
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x (B,S,d) -> q (B,S,Hp,hd), k/v (B,S,KVH,hd); RoPE applied if positions."""
    B, S, _ = x.shape
    hp, kvh, hd = cfg.padded_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["w_q"]
    k = x @ p["w_k"]
    v = x @ p["w_v"]
    if cfg.use_bias:
        q = q + p["b_q"].astype(q.dtype)
        k = k + p["b_k"].astype(k.dtype)
        v = v + p["b_v"].astype(v.dtype)
    q = constrain(q.reshape(B, S, hp, hd), "dp", None, "tp", None)
    k = constrain(k.reshape(B, S, kvh, hd), "dp", None, "tp", None)
    v = constrain(v.reshape(B, S, kvh, hd), "dp", None, "tp", None)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_proj(cfg: ModelConfig, p: Params, o: jax.Array) -> jax.Array:
    """o (B,S,Hp,hd) or (B,Hp,hd) -> (..., d)."""
    flat = o.reshape(*o.shape[:-2], -1)
    out = flat @ p["w_o"]
    if cfg.use_bias:
        out = out + p["b_o"].astype(out.dtype)
    return out


def self_attention(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence attention (train / prefill-without-cache)."""
    q, k, v = qkv_proj(cfg, p, x, positions)
    o = ops.flash_attention(q, k, v, causal=causal, window=cfg.sliding_window)
    return out_proj(cfg, p, o)


def cross_attention(
    cfg: ModelConfig, p: Params, x: jax.Array, memory_kv: Tuple[jax.Array, jax.Array]
) -> jax.Array:
    """Decoder cross-attention over precomputed encoder memory K/V (no RoPE)."""
    B, S, _ = x.shape
    hp, hd = cfg.padded_heads, cfg.head_dim
    q = (x @ p["w_q"])
    if cfg.use_bias:
        q = q + p["b_q"].astype(q.dtype)
    q = q.reshape(B, S, hp, hd)
    k, v = memory_kv
    o = ops.flash_attention(q, k, v, causal=False)
    return out_proj(cfg, p, o)


def memory_kv(cfg: ModelConfig, p: Params, memory: jax.Array):
    """Precompute cross-attention K/V from encoder output (B,S_enc,d)."""
    B, S, _ = memory.shape
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    k = memory @ p["w_k"]
    v = memory @ p["w_v"]
    if cfg.use_bias:
        k = k + p["b_k"].astype(k.dtype)
        v = v + p["b_v"].astype(v.dtype)
    return k.reshape(B, S, kvh, hd), v.reshape(B, S, kvh, hd)


def decode_self_attention(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # (B, 1, d) — single new token
    kv_cache: Tuple[jax.Array, jax.Array],  # (B, S, KVH, hd) each
    cache_len: jax.Array,  # (B,) valid slots BEFORE this token
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One decode step: project, write slot, attend over the cache."""
    B = x.shape[0]
    q, k_new, v_new = qkv_proj(cfg, p, x, cache_len[:, None])  # rope at pos=len
    kc, vc = kv_cache
    # scatter the new K/V into slot cache_len (per batch row)
    bidx = jnp.arange(B)
    kc = kc.at[bidx, cache_len].set(k_new[:, 0])
    vc = vc.at[bidx, cache_len].set(v_new[:, 0])
    o, _ = ops.decode_attention(
        q[:, 0], kc, vc, cache_len + 1, window=cfg.sliding_window
    )
    return out_proj(cfg, p, o), (kc, vc)
