"""Encoder-decoder backbone (seamless-m4t): bidirectional encoder over
precomputed audio-frame embeddings (frontend stub per the assignment) + causal
decoder with cross-attention. Both stacks scan over layers.

Decode caches: decoder self-attn KV (L,B,W,H,hd) + cross-attn KV precomputed
once from the encoder memory at prefill (L,B,S_enc,H,hd).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers
from repro.models.lm import (
    IGNORE,
    _head_matrix,
    ce_loss,
    embed_tokens,
    mask_padded_vocab,
    token_stats,
)

Params = Dict[str, Any]


def _init_enc_layer(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "norm1": layers.init_norm(cfg),
        "attn": layers.init_attention(cfg, ks[0]),
        "norm2": layers.init_norm(cfg),
        "mlp": layers.init_mlp(cfg, ks[1]),
    }


def _init_dec_layer(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "norm1": layers.init_norm(cfg),
        "self_attn": layers.init_attention(cfg, ks[0]),
        "norm_x": layers.init_norm(cfg),
        "cross_attn": layers.init_attention(cfg, ks[1], cross=True),
        "norm2": layers.init_norm(cfg),
        "mlp": layers.init_mlp(cfg, ks[2]),
    }


def init(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.num_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    v, d = cfg.padded_vocab, cfg.d_model
    params: Params = {
        "encoder": jax.vmap(lambda k: _init_enc_layer(cfg, k))(enc_keys),
        "decoder": jax.vmap(lambda k: _init_dec_layer(cfg, k))(dec_keys),
        "enc_norm": layers.init_norm(cfg),
        "final_norm": layers.init_norm(cfg),
        "embed": (jax.random.normal(ks[2], (v, d), jnp.float32) * 0.02).astype(jnp.bfloat16),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(ks[3], (d, v), jnp.float32) / (d**0.5)
        ).astype(jnp.bfloat16)
    return params


def _maybe_scan(body, carry, xs, *, unroll, length):
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *ts: jnp.stack(ts), *ys)
    else:
        ys = None
    return carry, ys


def encode(cfg: ModelConfig, params: Params, frames: jax.Array, *, remat=False,
           unroll=False):
    """frames (B, S_enc, d) precomputed embeddings -> memory (B, S_enc, d)."""
    positions = jnp.arange(frames.shape[1])[None, :]

    def body(h, p):
        hn = layers.apply_norm(cfg, p["norm1"], h)
        h = h + layers.self_attention(cfg, p["attn"], hn, positions, causal=False)
        hn2 = layers.apply_norm(cfg, p["norm2"], h)
        h = h + layers.apply_mlp(cfg, p["mlp"], hn2)
        return h, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = _maybe_scan(body, frames.astype(jnp.bfloat16), params["encoder"],
                       unroll=unroll, length=cfg.num_encoder_layers)
    return layers.apply_norm(cfg, params["enc_norm"], h)


def _dec_layer_full(cfg, p, h, positions, memory):
    hn = layers.apply_norm(cfg, p["norm1"], h)
    h = h + layers.self_attention(cfg, p["self_attn"], hn, positions, causal=True)
    hx = layers.apply_norm(cfg, p["norm_x"], h)
    mkv = layers.memory_kv(cfg, p["cross_attn"], memory)
    h = h + layers.cross_attention(cfg, p["cross_attn"], hx, mkv)
    hn2 = layers.apply_norm(cfg, p["norm2"], h)
    h = h + layers.apply_mlp(cfg, p["mlp"], hn2)
    return h


def decode_full(cfg, params, tokens, memory, *, remat=False, unroll=False):
    """Teacher-forced decoder pass -> hidden states (B, S_dec, d)."""
    h = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(h, p):
        return _dec_layer_full(cfg, p, h, positions, memory), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = _maybe_scan(body, h, params["decoder"], unroll=unroll,
                       length=cfg.num_layers)
    return layers.apply_norm(cfg, params["final_norm"], h)


def loss_fn(cfg: ModelConfig, params: Params, batch, *, remat=True, unroll=False):
    """batch: frames (B,S_enc,d), tokens (B,S_dec), labels (B,S_dec)."""
    memory = encode(cfg, params, batch["frames"], remat=remat, unroll=unroll)
    h = decode_full(cfg, params, batch["tokens"], memory, remat=remat, unroll=unroll)
    return ce_loss(cfg, params, h, batch["labels"], unroll=unroll)


def logprobs_fn(cfg, params, tokens, frames, *, remat=False):
    memory = encode(cfg, params, frames, remat=remat)
    h = decode_full(cfg, params, tokens, memory, remat=remat)
    labels = tokens[:, 1:]
    lp, ent, _ = token_stats(cfg, params, h[:, :-1], labels)
    zero = jnp.zeros((tokens.shape[0], 1), lp.dtype)
    return jnp.concatenate([zero, lp], 1), jnp.concatenate([zero, ent], 1)


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #
def prefill(cfg: ModelConfig, params: Params, tokens, frames, *, smax: int,
            unroll=False):
    """Encode + teacher-forced decoder prompt pass; emits decode caches."""
    memory = encode(cfg, params, frames, unroll=unroll)
    B, S = tokens.shape
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    h = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(S)[None, :]

    def body(h, p):
        hn = layers.apply_norm(cfg, p["norm1"], h)
        q, k, v = layers.qkv_proj(cfg, p["self_attn"], hn, positions)
        o = ops.flash_attention(q, k, v, causal=True)
        h = h + layers.out_proj(cfg, p["self_attn"], o)
        kc = jnp.zeros((B, smax, kvh, hd), k.dtype)
        vc = jnp.zeros((B, smax, kvh, hd), v.dtype)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
        hx = layers.apply_norm(cfg, p["norm_x"], h)
        mk, mv = layers.memory_kv(cfg, p["cross_attn"], memory)
        h = h + layers.cross_attention(cfg, p["cross_attn"], hx, (mk, mv))
        hn2 = layers.apply_norm(cfg, p["norm2"], h)
        h = h + layers.apply_mlp(cfg, p["mlp"], hn2)
        return h, {"k": kc, "v": vc, "mk": mk, "mv": mv}

    h, caches = _maybe_scan(body, h, params["decoder"], unroll=unroll,
                            length=cfg.num_layers)
    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = mask_padded_vocab(cfg, (h[:, -1] @ _head_matrix(cfg, params)).astype(jnp.float32))
    return logits, caches, jnp.full((B,), S, jnp.int32)


def init_caches(cfg: ModelConfig, batch: int, smax: int):
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    L, se = cfg.num_layers, cfg.encoder_len
    return {
        "k": jnp.zeros((L, batch, smax, kvh, hd), jnp.bfloat16),
        "v": jnp.zeros((L, batch, smax, kvh, hd), jnp.bfloat16),
        "mk": jnp.zeros((L, batch, se, kvh, hd), jnp.bfloat16),
        "mv": jnp.zeros((L, batch, se, kvh, hd), jnp.bfloat16),
    }


def decode_step(cfg: ModelConfig, params: Params, token, caches, cache_len,
                unroll=False):
    """One decoder token vs self-attn cache + fixed cross-attn memory KV."""
    token = token.reshape(-1, 1)
    B = token.shape[0]
    h = embed_tokens(cfg, params, token)
    enc_valid = jnp.full((B,), cfg.encoder_len, jnp.int32)

    def body(h, xs):
        p, c = xs
        hn = layers.apply_norm(cfg, p["norm1"], h)
        out, (kc, vc) = layers.decode_self_attention(
            cfg, p["self_attn"], hn, (c["k"], c["v"]), cache_len
        )
        h = h + out[:, None]  # out (B, d) -> (B, 1, d)
        hx = layers.apply_norm(cfg, p["norm_x"], h)
        q = (hx @ p["cross_attn"]["w_q"])
        if cfg.use_bias:
            q = q + p["cross_attn"]["b_q"].astype(q.dtype)
        q = q.reshape(B, cfg.padded_heads, cfg.head_dim)
        o, _ = ops.decode_attention(q, c["mk"], c["mv"], enc_valid)
        h = h + layers.out_proj(cfg, p["cross_attn"], o)[:, None]
        hn2 = layers.apply_norm(cfg, p["norm2"], h)
        h = h + layers.apply_mlp(cfg, p["mlp"], hn2)
        return h, {"k": kc, "v": vc, "mk": c["mk"], "mv": c["mv"]}

    h, new_caches = _maybe_scan(body, h, (params["decoder"], caches),
                                unroll=unroll, length=cfg.num_layers)
    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = mask_padded_vocab(cfg, (h[:, 0] @ _head_matrix(cfg, params)).astype(jnp.float32))
    return logits, new_caches, cache_len + 1
