"""Mamba2 (SSD) mixer block.

Projections follow the Mamba2 layout: in_proj fans out to (z, x, B, C, dt);
a short depthwise causal conv over the (x, B, C) stream; the SSD core (Pallas
kernel on TPU); a gated RMSNorm; out_proj back to d_model.

TP sharding: x/z/dt/A/D/head-dims shard over `model` (nheads divisible by 16
for all assigned archs); the B/C stream (ngroups * d_state channels) is
replicated — it is tiny (<= 256 channels). The conv is split into conv_x
(sharded) and conv_bc (replicated) accordingly.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.constraint import constrain
from repro.kernels import ops

Params = Dict[str, Any]


def init_ssm(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    din, g, n, nh = cfg.ssm_d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    kw = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    scale = 1.0 / (d**0.5)

    def w(key_, shape, s=scale):
        return (jax.random.normal(key_, shape, jnp.float32) * s).astype(jnp.bfloat16)

    # dt bias initialized so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 init)
    dt = jnp.exp(
        jax.random.uniform(ks[6], (nh,), jnp.float32)
        * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus

    return {
        "w_z": w(ks[0], (d, din)),
        "w_x": w(ks[1], (d, din)),
        "w_B": w(ks[2], (d, g * n)),
        "w_C": w(ks[3], (d, g * n)),
        "w_dt": w(ks[4], (d, nh)),
        "conv_x": w(ks[5], (kw, din), s=1.0 / kw),
        "conv_bc": w(ks[7], (kw, 2 * g * n), s=1.0 / kw),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),  # A = -exp(A_log)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias,
        "norm_w": jnp.zeros((din,), jnp.float32),
        "w_out": w(jax.random.fold_in(key, 99), (din, d), s=1.0 / (din**0.5)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv. x (B,S,C); w (K,C); state (B,K-1,C) or None.

    Returns (y (B,S,C), new_state (B,K-1,C)).
    """
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, -(K - 1) :] if K > 1 else state
    return y.astype(x.dtype), new_state


def apply_ssm(
    cfg: ModelConfig,
    p: Params,
    u: jax.Array,  # (B, S, d)
    *,
    return_state: bool = False,
):
    """Full-sequence SSD mixer (train / prefill)."""
    B, S, _ = u.shape
    din, g, n, nh, hd = (
        cfg.ssm_d_inner,
        cfg.ssm_ngroups,
        cfg.ssm_state,
        cfg.ssm_nheads,
        cfg.ssm_headdim,
    )
    z = constrain(u @ p["w_z"], "dp", None, "tp")
    x = constrain(u @ p["w_x"], "dp", None, "tp")
    bc = jnp.concatenate([u @ p["w_B"], u @ p["w_C"]], axis=-1)
    dt = jax.nn.softplus(
        (u @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"][None, None, :]
    )
    dt = constrain(dt, "dp", None, "tp")

    x, conv_x_state = _causal_conv(x, p["conv_x"])
    bc, conv_bc_state = _causal_conv(bc, p["conv_bc"])
    x = jax.nn.silu(x)
    bc = jax.nn.silu(bc)
    Bm = bc[..., : g * n].reshape(B, S, g, n)
    Cm = bc[..., g * n :].reshape(B, S, g, n)

    xh = x.reshape(B, S, nh, hd)
    A = -jnp.exp(p["A_log"])
    y, h = ops.ssd(xh, dt, A, Bm, Cm, p["D"], return_state=True)
    y = y.reshape(B, S, din)

    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = ops.rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["w_out"]
    if return_state:
        return out, {"ssm": h, "conv_x": conv_x_state, "conv_bc": conv_bc_state}
    return out


def ssm_state_shapes(cfg: ModelConfig, batch: int):
    """ShapeDtypeStructs for one layer's decode state."""
    din, g, n, nh, hd = (
        cfg.ssm_d_inner,
        cfg.ssm_ngroups,
        cfg.ssm_state,
        cfg.ssm_nheads,
        cfg.ssm_headdim,
    )
    kw = cfg.ssm_conv
    return {
        "ssm": jax.ShapeDtypeStruct((batch, nh, hd, n), jnp.float32),
        "conv_x": jax.ShapeDtypeStruct((batch, kw - 1, din), jnp.bfloat16),
        "conv_bc": jax.ShapeDtypeStruct((batch, kw - 1, 2 * g * n), jnp.bfloat16),
    }


def apply_ssm_decode(
    cfg: ModelConfig, p: Params, u: jax.Array, state: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token SSD step. u (B, 1, d); state from prefill/init."""
    B = u.shape[0]
    din, g, n, nh, hd = (
        cfg.ssm_d_inner,
        cfg.ssm_ngroups,
        cfg.ssm_state,
        cfg.ssm_nheads,
        cfg.ssm_headdim,
    )
    z = u @ p["w_z"]
    x = u @ p["w_x"]
    bc = jnp.concatenate([u @ p["w_B"], u @ p["w_C"]], axis=-1)
    dt = jax.nn.softplus(
        (u @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"][None, None, :]
    )

    x, conv_x_state = _causal_conv(x, p["conv_x"], state["conv_x"])
    bc, conv_bc_state = _causal_conv(bc, p["conv_bc"], state["conv_bc"])
    x = jax.nn.silu(x)
    bc = jax.nn.silu(bc)
    Bm = bc[:, 0, : g * n].reshape(B, g, n)
    Cm = bc[:, 0, g * n :].reshape(B, g, n)

    xh = x[:, 0].reshape(B, nh, hd)
    A = -jnp.exp(p["A_log"])
    y, h = ops.ssd_decode_step(xh, dt[:, 0], A, Bm, Cm, p["D"], state["ssm"])
    y = y.reshape(B, 1, din)
    y = ops.rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["w_out"]
    return out, {"ssm": h, "conv_x": conv_x_state, "conv_bc": conv_bc_state}
