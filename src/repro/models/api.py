"""Unified model API over both backbones (decoder-only LM and enc-dec).

All higher layers (RL engines, launcher, dry-run, benchmarks) talk to
:class:`Model` only — family dispatch stays here.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, lm


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_encdec = cfg.is_encoder_decoder

    # ---- init ----
    def init(self, key) -> Dict[str, Any]:
        return (encdec.init if self.is_encdec else lm.init)(self.cfg, key)

    # ---- training ----
    def loss(self, params, batch, *, remat: bool = True, unroll: bool = False):
        """batch keys: tokens, labels [, prefix_embeds | frames]."""
        if self.is_encdec:
            return encdec.loss_fn(self.cfg, params, batch, remat=remat, unroll=unroll)
        return lm.loss_fn(self.cfg, params, batch, remat=remat, unroll=unroll)

    # ---- RL scoring ----
    def logprobs(self, params, tokens, *, prefix_embeds=None, frames=None,
                 remat: bool = False):
        if self.is_encdec:
            return encdec.logprobs_fn(self.cfg, params, tokens, frames, remat=remat)
        return lm.logprobs_fn(self.cfg, params, tokens,
                              prefix_embeds=prefix_embeds, remat=remat)

    # ---- serving ----
    def init_caches(self, batch: int, smax: int):
        if self.is_encdec:
            return encdec.init_caches(self.cfg, batch, smax)
        return lm.init_caches(self.cfg, batch, smax)

    def prefill(self, params, tokens, *, smax: int, prefix_embeds=None, frames=None,
                unroll: bool = False):
        if self.is_encdec:
            return encdec.prefill(self.cfg, params, tokens, frames, smax=smax,
                                  unroll=unroll)
        return lm.prefill(self.cfg, params, tokens, smax=smax,
                          prefix_embeds=prefix_embeds, unroll=unroll)

    def decode_step(self, params, token, caches, cache_len, *, unroll: bool = False):
        if self.is_encdec:
            return encdec.decode_step(self.cfg, params, token, caches, cache_len,
                                      unroll=unroll)
        return lm.decode_step(self.cfg, params, token, caches, cache_len,
                              unroll=unroll)

    def decode_step_sample(self, params, token, caches, cache_len, key,
                           temperature, *, top_p: float = 1.0,
                           unroll: bool = False):
        """Decode step with the sampler fused behind the kernel dispatch
        (LM only). Returns (token, behaviour logprob, caches, cache_len+1);
        the ref dispatch path is bitwise the unfused sequence."""
        if self.is_encdec:
            raise NotImplementedError("fused sampling is decoder-only")
        return lm.decode_step_sample(
            self.cfg, params, token, caches, cache_len, key, temperature,
            top_p=top_p, unroll=unroll)

    def decode_step_paged(self, params, token, pool, cache_len, page_tables,
                          *, write_enable=None, unroll: bool = False):
        """Paged decode step over a shared page pool (LM only)."""
        if self.is_encdec:
            raise NotImplementedError("paged decode is decoder-only")
        return lm.decode_step_paged(
            self.cfg, params, token, pool, cache_len, page_tables,
            write_enable=write_enable, unroll=unroll)

    def decode_step_paged_sample(self, params, token, pool, cache_len,
                                 page_tables, keys, temps, *,
                                 write_enable=None, unroll: bool = False):
        """Paged decode + fused per-row sampling (the serving burst step)."""
        if self.is_encdec:
            raise NotImplementedError("paged decode is decoder-only")
        return lm.decode_step_paged_sample(
            self.cfg, params, token, pool, cache_len, page_tables, keys,
            temps, write_enable=write_enable, unroll=unroll)

    # ---- continuous-batching rollout engine hooks (LM only) ----
    def prefill_chunk(self, params, tokens, caches, *, offset: int,
                      unroll: bool = False):
        """One chunk of a chunked prefill into existing caches (see
        ``lm.prefill_chunk``)."""
        if self.is_encdec:
            raise NotImplementedError(
                "chunked prefill is decoder-only; enc-dec prefill runs the "
                "encoder over the whole input"
            )
        return lm.prefill_chunk(self.cfg, params, tokens, caches,
                                offset=offset, unroll=unroll)

    def gather_cache_rows(self, caches, slots):
        return lm.gather_cache_rows(caches, slots)

    def scatter_cache_rows(self, caches, rows, slots):
        """Slot-reset: overwrite arena rows at ``slots`` with fresh rows."""
        return lm.scatter_cache_rows(caches, rows, slots)

    # ---- paged KV arena hooks (serving subsystem; attention caches only) --
    def gather_cache_pages(self, caches, slots, *, num_pages, page_size):
        """Page-granular gather: leaves (N, R, num_pages, page_size, ...)."""
        return lm.gather_cache_pages(caches, slots, num_pages=num_pages,
                                     page_size=page_size)

    def scatter_cache_pages(self, caches, pages, slots):
        """Write page stacks contiguously into arena rows at ``slots``."""
        return lm.scatter_cache_pages(caches, pages, slots)


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
