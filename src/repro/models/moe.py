"""Mixture-of-Experts MLP (mixtral / granite / jamba).

Expert weights are stacked (E, d, f) so a single einsum carries all experts —
the GSPMD-friendly dense token-choice formulation: every token is dispatched
to its top-k experts with a one-hot combine. On the production mesh, `f` is
TP-sharded over `model` ("expert tensor parallelism"; E = 8/16/40 are not
16-divisible, see DESIGN.md §6) and `E` is FSDP-sharded over `data` where
divisible.

Router uses fp32 logits + softmax-renormalized top-k gates (mixtral style).
An auxiliary load-balancing loss (Switch-style) is returned for training.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.constraint import constrain

Params = Dict[str, Any]


def init_moe(cfg: ModelConfig, key) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    scale = 1.0 / (d**0.5)
    gated = cfg.mlp_type in ("swiglu", "geglu")
    p: Params = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * scale),
        "w_in": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(jnp.bfloat16),
        "w_out": (jax.random.normal(ks[2], (e, f, d), jnp.float32) * (1.0 / f**0.5)).astype(jnp.bfloat16),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[3], (e, d, f), jnp.float32) * scale).astype(jnp.bfloat16)
    return p


def apply_moe(cfg: ModelConfig, p: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    xt = x.reshape(B * S, d)

    logits = (xt.astype(jnp.float32) @ p["router"])  # (T, E) fp32
    logits = constrain(logits, "dp", None)  # keep tokens batch-sharded
    gates, idx = jax.lax.top_k(logits, k)  # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)  # renormalize over the chosen k

    # combine weights (T, E): sum of one-hots scaled by gate
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (T, k, E)
    combine = jnp.einsum("tk,tke->te", gates, onehot)  # (T, E)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    density = jnp.mean(onehot.sum(axis=1), axis=0)  # fraction routed per expert
    p_mean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * p_mean)

    # dense dispatch: every expert sees all tokens, combine masks the output.
    # (capacity-free and exactly load-balanced across devices; the top-k
    # sparsity is recovered in FLOP accounting as 6*N_active*D — see roofline.)
    h = jnp.einsum("td,edf->etf", xt, p["w_in"])
    h = constrain(h, None, "dp", "tp")
    if "w_gate" in p:
        g = jnp.einsum("td,edf->etf", xt, p["w_gate"])
        g = constrain(g, None, "dp", "tp")
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = act * h
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h, approximate=True)
    # Fold the top-k gates into h BEFORE the output contraction:
    #   out_td = sum_e c_te sum_f h_etf W_efd = sum_{e,f} (c_te h_etf) W_efd
    # so the (E,T,d) per-expert outputs are never materialized and the TP
    # all-reduce shrinks from (E,T,d) to (T,d) — E x less wire (§Perf, cell B).
    h = h * jnp.swapaxes(combine, 0, 1)[:, :, None].astype(h.dtype)
    # bf16 output on the TP-reduced contraction: the (T,d) partial sums cross
    # the wire in bf16, not the f32 accumulator dtype (halves the all-reduce;
    # on TPU the MXU still accumulates in f32 internally)
    out = jnp.einsum("etf,efd->td", h, p["w_out"],
                     preferred_element_type=jnp.bfloat16)
    out = constrain(out, "dp", None)
    return out.reshape(B, S, d), aux


def apply_moe_topk_sparse(cfg: ModelConfig, p: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Gather-based sparse dispatch: only top-k experts' FLOPs per token.

    Used on small/serving paths (and CPU examples) where the (T,k) gather is
    cheaper than the dense all-experts einsum. Identical output to
    :func:`apply_moe` (tested).
    """
    B, S, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    xt = x.reshape(B * S, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    gates, idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gates, axis=-1)

    w_in = p["w_in"][idx]  # (T, k, d, f)
    w_out = p["w_out"][idx]  # (T, k, f, d)
    h = jnp.einsum("td,tkdf->tkf", xt, w_in)
    if "w_gate" in p:
        g = jnp.einsum("td,tkdf->tkf", xt, p["w_gate"][idx])
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = act * h
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h, approximate=True)
    y = jnp.einsum("tkf,tkfd->tkd", h, w_out)
    out = jnp.einsum("tkd,tk->td", y, gates.astype(y.dtype))

    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)
    density = jnp.mean(onehot.sum(axis=1), axis=0)
    aux = e * jnp.sum(density * jnp.mean(probs, axis=0))
    return out.reshape(B, S, d), aux
