"""Request admission + streaming: the host-side front half of the server.

A :class:`Request` is one user call: prompt tokens, a response budget, a
temperature, and the arrival timestamp the TTFT clock starts from. Submitting
it yields a :class:`RequestStream` immediately — token deltas are appended as
decode bursts flush, each tagged with the weight version that decoded it, so
a caller can stream partial output while the request is still in flight (and
an RL trainer can attribute every token to the policy version that produced
it, the per-token-version hook ROADMAP item 2 needs).

The :class:`AdmissionQueue` holds work that owns no KV yet (fresh requests)
or owns KV only as pooled pages (parked requests). Fresh requests are
length-bucketed — page-aligned widths, so every admission batch prefills
through the same per-chunk executables — and FIFO within a bucket. Across
the fresh buckets and the parked lane, ``pop_work`` serves whichever head
item has waited longest: oldest-head scheduling is starvation-free by
construction (a deferred bucket's head only grows older until it *is* the
oldest), unlike fullest-bucket-first, and keeps global service order close
to arrival order while still batching same-shape prefills.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request. ``seed`` drives the per-request sampling key
    stream (``fold_in(base_key, seed)`` then ``fold_in(, position)``) —
    positional keys make output tokens independent of slot placement,
    co-resident requests, and park/resume timing. Defaults to ``rid``."""

    rid: int
    prompt: np.ndarray  # (L,) true-length token ids (no padding)
    max_new: int
    temperature: float = 1.0
    arrival: float = 0.0
    seed: Optional[int] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).ravel()
        if len(self.prompt) == 0:
            raise ValueError("empty prompt")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")
        if self.seed is None:
            self.seed = self.rid


class RequestStream:
    """Per-request output stream: token deltas with flush timestamps and
    weight-version tags, plus the finished-response metrics."""

    def __init__(self, req: Request):
        self.request = req
        self.tokens: List[int] = []
        # (first_token_index, weight_version) segment starts; contiguous
        # tokens[start:next_start] were decoded under that version
        self.version_segments: List[Tuple[int, int]] = []
        self.token_times: List[float] = []  # flush time per token
        self.finished = False
        self.finish_reason = ""  # "eos" | "budget" | "rejected"
        self.matched_prefix_tokens = 0  # prefix-cache hit size at admission

    def append(self, toks, when: float, version: int) -> None:
        if toks is None or len(toks) == 0:
            return
        if (not self.version_segments
                or self.version_segments[-1][1] != version):
            self.version_segments.append((len(self.tokens), version))
        self.tokens.extend(int(t) for t in toks)
        self.token_times.extend([when] * len(toks))

    def finish(self, reason: str) -> None:
        self.finished = True
        self.finish_reason = reason

    # ---- metrics ------------------------------------------------------ #
    @property
    def ttft(self) -> Optional[float]:
        """Time to first token, from arrival to its flush."""
        if not self.token_times:
            return None
        return self.token_times[0] - self.request.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Mean per-output-token latency after the first token."""
        if len(self.token_times) < 2:
            return None
        return ((self.token_times[-1] - self.token_times[0])
                / (len(self.token_times) - 1))

    @property
    def weight_versions(self) -> List[int]:
        return [v for _, v in self.version_segments]


class _Parked:
    """A preempted request waiting to resume: its block table plus the
    device-free resume state (current token, lengths, budget left)."""

    __slots__ = ("req", "stream", "page_ids", "cache_len", "resp_len",
                 "cur_tok", "budget_left", "enqueued")

    def __init__(self, req, stream, page_ids, cache_len, resp_len, cur_tok,
                 budget_left, enqueued):
        self.req = req
        self.stream = stream
        self.page_ids = page_ids
        self.cache_len = int(cache_len)
        self.resp_len = int(resp_len)
        self.cur_tok = int(cur_tok)
        self.budget_left = int(budget_left)
        self.enqueued = enqueued


class AdmissionQueue:
    """Length-bucketed FIFO admission with an oldest-head service policy."""

    def __init__(self, *, bucket: int, max_len: int):
        if bucket < 1:
            raise ValueError(f"bucket must be >= 1, got {bucket}")
        self.bucket = bucket
        self.max_len = max_len
        self._fresh: Dict[int, Deque[Tuple[int, Request]]] = {}
        self._parked: Deque[Tuple[int, _Parked]] = deque()
        self._seq = itertools.count()  # global enqueue order (age proxy)
        self._neg = itertools.count(-1, -1)  # requeue ages: older than live

    def __len__(self) -> int:
        return (sum(len(q) for q in self._fresh.values())
                + len(self._parked))

    @property
    def num_parked(self) -> int:
        return len(self._parked)

    def bucket_len(self, prompt_len: int) -> int:
        return min(-(-prompt_len // self.bucket) * self.bucket, self.max_len)

    def push(self, req: Request) -> None:
        lb = self.bucket_len(len(req.prompt))
        self._fresh.setdefault(lb, deque()).append((next(self._seq), req))

    def push_parked(self, parked: _Parked) -> None:
        self._parked.append((next(self._seq), parked))

    def pop_work(self, n: int):
        """Up to ``n`` homogeneous items from the longest-waiting head:
        ``("parked", 0, [_Parked, ...])`` or ``("fresh", bucket_len,
        [Request, ...])``. Oldest head wins across all lanes, so neither
        parked resumes nor any fresh bucket can be deferred indefinitely;
        within a bucket, arrival (enqueue) order is preserved exactly."""
        best_key, best = None, None
        if self._parked:
            best_key, best = self._parked[0][0], "parked"
        for lb, q in self._fresh.items():
            if q and (best_key is None or q[0][0] < best_key):
                best_key, best = q[0][0], lb
        if best is None:
            raise IndexError("pop_work on an empty queue")
        if best == "parked":
            take = [self._parked.popleft()[1]
                    for _ in range(min(n, len(self._parked)))]
            return "parked", 0, take
        q = self._fresh[best]
        take = [q.popleft()[1] for _ in range(min(n, len(q)))]
        if not q:
            del self._fresh[best]
        return "fresh", best, take

    def pop_parked(self, n: int) -> List[_Parked]:
        """Up to ``n`` parked items out of age order — the page-stall escape
        hatch: a parked resume needs ZERO new pool pages (its KV already
        lives in pages it owns), so when fresh admission stalls on pool
        pages the engine drains parked work instead of deadlocking."""
        return [self._parked.popleft()[1]
                for _ in range(min(n, len(self._parked)))]

    def requeue(self, reqs: List[Request]) -> None:
        """Return popped-but-unadmitted fresh requests to the head of their
        buckets with priority preserved (negative ages sort older than any
        live enqueue) after an admission stall."""
        for r in reversed(reqs):
            lb = self.bucket_len(len(r.prompt))
            self._fresh.setdefault(lb, deque()).appendleft(
                (next(self._neg), r))


# --------------------------------------------------------------------------- #
# percentile helpers + synthetic workloads (shared by launch/serve.py and
# benchmarks/serving.py)
# --------------------------------------------------------------------------- #
def record_stream_latency(registry, stream: RequestStream) -> None:
    """Feed one finished stream's TTFT/TPOT into the ``serving/ttft_s`` and
    ``serving/tpot_s`` histograms of a :class:`repro.obs.MetricsRegistry`
    (the engine calls this at every stream finish when built with one).
    Rejected streams and missing values are skipped."""
    if registry is None or stream.finish_reason == "rejected":
        return
    ttft, tpot = stream.ttft, stream.tpot
    if ttft is not None:
        registry.histogram("serving/ttft_s").record(ttft)
    if tpot is not None:
        registry.histogram("serving/tpot_s").record(tpot)


def percentiles(values, ps=(50, 99)) -> Dict[str, float]:
    vals = [v for v in values if v is not None]
    if not vals:
        return {f"p{p}": 0.0 for p in ps}
    return {f"p{p}": float(np.percentile(vals, p)) for p in ps}


def synthetic_requests(
    n: int,
    *,
    arrival_rate: float,
    page_size: int,
    shared_prefix_pages: int = 2,
    num_prefixes: int = 2,
    shared_frac: float = 0.8,
    suffix_len: Tuple[int, int] = (4, 12),
    max_new: int = 64,
    budget_mix: Tuple[float, float] = (0.7, 0.9),
    temperature: float = 1.0,
    seed: int = 0,
) -> List[Request]:
    """A Poisson-arrival, shared-prefix-heavy request stream.

    ``shared_frac`` of requests open with one of ``num_prefixes`` fixed
    system prompts of ``shared_prefix_pages`` pages (the million-users-one-
    system-prompt shape); the rest are fully unique. Response budgets follow
    the skewed 70/20/10 short/medium/full mix of ``benchmarks/rollout.py``.
    """
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(3, 200, shared_prefix_pages * page_size)
                .astype(np.int32) for _ in range(num_prefixes)]
    t = 0.0
    out: List[Request] = []
    for rid in range(n):
        t += float(rng.exponential(1.0 / arrival_rate))
        suffix = rng.integers(
            3, 200, int(rng.integers(suffix_len[0], suffix_len[1] + 1))
        ).astype(np.int32)
        if rng.random() < shared_frac:
            prompt = np.concatenate(
                [prefixes[int(rng.integers(num_prefixes))], suffix])
        else:
            prompt = np.concatenate(
                [rng.integers(3, 200, shared_prefix_pages * page_size)
                 .astype(np.int32), suffix])
        u = rng.random()
        if u < budget_mix[0]:
            budget = int(rng.integers(4, 9))
        elif u < budget_mix[1]:
            budget = int(rng.integers(12, 21))
        else:
            budget = max_new
        out.append(Request(rid=rid, prompt=prompt, max_new=budget,
                           temperature=temperature, arrival=t))
    return out
