"""Paged KV arena: a block table over fixed-size KV pages.

The continuous rollout engine's arena is strictly contiguous: ``num_slots``
rows of width ``smax``, one live sequence per row, and a sequence's KV exists
only while it holds a slot. That couples *residency* to *compute*: the total
KV the system can hold is ``num_slots x smax`` tokens, a freed row's storage
is recycled only at row granularity, and nothing can stay resident without
occupying a decode lane.

This module decouples the two, vLLM-style. KV storage is a pool of
``num_pages`` fixed-size pages (``page_size`` tokens each); a logical
sequence is a *block table* — an ordered list of page ids — and pages go
back to the free list the moment their owner releases them. The serving
engine uses the pool for everything that must be resident but is not
decoding right now:

  * **parked sequences** — fair-share preemption saves an in-flight
    request's KV to pages and frees its slot; resuming scatters the pages
    back and decoding continues with zero recompute;
  * **shared-prefix cache entries** — committed prompt pages owned by the
    radix cache (``serving/prefix_cache.py``), refcounted and LRU-evicted.

Because the pool capacity is independent of the slot count, resident KV
(parked + cached + staged) can outgrow ``num_slots x max_len`` — the block
table, not the slot arena, is the system's memory ceiling.

Compute still runs on the contiguous slot rows: pages are staged into a
slot's rows before decode and gathered back out at page granularity
(``lm.gather_cache_pages`` / ``lm.scatter_cache_pages``, the page-granular
generalization of the row primitives). ROADMAP item 3's paged decode kernel
reads the block table directly and removes the staging copy; the block-table
bookkeeping here is already in its final shape.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.models import lm


class ArenaOutOfPages(RuntimeError):
    """Raised when an allocation cannot be satisfied even after eviction."""


class PagedKVArena:
    """Fixed-size page pool + free list + per-owner block tables.

    The pool's device layout reuses the model's own cache constructor:
    ``model.init_caches(num_pages, page_size)`` — each "batch row" of the
    cache tree IS one page. Attention-only archs (every leaf carries the
    token axis); the serving engine enforces that gate.
    """

    def __init__(self, model: Model, *, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError(
                f"need num_pages >= 1 and page_size >= 1, got "
                f"{num_pages}/{page_size}")
        self.model = model
        self.num_pages = num_pages
        self.page_size = page_size
        self.pool = model.init_caches(num_pages, page_size)
        self._free: List[int] = list(range(num_pages))
        # owner tag -> block table (ordered page ids); owners are opaque
        # host-side keys (request ids for parked sequences; the prefix cache
        # keeps its own tables and only borrows alloc/free)
        self.tables: Dict[object, List[int]] = {}
        self._store_jit: Dict[int, callable] = {}
        self._fetch_jit: Dict[int, callable] = {}

    # ------------------------------------------------------------------ #
    # free-list accounting
    # ------------------------------------------------------------------ #
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> List[int]:
        """Take ``n`` pages off the free list (raises ArenaOutOfPages)."""
        if n > len(self._free):
            raise ArenaOutOfPages(
                f"need {n} pages, {len(self._free)} free of {self.num_pages}")
        ids, self._free = self._free[:n], self._free[n:]
        return ids

    def free(self, ids: Sequence[int]) -> None:
        """Return pages to the free list — recycled immediately."""
        for i in ids:
            if not (0 <= i < self.num_pages):
                raise ValueError(f"page id {i} out of range")
        self._free.extend(ids)
        assert len(self._free) <= self.num_pages, "double free"

    # ------------------------------------------------------------------ #
    # block-table ownership (parked sequences)
    # ------------------------------------------------------------------ #
    def park(self, owner, page_ids: List[int]) -> None:
        assert owner not in self.tables, f"{owner!r} already parked"
        self.tables[owner] = list(page_ids)

    def unpark(self, owner) -> List[int]:
        return self.tables.pop(owner)

    # ------------------------------------------------------------------ #
    # device copies: slot rows <-> pool pages
    # ------------------------------------------------------------------ #
    def _store_fn(self, start: int, k: int):
        """jitted: copy pages [start, start+k) of one slot row into pool
        pages (static start/k — the gather width is a compile-time shape)."""
        fn = self._store_jit.get((start, k))
        if fn is None:
            model, ps = self.model, self.page_size

            def store(pool, caches, slot, ids):
                pages = model.gather_cache_pages(
                    caches, slot, num_pages=start + k, page_size=ps)
                pages = jax.tree.map(lambda pg: pg[:, :, start:], pages)
                return jax.tree.map(
                    lambda pl, pg: pl.at[:, ids].set(
                        pg.astype(pl.dtype), mode="drop"),
                    pool, pages)

            fn = self._store_jit[(start, k)] = jax.jit(store)
        return fn

    def _fetch_fn(self, k: int):
        """jitted: scatter k pooled pages per lane into slot rows [0, k*ps)."""
        fn = self._fetch_jit.get(k)
        if fn is None:
            model = self.model

            def fetch(pool, caches, slots, ids):
                pages = jax.tree.map(
                    lambda pl: jnp.take(pl, ids, axis=1), pool)
                return model.scatter_cache_pages(caches, pages, slots)

            fn = self._fetch_jit[k] = jax.jit(fetch)
        return fn

    def save_rows(self, caches, slots, page_tables,
                  start_page: int = 0):
        """Copy pages ``[start_page, start_page + k)`` of the given slot
        rows into the pool (page-granular gather -> pool write). ``slots``
        is one slot id with a flat page-id list, or a sequence of slots
        with a (R, k) table — all lanes copy in ONE dispatch (the
        copy-on-admit path batches a whole admission group this way).
        ``start_page > 0`` is the prefix-commit path: matched pages are
        cache-owned and shared, so only the newly prefilled tail pages are
        copied out."""
        if np.ndim(slots) == 0:
            slots, page_tables = [slots], [page_tables]
        tables = np.asarray(page_tables, np.int32)
        if tables.size == 0:
            return
        self.pool = self._store_fn(start_page, tables.shape[1])(
            self.pool, caches,
            jnp.asarray(np.asarray(slots, np.int32)),
            jnp.asarray(tables))

    def load_rows(self, caches, slots: Sequence[int], page_tables):
        """Scatter pooled pages into the arena rows at ``slots``: lane ``j``
        gets ``page_tables[j]`` written contiguously from position 0. All
        lanes must share a block-table length (the engine groups admissions
        by matched-page count). Returns the updated caches."""
        tables = np.asarray(page_tables, np.int32)
        if tables.ndim != 2:
            raise ValueError("page_tables must be (R, k)")
        k = tables.shape[1]
        if k == 0:
            return caches
        return self._fetch_fn(k)(
            self.pool, caches,
            jnp.asarray(np.asarray(slots, np.int32)),
            jnp.asarray(tables))
