"""Shared-prefix radix cache: a token trie over committed KV pages.

A production serve fleet sees millions of requests that open with the same
system prompt; prefilling that prefix once per *request* is pure waste. This
cache generalizes PR 5's KV-reuse primitive — "resume from your own
episode's saved cache rows" — to "resume from ANY request's matching
prefix": after a request's prompt is prefilled, its full KV pages are
committed into a radix tree keyed by the page's token span; a later request
walks the tree with its own prompt and reuses every matching page instead of
recomputing it.

Structure: one node per committed page. A node's edge label is the exact
``page_size``-token tuple the page covers, so a root-to-node path spells a
page-aligned token prefix and holds the page ids of its KV. Page alignment
is what keeps a hit bitwise-identical to a cold prefill: the serving engine
prefills in ``page_size`` chunks through the same compiled per-chunk
executable whether or not pages were matched, so a hit only ever *skips*
leading chunks whose cached output bytes are scattered in verbatim — the
remaining chunks see bit-identical inputs and produce bit-identical logits.

Lifetime: nodes are refcounted (``acquire`` pins a matched path for the
duration of the slot load; the tree itself holds no refcount) and evicted
LRU from the leaves — an interior node is never evicted before its
descendants, and a pinned node is never evicted at all. ``match`` never
returns the *whole* prompt even on a full match: the last token is always
left to compute so the engine has fresh last-position logits to sample the
first response token from (the same contract as a cold prefill).

The cache owns its pages' ids but not their storage — the
:class:`repro.serving.paged_arena.PagedKVArena` pool holds the bytes, and
eviction hands the freed ids back to the caller to return to the arena's
free list.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("key", "page_id", "parent", "children", "refcount",
                 "last_use")

    def __init__(self, key: Optional[Tuple[int, ...]], page_id: Optional[int],
                 parent: Optional["_Node"]):
        self.key = key  # page_size-token tuple (None at the root)
        self.page_id = page_id  # pool page id (None at the root)
        self.parent = parent
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.refcount = 0  # active pins (requests mid-load)
        self.last_use = 0  # LRU clock tick of the last match/insert touch


class RadixPrefixCache:
    """Token-trie over committed KV pages with refcounts + LRU eviction."""

    def __init__(self, *, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.root = _Node(None, None, None)
        self._clock = 0
        self.num_pages = 0  # committed pages currently held
        # counters surfaced in engine stats
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.evicted_pages = 0

    # ------------------------------------------------------------------ #
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _walk(self, tokens: Sequence[int], limit_pages: int) -> List[_Node]:
        """Longest stored page-aligned path matching ``tokens`` (<= limit)."""
        ps = self.page_size
        path: List[_Node] = []
        node = self.root
        for p in range(min(len(tokens) // ps, limit_pages)):
            key = tuple(int(t) for t in tokens[p * ps:(p + 1) * ps])
            nxt = node.children.get(key)
            if nxt is None:
                break
            path.append(nxt)
            node = nxt
        return path

    # ------------------------------------------------------------------ #
    def match(self, tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest stored page-aligned strict prefix of ``tokens``.

        Returns ``(matched_tokens, page_ids)``. The match is capped at
        ``(len(tokens) - 1) // page_size`` pages so at least one prompt
        token is always left to prefill (fresh last-position logits).
        Touches the matched path's LRU clocks; does NOT pin.
        """
        limit = max(0, (len(tokens) - 1)) // self.page_size
        path = self._walk(tokens, limit)
        t = self._tick()
        for n in path:
            n.last_use = t
        if path:
            self.hits += 1
            self.hit_tokens += len(path) * self.page_size
        else:
            self.misses += 1
        return len(path) * self.page_size, [n.page_id for n in path]

    def acquire(self, tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """:meth:`match` + pin the matched path (refcount += 1 per node).
        Callers must :meth:`release` with the same tokens/length once the
        pages have been staged into slot rows."""
        m, ids = self.match(tokens)
        for n in self._walk(tokens, m // self.page_size):
            n.refcount += 1
        return m, ids

    def release(self, tokens: Sequence[int], matched_tokens: int) -> None:
        """Unpin a previously acquired path (refcounts stay >= 0)."""
        for n in self._walk(tokens, matched_tokens // self.page_size):
            assert n.refcount > 0, "release without acquire"
            n.refcount -= 1

    # ------------------------------------------------------------------ #
    def insert(self, tokens: Sequence[int], make_page) -> int:
        """Commit every full page of ``tokens`` not already stored.

        ``make_page(page_index)`` is called for each missing page (in
        order) and must return the pool page id now holding that span's KV
        — the engine allocates from the arena and copies from the slot rows
        there. May raise (e.g. pool exhausted); already-attached nodes stay
        valid. Returns the number of newly committed pages.
        """
        ps = self.page_size
        t = self._tick()
        node = self.root
        added = 0
        for p in range(len(tokens) // ps):
            key = tuple(int(x) for x in tokens[p * ps:(p + 1) * ps])
            nxt = node.children.get(key)
            if nxt is None:
                nxt = _Node(key, make_page(p), node)
                node.children[key] = nxt
                self.num_pages += 1
                added += 1
            nxt.last_use = t
            node = nxt
        return added

    # ------------------------------------------------------------------ #
    def _leaves(self) -> List[_Node]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not self.root and not n.children:
                out.append(n)
        return out

    def evict(self, n_pages: int) -> List[int]:
        """Evict up to ``n_pages`` unpinned pages, LRU leaves first, and
        return their page ids for the caller to free. Evicting a leaf may
        expose its parent as the next-oldest leaf — the sweep repeats until
        satisfied or nothing evictable remains."""
        freed: List[int] = []
        while len(freed) < n_pages:
            candidates = [l for l in self._leaves() if l.refcount == 0]
            if not candidates:
                break
            victim = min(candidates, key=lambda l: l.last_use)
            del victim.parent.children[victim.key]
            freed.append(victim.page_id)
            self.num_pages -= 1
        self.evicted_pages += len(freed)
        return freed

    def clear(self) -> List[int]:
        """Drop every unpinned page (weight hot-swap invalidation: cached
        KV is weight-version-scoped — pages prefilled under version v must
        not seed a request decoded under v+1). Returns the freed ids."""
        return self.evict(self.num_pages)

    # introspection (tests / hypothesis properties) --------------------- #
    def _all_nodes(self) -> List[_Node]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not self.root:
                out.append(n)
        return out

    def check_invariants(self) -> None:
        nodes = self._all_nodes()
        assert len(nodes) == self.num_pages, "page count drifted"
        ids = [n.page_id for n in nodes]
        assert len(ids) == len(set(ids)), "duplicate page id in trie"
        for n in nodes:
            assert n.refcount >= 0, "negative refcount"
            assert n.key is not None and len(n.key) == self.page_size
