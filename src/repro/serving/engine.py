"""The streaming serving engine: slot pool + paged arena + prefix cache +
live weight hot-swap, behind a submit/step/serve host loop.

This is the continuous rollout engine's slot machinery promoted to a
request-streaming server. The rollout engine answers "generate this fixed
batch as fast as possible"; serving answers "requests arrive whenever they
arrive, stream tokens back as they decode, and never stop for a weight
update". Concretely, per scheduler visit (one ``step()``):

  1. **poll weights** — if the :class:`WeightVersionStore` has published a
     newer version, swap to it *between* decode bursts: in-flight requests
     keep their KV and simply continue under the new weights, and every
     flushed token delta is tagged with the version that decoded it (the
     prefix cache is cleared on swap — cached KV is version-scoped);
  2. **admit** — pop the longest-waiting work from the
     :class:`AdmissionQueue`: parked requests resume by pointing a free
     slot's block-table row back at the pages they never stopped owning
     (zero recompute, zero device copies); fresh requests are matched
     against the radix prefix cache, their cached pages are staged in, and
     only the uncached tail of the prompt is prefilled — in ``page_size``
     chunks through per-chunk compiled executables, so a cache hit is
     bitwise-identical to the cold prefill of the same request (the hit
     path *skips* leading chunks; it never recomputes them differently).
     The prefilled KV is then copied once into pool pages the request owns
     exclusively for its whole lifetime (copy-on-admit);
  3. **decode burst** — a jitted ``lax.while_loop`` stepping every slot up
     to ``decode_burst`` times, exiting early when any slot finishes (its
     KV pages and slot go straight back into circulation). Decode runs
     *directly on the page pool* through each slot's block-table row (the
     paged flash-decode kernel + fused per-row sampler behind
     ``model.decode_step_paged_sample``) — there is no page-staging copy
     and no separate slot KV arena. Sampling keys are per-request and
     per-position (``fold_in(fold_in(base, seed), position)``), so a
     request's tokens are independent of slot placement, co-resident
     traffic, and park/resume timing;
  4. **flush** — one bundled host sync; new tokens are appended to each
     request's :class:`RequestStream` with a timestamp (TTFT/TPOT) and the
     current weight version; finished slots free; under ``yield_quota``,
     long-running requests are parked to pages to let waiting arrivals in.

``docs/serving.md`` has the request lifecycle diagram, the page/block-table
semantics, and the metrics glossary.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServingConfig
from repro.models.api import Model
from repro.serving.paged_arena import ArenaOutOfPages, PagedKVArena
from repro.serving.prefix_cache import RadixPrefixCache
from repro.obs.trace import get_tracer
from repro.serving.scheduler import (
    AdmissionQueue,
    Request,
    RequestStream,
    _Parked,
    percentiles,
    record_stream_latency,
)


class _Active:
    """Host record of the request occupying a slot."""

    __slots__ = ("req", "stream", "flushed", "since_admit")

    def __init__(self, req: Request, stream: RequestStream,
                 flushed: int = 0):
        self.req = req
        self.stream = stream
        self.flushed = flushed  # out-row tokens already streamed
        self.since_admit = 0  # tokens decoded since (re)admission (quota)


def _row_sample(logits: jax.Array, keys: jax.Array,
                temp: jax.Array) -> jax.Array:
    """Per-row sampling: each lane uses its own key and temperature
    (temperature 0 = greedy). Row-wise independence is what makes a
    request's token stream invariant to its co-residents."""
    scaled = logits / jnp.maximum(temp, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temp <= 0.0, jnp.argmax(logits, axis=-1), sampled)


class ServingEngine:
    """Request-streaming server over one persistent slot arena."""

    def __init__(
        self,
        model: Model,
        scfg: ServingConfig,
        *,
        params=None,
        weight_store=None,
        eos_id: Optional[int] = None,
        pad_id: int = 0,
        key=None,
        clock=time.perf_counter,
        registry=None,
    ):
        kinds = model.cfg.layer_kinds()
        if (model.is_encdec or model.cfg.num_prefix_embeds
                or any(k[0] != "attn" for k in kinds)
                or model.cfg.sliding_window is not None
                or model.cfg.kv_quant):
            raise ValueError(
                "the serving engine needs page-addressable KV and chunked "
                "prefill: attention-only text decoders without SWA rings or "
                f"int8 caches ({model.cfg.name!r} doesn't qualify)"
            )
        if params is None:
            if weight_store is None or weight_store.current is None:
                raise ValueError("need params or a published weight store")
            params = weight_store.current.params
        self.model = model
        self.scfg = scfg
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.clock = clock
        # an obs.MetricsRegistry: every finished stream's TTFT/TPOT lands
        # in its serving/* histograms (None = keep stats() as the only view)
        self.registry = registry
        self.weight_store = weight_store
        self._params = params
        self._weight_version = (
            weight_store.version if weight_store is not None
            and weight_store.current is not None else 0)
        self._base_key = (jax.random.PRNGKey(0) if key is None else key)
        self._t0 = clock()

        S, W, ps = scfg.num_slots, scfg.max_len, scfg.page_size
        self.arena = PagedKVArena(model, num_pages=scfg.pool_pages,
                                  page_size=ps)
        self.prefix_cache = (RadixPrefixCache(page_size=ps)
                             if scfg.prefix_cache else None)
        self.queue = AdmissionQueue(bucket=ps, max_len=W)
        self.streams: Dict[int, RequestStream] = {}

        # device slot state ------------------------------------------------
        # No slot KV arena: decode attends the page pool directly through
        # per-slot block-table rows (T_max = max pages a request can span).
        self.T_max = W // ps
        self.tables_dev = jnp.zeros((S, self.T_max), jnp.int32)
        self.cur_tok = jnp.zeros((S,), jnp.int32)
        self.cache_len = jnp.zeros((S,), jnp.int32)
        self.resp_len = jnp.zeros((S,), jnp.int32)
        self.done = jnp.ones((S,), bool)  # every slot starts free
        self.budget = jnp.zeros((S,), jnp.int32)
        self.temp = jnp.zeros((S,), jnp.float32)
        self.slot_keys = jnp.zeros((S, 2), jnp.uint32)
        self.out_tok = jnp.full((S, scfg.max_new), pad_id, jnp.int32)

        # host slot state --------------------------------------------------
        self.active: List[Optional[_Active]] = [None] * S
        # pool pages each busy slot owns exclusively (admission -> finish;
        # parked requests keep theirs in the arena's park table meanwhile)
        self._slot_pages: List[List[int]] = [[] for _ in range(S)]

        # jit caches -------------------------------------------------------
        self._chunk_jit: Dict[tuple, callable] = {}
        self._admit_jit: Dict[int, callable] = {}
        self._burst = self._make_burst(S)

        # counters ---------------------------------------------------------
        self.total_tokens = 0
        self.decode_steps = 0
        self.active_lane_steps = 0
        self.bursts = 0
        self.parks = 0
        self.resumes = 0
        self.weight_swaps = 0
        self.prefill_chunks = 0
        self.prompt_tokens = 0

    # ------------------------------------------------------------------ #
    def now(self) -> float:
        return self.clock() - self._t0

    def reset_stats(self, *, clear_cache: bool = True) -> None:
        """Zero every counter, drop finished streams, and restart the wall
        clock. With ``clear_cache`` the prefix cache empties too (pages back
        to the pool). The jit caches survive — replaying the identical
        workload once, resetting, then timing the second pass is how the
        benchmark keeps compiles out of TTFT. Only valid when drained."""
        assert self.num_active == 0 and len(self.queue) == 0, \
            "reset_stats on a busy engine"
        if clear_cache and self.prefix_cache is not None:
            self.arena.free(self.prefix_cache.clear())
            self.prefix_cache.hits = self.prefix_cache.misses = 0
            self.prefix_cache.hit_tokens = self.prefix_cache.evicted_pages = 0
        self.streams.clear()
        self.total_tokens = self.decode_steps = self.active_lane_steps = 0
        self.bursts = self.parks = self.resumes = self.weight_swaps = 0
        self.prefill_chunks = self.prompt_tokens = 0
        self._t0 = self.clock()

    @property
    def weight_version(self) -> int:
        return self._weight_version

    @property
    def num_active(self) -> int:
        return sum(a is not None for a in self.active)

    # ------------------------------------------------------------------ #
    # jitted pieces
    # ------------------------------------------------------------------ #
    def _chunk_fn(self, R: int, off: int):
        """One page_size-wide prefill chunk at static offset ``off``. Keyed
        by (R, off) ONLY: a prefix-cache hit runs the exact executables the
        cold path ran for the same offsets — the bitwise-identity anchor."""
        fn = self._chunk_jit.get((R, off))
        if fn is None:
            model = self.model

            def chunk(params, tokens, rows):
                return model.prefill_chunk(params, tokens, rows, offset=off)

            fn = self._chunk_jit[(R, off)] = jax.jit(chunk)
        return fn

    def _admit_fn(self, R: int):
        """Admission epilogue: point the slots' block-table rows at the
        lanes' own pool pages, sample each lane's first token (per-request
        key, position 0), and seed the slot arrays. Out-of-range slot ids
        drop (pad lanes)."""
        fn = self._admit_jit.get(R)
        if fn is None:
            eos, pad = self.eos_id, self.pad_id
            W_out = self.scfg.max_new

            def admit(slots, logits, req_keys, lane_tables,
                      lane_len, lane_budget, lane_temp,
                      cur_tok, cache_len, resp_len, done, budget, temp,
                      slot_keys, tables_dev, out_tok):
                k0 = jax.vmap(lambda k: jax.random.fold_in(k, 0))(req_keys)
                tok0 = _row_sample(logits, k0, lane_temp)
                done0 = (tok0 == eos) if eos is not None else jnp.zeros(
                    (R,), bool)
                done0 = done0 | (lane_budget <= 1)
                row = jnp.full((R, W_out), pad, out_tok.dtype)
                row = row.at[:, 0].set(tok0)
                cur_tok = cur_tok.at[slots].set(tok0, mode="drop")
                cache_len = cache_len.at[slots].set(lane_len, mode="drop")
                resp_len = resp_len.at[slots].set(1, mode="drop")
                done = done.at[slots].set(done0, mode="drop")
                budget = budget.at[slots].set(lane_budget, mode="drop")
                temp = temp.at[slots].set(lane_temp, mode="drop")
                slot_keys = slot_keys.at[slots].set(req_keys, mode="drop")
                tables_dev = tables_dev.at[slots].set(
                    lane_tables, mode="drop")
                out_tok = out_tok.at[slots].set(row, mode="drop")
                return (cur_tok, cache_len, resp_len, done, budget,
                        temp, slot_keys, tables_dev, out_tok, tok0, done0)

            fn = self._admit_jit[R] = jax.jit(admit)
        return fn

    def _make_burst(self, S: int):
        """The decode loop: up to ``decode_burst`` steps over every slot,
        exiting early the moment any slot newly finishes (so its pages and
        lane recycle immediately) or everything is done.

        Decode runs straight on the page pool: the paged flash-decode
        kernel gathers each slot's K/V through its block-table row and the
        per-row sampler is fused behind the kernel dispatch
        (``model.decode_step_paged_sample``) — no page staging, no slot KV
        arena, no (S, vocab) logits round-trip in the Pallas modes.
        Retired lanes keep stepping until the loop exits; ``write_enable``
        routes their pool writes to a dropped out-of-range page so they
        cannot corrupt pages they no longer own."""
        model, eos, pad = self.model, self.eos_id, self.pad_id
        W_out, cap = self.scfg.max_new, self.scfg.decode_burst

        def burst(params, pool, tables, cur_tok, cache_len, resp_len, done,
                  budget, temp, slot_keys, out_tok):
            n_done_entry = jnp.sum(done)
            lane = jnp.arange(S)

            def cond(st):
                done, t = st[4], st[9]
                return (~jnp.all(done) & (t < cap)
                        & (jnp.sum(done) == n_done_entry))

            def body(st):
                (pool, cur_tok, cache_len, resp_len, done, budget,
                 temp, slot_keys, out_tok, t, occ) = st
                occ = occ + jnp.sum(~done)
                keys_t = jax.vmap(jax.random.fold_in)(slot_keys, resp_len)
                nxt, pool, cache_len = model.decode_step_paged_sample(
                    params, cur_tok, pool, cache_len, tables, keys_t, temp,
                    write_enable=~done)
                nxt = jnp.where(done, pad, nxt)
                wr = (~done) & (resp_len < W_out)
                idx = jnp.where(wr, resp_len, W_out)  # OOB -> dropped
                out_tok = out_tok.at[lane, idx].set(nxt, mode="drop")
                resp_len = resp_len + wr
                new_done = done
                if eos is not None:
                    new_done = new_done | ((~done) & (nxt == eos))
                new_done = new_done | (resp_len >= budget)
                return (pool, nxt, cache_len, resp_len, new_done, budget,
                        temp, slot_keys, out_tok, t + 1, occ)

            st = (pool, cur_tok, cache_len, resp_len, done, budget,
                  temp, slot_keys, out_tok, jnp.zeros((), jnp.int32),
                  jnp.zeros((), jnp.int32))
            return jax.lax.while_loop(cond, body, st)

        return jax.jit(burst)

    # ------------------------------------------------------------------ #
    # page bookkeeping helpers
    # ------------------------------------------------------------------ #
    def _alloc_pages(self, n: int) -> List[int]:
        """Allocate from the arena, evicting LRU prefix-cache pages under
        pressure (freed pages recycle immediately)."""
        try:
            return self.arena.alloc(n)
        except ArenaOutOfPages:
            if self.prefix_cache is not None:
                need = n - self.arena.num_free
                self.arena.free(self.prefix_cache.evict(need))
            return self.arena.alloc(n)  # may still raise: pool truly full

    def _commit_prompt_pages(self, rows, lane: int, prompt: np.ndarray,
                             matched: int) -> None:
        """Commit the prompt's uncached full pages (beyond the ``matched``
        prefix) into the radix cache, copying their KV out of the lane's
        freshly prefilled admission rows. Pool pressure stops the commit
        early — serving never fails because the cache is full."""
        ps = self.scfg.page_size
        n_full = len(prompt) // ps
        if self.prefix_cache is None or n_full * ps <= matched:
            return
        new_pages: List[tuple] = []

        def make_page(p: int) -> int:
            (pid,) = self._alloc_pages(1)
            new_pages.append((p, pid))
            return pid

        try:
            self.prefix_cache.insert(prompt[: n_full * ps], make_page)
        except ArenaOutOfPages:
            pass  # partial commit: attached nodes all have ids in new_pages
        if new_pages:
            start = new_pages[0][0]
            ids = [pid for _, pid in new_pages]
            self.arena.save_rows(rows, lane, ids, start_page=start)

    # ------------------------------------------------------------------ #
    # admission
    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> RequestStream:
        """Enqueue a request; returns its stream immediately."""
        stream = RequestStream(req)
        self.streams[req.rid] = stream
        if len(req.prompt) > self.scfg.max_len - 1:
            stream.finish("rejected")
            return stream
        self.queue.push(req)
        return stream

    def _admit_fresh(self, reqs: List[Request], lb: int,
                     lanes: List[int]) -> bool:
        """Admit one length bucket of fresh requests: prefix-match each,
        then prefill sub-groups that share a matched length (identical
        chunk schedules) as one padded lane batch.

        Copy-on-admit page ownership: BEFORE prefilling, each lane
        allocates every pool page its lifetime can touch
        (``ceil((lb + budget - 1) / page_size)``) and owns them exclusively
        until finish. The prefilled KV — matched prefix included — is
        copied into them once, so prefix pins release immediately and
        park/resume later needs no copies and no allocations. Returns
        False on a pool-page stall (the stalled group and every
        not-yet-admitted group return to the queue head; the caller falls
        back to parked work, which needs zero new pages)."""
        ps, S, W = self.scfg.page_size, self.scfg.num_slots, self.scfg.max_len
        groups: Dict[int, List[Request]] = {}
        matches: Dict[int, tuple] = {}
        for r in reqs:
            if self.prefix_cache is not None:
                m, ids = self.prefix_cache.acquire(r.prompt)
            else:
                m, ids = 0, []
            matches[r.rid] = (m, ids)
            groups.setdefault(m, []).append(r)

        pending = list(groups.items())
        for gi, (m, group) in enumerate(pending):
            n = len(group)
            budgets = [min(r.max_new, self.scfg.max_new, W - lb)
                       for r in group]
            n_own = [-(-(lb + b - 1) // ps) for b in budgets]
            try:
                flat = self._alloc_pages(sum(n_own))
            except ArenaOutOfPages:
                stalled: List[Request] = []
                for m2, g2 in pending[gi:]:
                    for r in g2:
                        if self.prefix_cache is not None:
                            self.prefix_cache.release(r.prompt, m2)
                        stalled.append(r)
                self.queue.requeue(stalled)
                return False
            own = []
            for k in n_own:
                own.append(flat[:k])
                flat = flat[k:]

            R = 1
            while R < n:
                R *= 2
            R = min(R, S)
            gl, lanes = lanes[:n], lanes[n:]
            slots_arr = jnp.asarray(
                np.concatenate([gl, np.full(R - n, S)]).astype(np.int32))
            batch = np.zeros((R, lb), np.int32)
            lane_budget = np.full(R, 1, np.int32)
            lane_temp = np.zeros(R, np.float32)
            lane_tables = np.zeros((R, self.T_max), np.int32)
            for j, r in enumerate(group):
                batch[j, : len(r.prompt)] = r.prompt
                lane_budget[j] = budgets[j]
                lane_temp[j] = r.temperature
                lane_tables[j, : n_own[j]] = own[j]
                self.prompt_tokens += len(r.prompt)
                self.streams[r.rid].matched_prefix_tokens = m
            req_keys = jnp.stack(
                [jax.random.fold_in(self._base_key, group[j].seed)
                 if j < n else self._base_key for j in range(R)])

            rows = self.model.init_caches(R, W)
            if m:
                tables = np.stack(
                    [matches[r.rid][1] for r in group]
                    + [matches[group[0].rid][1]] * (R - n))
                rows = self.arena.load_rows(rows, np.arange(R), tables)
            logits = None
            with get_tracer().span("serving/prefill", cat="serving",
                                   lanes=R, bucket=lb, matched=m):
                for off in range(m, lb, ps):
                    logits, rows = self._chunk_fn(R, off)(
                        self._params, jnp.asarray(batch[:, off:off + ps]),
                        rows)
                    self.prefill_chunks += 1
            # copy every lane's prefilled span into its own pool pages in
            # one dispatch — from here the requests' KV lives ONLY in the
            # pool (the admission rows are scratch) and decode writes
            # continue at page lb/ps, offset 0 (lb is page-aligned by the
            # bucketing; all lanes share it, so the copy is rectangular)
            self.arena.save_rows(
                rows, np.arange(n), [own[j][: lb // ps] for j in range(n)])
            (self.cur_tok, self.cache_len, self.resp_len,
             self.done, self.budget, self.temp, self.slot_keys,
             self.tables_dev, self.out_tok, tok0, done0) = self._admit_fn(R)(
                slots_arr, logits, req_keys, jnp.asarray(lane_tables),
                jnp.full((R,), lb, jnp.int32),
                jnp.asarray(lane_budget), jnp.asarray(lane_temp),
                self.cur_tok, self.cache_len, self.resp_len, self.done,
                self.budget, self.temp, self.slot_keys, self.tables_dev,
                self.out_tok)

            tok0_h, done0_h = jax.device_get((tok0, done0))
            when = self.now()
            for j, r in enumerate(group):
                st = self.streams[r.rid]
                st.append([tok0_h[j]], when, self._weight_version)
                self.total_tokens += 1
                self.active[gl[j]] = _Active(r, st, flushed=1)
                self._slot_pages[gl[j]] = own[j]
                self._commit_prompt_pages(rows, j, r.prompt, m)
                if self.prefix_cache is not None:
                    self.prefix_cache.release(r.prompt, m)
                if done0_h[j]:
                    reason = ("eos" if self.eos_id is not None
                              and tok0_h[j] == self.eos_id else "budget")
                    st.finish(reason)
                    record_stream_latency(self.registry, st)
                    self.active[gl[j]] = None
                    self.arena.free(self._slot_pages[gl[j]])
                    self._slot_pages[gl[j]] = []
        return True

    def _resume_parked(self, items: List[_Parked], lanes: List[int]) -> None:
        """Resume parked requests: metadata only. The request's KV never
        left its own pool pages, so resuming is pointing a free slot's
        block-table row back at them and restoring the device scalars —
        zero recompute, zero device copies, zero new pages."""
        for p, slot in zip(items, lanes):
            ids = self.arena.unpark(p.req.rid)
            self._slot_pages[slot] = ids
            row = np.zeros(self.T_max, np.int32)
            row[: len(ids)] = ids
            self.tables_dev = self.tables_dev.at[slot].set(
                jnp.asarray(row, jnp.int32))
            req_key = jax.random.fold_in(self._base_key, p.req.seed)
            s = jnp.asarray([slot], jnp.int32)
            self.cur_tok = self.cur_tok.at[s].set(p.cur_tok)
            self.cache_len = self.cache_len.at[s].set(p.cache_len)
            self.resp_len = self.resp_len.at[s].set(p.resp_len)
            self.done = self.done.at[s].set(False)
            self.budget = self.budget.at[s].set(
                p.resp_len + p.budget_left)
            self.temp = self.temp.at[s].set(p.req.temperature)
            self.slot_keys = self.slot_keys.at[s].set(req_key[None])
            self.active[slot] = _Active(p.req, p.stream, flushed=p.resp_len)
            self.resumes += 1

    def _admit(self) -> None:
        with get_tracer().span("serving/admit", cat="serving",
                               queued=len(self.queue)):
            self._admit_inner()

    def _admit_inner(self) -> None:
        stalled = False
        while len(self.queue):
            # recompute each round: immediately-done admissions (EOS or a
            # one-token budget on the first sample) free their lane again
            free = [s for s in range(self.scfg.num_slots)
                    if self.active[s] is None]
            if not free:
                return
            if stalled:
                # fresh admission ran out of pool pages this visit; only
                # parked work (which already owns its pages) can still
                # come in. Finishing it returns pages, unsticking fresh
                # admission on the next visit.
                items = self.queue.pop_parked(len(free))
                if not items:
                    if self.num_active == 0:
                        raise ArenaOutOfPages(
                            "admission stalled on an idle engine: the pool "
                            "cannot hold one request's pages even after "
                            "evicting the prefix cache (raise "
                            "ServingConfig.pool_pages)")
                    return
                self._resume_parked(items, free[: len(items)])
                continue
            kind, lb, items = self.queue.pop_work(len(free))
            if kind == "parked":
                self._resume_parked(items, free[: len(items)])
            else:
                stalled = not self._admit_fresh(items, lb, free[: len(items)])

    # ------------------------------------------------------------------ #
    # the scheduler visit
    # ------------------------------------------------------------------ #
    def poll_weights(self) -> bool:
        """Hot-swap to the newest published weights (between bursts; never
        drops in-flight requests). Clears the prefix cache: cached KV is
        scoped to the weight version that prefilled it."""
        if (self.weight_store is None or not self.scfg.poll_weights
                or self.weight_store.current is None
                or self.weight_store.version <= self._weight_version):
            return False
        self._params = self.weight_store.current.params
        self._weight_version = self.weight_store.version
        self.weight_swaps += 1
        if self.prefix_cache is not None:
            self.arena.free(self.prefix_cache.clear())
        return True

    def _flush(self) -> None:
        """One bundled host sync: stream new tokens, retire finished slots,
        park over-quota slots when arrivals are waiting."""
        done_h, resp_h, out_h, cur_h, clen_h, budget_h = jax.device_get(
            (self.done, self.resp_len, self.out_tok, self.cur_tok,
             self.cache_len, self.budget))
        when = self.now()
        quota = self.scfg.yield_quota
        fresh_waiting = len(self.queue) - self.queue.num_parked
        for s in range(self.scfg.num_slots):
            a = self.active[s]
            if a is None:
                continue
            n = int(resp_h[s])
            new = out_h[s, a.flushed: n]
            a.stream.append(new, when, self._weight_version)
            self.total_tokens += len(new)
            a.since_admit += len(new)
            a.flushed = n
            if done_h[s]:
                last = a.stream.tokens[-1] if a.stream.tokens else None
                reason = ("eos" if self.eos_id is not None
                          and last == self.eos_id else "budget")
                a.stream.finish(reason)
                record_stream_latency(self.registry, a.stream)
                self.active[s] = None
                if self._slot_pages[s]:
                    self.arena.free(self._slot_pages[s])
                    self._slot_pages[s] = []
            elif quota and fresh_waiting > 0 and a.since_admit >= quota:
                self._park(s, a, cur_h[s], clen_h[s], n, int(budget_h[s]))
                fresh_waiting -= 1

    def _park(self, slot: int, a: _Active, cur_tok: int, cache_len: int,
              resp_len: int, budget: int) -> None:
        """Fair-share preemption, metadata only: the slot's KV already
        lives in pool pages the request owns, so parking hands those pages
        to the arena's park table and frees the lane. No allocation, no
        copy — parking cannot fail."""
        ids = self._slot_pages[slot]
        self._slot_pages[slot] = []
        self.arena.park(a.req.rid, ids)
        self.queue.push_parked(_Parked(
            a.req, a.stream, ids, cache_len, resp_len, cur_tok,
            budget - resp_len, self.now()))
        self.done = self.done.at[slot].set(True)
        self.active[slot] = None
        self.parks += 1
        get_tracer().instant("serving/park", cat="serving",
                             rid=a.req.rid, resp_len=resp_len)

    def step(self) -> bool:
        """One scheduler visit: poll weights, admit, decode, flush.
        Returns True while any work remains (active or queued)."""
        self.poll_weights()
        self._admit()
        if self.num_active:
            with get_tracer().span("serving/burst", cat="serving",
                                   active=self.num_active):
                (self.arena.pool, self.cur_tok, self.cache_len,
                 self.resp_len, self.done, self.budget, self.temp,
                 self.slot_keys, self.out_tok, t, occ) = self._burst(
                    self._params, self.arena.pool, self.tables_dev,
                    self.cur_tok, self.cache_len, self.resp_len, self.done,
                    self.budget, self.temp, self.slot_keys, self.out_tok)
                self.bursts += 1
                self.decode_steps += int(jax.device_get(t))
                self.active_lane_steps += int(jax.device_get(occ))
            with get_tracer().span("serving/flush", cat="serving"):
                self._flush()
        return bool(self.num_active or len(self.queue))

    def serve(self, requests: List[Request], *,
              realtime: bool = True) -> List[RequestStream]:
        """Drive a whole request stream to completion. ``requests`` carry
        arrival offsets (seconds from call time); with ``realtime`` the
        engine waits for arrivals, otherwise everything is enqueued up
        front (max-pressure replay, arrival stamps kept for TTFT)."""
        t_in = self.now()
        pending = sorted(requests, key=lambda r: r.arrival)
        for r in pending:
            r.arrival += t_in
        streams = [self.streams.get(r.rid) for r in pending]
        i = 0
        while i < len(pending) or self.num_active or len(self.queue):
            while i < len(pending) and (
                    not realtime or pending[i].arrival <= self.now()):
                streams[i] = self.submit(pending[i])
                i += 1
            if not self.step() and i < len(pending) and realtime:
                time.sleep(
                    max(0.0, min(pending[i].arrival - self.now(), 0.01)))
        return [self.streams[r.rid] for r in requests]

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, float]:
        """Aggregate serving metrics over everything streamed so far."""
        finished = [s for s in self.streams.values() if s.finished
                    and s.finish_reason != "rejected"]
        ttft = percentiles([s.ttft for s in finished])
        tpot = percentiles([s.tpot for s in finished])
        wall = self.now()
        lane_steps = self.scfg.num_slots * self.decode_steps
        hit_tokens = (self.prefix_cache.hit_tokens
                      if self.prefix_cache else 0)
        return {
            "requests_finished": float(len(finished)),
            "tokens": float(self.total_tokens),
            "wall_s": wall,
            "goodput_tokens_per_s": self.total_tokens / wall if wall else 0.0,
            "ttft_p50_s": ttft["p50"],
            "ttft_p99_s": ttft["p99"],
            "tpot_p50_s": tpot["p50"],
            "tpot_p99_s": tpot["p99"],
            "prefix_hit_tokens": float(hit_tokens),
            "prompt_tokens": float(self.prompt_tokens),
            "prefix_hit_rate": (hit_tokens / self.prompt_tokens
                                if self.prompt_tokens else 0.0),
            "prefill_chunks": float(self.prefill_chunks),
            "decode_steps": float(self.decode_steps),
            "bursts": float(self.bursts),
            "slot_occupancy": (self.active_lane_steps / lane_steps
                               if lane_steps else 0.0),
            "parks": float(self.parks),
            "resumes": float(self.resumes),
            "weight_swaps": float(self.weight_swaps),
            "cached_pages": float(self.prefix_cache.num_pages
                                  if self.prefix_cache else 0),
            "pool_pages_used": float(self.arena.num_used),
            "weight_version": float(self._weight_version),
        }
