"""Request-streaming serving subsystem (see docs/serving.md).

Promotes the continuous rollout engine's slot machinery to a server:
requests arrive whenever they arrive, stream token deltas back as they
decode, share prompt KV through a radix prefix cache over a paged arena,
and keep decoding across live weight hot-swaps.
"""
from repro.serving.engine import ServingEngine
from repro.serving.paged_arena import ArenaOutOfPages, PagedKVArena
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.scheduler import (
    AdmissionQueue,
    Request,
    RequestStream,
    percentiles,
    synthetic_requests,
)

__all__ = [
    "ServingEngine",
    "ArenaOutOfPages",
    "PagedKVArena",
    "RadixPrefixCache",
    "AdmissionQueue",
    "Request",
    "RequestStream",
    "percentiles",
    "synthetic_requests",
]
