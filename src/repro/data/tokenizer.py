"""Toy byte tokenizer: vocab = 256 raw bytes + BOS/EOS/PAD specials."""
from __future__ import annotations

from typing import List

import numpy as np


class ByteTokenizer:
    PAD, BOS, EOS = 0, 1, 2
    SPECIALS = 3

    def __init__(self):
        self.vocab_size = 256 + self.SPECIALS
        self.pad_id, self.bos_id, self.eos_id = self.PAD, self.BOS, self.EOS

    def encode(self, text: str, *, bos: bool = False, eos: bool = False) -> np.ndarray:
        ids = [b + self.SPECIALS for b in text.encode("utf-8")]
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        out = bytearray()
        for i in np.asarray(ids).tolist():
            if i == self.EOS:
                break
            # skip non-byte ids: specials other than EOS, and ids a model
            # with vocab_size > 259 may sample from its padded tail
            if self.SPECIALS <= i < 256 + self.SPECIALS:
                out.append(i - self.SPECIALS)
        return out.decode("utf-8", errors="replace")

    def decode_batch(self, ids) -> List[str]:
        return [self.decode(row) for row in np.asarray(ids)]
