"""Datasets. Index-addressable and deterministic: sample i is a pure function
of (seed, i), so any worker can materialize exactly its own rows — the
property the Distributed Dataloader (paper §6.1) relies on."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.data.tokenizer import ByteTokenizer


class SyntheticMathDataset:
    """Fixed-length '<aa>+<bb>=' prompts with integer answers (the function-
    reward task standing in for DeepScaleR in the paper's experiments)."""

    PROMPT_LEN = 6  # "aa+bb="

    def __init__(self, size: int, *, seed: int = 0, max_operand: int = 99):
        self.size = size
        self.seed = seed
        self.max_operand = max_operand
        self.tok = ByteTokenizer()

    def __len__(self) -> int:
        return self.size

    def get_rows(self, idx: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Materialize ONLY the requested rows: (prompts (n, Lp), answers (n,))."""
        idx = np.asarray(idx, np.int64)
        rng_a = ((self.seed * 1_000_003 + idx) * 2654435761) % (self.max_operand + 1)
        rng_b = ((self.seed * 998_244_353 + idx) * 40503) % (self.max_operand + 1)
        prompts = np.zeros((len(idx), self.PROMPT_LEN), np.int32)
        for row, (a, b) in enumerate(zip(rng_a, rng_b)):
            prompts[row] = self.tok.encode(f"{a:02d}+{b:02d}=")
        return prompts, (rng_a + rng_b).astype(np.int32)


class SyntheticTextDataset:
    """Deterministic token streams for supervised / throughput workloads."""

    def __init__(self, size: int, seq_len: int, vocab: int, *, seed: int = 0):
        self.size, self.seq_len, self.vocab, self.seed = size, seq_len, vocab, seed

    def __len__(self):
        return self.size

    def get_rows(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx, np.int64)
        out = np.zeros((len(idx), self.seq_len), np.int32)
        for row, i in enumerate(idx):
            rng = np.random.default_rng(self.seed * 7_777_777 + int(i))
            out[row] = rng.integers(3, self.vocab, size=self.seq_len)
        return out
