"""Distributed Dataloader (paper §6.1, Fig. 6).

Decentralized initial data loading: the dataset is partitioned by the rollout
stage's DP layout and **each worker materializes only its own partition** —
no node ever holds the global dataset. Concretely, batches are built with
``jax.make_array_from_callback``: the callback is invoked per local device
with that device's index slice, and only those dataset rows are generated /
read. A deterministic epoch-seeded permutation gives the global shuffle
without any coordination (every worker derives the identical permutation from
(seed, epoch)).

Rows-loaded accounting proves the Fig. 6 property in tests: with DP=2 over
512 samples, the dp-rank-0 group touches rows 0-255 only.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class DistributedDataloader:
    def __init__(
        self,
        dataset,
        *,
        mesh: Mesh,
        global_batch: int,
        dp_spec: P = P(("data",)),
        seed: int = 0,
        prefetch: int = 0,
    ):
        self.dataset = dataset
        self.mesh = mesh
        self.global_batch = global_batch
        self.dp_spec = dp_spec
        self.seed = seed
        self.step = 0
        self.rows_loaded = 0  # local accounting (tests / Fig. 6 property)
        self._excluded: set = set()  # straggler mitigation (ft.straggler)
        # look-ahead depth (paper §6.2 double buffering on the load side):
        # with prefetch=k, batch for step s+k is materialized — its rows read
        # and its device_put dispatched — while the consumer computes step s.
        self.prefetch = prefetch
        self._built_step = 0  # next step a build will materialize
        self._ready: Deque[Dict[str, jax.Array]] = deque()
        self.prefetch_hits = 0  # batches served from the look-ahead queue

    # ------------------------------------------------------------------ #
    def _epoch_perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(len(self.dataset))

    def batch_indices(self, step: Optional[int] = None) -> np.ndarray:
        step = self.step if step is None else step
        bs = self.global_batch
        steps_per_epoch = max(len(self.dataset) // bs, 1)
        epoch, within = divmod(step, steps_per_epoch)
        perm = self._epoch_perm(epoch)
        lo = (within * bs) % max(len(self.dataset) - bs + 1, 1)
        return perm[lo : lo + bs]

    # ------------------------------------------------------------------ #
    def next_batch(self) -> Dict[str, jax.Array]:
        """Return the batch for the current step, then advance. With
        ``prefetch > 0`` the returned batch was (except on the very first
        call) already materialized during an earlier call; the batches for
        the next ``prefetch`` steps are dispatched before returning, so host
        row-loading and device transfers overlap the consumer's compute.
        Batch CONTENT is a pure function of the step index, so prefetch depth
        never changes what is returned — only when it is built."""
        if self.prefetch <= 0:
            batch = self._build_batch(self.step)
            self.step += 1
            return batch
        served_from_queue = bool(self._ready)
        while self._built_step <= self.step + self.prefetch:
            self._ready.append(self._build_batch(self._built_step))
            self._built_step += 1
        batch = self._ready.popleft()
        if served_from_queue:
            self.prefetch_hits += 1
        self.step += 1
        return batch

    def _build_batch(self, step: int) -> Dict[str, jax.Array]:
        """Build the global batch for ``step`` as sharded jax.Arrays, loading
        only the locally-needed partitions."""
        idx = self.batch_indices(step)
        rows = self.dataset.get_rows(idx)
        if isinstance(rows, tuple):
            prompts, answers = rows
            return {
                "prompts": self._shard(prompts, self.dp_spec),
                "answers": self._shard(answers, P(self.dp_spec[0])),
            }
        return {"tokens": self._shard(rows, self.dp_spec)}

    def make_sharded(
        self, global_shape, dtype, dp_spec: P, row_loader: Callable[[np.ndarray], np.ndarray]
    ) -> jax.Array:
        """The decentralized materialization primitive: ``row_loader`` is
        called with ONLY the row indices a given device owns."""
        sharding = NamedSharding(self.mesh, dp_spec)

        def cb(index) -> np.ndarray:
            rows = np.arange(*index[0].indices(global_shape[0]))
            self.rows_loaded += len(rows)
            data = row_loader(rows)
            tail = tuple(sl for sl in index[1:])
            return data[(slice(None),) + tail] if tail else data

        return jax.make_array_from_callback(tuple(global_shape), sharding, cb)

    def _shard(self, host_rows: np.ndarray, spec: P) -> jax.Array:
        """Used when rows were already materialized host-side (small CPU runs);
        large-scale path should prefer make_sharded."""
        self.rows_loaded += len(host_rows)
        return jax.device_put(host_rows, NamedSharding(self.mesh, spec))

    # ------------------------------------------------------------------ #
    # straggler mitigation hook (ft/straggler.py): re-partition the epoch
    # permutation away from excluded (slow/dead) dp ranks.
    def exclude_ranks(self, ranks) -> None:
        self._excluded.update(ranks)
