# Pallas TPU kernels for the compute hot-spots of the RL loop:
#   flash_attention  - train/prefill attention (causal + SWA + GQA)
#   decode_attention - flash-decode with shard-combinable (o, lse)
#   ssd              - Mamba2 state-space-dual chunked scan
#   rmsnorm          - fused norm
# ops.py dispatches per backend (Pallas on TPU / interpret in tests /
# pure-jnp ref on the CPU dry-run); ref.py holds the oracles.
from repro.kernels import ops
