"""Flash-decode Pallas TPU kernel (serve_step path).

One new token per sequence attends to a long (possibly partially filled,
possibly sequence-sharded) KV cache. Grid (B, KVH, nS) with the S axis minor;
all H//KVH query heads sharing a kv head are processed together so the
(group x block_s) logits matmul has some MXU utilisation. Emits (o, lse) so
that shards of a sequence-sharded cache can be combined exactly with
``ref.combine_decode_shards`` across the `model` mesh axis.

cache_len is a scalar-prefetch operand ((B,) int32): number of valid slots
per sequence; ``pos_offset`` is the absolute position of local cache slot 0.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _pick_block_s(S: int, want: int) -> int:
    """Largest divisor of ``S`` that is <= ``want``.

    Arena widths are not always powers of two (prompt_len + max_new from a
    workload spec, e.g. S=160); asserting divisibility made those shapes hard
    failures. Falling back to the largest divisor keeps the grid exact —
    every position is covered exactly once, no padding tile."""
    bs = max(1, min(want, S))
    while S % bs:
        bs -= 1
    return bs


def _ragged_block_index(si, lens_b, *, block_s: int, num_blocks: int,
                        pos_offset: int, window):
    """Clamp the S-block index for the ragged fetch-skip.

    The grid sweeps ``si = 0..num_blocks-1`` (minor axis) for every
    (sequence, kv-head) cell, but a slot with ``kv_len`` valid positions
    only *needs* blocks ``first..last``:

      last  = ceil((kv_len - pos_offset) / block_s) - 1       (tail cutoff)
      first = (kv_len - window - pos_offset) // block_s       (SWA head cutoff)

    Dead steps clamp to the nearest needed block, so consecutive grid steps
    map to the *same* block index and Pallas elides the K/V copy entirely —
    per-slot grid truncation via the scalar-prefetch lane, not just in-kernel
    masking of a full sweep. The clamped sequence is monotone, so every
    needed block is still fetched exactly once, and the compute-side
    ``pl.when(needed)`` guard (unchanged) skips the dead steps' math."""
    last = (lens_b - pos_offset + block_s - 1) // block_s - 1
    last = jnp.clip(last, 0, num_blocks - 1)
    si_c = jnp.minimum(si, last)
    if window is not None:
        first = jnp.clip((lens_b - window - pos_offset) // block_s,
                         0, num_blocks - 1)
        si_c = jnp.maximum(si_c, first)
    return si_c


def _kernel(
    len_ref,  # scalar prefetch (B,) int32
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    lse_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    block_s: int,
    num_s_blocks: int,
    pos_offset: int,
    window: Optional[int],
    group: int,
):
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = len_ref[b]
    blk_lo = si * block_s + pos_offset
    # skip blocks entirely beyond the valid region (or before the window)
    needed = blk_lo < cache_len
    if window is not None:
        needed &= (blk_lo + block_s) > (cache_len - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0, :, :]  # (group, D)
        k = k_ref[0, :, 0, :]  # (block_s, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        s = s * scale  # (group, block_s)
        kpos = blk_lo + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
        valid = kpos < cache_len
        if window is not None:
            valid &= kpos > (cache_len - 1) - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(si == num_s_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-20)
        o_ref[0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, :] = (m_ref[:, 0] + jnp.log(l[:, 0]))


def _quant_kernel(
    len_ref,  # scalar prefetch (B,) int32
    q_ref,
    k_ref,  # int8 tile
    v_ref,  # int8 tile
    ks_ref,  # f32 per-slot-per-head scales
    vs_ref,
    o_ref,
    lse_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    block_s: int,
    num_s_blocks: int,
    pos_offset: int,
    window: Optional[int],
    group: int,
):
    """Flash-decode over an int8 KV cache: dequantization is fused into the
    tile loop (int8 tile + f32 scales dequantized in VMEM right before the
    logits matmul), so the full-width bf16 cache never exists in HBM — the
    whole point of ``ModelConfig.kv_quant``. Math otherwise identical to
    :func:`_kernel`."""
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = len_ref[b]
    blk_lo = si * block_s + pos_offset
    needed = blk_lo < cache_len
    if window is not None:
        needed &= (blk_lo + block_s) > (cache_len - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0, :, :]  # (group, D)
        # fused per-tile dequant: (block_s, D) int8 * (block_s, 1) f32
        k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0, :, 0][:, None]
        v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        s = s * scale  # (group, block_s)
        kpos = blk_lo + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
        valid = kpos < cache_len
        if window is not None:
            valid &= kpos > (cache_len - 1) - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(si == num_s_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-20)
        o_ref[0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, :] = (m_ref[:, 0] + jnp.log(l[:, 0]))


def decode_attention_quant(
    q: jax.Array,
    k: jax.Array,  # (B, S, KVH, D) int8
    v: jax.Array,  # (B, S, KVH, D) int8
    k_scale: jax.Array,  # (B, S, KVH) f32
    v_scale: jax.Array,  # (B, S, KVH) f32
    cache_len: jax.Array,
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    pos_offset: int = 0,
    block_s: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Flash-decode over a quantized cache; returns (o (B,H,D), lse (B,H)).

    Equivalent to ``dequant_kv`` + :func:`decode_attention` but the cache
    stays int8 end-to-end in HBM (the previous ``_decode_quant`` model path
    materialized the full bf16 cache every decode step)."""
    B, H, D = q.shape
    _, S, KVH, _ = k.shape
    assert H % KVH == 0
    group = H // KVH
    scale = scale if scale is not None else 1.0 / (D**0.5)
    block_s = _pick_block_s(S, block_s)
    ns = S // block_s
    qg = q.reshape(B, KVH, group, D)

    kernel = functools.partial(
        _quant_kernel,
        scale=scale,
        block_s=block_s,
        num_s_blocks=ns,
        pos_offset=pos_offset,
        window=window,
        group=group,
    )
    ragged = functools.partial(
        _ragged_block_index, block_s=block_s, num_blocks=ns,
        pos_offset=pos_offset, window=window,
    )
    kv_map = lambda b, kh, si, lens: (b, ragged(si, lens[b]), kh, 0)
    sc_map = lambda b, kh, si, lens: (b, ragged(si, lens[b]), kh)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KVH, ns),
        in_specs=[
            pl.BlockSpec((1, 1, group, D), lambda b, kh, si, lens: (b, kh, 0, 0)),
            pl.BlockSpec((1, block_s, 1, D), kv_map),
            pl.BlockSpec((1, block_s, 1, D), kv_map),
            pl.BlockSpec((1, block_s, 1), sc_map),
            pl.BlockSpec((1, block_s, 1), sc_map),
        ],
        out_specs=[
            pl.BlockSpec((1, group, D), lambda b, kh, si, lens: (b * KVH + kh, 0, 0)),
            pl.BlockSpec((1, group), lambda b, kh, si, lens: (b * KVH + kh, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, D), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
        ],
    )
    o, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * KVH, group, D), q.dtype),
            jax.ShapeDtypeStruct((B * KVH, group), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len.astype(jnp.int32), qg, k, v, k_scale, v_scale)
    return o.reshape(B, H, D), lse.reshape(B, H)


def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cache_len: jax.Array,
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    pos_offset: int = 0,
    block_s: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (o (B,H,D), lse (B,H))."""
    B, H, D = q.shape
    _, S, KVH, _ = k.shape
    assert H % KVH == 0
    group = H // KVH
    scale = scale if scale is not None else 1.0 / (D**0.5)
    block_s = _pick_block_s(S, block_s)
    ns = S // block_s
    # reshape q to (B, KVH, group, D): heads are kv-major contiguous
    qg = q.reshape(B, KVH, group, D)

    kernel = functools.partial(
        _kernel,
        scale=scale,
        block_s=block_s,
        num_s_blocks=ns,
        pos_offset=pos_offset,
        window=window,
        group=group,
    )
    ragged = functools.partial(
        _ragged_block_index, block_s=block_s, num_blocks=ns,
        pos_offset=pos_offset, window=window,
    )
    kv_map = lambda b, kh, si, lens: (b, ragged(si, lens[b]), kh, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KVH, ns),
        in_specs=[
            pl.BlockSpec((1, 1, group, D), lambda b, kh, si, lens: (b, kh, 0, 0)),
            pl.BlockSpec((1, block_s, 1, D), kv_map),
            pl.BlockSpec((1, block_s, 1, D), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, group, D), lambda b, kh, si, lens: (b * KVH + kh, 0, 0)),
            pl.BlockSpec((1, group), lambda b, kh, si, lens: (b * KVH + kh, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, D), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
        ],
    )
    o, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * KVH, group, D), q.dtype),
            jax.ShapeDtypeStruct((B * KVH, group), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len.astype(jnp.int32), qg, k, v)
    return o.reshape(B, H, D), lse.reshape(B, H)


def _paged_kernel(
    len_ref,  # scalar prefetch (B,) int32
    tbl_ref,  # scalar prefetch (B, T) int32 block tables (unused in body:
    #           pages are resolved in the BlockSpec index_map)
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    lse_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    block_s: int,
    num_s_blocks: int,
    group: int,
):
    del tbl_ref
    _kernel(
        len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
        scale=scale, block_s=block_s, num_s_blocks=num_s_blocks,
        pos_offset=0, window=None, group=group,
    )


def paged_decode_attention(
    q: jax.Array,  # (B, H, D)
    pool_k: jax.Array,  # (P, page_size, KVH, D) page pool
    pool_v: jax.Array,  # (P, page_size, KVH, D)
    tables: jax.Array,  # (B, T) int32 page ids; logical position p lives in
    #                     pool page tables[b, p // page_size] at offset
    #                     p % page_size
    kv_len: jax.Array,  # (B,) int32 valid positions per sequence
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Flash decode straight through a block table (serving's paged arena).

    Grid (B, KVH, T) with the page axis minor; each step's K/V tile is ONE
    pool page, picked by the BlockSpec index_map from the scalar-prefetched
    block table — the kernel never sees a contiguous cache, so the serving
    engine's page-staging copy (pool -> slot rows before every burst)
    disappears. Dead steps (``ti`` past ``ceil(kv_len / page_size)``) clamp
    the table lookup to the last live page: the same fetch-skip trick as
    :func:`_ragged_block_index`, on table entries instead of raw block
    indices. Table rows of finished/inactive lanes may point anywhere inside
    the pool — the in-kernel ``kpos < kv_len`` mask zeroes their
    contribution, so the outputs of those lanes are well-defined garbage the
    caller discards. Returns (o (B,H,D), lse (B,H))."""
    B, H, D = q.shape
    P, ps, KVH, _ = pool_k.shape
    T = tables.shape[1]
    assert H % KVH == 0
    group = H // KVH
    scale = scale if scale is not None else 1.0 / (D**0.5)
    qg = q.reshape(B, KVH, group, D)

    kernel = functools.partial(
        _paged_kernel,
        scale=scale,
        block_s=ps,
        num_s_blocks=T,
        group=group,
    )

    def kv_map(b, kh, ti, lens, tbl):
        last = jnp.clip((lens[b] + ps - 1) // ps - 1, 0, T - 1)
        return (tbl[b, jnp.minimum(ti, last)], 0, kh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, T),
        in_specs=[
            pl.BlockSpec((1, 1, group, D),
                         lambda b, kh, ti, lens, tbl: (b, kh, 0, 0)),
            pl.BlockSpec((1, ps, 1, D), kv_map),
            pl.BlockSpec((1, ps, 1, D), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, group, D),
                         lambda b, kh, ti, lens, tbl: (b * KVH + kh, 0, 0)),
            pl.BlockSpec((1, group),
                         lambda b, kh, ti, lens, tbl: (b * KVH + kh, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, D), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
        ],
    )
    o, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * KVH, group, D), q.dtype),
            jax.ShapeDtypeStruct((B * KVH, group), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), tables.astype(jnp.int32), qg, pool_k, pool_v)
    return o.reshape(B, H, D), lse.reshape(B, H)
