"""Mamba2 SSD (state-space dual) chunked-scan Pallas TPU kernel.

The SSD form turns the linear recurrence into chunk-local matmuls (MXU work)
plus a tiny inter-chunk recurrence. Grid (B, NH, NC) with the chunk axis
minor: the running state h (P x N, fp32) lives in VMEM scratch and is carried
across the sequential chunk iterations — the TPU-native replacement for the
CUDA warp-parallel scan of the original implementation.

Per chunk of length L (default 128):
  a        = dt * A                              (L,)       log-decay
  L[i,j]   = exp(sum_{j<k<=i} a_k) (i>=j)        (L,L)
  scores   = (C B^T) * L                         (L,L)      MXU
  y_intra  = scores @ (dt * x)                   (L,P)      MXU
  y_inter  = (C * exp(cum_a)) @ h^T              (L,P)      MXU
  h       <- exp(tot_a) h + x^T @ (B * dt * exp(tot_a - cum_a))   (P,N) MXU
  y        = y_intra + y_inter + D * x

Layouts: x (B,S,NH,P); dt (B,S,NH); A,D (NH,); Bm,Cm (B,S,G,N).
State dim N and head dim P are zero-padded to the 128-lane boundary by the
wrapper when needed.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    x_ref,
    dt_ref,
    a_ref,  # A (1,) for this head
    b_ref,
    c_ref,
    d_ref,  # D (1,)
    y_ref,
    hout_ref,
    h_ref,  # scratch (P, N) fp32
    *,
    chunk: int,
    num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (L, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (L, N)
    A = a_ref[0]
    D = d_ref[0]

    a = dt * A  # (L,)
    a_cum = jnp.cumsum(a)  # inclusive
    a_tot = a_cum[-1]

    # intra-chunk
    seg = a_cum[:, None] - a_cum[None, :]  # sum_{j<k<=i}
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(li >= lj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    gated = scores * Lmat
    y_intra = jax.lax.dot_general(
        gated, dt[:, None] * x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # inter-chunk: contribution of incoming state
    h = h_ref[...]  # (P, N)
    c_dec = Cm * jnp.exp(a_cum)[:, None]  # (L, N)
    y_inter = jax.lax.dot_general(
        c_dec, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, P)

    # state update
    w = (dt * jnp.exp(a_tot - a_cum))[:, None] * Bm  # (L, N)
    s_new = jax.lax.dot_general(
        x, w, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)
    h_ref[...] = jnp.exp(a_tot) * h + s_new

    y = y_intra + y_inter + D * x
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        hout_ref[0, 0, :, :] = h_ref[...]


def ssd(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    D: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,NH,P), final state (B,NH,P,N) fp32)."""
    b, s, nh, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert nh % g == 0
    rep = nh // g
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    kernel = functools.partial(_kernel, chunk=chunk, num_chunks=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // rep, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // rep, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, nh, p), x.dtype),
            jax.ShapeDtypeStruct((b, nh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, D)
    return y, h
