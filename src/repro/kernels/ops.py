"""jit'd dispatch wrappers over the Pallas kernels.

Mode resolution (``REPRO_KERNEL_MODE`` env var or :func:`set_mode`):
  auto      -> Pallas on TPU backends, pure-jnp ref elsewhere (CPU dry-run
               lowers the ref path; Mosaic has no CPU target)
  pallas    -> force compiled Pallas
  interpret -> Pallas with interpret=True (kernel-correctness tests on CPU)
  ref       -> force pure-jnp oracles
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import ssd as _ssd
from repro.kernels import rmsnorm as _rn

_MODE: Optional[str] = None


def set_mode(mode: Optional[str]) -> None:
    """Override kernel dispatch: auto | pallas | interpret | ref | None."""
    global _MODE
    _MODE = mode


def current_mode() -> str:
    mode = _MODE or os.environ.get("REPRO_KERNEL_MODE", "auto")
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return mode


def flash_attention(
    q, k, v, *, causal=True, window=None, scale=None, q_offset=0
):
    mode = current_mode()
    if mode == "ref":
        return _ref.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale, q_offset=q_offset
        )
    return _fa.flash_attention(
        q,
        k,
        v,
        causal=causal,
        window=window,
        scale=scale,
        q_offset=q_offset,
        interpret=(mode == "interpret"),
    )


def decode_attention(
    q, k, v, cache_len, *, scale=None, window=None, pos_offset=0
) -> Tuple[jax.Array, jax.Array]:
    """Returns (o, lse) in every mode (shard-combinable)."""
    mode = current_mode()
    if mode == "ref":
        return _ref.decode_attention(
            q,
            k,
            v,
            cache_len,
            scale=scale,
            window=window,
            pos_offset=pos_offset,
            return_lse=True,
        )
    return _da.decode_attention(
        q,
        k,
        v,
        cache_len,
        scale=scale,
        window=window,
        pos_offset=pos_offset,
        interpret=(mode == "interpret"),
    )


def decode_attention_quant(
    q, k, v, k_scale, v_scale, cache_len, *, scale=None, window=None,
    pos_offset=0
) -> Tuple[jax.Array, jax.Array]:
    """int8-cache flash decode; returns (o, lse) in every mode. The Pallas
    path fuses dequantization into the tile loop; the ref path dequantizes
    up front (bitwise-identical to the pre-fusion ``_decode_quant``)."""
    mode = current_mode()
    if mode == "ref":
        return _ref.decode_attention_quant(
            q, k, v, k_scale, v_scale, cache_len,
            scale=scale, window=window, pos_offset=pos_offset,
            return_lse=True,
        )
    return _da.decode_attention_quant(
        q, k, v, k_scale, v_scale, cache_len,
        scale=scale, window=window, pos_offset=pos_offset,
        interpret=(mode == "interpret"),
    )


def combine_decode_shards(o_parts, lse_parts):
    return _ref.combine_decode_shards(o_parts, lse_parts)


def ssd(x, dt, A, Bm, Cm, D, *, chunk=128, return_state=False):
    mode = current_mode()
    if mode == "ref":
        out = _ref.ssd_chunked(
            x, dt, A, Bm, Cm, D, chunk=min(chunk, x.shape[1]), return_state=True
        )
        y, h = out
    else:
        y, h = _ssd.ssd(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=(mode == "interpret"))
    if return_state:
        return y, h
    return y


def ssd_decode_step(x, dt, A, Bm, Cm, D, h):
    # single-token step is pure VPU work; the jnp form is already minimal
    return _ref.ssd_decode_step(x, dt, A, Bm, Cm, D, h)


def rmsnorm(x, w, *, eps: float = 1e-6):
    mode = current_mode()
    if mode == "ref":
        return _ref.rmsnorm(x, w, eps=eps)
    return _rn.rmsnorm(x, w, eps=eps, interpret=(mode == "interpret"))
