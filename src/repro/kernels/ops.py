"""jit'd dispatch wrappers over the Pallas kernels.

Mode resolution (``REPRO_KERNEL_MODE`` env var or :func:`set_mode`):
  auto      -> Pallas on TPU backends, pure-jnp ref elsewhere (CPU dry-run
               lowers the ref path; Mosaic has no CPU target)
  pallas    -> force compiled Pallas
  interpret -> Pallas with interpret=True (kernel-correctness tests on CPU)
  ref       -> force pure-jnp oracles
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import sampling as _sm
from repro.kernels import ssd as _ssd
from repro.kernels import rmsnorm as _rn

_MODE: Optional[str] = None


def set_mode(mode: Optional[str]) -> None:
    """Override kernel dispatch: auto | pallas | interpret | ref | None."""
    global _MODE
    _MODE = mode


def current_mode() -> str:
    mode = _MODE or os.environ.get("REPRO_KERNEL_MODE", "auto")
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return mode


def flash_attention(
    q, k, v, *, causal=True, window=None, scale=None, q_offset=0
):
    mode = current_mode()
    if mode == "ref":
        return _ref.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale, q_offset=q_offset
        )
    return _fa.flash_attention(
        q,
        k,
        v,
        causal=causal,
        window=window,
        scale=scale,
        q_offset=q_offset,
        interpret=(mode == "interpret"),
    )


def decode_attention(
    q, k, v, cache_len, *, scale=None, window=None, pos_offset=0
) -> Tuple[jax.Array, jax.Array]:
    """Returns (o, lse) in every mode (shard-combinable)."""
    mode = current_mode()
    if mode == "ref":
        return _ref.decode_attention(
            q,
            k,
            v,
            cache_len,
            scale=scale,
            window=window,
            pos_offset=pos_offset,
            return_lse=True,
        )
    return _da.decode_attention(
        q,
        k,
        v,
        cache_len,
        scale=scale,
        window=window,
        pos_offset=pos_offset,
        interpret=(mode == "interpret"),
    )


def decode_attention_quant(
    q, k, v, k_scale, v_scale, cache_len, *, scale=None, window=None,
    pos_offset=0
) -> Tuple[jax.Array, jax.Array]:
    """int8-cache flash decode; returns (o, lse) in every mode. The Pallas
    path fuses dequantization into the tile loop; the ref path dequantizes
    up front (bitwise-identical to the pre-fusion ``_decode_quant``)."""
    mode = current_mode()
    if mode == "ref":
        return _ref.decode_attention_quant(
            q, k, v, k_scale, v_scale, cache_len,
            scale=scale, window=window, pos_offset=pos_offset,
            return_lse=True,
        )
    return _da.decode_attention_quant(
        q, k, v, k_scale, v_scale, cache_len,
        scale=scale, window=window, pos_offset=pos_offset,
        interpret=(mode == "interpret"),
    )


def paged_decode_attention(
    q, pool_k, pool_v, tables, kv_len, *, scale=None
) -> Tuple[jax.Array, jax.Array]:
    """Flash decode straight out of the paged KV pool — the kernel gathers
    each sequence's pages through its block-table row, so the serving burst
    never stages pages into contiguous per-slot KV rows. Returns (o, lse)
    in every mode."""
    mode = current_mode()
    if mode == "ref":
        return _ref.paged_decode_attention(
            q, pool_k, pool_v, tables, kv_len, scale=scale, return_lse=True
        )
    return _da.paged_decode_attention(
        q, pool_k, pool_v, tables, kv_len, scale=scale,
        interpret=(mode == "interpret"),
    )


def _row_seeds(keys: jax.Array) -> jax.Array:
    """Per-row int32 seeds for the fused sampler's counter-based hash RNG,
    derived from a batch of PRNG keys."""
    bits = jax.vmap(lambda k: jax.random.bits(k, (), jnp.uint32))(keys)
    return jax.lax.bitcast_convert_type(bits, jnp.int32)


def fused_sample(
    h, w_head, key, temperature, *, vocab_size=None, top_p: float = 1.0
) -> Tuple[jax.Array, jax.Array]:
    """Fused LM-head + sampler for one decode step: (hidden, head weights)
    -> (sampled token, behaviour logprob of that token under the untempered
    masked distribution).

    The ref path IS the pre-fusion op sequence (matmul, vocab mask,
    ``jax.random.categorical``, ``log_softmax`` gather) — bitwise-identical
    to what ``rl/rollout.generate`` historically computed. The Pallas path
    streams head-weight tiles and samples via hash-Gumbel-max in-kernel:
    same distribution, different random stream. ``temperature`` and
    ``top_p`` are static floats; ``top_p < 1`` routes to the ref path (the
    kernel's online sweep cannot see the sorted CDF)."""
    mode = current_mode()
    if mode == "ref" or top_p < 1.0:
        return _ref.fused_sample(
            h, w_head, key, temperature, vocab_size=vocab_size, top_p=top_p
        )
    B = h.shape[0]
    seeds = _row_seeds(jax.random.split(key, B))
    inv_t = jnp.full(
        (B,), 0.0 if temperature == 0.0 else 1.0 / temperature, jnp.float32
    )
    return _sm.fused_sample(
        h, w_head, seeds, inv_t, vocab_size=vocab_size,
        interpret=(mode == "interpret"),
    )


def fused_sample_rows(h, w_head, keys, temps, *, vocab_size=None) -> jax.Array:
    """Per-row-temperature variant for the serving engine: ``temps`` is a
    traced (B,) array, rows with ``temps <= 0`` take the argmax. Returns the
    sampled tokens only (serving keeps no behaviour logprobs)."""
    mode = current_mode()
    if mode == "ref":
        return _ref.fused_sample_rows(
            h, w_head, keys, temps, vocab_size=vocab_size
        )
    seeds = _row_seeds(keys)
    inv_t = jnp.where(
        temps <= 0.0, 0.0, 1.0 / jnp.maximum(temps, 1e-6)
    ).astype(jnp.float32)
    tok, _ = _sm.fused_sample(
        h, w_head, seeds, inv_t, vocab_size=vocab_size,
        interpret=(mode == "interpret"),
    )
    return tok


def combine_decode_shards(o_parts, lse_parts):
    return _ref.combine_decode_shards(o_parts, lse_parts)


def ssd(x, dt, A, Bm, Cm, D, *, chunk=128, return_state=False):
    mode = current_mode()
    if mode == "ref":
        out = _ref.ssd_chunked(
            x, dt, A, Bm, Cm, D, chunk=min(chunk, x.shape[1]), return_state=True
        )
        y, h = out
    else:
        y, h = _ssd.ssd(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=(mode == "interpret"))
    if return_state:
        return y, h
    return y


def ssd_decode_step(x, dt, A, Bm, Cm, D, h):
    # single-token step is pure VPU work; the jnp form is already minimal
    return _ref.ssd_decode_step(x, dt, A, Bm, Cm, D, h)


def rmsnorm(x, w, *, eps: float = 1e-6):
    mode = current_mode()
    if mode == "ref":
        return _ref.rmsnorm(x, w, eps=eps)
    return _rn.rmsnorm(x, w, eps=eps, interpret=(mode == "interpret"))
