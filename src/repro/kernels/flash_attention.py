"""Flash attention Pallas TPU kernel (training / prefill path).

Causal + optional sliding-window + GQA. Grid (B, H, nQ, nK) with the K axis
minor: TPU executes the grid sequentially over the last dimension, so the
online-softmax running state (acc, m, l) lives in VMEM scratch and is carried
across K blocks. Block sizes default to 128 (MXU-aligned); q/k/v tiles are
streamed HBM->VMEM by BlockSpecs.

Layouts: q (B, Sq, H, D); k, v (B, Sk, KVH, D); out (B, Sq, H, D).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128  # TPU vector lane width; m/l scratch is (block_q, LANES)


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    num_k_blocks: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- block relevance (skip fully-masked K blocks) ----
    q_lo = qi * block_q + q_offset
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_k
    k_hi = k_lo + block_k - 1
    needed = jnp.bool_(True)
    if causal:
        needed &= q_hi >= k_lo
    if window is not None:
        needed &= k_hi > q_lo - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :]  # (block_q, D)
        k = k_ref[0, :, 0, :]  # (block_k, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        s = s * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-20)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    assert H % KVH == 0
    group = H // KVH
    scale = scale if scale is not None else 1.0 / (D**0.5)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec(
                (1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h // group, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h // group, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
