"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth for kernel tests (``assert_allclose`` sweeps) AND
the lowering path used inside the CPU dry-run (Pallas/Mosaic only lowers on
TPU). FLOP counts match the kernels; fusion differences are noted in
EXPERIMENTS.md.

Shapes (conventions used throughout the repo):
  q              (B, S, H, D)
  k, v           (B, S, KVH, D)      KVH | H  (GQA groups = H // KVH)
  decode q       (B, H, D)           single new token per sequence
  ssd x          (B, S, NH, P)       P = head dim
  ssd dt         (B, S, NH)          softplus'd, positive
  ssd A          (NH,)               negative scalars
  ssd B, C       (B, S, G, N)        N = state dim, G | NH
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.constraint import constrain

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# attention (training / prefill)
# --------------------------------------------------------------------------- #
def _gqa_repeat(k: jax.Array, num_heads: int) -> jax.Array:
    """(B,S,KVH,D) -> (B,S,H,D) by repeating each kv head H//KVH times."""
    kvh = k.shape[2]
    if kvh == num_heads:
        return k
    return jnp.repeat(k, num_heads // kvh, axis=2)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Full (masked) attention oracle.

    ``q_offset``: absolute position of q[0] (used when queries are a suffix of
    the kv sequence, e.g. chunked prefill).
    ``window``: sliding-window width; position i attends to [i-window+1, i].

    GQA is computed GROUPED — q reshaped (B, KVH, G, Sq, D) against the raw
    (B, Sk, KVH, D) k/v — never materializing the repeated (B, Sk, H, D)
    tensors (a 6x HBM-traffic saving at kv=8/H=48; §Perf iteration 1).
    """
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    g = H // KVH
    scale = scale if scale is not None else 1.0 / (D**0.5)
    qg = q.reshape(B, Sq, KVH, g, D)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    )
    logits = logits * scale
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# --------------------------------------------------------------------------- #
# decode attention (one new token vs a long cache)
# --------------------------------------------------------------------------- #
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cache_len: jax.Array,
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    return_lse: bool = False,
    pos_offset: int = 0,
):
    """One-token attention vs a (possibly partially-filled) KV cache.

    q (B,H,D); k,v (B,S,KVH,D); cache_len (B,) int32 — number of valid slots.
    ``pos_offset``: absolute position of cache slot 0 (non-zero when the cache
    is sequence-sharded; lets shards mask + combine exactly via the returned
    log-sum-exp).

    Returns o (B,H,D) [and lse (B,H) if ``return_lse``].

    GQA grouped (no repeated-kv materialization): logits are computed
    (B, KVH, G, S) straight against the cache layout, and the seq axis stays
    shardable over `model` — the layout the decode cache lives in.
    """
    B, H, D = q.shape
    S, KVH = k.shape[1], k.shape[2]
    g = H // KVH
    scale = scale if scale is not None else 1.0 / (D**0.5)
    qg = q.reshape(B, KVH, g, D)
    logits = jnp.einsum(
        "bhgd,bkhd->bhgk", qg, k, preferred_element_type=jnp.float32
    )
    logits = logits * scale
    logits = constrain(logits, "dp", None, None, "tp")  # seq stays sharded
    kpos = jnp.arange(S)[None, :] + pos_offset  # absolute positions
    valid = kpos < cache_len[:, None]
    if window is not None:
        valid &= kpos > (cache_len[:, None] - 1) - window
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    # guard fully-masked shards: exp(NEG_INF - NEG_INF) would be 1
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(logits - m_safe)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v).astype(jnp.float32)
    o = (o / jnp.maximum(l, 1e-20)).reshape(B, H, D)
    if return_lse:
        lse = (m_safe + jnp.log(jnp.maximum(l, 1e-20))).reshape(B, H)
        return o.astype(q.dtype), lse
    return o.astype(q.dtype)


def decode_attention_quant(
    q: jax.Array,
    k: jax.Array,  # (B, S, KVH, D) int8
    v: jax.Array,
    k_scale: jax.Array,  # (B, S, KVH) f32 per-slot-per-head scales
    v_scale: jax.Array,
    cache_len: jax.Array,
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    return_lse: bool = False,
    pos_offset: int = 0,
):
    """Oracle for the fused int8-cache decode kernel: dequantize the whole
    cache to bf16 (the pre-fusion model path, bitwise-preserved) and run the
    standard decode oracle. The Pallas kernel dequantizes per tile in VMEM
    instead — correctness-equivalent, but never materializes the bf16 cache."""
    kd = (k.astype(jnp.float32) * k_scale[..., None]).astype(jnp.bfloat16)
    vd = (v.astype(jnp.float32) * v_scale[..., None]).astype(jnp.bfloat16)
    return decode_attention(
        q, kd, vd, cache_len,
        scale=scale, window=window, return_lse=return_lse,
        pos_offset=pos_offset,
    )


def paged_decode_attention(
    q: jax.Array,  # (B, H, D)
    pool_k: jax.Array,  # (P, page_size, KVH, D)
    pool_v: jax.Array,  # (P, page_size, KVH, D)
    tables: jax.Array,  # (B, T) int32 page ids
    kv_len: jax.Array,  # (B,) int32
    *,
    scale: Optional[float] = None,
    return_lse: bool = False,
):
    """Oracle for the paged decode kernel: gather each sequence's pages out
    of the pool into a contiguous (B, T*page_size, KVH, D) cache and run the
    standard decode oracle. Positions at and beyond ``kv_len`` are masked
    identically in both paths, so whatever a table row points at past its
    live span never reaches the output."""
    B = q.shape[0]
    T = tables.shape[1]
    ps = pool_k.shape[1]
    idx = tables.astype(jnp.int32).reshape(-1)
    kg = jnp.take(pool_k, idx, axis=0).reshape(
        (B, T * ps) + pool_k.shape[2:])
    vg = jnp.take(pool_v, idx, axis=0).reshape(
        (B, T * ps) + pool_v.shape[2:])
    return decode_attention(q, kg, vg, kv_len, scale=scale,
                            return_lse=return_lse)


def combine_decode_shards(o_parts: jax.Array, lse_parts: jax.Array) -> jax.Array:
    """Exactly combine per-shard (o, lse) from a sequence-sharded cache.

    o_parts (P, B, H, D) float; lse_parts (P, B, H). Standard flash-decode
    log-sum-exp merge.
    """
    m = jnp.max(lse_parts, axis=0, keepdims=True)
    w = jnp.exp(lse_parts - m)  # (P,B,H)
    num = jnp.sum(o_parts.astype(jnp.float32) * w[..., None], axis=0)
    den = jnp.sum(w, axis=0)[..., None]
    return (num / jnp.maximum(den, 1e-20)).astype(o_parts.dtype)


# --------------------------------------------------------------------------- #
# fused sampling (logits -> temperature -> top-p -> token, one op)
# --------------------------------------------------------------------------- #
def _mask_vocab(logits: jax.Array, vocab_size: Optional[int]) -> jax.Array:
    """Replicates ``lm.mask_padded_vocab`` (including its no-op when the
    vocab is unpadded — the Python-level check keeps the default bitwise)."""
    vpad = logits.shape[-1]
    if vocab_size is None or vocab_size >= vpad:
        return logits
    return jnp.where(jnp.arange(vpad) < vocab_size, logits, -1e30)


def top_p_filter(logits: jax.Array, top_p: float) -> jax.Array:
    """Nucleus filter: per row, keep the smallest set of tokens whose
    cumulative probability reaches ``top_p`` (the top-1 token always
    survives); everything else drops to NEG_INF. ``top_p >= 1`` returns the
    input object unchanged (bitwise no-op)."""
    if top_p >= 1.0:
        return logits
    order = jnp.argsort(-logits, axis=-1)
    sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    # keep while the mass *before* this token is < top_p: position 0 always
    # kept, and the first token to cross the threshold is included
    before = jnp.cumsum(probs, axis=-1) - probs
    keep_sorted = before < top_p
    keep = jnp.zeros_like(keep_sorted)
    rows = jnp.arange(logits.shape[0])[:, None]
    keep = keep.at[rows, order].set(keep_sorted)
    return jnp.where(keep, logits, NEG_INF)


def fused_sample(
    h: jax.Array,  # (B, d) final hidden state
    w_head: jax.Array,  # (d, Vp) LM head (tied embed.T or lm_head)
    key: jax.Array,
    temperature: float,
    *,
    vocab_size: Optional[int] = None,
    top_p: float = 1.0,
):
    """Oracle for the fused decode-step sampler: literally the pre-fusion op
    sequence (head matmul -> padded-vocab mask -> ``rollout.sample_token``
    -> untempered log-softmax gather), so the ref dispatch path is
    bitwise-identical to ``decode_step`` + host sampling. Returns
    (token (B,), logprob (B,) under the untempered distribution)."""
    logits = (h @ w_head).astype(jnp.float32)
    logits = _mask_vocab(logits, vocab_size)
    if temperature == 0.0:
        tok = jnp.argmax(logits, axis=-1)
    else:
        tok = jax.random.categorical(
            key, top_p_filter(logits / temperature, top_p), axis=-1)
    lp = jax.nn.log_softmax(logits, axis=-1)[jnp.arange(h.shape[0]), tok]
    return tok, lp


def fused_sample_rows(
    h: jax.Array,  # (B, d)
    w_head: jax.Array,  # (d, Vp)
    keys: jax.Array,  # (B, 2) per-row PRNG keys
    temps: jax.Array,  # (B,) per-row temperatures (<= 0 -> greedy)
    *,
    vocab_size: Optional[int] = None,
) -> jax.Array:
    """Oracle for the serving-engine variant: per-row keys and temperatures
    (the ``_row_sample`` contract — row-wise independence is what makes a
    request's tokens invariant to its co-residents). Returns tokens (B,)."""
    logits = (h @ w_head).astype(jnp.float32)
    logits = _mask_vocab(logits, vocab_size)
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temps <= 0.0, jnp.argmax(logits, axis=-1), sampled)


# --------------------------------------------------------------------------- #
# Mamba2 / SSD
# --------------------------------------------------------------------------- #
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    D: jax.Array,
    *,
    h0: Optional[jax.Array] = None,
    return_state: bool = False,
):
    """Sequential (exact) SSD recurrence — the oracle.

        h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T        (per head)
        y_t = C_t . h_t + D x_t

    x (B,S,NH,P); dt (B,S,NH); A (NH,); B,C (B,S,G,N); D (NH,);
    h0 (B,NH,P,N) optional initial state. Returns y (B,S,NH,P)
    [and final state if ``return_state``].
    """
    b, s, nh, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = nh // g
    Bh = jnp.repeat(B, rep, axis=2)  # (B,S,NH,N)
    Ch = jnp.repeat(C, rep, axis=2)
    if h0 is None:
        h0 = jnp.zeros((b, nh, p, n), dtype=jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,NH,P),(B,NH),(B,NH,N),(B,NH,N)
        decay = jnp.exp(dtt * A[None, :])[..., None, None]  # (B,NH,1,1)
        dBx = (dtt[..., None, None] * bt[:, :, None, :]) * xt[..., None]
        h = decay * h.astype(jnp.float32) + dBx.astype(jnp.float32)
        y = jnp.einsum("bhpn,bhn->bhp", h, ct.astype(jnp.float32))
        return h, y

    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Ch, 1, 0).astype(jnp.float32),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + D[None, None, :, None] * x.astype(jnp.float32)
    y = y.astype(x.dtype)
    if return_state:
        return y, hT
    return y


def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} a[..., k].

    Lower-triangular (i >= j); -inf above the diagonal.
    """
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{j<k<=i}
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    return jnp.where(i >= j, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    D: jax.Array,
    *,
    chunk: int = 128,
    h0: Optional[jax.Array] = None,
    return_state: bool = False,
):
    """Chunked (SSD / state-space-dual) form — matmul-rich, what the Pallas
    kernel implements. Mathematically identical to :func:`ssd_scan`.
    """
    b, s, nh, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk
    rep = nh // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = dtf * A[None, None, :]  # (B,S,NH) log-decay per step

    # reshape to chunks: (B,NC,L,...)
    def ch(t):
        return t.reshape(b, nc, chunk, *t.shape[2:])

    xc, dtc, ac, Bc, Cc = ch(xf), ch(dtf), ch(a), ch(Bh), ch(Ch)

    # ---- intra-chunk (quadratic within chunk) ----
    # L[i,j] = exp(sum_{j<k<=i} a_k), masked lower-triangular
    aseg = _segsum(jnp.moveaxis(ac, -1, -2))  # (B,NC,NH,L,L)
    Lmat = jnp.exp(aseg)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)  # (B,NC,NH,L,L)
    gated = scores * Lmat
    y_intra = jnp.einsum("bchls,bcshp->bclhp", gated, dtc[..., None] * xc)

    # ---- chunk states: S_c = sum_i exp(a_end..i) dt_i B_i x_i^T ----
    a_cum = jnp.cumsum(ac, axis=2)  # (B,NC,L,NH) inclusive
    a_tot = a_cum[:, :, -1:, :]  # (B,NC,1,NH)
    decay_to_end = jnp.exp(a_tot - a_cum)  # exp(sum_{i<k<=end})
    states = jnp.einsum(
        "bclhn,bclhp->bchpn", Bc * (dtc * decay_to_end)[..., None], xc
    )

    # ---- inter-chunk recurrence over chunk states ----
    if h0 is None:
        h0 = jnp.zeros((b, nh, p, n), dtype=jnp.float32)
    chunk_decay = jnp.exp(a_tot[:, :, 0, :])  # (B,NC,NH)

    def step(h, inp):
        st, dec = inp  # (B,NH,P,N), (B,NH)
        h_new = dec[..., None, None] * h + st
        return h_new, h  # emit state *entering* the chunk

    hT, h_in = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,NC,NH,P,N) state entering each chunk

    # ---- inter-chunk output: y_i += C_i . (exp(a_cum_i) * h_in) ----
    in_decay = jnp.exp(a_cum)  # (B,NC,L,NH)
    y_inter = jnp.einsum("bclhn,bchpn->bclhp", Cc * in_decay[..., None], h_in)

    y = y_intra + y_inter + D[None, None, None, :, None] * xc
    y = y.reshape(b, s, nh, p).astype(x.dtype)
    if return_state:
        return y, hT
    return y


def ssd_decode_step(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    D: jax.Array,
    h: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD step. x (B,NH,P); dt (B,NH); B,C (B,G,N); h (B,NH,P,N).

    Returns (y (B,NH,P), h_next).
    """
    nh = x.shape[1]
    g = B.shape[1]
    rep = nh // g
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None, :])[..., None, None]
    dBx = (dtf[..., None, None] * Bh[:, :, None, :]) * xf[..., None]
    h_next = decay * h.astype(jnp.float32) + dBx
    y = jnp.einsum("bhpn,bhn->bhp", h_next, Ch) + D[None, :, None] * xf
    return y.astype(x.dtype), h_next.astype(h.dtype)


# --------------------------------------------------------------------------- #
# rmsnorm
# --------------------------------------------------------------------------- #
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)
