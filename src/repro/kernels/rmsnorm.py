"""Fused RMSNorm Pallas TPU kernel.

Single HBM pass: mean-square reduce + rsqrt + scale, tiled over rows.
x (R, D) — callers flatten leading dims; w (D,) with the gemma-style
(1 + w) scale convention used across the repo.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w[None, :])).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    r = x2.shape[0]
    block_rows = min(block_rows, r)
    # pad rows so the grid divides evenly
    pad = (-r) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    nr = x2.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    if pad:
        out = out[:r]
    return out.reshape(orig_shape)
