"""Fused decode-step sampler: LM-head matmul + temperature + Gumbel-max
sampling + logprob, one Pallas kernel, vocab tile by vocab tile.

The pre-fusion decode step materializes the full (B, padded_vocab) logits in
HBM, then ``jax.random.categorical`` reads them back (twice, counting the
logprob gather) — at small batch the decode step is *head-bandwidth* bound,
not attention bound. This kernel streams the head weight tiles once, keeps
the per-row online state (running max / sum-exp for the logprob, running
Gumbel-max winner for the sample) in VMEM scalars, and emits only (token,
logprob) per row: the logits never exist as an array.

Sampling uses the Gumbel-max trick: ``argmax(z * inv_temp + g)`` with
``g = -log(-log(u))`` draws exactly from ``softmax(z / temp)``, and an argmax
folds into the online tile sweep where a CDF inversion would not. Uniforms
come from a counter-based integer hash (splitmix32 over seed x vocab index):
stateless, identical in interpret mode and on TPU, and independent per
(row, token) — statistically equivalent to ``jax.random.categorical``'s
stream but not bitwise-identical to it (that contract lives in
``kernels/ops.py``: the ref dispatch path IS the old op sequence).

``inv_temp`` is per row with 0.0 meaning greedy (argmax of the raw logits) —
one kernel serves both the rollout engine (one shared temperature) and the
serving engine (per-request temperatures). Per-row seeds arrive through the
scalar-prefetch lane as int32; inv_temp travels as f32 bits in int32 (SMEM's
blessed dtype) and is bitcast back in-kernel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import _pick_block_s

NEG_INF = -1e30
LANES = 128


def _hash_u32(x: jax.Array) -> jax.Array:
    """splitmix32-style avalanche hash on uint32 (wrapping arithmetic)."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _uniform_01(seed: jax.Array, pos: jax.Array) -> jax.Array:
    """Counter-based uniform in the OPEN interval (0, 1): hash (seed, pos),
    keep 24 bits, center on the half-ulp grid so log(u) and log(-log(u))
    are always finite."""
    mixed = pos.astype(jnp.uint32) * jnp.uint32(0x9E3779B1) + seed
    bits = _hash_u32(mixed) >> jnp.uint32(8)
    return (bits.astype(jnp.float32) + 0.5) * jnp.float32(1.0 / (1 << 24))


def _sample_kernel(
    seed_ref,  # scalar prefetch (B,) int32 per-row hash seeds
    it_ref,  # scalar prefetch (B,) int32: f32 inv-temperature bits (0=greedy)
    h_ref,
    w_ref,
    tok_ref,
    lp_ref,
    m_ref,
    l_ref,
    by_ref,
    bz_ref,
    bi_ref,
    *,
    block_v: int,
    num_v_blocks: int,
    vocab_size: int,
):
    b = pl.program_id(0)
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        by_ref[...] = jnp.full_like(by_ref, NEG_INF)
        bz_ref[...] = jnp.full_like(bz_ref, NEG_INF)
        bi_ref[...] = jnp.zeros_like(bi_ref)

    z = jax.lax.dot_general(
        h_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, block_v) untempered logits tile
    pos = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, (1, block_v), 1)
    pv = pos < vocab_size
    z = jnp.where(pv, z, NEG_INF)

    # online log-sum-exp of the untempered logits (for the logprob)
    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_cur = jnp.max(z, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(pv, jnp.exp(z - m_new), 0.0)
    l_new = jnp.exp(m_prev - m_new) * l_prev + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    # Gumbel-max score (greedy rows score the raw logits)
    inv_temp = jax.lax.bitcast_convert_type(it_ref[b], jnp.float32)
    seed = seed_ref[b].astype(jnp.uint32)
    u = _uniform_01(seed, pos)
    g = -jnp.log(-jnp.log(u))
    y = jnp.where(inv_temp == 0.0, z, z * inv_temp + g)
    y = jnp.where(pv, y, NEG_INF)

    # running winner: strictly-better keeps the earliest tile on ties, and
    # the min-index trick inside a tile matches argmax's first-max rule
    t_max = jnp.max(y, axis=1, keepdims=True)
    t_arg = jnp.min(jnp.where(y == t_max, pos, jnp.int32(2**30)),
                    axis=1, keepdims=True)
    z_at = jnp.max(jnp.where(pos == t_arg, z, NEG_INF), axis=1, keepdims=True)
    better = t_max > by_ref[:, :1]
    by_ref[...] = jnp.broadcast_to(
        jnp.where(better, t_max, by_ref[:, :1]), by_ref.shape)
    bz_ref[...] = jnp.broadcast_to(
        jnp.where(better, z_at, bz_ref[:, :1]), bz_ref.shape)
    bi_ref[...] = jnp.broadcast_to(
        jnp.where(better, t_arg, bi_ref[:, :1]), bi_ref.shape)

    @pl.when(vi == num_v_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-20)
        lse = m_ref[:, :1] + jnp.log(l)
        tok_ref[0, :] = jnp.broadcast_to(bi_ref[:, :1], (1, LANES))[0]
        lp_ref[0, :] = jnp.broadcast_to(
            bz_ref[:, :1] - lse, (1, LANES))[0]


def fused_sample(
    h: jax.Array,  # (B, d)
    w_head: jax.Array,  # (d, Vp)
    seeds: jax.Array,  # (B,) int32 per-row hash seeds
    inv_temp: jax.Array,  # (B,) f32; 0.0 = greedy, else 1/temperature
    *,
    vocab_size: Optional[int] = None,
    block_v: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused head+sampler. Returns (token (B,) int32, logprob (B,) f32 of the
    sampled token under the *untempered* masked distribution — the
    behaviour-logprob contract of ``rl/rollout.generate``)."""
    B, d = h.shape
    Vp = w_head.shape[1]
    vocab = Vp if vocab_size is None else vocab_size
    block_v = _pick_block_s(Vp, block_v)
    nv = Vp // block_v

    kernel = functools.partial(
        _sample_kernel, block_v=block_v, num_v_blocks=nv, vocab_size=vocab)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nv),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, vi, seeds, its: (b, 0)),
            pl.BlockSpec((d, block_v), lambda b, vi, seeds, its: (0, vi)),
        ],
        out_specs=[
            pl.BlockSpec((1, LANES), lambda b, vi, seeds, its: (b, 0)),
            pl.BlockSpec((1, LANES), lambda b, vi, seeds, its: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, LANES), jnp.float32),  # m
            pltpu.VMEM((1, LANES), jnp.float32),  # l
            pltpu.VMEM((1, LANES), jnp.float32),  # best gumbel score
            pltpu.VMEM((1, LANES), jnp.float32),  # best untempered logit
            pltpu.VMEM((1, LANES), jnp.int32),  # best index
        ],
    )
    it_bits = jax.lax.bitcast_convert_type(
        inv_temp.astype(jnp.float32), jnp.int32)
    tok, lp = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, LANES), jnp.int32),
            jax.ShapeDtypeStruct((B, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(seeds.astype(jnp.int32), it_bits, h, w_head)
    return tok[:, 0], lp[:, 0]
