"""(Role, Type) -> execution-function registry (paper §5, Fig. 5).

The DAG Worker binds each node to its computational function through this
table at initialization. Researchers extend the pipeline by registering a new
function for a (role, type) key — or overriding a built-in — without touching
the surrounding dataflow (the paper's pluggability story).

Every stage function has the uniform signature::

    fn(ctx: WorkerContext, buffer: DistributedDatabuffer, node: Node) -> dict

reading its inputs from / writing its outputs to the databuffer under the
node's stage sharding.
"""
from __future__ import annotations

import difflib
from typing import Callable, Dict, Tuple

from repro.core.dag import Node, NodeType, Role

StageFn = Callable[..., Dict]


def _key_str(key: Tuple[Role, NodeType]) -> str:
    return f"{key[0].value}/{key[1].value}"


class Registry:
    def __init__(self):
        self._fns: Dict[Tuple[Role, NodeType], StageFn] = {}

    def _registered_str(self) -> str:
        return ", ".join(sorted(_key_str(k) for k in self._fns)) or "<none>"

    def register(self, role: Role, type_: NodeType, fn: StageFn, *, override=False):
        key = (role, type_)
        if key in self._fns and not override:
            bound = getattr(self._fns[key], "__name__", repr(self._fns[key]))
            raise KeyError(
                f"({_key_str(key)}) already registered (bound to {bound}); "
                f"pass override=True to replace it. "
                f"Registered keys: [{self._registered_str()}]"
            )
        self._fns[key] = fn
        return fn

    def resolve(self, node: Node) -> StageFn:
        try:
            return self._fns[node.fn_key]
        except KeyError:
            want = _key_str(node.fn_key)
            near = difflib.get_close_matches(
                want, [_key_str(k) for k in self._fns], n=1, cutoff=0.4
            )
            hint = f" Nearest match: {near[0]}." if near else ""
            raise KeyError(
                f"no function registered for node {node.node_id!r} with "
                f"(role={node.role.value}, type={node.type.value}). "
                f"Registered keys: [{self._registered_str()}].{hint}"
            ) from None

    def keys(self):
        return list(self._fns)


def default_registry() -> Registry:
    """The built-in PPO/GRPO function table (lazily imported to avoid
    circular deps)."""
    from repro.core import stages

    r = Registry()
    r.register(Role.ACTOR, NodeType.GENERATE, stages.actor_generate)
    r.register(Role.ACTOR, NodeType.MODEL_INFERENCE, stages.actor_logprobs)
    r.register(Role.REFERENCE, NodeType.MODEL_INFERENCE, stages.reference_logprobs)
    r.register(Role.CRITIC, NodeType.MODEL_INFERENCE, stages.critic_values)
    r.register(Role.REWARD, NodeType.COMPUTE, stages.reward_compute)
    r.register(Role.ENV, NodeType.COMPUTE, stages.env_compute)
    r.register(Role.ADVANTAGE, NodeType.COMPUTE, stages.advantage_compute)
    r.register(Role.ACTOR, NodeType.MODEL_TRAIN, stages.actor_train)
    r.register(Role.CRITIC, NodeType.MODEL_TRAIN, stages.critic_train)
    return r
