"""Built-in stage functions — the bodies behind the Fig. 5 dispatch table.

Stage shardings realize the paper's per-stage parallelism: model-bound stages
(generate / inference / train) shard the batch over the `data` axes only (the
`model` axis carries TP), while pure COMPUTE stages (reward, advantage) shard
the batch over *all* axes — a genuinely different DP size, so the
Distributed Databuffer's redistribution path (Figs. 7-8) is exercised at
every model<->compute boundary exactly as in the paper.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.dag import Node


def _algo(ctx):
    """The AlgorithmSpec driving this run (bound by build_pipeline; resolved
    from the registry for hand-rolled contexts)."""
    from repro.rl import algorithms

    return algorithms.resolve(ctx)


def _specs(ctx):
    """(model-stage batch spec, compute-stage batch spec) for ctx.mesh."""
    axes = ctx.mesh.axis_names
    data_axes = tuple(a for a in axes if a != "model")
    model_spec = P(data_axes)
    compute_spec = P(tuple(axes))
    return model_spec, compute_spec


# --------------------------------------------------------------------------- #
def actor_generate(ctx, buffer, node: Node) -> Dict:
    """(ACTOR, GENERATE): pull the iteration's prompts from the worker-bound
    prompt iterator (``ctx.prompt_source``, already group-expanded), drive
    the generation engine — the jitted lockstep path or the slot-refill
    continuous-batching engine, same call contract — and store the
    trajectory. Continuous-engine runs additionally report the engine's
    tokens/sec, padding-waste, and slot-occupancy metrics."""
    model_spec, _ = _specs(ctx)
    if ctx.prompt_source is None:
        # hand-rolled ctx without a worker: bind the same iterator the
        # worker would, so group expansion has exactly one implementation
        from repro.core.worker import PromptSource

        ctx.prompt_source = PromptSource(
            ctx.dataloader, _algo(ctx).group_size(ctx.rl))
    prompts, answers = ctx.prompt_source.next_prompts()
    key = ctx.next_key()
    engine = ctx.engines["generate"]
    res = engine(ctx.actor_state.params, prompts, key)
    buffer.put("tokens", res.tokens, model_spec)
    buffer.put("response_mask", res.response_mask, model_spec)
    buffer.put("old_logprob", res.old_logprob, model_spec)
    buffer.put("answers", answers, model_spec)
    if res.role_mask is not None:
        # multi-turn episodes: per-token roles (0 prompt, 1 action, 2 env
        # observation) so downstream masking can be audited; response_mask
        # already excludes observation tokens
        buffer.put("role_mask", res.role_mask, model_spec)
    env_out = getattr(engine, "last_env", None)
    if env_out:
        # engine-driven episodes: env rewards/turns ride the buffer to the
        # (ENV, COMPUTE) stage (they repack with the batch under the load
        # balancer exactly like every other per-sequence key)
        buffer.put("env_rewards", jnp.asarray(env_out["rewards"]), model_spec)
        buffer.put("env_turns", jnp.asarray(env_out["turns"]), model_spec)
    gen_tokens = float(jnp.sum(res.lengths))
    ctx.counters["gen_tokens"] = ctx.counters.get("gen_tokens", 0.0) + gen_tokens
    out = {
        "rollout/mean_len": float(jnp.mean(res.lengths.astype(jnp.float32))),
        "rollout/tokens": gen_tokens,
    }
    stats = getattr(engine, "last_stats", None)
    if stats:  # continuous engine: slot/throughput accounting
        out.update({f"rollout/{k}": float(v) for k, v in stats.items()})
    return out


def actor_logprobs(ctx, buffer, node: Node) -> Dict:
    """(ACTOR, MODEL_INFERENCE): recompute behaviour logprobs under the
    training engine (verl does this because its rollout engine differs from
    its training engine; ours are exact, so this node is optional and used by
    custom DAGs to validate engine agreement)."""
    model_spec, _ = _specs(ctx)
    tokens = buffer.get("tokens", model_spec)
    lp, _ = ctx.engines["logprobs"](ctx.actor_state.params, tokens)
    buffer.put("old_logprob", lp * buffer.get("response_mask", model_spec), model_spec)
    return {}


def reference_logprobs(ctx, buffer, node: Node) -> Dict:
    model_spec, _ = _specs(ctx)
    tokens = buffer.get("tokens", model_spec)
    lp, _ = ctx.engines["logprobs"](ctx.ref_params, tokens)
    buffer.put("ref_logprob", lp, model_spec)
    return {}


def critic_values(ctx, buffer, node: Node) -> Dict:
    model_spec, _ = _specs(ctx)
    tokens = buffer.get("tokens", model_spec)
    v = ctx.engines["values"](ctx.critic_state.params, tokens)
    buffer.put("old_values", v, model_spec)
    return {}


def reward_compute(ctx, buffer, node: Node) -> Dict:
    """(REWARD, COMPUTE): function reward (paper's PPO uses a function reward
    in place of a reward model). Runs at compute-stage DP (all axes)."""
    _, compute_spec = _specs(ctx)
    tokens = buffer.get("tokens", compute_spec)
    mask = buffer.get("response_mask", compute_spec)
    answers = buffer.get("answers", P(compute_spec[0]))
    rewards = ctx.engines["reward"](tokens, mask, answers)
    buffer.put("rewards", rewards, P(compute_spec[0]))
    return {"reward/mean": float(jnp.mean(rewards))}


def env_compute(ctx, buffer, node: Node) -> Dict:
    """(ENV, COMPUTE): episode rewards from the environment subsystem
    (``repro.rl.envs``; replaces the REWARD stage when ``EnvConfig`` names an
    env). Engine-driven multi-turn runs already stepped the envs during
    generation — their rewards ride the buffer as ``env_rewards``; the
    lockstep engine's single-turn path steps each episode post-hoc over the
    finished rollout here."""
    _, compute_spec = _specs(ctx)
    seq_spec = P(compute_spec[0])
    out: Dict[str, float] = {}
    if "env_rewards" in buffer.keys():
        rewards = buffer.get("env_rewards", seq_spec)
        turns = buffer.get("env_turns", seq_spec)
        out["env/turns_mean"] = float(jnp.mean(turns.astype(jnp.float32)))
    else:
        if ctx.env is None:
            raise RuntimeError(
                "env_compute needs WorkerContext.env (an EnvRuntime); "
                "was the pipeline built with an enabled EnvConfig?"
            )
        tokens = buffer.get("tokens", compute_spec)
        mask = buffer.get("response_mask", compute_spec)
        rewards = jnp.asarray(ctx.env.score_single_turn(
            np.asarray(jax.device_get(tokens)),
            np.asarray(jax.device_get(mask))))
    buffer.put("rewards", rewards, seq_spec)
    out["reward/mean"] = float(jnp.mean(rewards))
    return out


def advantage_compute(ctx, buffer, node: Node) -> Dict:
    """(ADVANTAGE, COMPUTE): run the spec's advantage engine. The spec
    declares which extra buffer keys the engine consumes beyond
    (rewards, mask) — e.g. PPO's GAE reads logprobs + values — and which
    keys its outputs land under (advantages, and returns for critic
    algorithms)."""
    spec = _algo(ctx)
    _, compute_spec = _specs(ctx)
    seq_spec = P(compute_spec[0])
    mask = buffer.get("response_mask", compute_spec)
    rewards = buffer.get("rewards", seq_spec)
    extra = [buffer.get(k, compute_spec) for k in spec.advantage_inputs]
    out = ctx.engines["advantage"](rewards, mask, *extra)
    if len(spec.advantage_outputs) == 1:
        out = (out,)
    for key, val in zip(spec.advantage_outputs, out):
        buffer.put(key, val, compute_spec)
    return {}


def actor_train(ctx, buffer, node: Node) -> Dict:
    model_spec, _ = _specs(ctx)
    batch = {
        "tokens": buffer.get("tokens", model_spec),
        "response_mask": buffer.get("response_mask", model_spec),
        "old_logprob": buffer.get("old_logprob", model_spec),
        "advantages": buffer.get("advantages", model_spec),
    }
    if _algo(ctx).needs_reference:
        if "ref_logprob" in buffer.keys():
            batch["ref_logprob"] = buffer.get("ref_logprob", model_spec)
        else:
            # reference-free DAG variant (custom_dag example): KL term is 0
            batch["ref_logprob"] = batch["old_logprob"]
    if "behavior_logprob" in buffer.keys():
        # stale batch from the async scheduler: gen-time logprobs ride along
        # for the decoupled truncated-IS correction (trainer.apply_is_correction)
        batch["behavior_logprob"] = buffer.get("behavior_logprob", model_spec)
    ctx.actor_state, metrics = ctx.engines["actor_step"](ctx.actor_state, batch)
    return {f"actor/{k}": float(v) for k, v in metrics.items()}


def critic_train(ctx, buffer, node: Node) -> Dict:
    model_spec, _ = _specs(ctx)
    batch = {
        "tokens": buffer.get("tokens", model_spec),
        "response_mask": buffer.get("response_mask", model_spec),
        "old_values": buffer.get("old_values", model_spec),
        "returns": buffer.get("returns", model_spec),
    }
    ctx.critic_state, metrics = ctx.engines["critic_step"](ctx.critic_state, batch)
    return {f"critic/{k}": float(v) for k, v in metrics.items()}
