"""Distributed Databuffer (paper §6.2, Figs. 7-8).

One buffer instance per process (paper: per node, shared by its local
workers). Stage outputs are stored as *global* ``jax.Array``s whose shards
live on the producing stage's devices under the producing stage's sharding —
nothing is ever gathered to a controller.

At a stage boundary the consumer asks for a key under ITS sharding:
  * DP unchanged  -> the sharding matches: **fast path**, the exact same
    buffers are handed over (zero copy, zero collective) — the paper's
    shared-memory fast path.
  * DP changed    -> ``jax.device_put`` to the new NamedSharding; GSPMD lowers
    this to the all-to-all among peers of Fig. 7 (each source shard slices,
    sends, each destination concatenates). No central node participates.

The buffer records fast-path hits, redistributions, and bytes moved so
benchmarks can compare against the centralized baseline's all-to-one volume.
"""
from __future__ import annotations

import contextlib
import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class BufferStats:
    puts: int = 0
    fast_path_hits: int = 0
    redistributions: int = 0
    bytes_moved: int = 0  # bytes crossing device boundaries in redistributions
    bytes_through_controller: int = 0  # always 0 for the distributed buffer
    # per-destination-HOST inbound bytes of those redistributions (host index
    # from the mesh's pod axis / device process): the cross-host-awareness
    # invariant is that no host ever stages the full global array — its
    # inbound volume is only its own destination shards (tests/test_fleet.py
    # asserts max_host_inbound_bytes << the centralized all-to-one volume)
    host_inbound_bytes: Dict[int, int] = field(default_factory=dict)
    # double-buffer accounting (DoubleBufferedDatabuffer only):
    overlap_hits: int = 0  # gets served by a reshard issued ahead of time
    sync_waits: int = 0  # gets that had to issue the reshard on the spot
    rotations: int = 0  # iteration boundaries (slot swaps)

    @property
    def max_host_inbound_bytes(self) -> int:
        return max(self.host_inbound_bytes.values(), default=0)

    def reset(self):
        self.puts = self.fast_path_hits = self.redistributions = 0
        self.bytes_moved = self.bytes_through_controller = 0
        self.host_inbound_bytes = {}
        self.overlap_hits = self.sync_waits = self.rotations = 0


class DistributedDatabuffer:
    """Parallelism-aware intermediary between RL stages."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self._store: Dict[str, jax.Array] = {}
        self.stats = BufferStats()
        # device id -> host row, from the mesh's pod axis (fleet meshes) or
        # the devices' process index (real multi-host); flat local mesh = 1
        from repro.distributed.fleet import host_device_groups

        self._dev_host = {
            d: h for h, devs in enumerate(host_device_groups(mesh))
            for d in devs
        }

    # ------------------------------------------------------------------ #
    def put(self, key: str, value: jax.Array, spec: Optional[P] = None) -> None:
        """Store a stage output. If ``spec`` is given and the value is not yet
        a committed global array, shard it accordingly (this is where 'only
        TP rank 0 writes' is realized: the array is stored sharded over the
        data axes and replicated over `model`, so there is exactly one
        logical copy — TP replicas do not append duplicates)."""
        if spec is not None and (
            not isinstance(value, jax.Array)
            or not self._matches(value, spec)
        ):
            value = jax.device_put(value, NamedSharding(self.mesh, spec))
        self._store[key] = value
        self.stats.puts += 1

    def get(self, key: str, spec: Optional[P] = None) -> jax.Array:
        """Fetch under the consumer stage's sharding (None = as stored)."""
        value = self._store[key]
        if spec is None:
            return value
        if self._matches(value, spec):
            self.stats.fast_path_hits += 1  # DP unchanged: zero-copy handoff
            return value
        target = NamedSharding(self.mesh, spec)
        self.stats.redistributions += 1
        self._account_reshard(value, target)
        return jax.device_put(value, target)  # GSPMD all-to-all among peers

    def keys(self):
        return list(self._store)

    def pop(self, key: str) -> jax.Array:
        return self._store.pop(key)

    def clear(self) -> None:
        self._store.clear()

    # ------------------------------------------------------------------ #
    def _account_reshard(self, value: jax.Array, target: NamedSharding) -> None:
        """Charge one redistribution's traffic to its destination hosts.

        For each destination device, the bytes of its target index slice are
        charged to that device's host — deduped per (host, slice) so model-
        axis replicas on the same host count once, and skipped entirely when
        the identical slice is already resident on that host under the
        source sharding (no inter-host traffic for data that never leaves).
        This is exactly the "stage per-host destination shards only"
        property: no host's inbound volume ever approaches the full global
        array, unlike the centralized baseline's all-to-one gather.
        """
        item = value.dtype.itemsize

        def slice_bytes(index) -> int:
            n = item
            for sl, dim in zip(index, value.shape):
                start, stop, _ = sl.indices(dim)
                n *= max(stop - start, 0)
            return n

        def key_of(index) -> tuple:
            return tuple(sl.indices(dim)
                         for sl, dim in zip(index, value.shape))

        try:
            tmap = target.devices_indices_map(value.shape)
            sh = getattr(value, "sharding", None)
            smap = (sh.devices_indices_map(value.shape)
                    if sh is not None else {})
        except (TypeError, ValueError, AttributeError):
            self.stats.bytes_moved += value.size * item  # conservative
            return
        resident: Dict[int, set] = {}
        for d, idx in smap.items():
            resident.setdefault(
                self._dev_host.get(d.id, 0), set()).add(key_of(idx))
        seen: Dict[int, set] = {}
        moved = 0
        for d, idx in tmap.items():
            h = self._dev_host.get(d.id, 0)
            k = key_of(idx)
            if k in seen.setdefault(h, set()):
                continue  # replicated copy on the same host: one transfer
            seen[h].add(k)
            if k in resident.get(h, set()):
                continue  # already resident on this host
            b = slice_bytes(idx)
            moved += b
            self.stats.host_inbound_bytes[h] = (
                self.stats.host_inbound_bytes.get(h, 0) + b
            )
        self.stats.bytes_moved += moved

    # ------------------------------------------------------------------ #
    def _matches(self, value: jax.Array, spec: P) -> bool:
        sh = getattr(value, "sharding", None)
        if not isinstance(sh, NamedSharding):
            return False
        if sh.mesh is not self.mesh and sh.mesh != self.mesh:
            return False
        return _normalize(sh.spec, value.ndim) == _normalize(spec, value.ndim)


def _normalize(spec: P, ndim: int) -> tuple:
    """Pad with None to ndim and canonicalize single-axis tuples, so
    P('data'), P('data', None) and P(('data',), None) all compare equal."""
    parts = list(spec) + [None] * (ndim - len(spec))
    out = []
    for p in parts:
        if isinstance(p, tuple):
            p = p[0] if len(p) == 1 else p
        out.append(p)
    return tuple(out)


class DoubleBufferedDatabuffer(DistributedDatabuffer):
    """Asynchronous double buffer (paper §6.2: "local caching, load balancing,
    and asynchronous double buffer").

    Two rotating slots decouple producer and consumer iterations: ``clear()``
    at an iteration boundary *rotates* instead of dropping — the retired
    slot's arrays stay referenced, so transfers still in flight for iteration
    i's consumers are never invalidated while iteration i+1's producers
    already fill the other slot.

    On top of the slots sits spec prefetch: the buffer records, per key, the
    PartitionSpecs consumers have historically requested (iteration 0 is the
    recording pass). From then on every ``put`` immediately issues the
    ``jax.device_put`` toward each recorded consumer sharding. JAX dispatch
    is asynchronous, so the GSPMD all-to-all for stage boundary k+1 runs
    while the host is still driving stage k — ``get`` then finds the staged
    array and returns it without issuing (or waiting on dispatch of) any
    transfer. ``overlap_hits`` counts those; ``sync_waits`` counts gets that
    still had to reshard on the spot (first iteration, or a never-seen spec).

    Values are bitwise-identical to the synchronous path: the staged array is
    the product of exactly the same ``device_put`` the base class would issue
    inside ``get``, just dispatched earlier.
    """

    def __init__(self, mesh: Mesh):
        super().__init__(mesh)
        self._slots = [{}, {}]
        self._staged_slots = [{}, {}]  # (key, norm_spec) -> prefetched array
        self._active = 0
        self._store = self._slots[0]
        self._staged = self._staged_slots[0]
        # key -> {normalized spec -> PartitionSpec} learned from consumers
        self._consumer_specs: Dict[str, Dict[tuple, P]] = {}
        self._staging_paused = False

    # ------------------------------------------------------------------ #
    def put(self, key: str, value: jax.Array, spec: Optional[P] = None) -> None:
        # drop any staged reshard of a previous value under this key
        for sk in [sk for sk in self._staged if sk[0] == key]:
            del self._staged[sk]
        super().put(key, value, spec)
        self._stage(key)

    def prefetch(self, key: str, spec: P) -> None:
        """Explicitly pre-declare a consumer sharding (optional API: the
        learned path makes this unnecessary after the first iteration)."""
        stored = self._store.get(key)
        if stored is None:
            return
        norm = _normalize(spec, stored.ndim)
        self._consumer_specs.setdefault(key, {})[norm] = spec
        self._stage(key)

    @contextlib.contextmanager
    def staging_paused(self):
        """Suspend put-time staging, then stage the final contents once on
        exit. Used by the worker around stages that rewrite their own outputs
        (the load-balance repack re-puts every rollout key), so each key's
        reshard is dispatched once, for the value consumers will read."""
        self._staging_paused = True
        try:
            yield
        finally:
            self._staging_paused = False
            for key in list(self._store):
                self._stage(key)

    def _stage(self, key: str) -> None:
        """Issue async reshards of ``key`` toward every recorded consumer
        sharding that differs from how the value is stored."""
        if self._staging_paused:
            return
        value = self._store[key]
        for norm, spec in self._consumer_specs.get(key, {}).items():
            if self._matches(value, spec) or (key, norm) in self._staged:
                continue
            target = NamedSharding(self.mesh, spec)
            self.stats.redistributions += 1
            self._account_reshard(value, target)
            # async dispatch: returns immediately, transfer overlaps compute
            self._staged[(key, norm)] = jax.device_put(value, target)

    def get(self, key: str, spec: Optional[P] = None) -> jax.Array:
        value = self._store[key]
        if spec is None:
            return value
        norm = _normalize(spec, value.ndim)
        self._consumer_specs.setdefault(key, {})[norm] = spec
        if self._matches(value, spec):
            self.stats.fast_path_hits += 1
            return value
        staged = self._staged.get((key, norm))
        if staged is not None:
            self.stats.overlap_hits += 1  # transfer already in flight / done
            return staged
        self.stats.sync_waits += 1
        target = NamedSharding(self.mesh, spec)
        self.stats.redistributions += 1
        self._account_reshard(value, target)
        out = jax.device_put(value, target)
        self._staged[(key, norm)] = out  # serve repeat gets from the cache
        return out

    def pop(self, key: str) -> jax.Array:
        for sk in [sk for sk in self._staged if sk[0] == key]:
            del self._staged[sk]
        return self._store.pop(key)

    def rotate(self) -> None:
        """Iteration boundary: swap slots; the new active slot starts empty
        while the retired slot keeps its references alive for in-flight
        consumers of the previous iteration."""
        self._active ^= 1
        self._store = self._slots[self._active]
        self._staged = self._staged_slots[self._active]
        self._store.clear()
        self._staged.clear()
        self.stats.rotations += 1

    def clear(self) -> None:
        # the worker calls clear() at end of iteration; for the double buffer
        # that is a rotation, not a drop (paper's asynchronous double buffer)
        self.rotate()


class CentralizedDatabuffer(DistributedDatabuffer):
    """The single-controller baseline arm (paper Fig. 2, the verl-style
    hybrid-controller dataflow): every stage output is gathered to the
    controller (host rank 0) and re-dispatched from there. Functionally
    identical; the all-to-one / one-to-all traffic and the controller-resident
    bytes are what the paper identifies as the scaling bottleneck, and what
    our benchmarks measure."""

    def __init__(self, mesh: Mesh):
        super().__init__(mesh)
        self.controller_resident_bytes = 0  # peak bytes held by controller

    def put(self, key: str, value: jax.Array, spec: Optional[P] = None) -> None:
        # all-to-one: controller materializes the full global batch on host
        host_value = jax.device_get(value)  # gather to the controller
        nbytes = host_value.size * host_value.dtype.itemsize
        self.stats.bytes_through_controller += nbytes
        # the whole array lands on the controller host — the inbound-volume
        # contrast with the distributed buffer's per-host shards
        self.stats.host_inbound_bytes[0] = (
            self.stats.host_inbound_bytes.get(0, 0) + nbytes
        )
        self._host_store = getattr(self, "_host_store", {})
        self._host_store[key] = host_value
        self.controller_resident_bytes = max(
            self.controller_resident_bytes,
            sum(v.size * v.dtype.itemsize for v in self._host_store.values()),
        )
        self.stats.puts += 1

    def get(self, key: str, spec: Optional[P] = None) -> jax.Array:
        # one-to-all: controller re-dispatches to the consumer's sharding
        host_value = self._host_store[key]
        nbytes = host_value.size * host_value.dtype.itemsize
        self.stats.bytes_through_controller += nbytes
        self.stats.redistributions += 1
        if spec is None:
            spec = P()
        return jax.device_put(host_value, NamedSharding(self.mesh, spec))

    def clear(self) -> None:
        super().clear()
        if hasattr(self, "_host_store"):
            self._host_store.clear()
