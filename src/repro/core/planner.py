"""DAG Planner (paper §4.2, Fig. 4).

Translates the logical DAG into a linearized execution pipeline safe for a
colocated cluster where all models share one resource pool: nodes at the same
logical depth (would-be parallel) are serialized by injecting dependencies, so
only one node is ever active — avoiding resource contention / OOM from two
engines running at once. The planner then replicates the resulting task chain
across DAG Workers (every worker executes the same chain on its own data
shard — the multi-controller SPMD execution model).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.dag import DAG, Node, NodeType, Role


@dataclass(frozen=True)
class StageTask:
    """Smallest executable unit dispatched to a DAG Worker."""

    node: Node
    order: int  # position in the serialized chain
    # the serialized predecessor (includes injected serialization edges)
    after: Optional[str]


@dataclass(frozen=True)
class ExecutionPlan:
    tasks: Tuple[StageTask, ...]
    injected_edges: Tuple[Tuple[str, str], ...]  # (prerequisite, node)

    @property
    def order(self) -> List[str]:
        return [t.node.node_id for t in self.tasks]


class DAGPlanner:
    """Decompose + serialize a user DAG into a per-worker task chain."""

    def plan(self, dag: DAG) -> ExecutionPlan:
        tasks: List[StageTask] = []
        injected: List[Tuple[str, str]] = []
        prev: Optional[str] = None
        for level in dag.levels():
            # Same-depth nodes imply parallel execution: serialize them in a
            # deterministic (node_id) order, injecting an edge from each to
            # the next (paper Fig. 4: Inference I becomes a prerequisite of
            # Inference II).
            for n in level:
                if prev is not None and prev not in n.deps:
                    injected.append((prev, n.node_id))
                tasks.append(StageTask(node=n, order=len(tasks), after=prev))
                prev = n.node_id
        return ExecutionPlan(tasks=tuple(tasks), injected_edges=tuple(injected))

    def plan_for_workers(self, dag: DAG, num_workers: int) -> List[ExecutionPlan]:
        """Replicate the chain across workers (paper §3: DAG tasks 'can be
        replicated across different DAG Workers', one per GPU). Every worker
        receives an identical chain; the Data Coordinator gives each its own
        data shard."""
        plan = self.plan(dag)
        return [plan] * num_workers


def validate_serialization(plan: ExecutionPlan) -> bool:
    """Invariant: at most one node active at any time — i.e. the chain is a
    total order consistent with all (original + injected) edges."""
    pos = {t.node.node_id: i for i, t in enumerate(plan.tasks)}
    for t in plan.tasks:
        for d in t.node.deps:
            if pos[d] >= pos[t.node.node_id]:
                return False
    for pre, nxt in plan.injected_edges:
        if pos[pre] >= pos[nxt]:
            return False
    return True
