"""Built-in RL pipelines (paper Fig. 1) + the end-to-end driver.

``build_pipeline`` is a thin compiler over specs: it resolves the
:class:`~repro.rl.algorithms.AlgorithmSpec` for ``rl.algorithm`` (or takes one
directly), wires together every subsystem — model init, jitted engines, the
DAG (the spec's template or user-supplied), the planner's serialized chain,
the Data Coordinator (Distributed Dataloader + Databuffer), and a DAG Worker.
No layer below this point ever inspects the algorithm *name*; they only see
the spec's callables. ``centralized=True`` swaps in the single-controller
databuffer — the baseline arm for the paper's comparisons.

The user-facing entry point is :class:`repro.api.ExperimentSpec`, whose
``compile()`` lands here.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import (
    AsyncPipelineConfig,
    DataCoordinatorConfig,
    DistributedConfig,
    EnvConfig,
    ModelConfig,
    RolloutEngineConfig,
)
from repro.core.dag import DAG
from repro.core.databuffer import (
    CentralizedDatabuffer,
    DistributedDatabuffer,
    DoubleBufferedDatabuffer,
)
from repro.core.planner import DAGPlanner
from repro.core.registry import Registry, default_registry
from repro.core.worker import DAGWorker, WorkerContext
from repro.data.dataloader import DistributedDataloader
from repro.data.dataset import SyntheticMathDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_model
from repro.rl import critic as critic_mod
from repro.rl import rollout as rollout_mod
from repro.rl import trainer
from repro.rl.trainer import RLConfig


# --------------------------------------------------------------------------- #
# built-in DAGs (paper Fig. 1) — re-exported from the algorithm registry for
# backward compatibility; the templates now live with their specs.
# --------------------------------------------------------------------------- #
def grpo_dag() -> DAG:
    from repro.rl import algorithms

    return algorithms.grpo_dag()


def ppo_dag() -> DAG:
    from repro.rl import algorithms

    return algorithms.ppo_dag()


# --------------------------------------------------------------------------- #
def _build_engines(model, cfg: ModelConfig, rl: RLConfig, tok: ByteTokenizer,
                   spec, rollout: Optional[RolloutEngineConfig] = None,
                   env_runtime=None):
    """Jitted engines for one algorithm spec. The advantage engine comes from
    ``spec.make_advantage``; critic engines exist iff the spec uses a critic.
    The GENERATE engine is either the jitted lockstep ``rollout.generate`` or
    the slot-refill :class:`~repro.rl.rollout_engine.ContinuousRolloutEngine`
    (``RolloutEngineConfig.engine == "continuous"``) — same call contract,
    same RolloutResult. An ``env_runtime`` turns the continuous engine's slot
    loop into the multi-turn episode loop (docs/environments.md)."""
    from repro.rl import envs as envs_mod

    eng: Dict[str, Any] = {}

    def _generate(params, prompts, key):
        return rollout_mod.generate(
            model, params, prompts, key,
            max_new=rl.max_new_tokens, temperature=rl.temperature,
            eos_id=tok.eos_id, pad_id=tok.pad_id,
        )

    if rollout is not None and rollout.engine == "continuous":
        from repro.rl.rollout_engine import ContinuousRolloutEngine

        env_kw = {}
        if env_runtime is not None:
            env_kw = dict(
                env=env_runtime,
                max_turns=env_runtime.cfg.max_turns,
                turn_budget=env_runtime.cfg.turn_budget,
                obs_budget=env_runtime.cfg.obs_budget,
            )
        eng["generate"] = ContinuousRolloutEngine(
            model,
            max_new=rl.max_new_tokens,
            temperature=rl.temperature,
            eos_id=tok.eos_id,
            pad_id=tok.pad_id,
            num_slots=rollout.num_slots,
            prefill_chunk=rollout.prefill_chunk,
            prefill_bucket=rollout.prefill_bucket,
            refill_threshold=rollout.refill_threshold,
            **env_kw,
        )
    else:
        eng["generate"] = jax.jit(_generate)
    eng["logprobs"] = jax.jit(lambda p, t: model.logprobs(p, t))
    # the REWARD stage's scorer is resolved from the reward registry (the
    # default "math" is exactly the pre-registry math_reward_tokens path)
    reward_name = env_runtime.cfg.reward if env_runtime is not None else "math"
    token_fn = envs_mod.get_reward(reward_name).token_fn
    eng["reward"] = jax.jit(
        lambda tokens, mask, answers: token_fn(tokens, mask, answers, tok)
    )
    eng["advantage"] = jax.jit(spec.make_advantage(rl))
    if spec.uses_critic:
        eng["values"] = jax.jit(
            lambda p, t: critic_mod.values_fn(model.cfg, p, t)
        )
        eng["critic_step"] = jax.jit(trainer.make_critic_step(model.cfg, rl))
    eng["actor_step"] = jax.jit(trainer.make_actor_step(model, rl,
                                                        algorithm=spec))
    return eng


@dataclasses.dataclass
class Pipeline:
    worker: DAGWorker
    ctx: WorkerContext
    buffer: DistributedDatabuffer
    dag: DAG
    plan: Any

    def run(self, iterations: int):
        history = []
        for _ in range(iterations):
            history.append(self.worker.run_iteration())
        return history


def build_pipeline(
    cfg: ModelConfig,
    rl: RLConfig,
    *,
    mesh: Optional[Mesh] = None,
    dag: Optional[DAG] = None,
    dataset=None,
    prompts_per_iter: int = 8,
    centralized: bool = False,
    coordinator: Optional[DataCoordinatorConfig] = None,
    async_pipeline: Optional[AsyncPipelineConfig] = None,
    rollout: Optional[RolloutEngineConfig] = None,
    env: Optional[EnvConfig] = None,
    distributed: Optional[DistributedConfig] = None,
    obs=None,
    registry: Optional[Registry] = None,
    algorithm=None,
    seed: int = 0,
) -> Pipeline:
    from repro.rl import algorithms
    from repro.rl import envs as envs_mod

    spec = algorithm or algorithms.get_algorithm(rl.algorithm)
    coordinator = coordinator or DataCoordinatorConfig()
    if distributed is not None and distributed.enabled:
        if centralized:
            raise ValueError(
                "a multi-host fleet has no single controller to centralize "
                "through; distributed cannot be combined with centralized=True"
            )
        if async_pipeline is not None and async_pipeline.enabled:
            raise ValueError(
                "the fleet gradient exchange is a per-iteration collective; "
                "combine it with the async pipeline once the exchange is "
                "staleness-aware (not yet supported)"
            )
        if mesh is None:
            from repro.launch.mesh import make_fleet_mesh

            mesh = make_fleet_mesh(
                distributed.num_hosts, distributed.devices_per_host
            )
    if mesh is None:
        from repro.launch.mesh import make_compat_mesh

        mesh = make_compat_mesh((1, 1), ("data", "model"))
    tok = ByteTokenizer()
    assert cfg.vocab_size >= tok.vocab_size, "model vocab must cover the tokenizer"
    model = get_model(cfg)

    env_runtime = None
    if env is not None and env.enabled:
        if env.max_turns > 1 and (rollout is None
                                  or rollout.engine != "continuous"):
            raise ValueError(
                "multi-turn environments need the continuous rollout "
                "engine's episode loop: set RolloutEngineConfig("
                "engine='continuous') (single-turn envs run on either engine)"
            )
        env_runtime = envs_mod.EnvRuntime(envs_mod.get_env(env.name), env, tok)

    key = jax.random.PRNGKey(seed)
    k_actor, k_critic, k_run = jax.random.split(key, 3)
    actor_params = model.init(k_actor)
    ref_params = jax.tree.map(jnp.copy, actor_params)  # frozen reference

    ctx = WorkerContext(
        mesh=mesh,
        rl=rl,
        engines=_build_engines(model, cfg, rl, tok, spec, rollout,
                               env_runtime),
        dataloader=DistributedDataloader(
            dataset or SyntheticMathDataset(4096, seed=seed),
            mesh=mesh,
            global_batch=prompts_per_iter,
            seed=seed,
            prefetch=coordinator.prefetch,
        ),
        actor_state=trainer.init_state(actor_params),
        ref_params=ref_params,
        tokenizer=tok,
        key=k_run,
        algorithm=spec,
    )
    if spec.uses_critic:
        ctx.critic_state = trainer.init_state(critic_mod.init(cfg, k_critic))
    ctx.env = env_runtime

    if distributed is not None and distributed.enabled:
        # Fleet DP gradient exchange: split the fused actor step so the
        # gradient crosses the host data plane between grad and apply —
        # bitwise-equivalent to the fused step when grad_compression="none"
        # (tests/test_fleet.py), genuinely int8 on the wire otherwise.
        from repro.distributed import fleet as fleet_mod

        fleet_ctx = fleet_mod.ensure_context(distributed)
        exchange = fleet_mod.GradExchange(
            fleet_ctx, distributed.grad_compression
        )
        ctx.engines["actor_step"] = fleet_mod.fleet_actor_step(
            jax.jit(trainer.make_actor_grad_fn(model, rl, algorithm=spec)),
            jax.jit(trainer.make_actor_apply_fn(rl)),
            exchange,
        )
        ctx.fleet = fleet_ctx
        ctx.grad_exchange = exchange

    if obs is not None and obs.enabled:
        # Telemetry runtime: a process-global tracer (instrumented call
        # sites reach it via obs.get_tracer) plus a registry that absorbs
        # each iteration's metrics dict. Disabled obs leaves the global
        # tracer untouched — the zero-overhead default path.
        from repro import obs as obs_mod

        tracer = obs_mod.Tracer(
            enabled=obs.trace,
            host=distributed.process_id if distributed is not None else 0,
            capacity=obs.ring_capacity,
        )
        obs_mod.set_tracer(tracer)
        ctx.obs = obs_mod.ObsState(
            cfg=obs, tracer=tracer, registry=obs_mod.MetricsRegistry()
        )

    dag = dag or spec.dag_factory()
    if env_runtime is not None:
        # retarget the reward node at the environment stage (the env writes
        # the same `rewards` buffer key; validate_dag treats ENV as REWARD)
        dag = envs_mod.with_env_stage(dag)
    spec.validate_dag(dag)
    plan = DAGPlanner().plan(dag)
    if centralized:
        buffer_cls = CentralizedDatabuffer
    elif coordinator.double_buffer:
        buffer_cls = DoubleBufferedDatabuffer
    else:
        buffer_cls = DistributedDatabuffer
    buffer = buffer_cls(mesh)
    if async_pipeline is not None and async_pipeline.enabled:
        if centralized:
            raise ValueError(
                "the centralized baseline gathers every stage output through "
                "one controller and is inherently synchronous; async_pipeline "
                "cannot be combined with centralized=True"
            )
        from repro.core.async_worker import AsyncDAGWorker

        worker = AsyncDAGWorker(ctx, plan, registry or default_registry(),
                                buffer, coordinator,
                                async_cfg=async_pipeline)
    else:
        worker = DAGWorker(ctx, plan, registry or default_registry(), buffer,
                           coordinator)
    return Pipeline(worker=worker, ctx=ctx, buffer=buffer, dag=dag, plan=plan)
