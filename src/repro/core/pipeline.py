"""Built-in RL pipelines (paper Fig. 1) + the end-to-end driver.

``build_pipeline`` wires together every subsystem: model init, jitted engines,
the DAG (built-in PPO/GRPO or user-supplied), the planner's serialized chain,
the Data Coordinator (Distributed Dataloader + Databuffer), and a DAG Worker.
``centralized=True`` swaps in the single-controller databuffer — the baseline
arm for the paper's comparisons.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import DataCoordinatorConfig, ModelConfig
from repro.core.dag import DAG, Node, NodeType, Role
from repro.core.databuffer import (
    CentralizedDatabuffer,
    DistributedDatabuffer,
    DoubleBufferedDatabuffer,
)
from repro.core.planner import DAGPlanner
from repro.core.registry import Registry, default_registry
from repro.core.worker import DAGWorker, WorkerContext
from repro.data.dataloader import DistributedDataloader
from repro.data.dataset import SyntheticMathDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_model
from repro.rl import advantage as adv_mod
from repro.rl import critic as critic_mod
from repro.rl import reward as reward_mod
from repro.rl import rollout as rollout_mod
from repro.rl import trainer
from repro.rl.trainer import RLConfig


# --------------------------------------------------------------------------- #
# built-in DAGs (paper Fig. 1)
# --------------------------------------------------------------------------- #
def grpo_dag() -> DAG:
    return DAG.from_nodes(
        [
            Node("actor_generation", Role.ACTOR, NodeType.GENERATE),
            Node("reference_inference", Role.REFERENCE, NodeType.MODEL_INFERENCE,
                 deps=("actor_generation",)),
            Node("reward_compute", Role.REWARD, NodeType.COMPUTE,
                 deps=("actor_generation",)),
            Node("advantage_compute", Role.ADVANTAGE, NodeType.COMPUTE,
                 deps=("reward_compute",)),
            Node("actor_train", Role.ACTOR, NodeType.MODEL_TRAIN,
                 deps=("reference_inference", "advantage_compute")),
        ]
    )


def ppo_dag() -> DAG:
    return DAG.from_nodes(
        [
            Node("actor_generation", Role.ACTOR, NodeType.GENERATE),
            Node("reference_inference", Role.REFERENCE, NodeType.MODEL_INFERENCE,
                 deps=("actor_generation",)),
            Node("reward_compute", Role.REWARD, NodeType.COMPUTE,
                 deps=("actor_generation",)),
            Node("critic_inference", Role.CRITIC, NodeType.MODEL_INFERENCE,
                 deps=("actor_generation",)),
            Node("advantage_compute", Role.ADVANTAGE, NodeType.COMPUTE,
                 deps=("reward_compute", "critic_inference",
                       "reference_inference")),
            Node("actor_train", Role.ACTOR, NodeType.MODEL_TRAIN,
                 deps=("advantage_compute",)),
            Node("critic_train", Role.CRITIC, NodeType.MODEL_TRAIN,
                 deps=("advantage_compute",)),
        ]
    )


# --------------------------------------------------------------------------- #
def _build_engines(model, cfg: ModelConfig, rl: RLConfig, tok: ByteTokenizer):
    eng: Dict[str, Any] = {}

    def _generate(params, prompts, key):
        return rollout_mod.generate(
            model, params, prompts, key,
            max_new=rl.max_new_tokens, temperature=rl.temperature,
            eos_id=tok.eos_id, pad_id=tok.pad_id,
        )

    eng["generate"] = jax.jit(_generate)
    eng["logprobs"] = jax.jit(lambda p, t: model.logprobs(p, t))
    eng["reward"] = jax.jit(
        lambda tokens, mask, answers: reward_mod.math_reward_tokens(
            tokens, mask, answers, tok
        )
    )
    if rl.algorithm == "grpo":
        eng["advantage"] = jax.jit(
            lambda rewards, mask: adv_mod.grpo(rewards, mask, group_size=rl.group_size)
        )
    else:
        def _ppo_adv(rewards, mask, old_lp, ref_lp, values):
            B, T = mask.shape
            kl = old_lp - ref_lp  # per-token KL estimate (k1)
            m = mask.astype(jnp.float32)
            # terminal reward at the last response token
            last = jnp.maximum(jnp.sum(m, axis=1) - 1, 0).astype(jnp.int32)
            first = jnp.argmax(mask, axis=1)
            pos = jnp.clip(first + last, 0, T - 1)
            tok_rewards = -rl.kl_coef * kl * m
            tok_rewards = tok_rewards.at[jnp.arange(B), pos].add(rewards)
            adv, ret = adv_mod.gae(
                tok_rewards, values * m, m, gamma=rl.gamma, lam=rl.gae_lambda
            )
            return adv_mod.whiten(adv, m), ret

        eng["advantage"] = jax.jit(_ppo_adv)
        eng["values"] = jax.jit(
            lambda p, t: critic_mod.values_fn(model.cfg, p, t)
        )
        eng["critic_step"] = jax.jit(trainer.make_critic_step(model.cfg, rl))
    eng["actor_step"] = jax.jit(trainer.make_actor_step(model, rl))
    return eng


@dataclasses.dataclass
class Pipeline:
    worker: DAGWorker
    ctx: WorkerContext
    buffer: DistributedDatabuffer
    dag: DAG
    plan: Any

    def run(self, iterations: int):
        history = []
        for _ in range(iterations):
            history.append(self.worker.run_iteration())
        return history


def build_pipeline(
    cfg: ModelConfig,
    rl: RLConfig,
    *,
    mesh: Optional[Mesh] = None,
    dag: Optional[DAG] = None,
    dataset=None,
    prompts_per_iter: int = 8,
    centralized: bool = False,
    coordinator: Optional[DataCoordinatorConfig] = None,
    registry: Optional[Registry] = None,
    seed: int = 0,
) -> Pipeline:
    coordinator = coordinator or DataCoordinatorConfig()
    if mesh is None:
        from repro.launch.mesh import make_compat_mesh

        mesh = make_compat_mesh((1, 1), ("data", "model"))
    tok = ByteTokenizer()
    assert cfg.vocab_size >= tok.vocab_size, "model vocab must cover the tokenizer"
    model = get_model(cfg)

    key = jax.random.PRNGKey(seed)
    k_actor, k_critic, k_run = jax.random.split(key, 3)
    actor_params = model.init(k_actor)
    ref_params = jax.tree.map(jnp.copy, actor_params)  # frozen reference

    ctx = WorkerContext(
        mesh=mesh,
        rl=rl,
        engines=_build_engines(model, cfg, rl, tok),
        dataloader=DistributedDataloader(
            dataset or SyntheticMathDataset(4096, seed=seed),
            mesh=mesh,
            global_batch=prompts_per_iter,
            seed=seed,
            prefetch=coordinator.prefetch,
        ),
        actor_state=trainer.init_state(actor_params),
        ref_params=ref_params,
        tokenizer=tok,
        key=k_run,
    )
    if rl.algorithm == "ppo":
        ctx.critic_state = trainer.init_state(critic_mod.init(cfg, k_critic))

    dag = dag or (grpo_dag() if rl.algorithm == "grpo" else ppo_dag())
    plan = DAGPlanner().plan(dag)
    if centralized:
        buffer_cls = CentralizedDatabuffer
    elif coordinator.double_buffer:
        buffer_cls = DoubleBufferedDatabuffer
    else:
        buffer_cls = DistributedDatabuffer
    buffer = buffer_cls(mesh)
    worker = DAGWorker(ctx, plan, registry or default_registry(), buffer,
                       coordinator)
    return Pipeline(worker=worker, ctx=ctx, buffer=buffer, dag=dag, plan=plan)
