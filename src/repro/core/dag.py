"""The user-facing DAG abstraction (paper §4.1).

A node is (Node ID, Role, Type, Dependencies) exactly as the paper defines:
Role names the functional model (ACTOR / CRITIC / REWARD / REFERENCE / ...),
Type names the computation class (GENERATE / MODEL_INFERENCE / MODEL_TRAIN /
COMPUTE), and Dependencies fix the data flow. DAGs are declared in python or
loaded from a JSON config file — the "researchers define their entire RL
workflow in a DAG" interface.
"""
from __future__ import annotations

import dataclasses
import enum
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


class Role(str, enum.Enum):
    ACTOR = "actor"
    CRITIC = "critic"
    REWARD = "reward"
    REFERENCE = "reference"
    ADVANTAGE = "advantage"
    ENV = "env"  # environment stage: episode rewards in place of REWARD
    DATA = "data"


class NodeType(str, enum.Enum):
    GENERATE = "generate"
    MODEL_INFERENCE = "model_inference"
    MODEL_TRAIN = "model_train"
    COMPUTE = "compute"


@dataclass(frozen=True)
class Node:
    node_id: str
    role: Role
    type: NodeType
    deps: Tuple[str, ...] = ()
    # per-stage resource config (paper: "each stage may employ different
    # parallel strategies"): logical dp/tp requested for this node's engine.
    parallelism: Dict[str, int] = field(default_factory=dict)

    @property
    def fn_key(self) -> Tuple[Role, NodeType]:
        return (self.role, self.type)


class DAGError(ValueError):
    pass


@dataclass
class DAG:
    nodes: Dict[str, Node]

    def __post_init__(self):
        self.validate()

    @classmethod
    def from_nodes(cls, nodes: Sequence[Node]) -> "DAG":
        d = {}
        for n in nodes:
            if n.node_id in d:
                raise DAGError(f"duplicate node id {n.node_id!r}")
            d[n.node_id] = n
        return cls(nodes=d)

    @classmethod
    def from_spec(cls, spec: Dict) -> "DAG":
        """Build a DAG from the in-memory config form: a dict with a
        ``nodes`` list (the same schema ``to_spec``/``to_json`` emit). This
        is what lets DAG definitions travel inside an ExperimentSpec instead
        of requiring a file on disk."""
        if "nodes" not in spec:
            raise DAGError("DAG spec must contain a 'nodes' list")
        nodes = [
            Node(
                node_id=n["id"],
                role=Role(n["role"]),
                type=NodeType(n["type"]),
                deps=tuple(n.get("deps", ())),
                parallelism=dict(n.get("parallelism", {})),
            )
            for n in spec["nodes"]
        ]
        return cls.from_nodes(nodes)

    @classmethod
    def loads(cls, s: str) -> "DAG":
        """Parse a DAG from a JSON string (``to_json`` round-trips)."""
        return cls.from_spec(json.loads(s))

    @classmethod
    def from_json(cls, path: str) -> "DAG":
        """Load the paper's config-file form from a file path."""
        with open(path) as f:
            return cls.from_spec(json.load(f))

    def validate(self) -> None:
        for n in self.nodes.values():
            for d in n.deps:
                if d not in self.nodes:
                    raise DAGError(f"{n.node_id}: unknown dependency {d!r}")
        # acyclicity via depth computation (raises on cycles)
        self.depths()

    def depths(self) -> Dict[str, int]:
        """Longest-path depth per node; DAGError on cycles."""
        memo: Dict[str, int] = {}
        visiting = set()

        def depth(nid: str) -> int:
            if nid in memo:
                return memo[nid]
            if nid in visiting:
                raise DAGError(f"cycle through {nid!r}")
            visiting.add(nid)
            n = self.nodes[nid]
            memo[nid] = 0 if not n.deps else 1 + max(depth(d) for d in n.deps)
            visiting.discard(nid)
            return memo[nid]

        for nid in self.nodes:
            depth(nid)
        return memo

    def levels(self) -> List[List[Node]]:
        """Nodes grouped by depth (ascending); same-level nodes are the
        'parallel nodes' the planner must serialize (paper Fig. 4)."""
        depths = self.depths()
        out: Dict[int, List[Node]] = {}
        for nid, d in depths.items():
            out.setdefault(d, []).append(self.nodes[nid])
        return [sorted(out[d], key=lambda n: n.node_id) for d in sorted(out)]

    def to_spec(self) -> Dict:
        """The in-memory config form (inverse of ``from_spec``)."""
        return {
            "nodes": [
                {
                    "id": n.node_id,
                    "role": n.role.value,
                    "type": n.type.value,
                    "deps": list(n.deps),
                    "parallelism": dict(n.parallelism),
                }
                for n in self.nodes.values()
            ]
        }

    def to_json(self) -> str:
        return json.dumps(self.to_spec(), indent=2)
