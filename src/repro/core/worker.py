"""DAG Worker (paper §5): the per-process controller.

Lifecycle: Initialization (bind functions to nodes via the registry,
materialize the execution queue) + iterative Execution (walk the chain, the
databuffer brokering every stage boundary). In JAX SPMD every process runs an
identical DAGWorker over its own data shard — the multi-controller paradigm;
there is no coordinator process anywhere.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.databuffer import DistributedDatabuffer
from repro.core.dag import Node
from repro.core.planner import ExecutionPlan
from repro.core.registry import Registry


@dataclass
class WorkerContext:
    """Everything a stage function may touch. Mutable fields (actor_state,
    critic_state) are updated in place by train nodes."""

    mesh: Any
    rl: Any
    engines: Dict[str, Callable]
    dataloader: Any
    actor_state: Any = None
    critic_state: Any = None
    ref_params: Any = None
    tokenizer: Any = None
    key: Any = None
    counters: Dict[str, float] = field(default_factory=dict)

    def next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub


class DAGWorker:
    def __init__(
        self,
        ctx: WorkerContext,
        plan: ExecutionPlan,
        registry: Registry,
        buffer: DistributedDatabuffer,
    ):
        self.ctx = ctx
        self.plan = plan
        self.registry = registry
        self.buffer = buffer
        # Initialization phase: materialize the execution queue by binding a
        # concrete function to every node (paper Fig. 5).
        self.queue: List[tuple] = [
            (task.node, self.registry.resolve(task.node)) for task in plan.tasks
        ]

    def run_iteration(self) -> Dict[str, float]:
        """One RL iteration: execute the serialized chain; the databuffer is
        the intermediary state manager between nodes."""
        metrics: Dict[str, float] = {}
        for node, fn in self.queue:
            t0 = time.perf_counter()
            out = fn(self.ctx, self.buffer, node)
            metrics.update(out or {})
            metrics[f"time/{node.node_id}"] = time.perf_counter() - t0
        self.buffer.clear()  # intermediate data is transient (paper §6)
        return metrics
