"""DAG Worker (paper §5): the per-process controller.

Lifecycle: Initialization (bind functions to nodes via the registry,
materialize the execution queue) + iterative Execution (walk the chain, the
databuffer brokering every stage boundary). In JAX SPMD every process runs an
identical DAGWorker over its own data shard — the multi-controller paradigm;
there is no coordinator process anywhere.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DataCoordinatorConfig
from repro.core.databuffer import DistributedDatabuffer
from repro.core.dag import Node, NodeType
from repro.core.planner import ExecutionPlan
from repro.core.registry import Registry
from repro.ft import straggler
from repro.obs.trace import get_tracer


@dataclass
class WorkerContext:
    """Everything a stage function may touch. Mutable fields (actor_state,
    critic_state) are updated in place by train nodes."""

    mesh: Any
    rl: Any
    engines: Dict[str, Callable]
    dataloader: Any
    actor_state: Any = None
    critic_state: Any = None
    ref_params: Any = None
    tokenizer: Any = None
    key: Any = None
    # the AlgorithmSpec driving this run (repro.rl.algorithms); None means
    # "resolve rl.algorithm from the registry on demand"
    algorithm: Any = None
    # the prompt iterator the GENERATE stage pulls from (bound by the worker
    # at init — see PromptSource); None falls back to ctx.dataloader directly
    prompt_source: Any = None
    # the bound environment runtime (repro.rl.envs.EnvRuntime) when an
    # EnvConfig is enabled; the (ENV, COMPUTE) stage and the rollout
    # engine's episode loop both read it. None = pre-env reward path.
    env: Any = None
    # the ObsState (repro.obs) when an ObsConfig is enabled; None = no
    # telemetry, the zero-overhead default
    obs: Any = None
    counters: Dict[str, float] = field(default_factory=dict)

    def next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub


class PromptSource:
    """The worker-owned prompt iterator handed to the GENERATE stage.

    The continuous-batching rollout engine consumes one flat queue of
    sequences per iteration; the worker — not the stage function — owns where
    that queue comes from, so a custom driver (or the async scheduler) can
    swap the source without touching the registry. Each ``next_prompts()``
    serves the iteration's prompt batch already group-expanded (GRPO's
    ``group_size`` rollouts per prompt)."""

    def __init__(self, dataloader, group_size: int = 1):
        self.dataloader = dataloader
        self.group_size = group_size

    def next_prompts(self):
        batch = self.dataloader.next_batch()
        prompts, answers = batch["prompts"], batch["answers"]
        if self.group_size > 1:
            prompts = jnp.repeat(prompts, self.group_size, axis=0)
            answers = jnp.repeat(answers, self.group_size, axis=0)
        return prompts, answers


class DAGWorker:
    def __init__(
        self,
        ctx: WorkerContext,
        plan: ExecutionPlan,
        registry: Registry,
        buffer: DistributedDatabuffer,
        coordinator: Optional[DataCoordinatorConfig] = None,
    ):
        self.ctx = ctx
        self.plan = plan
        self.registry = registry
        self.buffer = buffer
        self.coordinator = coordinator or DataCoordinatorConfig()
        # Initialization phase: materialize the execution queue by binding a
        # concrete function to every node (paper Fig. 5).
        self.queue: List[tuple] = [
            (task.node, self.registry.resolve(task.node)) for task in plan.tasks
        ]
        # hand the GENERATE stage its prompt iterator (rollout-engine
        # contract): bound here, once, so the group expansion is resolved
        # from the algorithm spec instead of re-derived per stage call
        if ctx.prompt_source is None and ctx.dataloader is not None:
            try:
                from repro.rl import algorithms

                g = algorithms.resolve(ctx).group_size(ctx.rl)
            except (KeyError, AttributeError):
                # hand-rolled ctx without a resolvable algorithm (unknown
                # registry name / no rl config): no grouping. Anything else
                # — e.g. a custom spec whose group_size raises — stays loud.
                g = 1
            ctx.prompt_source = PromptSource(ctx.dataloader, g)

    def run_iteration(self) -> Dict[str, float]:
        """One RL iteration: execute the serialized chain; the databuffer is
        the intermediary state manager between nodes."""
        metrics: Dict[str, float] = {}
        for node, fn in self.queue:
            self.execute_node(node, fn, metrics)
        self.buffer.clear()  # intermediate data is transient (paper §6)
        if self.ctx.obs is not None:
            self.ctx.obs.registry.record_dict(metrics)
        return metrics

    def execute_node(self, node: Node, fn, metrics: Dict[str, float]) -> None:
        """Run one stage, record its wall time, and apply the Data
        Coordinator's post-rollout hooks (length-aware load balancing runs
        right after GENERATE, once response lengths are known). While the
        balance repack may rewrite the rollout keys, a double buffer's
        put-time staging is paused so each reshard is dispatched only once,
        for the batch order consumers will actually read."""
        t0 = time.perf_counter()
        balance_here = (
            node.type == NodeType.GENERATE and self.coordinator.load_balance
        )
        pause = getattr(self.buffer, "staging_paused", None)
        with get_tracer().span(f"node/{node.node_id}", cat="dag",
                               node=node.node_id, role=node.role) as sp:
            try:
                with contextlib.ExitStack() as stack:
                    if balance_here and pause is not None:
                        stack.enter_context(pause())
                    out = fn(self.ctx, self.buffer, node)
                    metrics.update(out or {})
                    metrics[f"time/{node.node_id}"] = time.perf_counter() - t0
                    if balance_here:
                        metrics.update(self._balance_rollouts())
            except BaseException:
                # a raising stage is exactly when timing matters: keep the
                # partial duration and flag the failure instead of losing both
                metrics[f"time/{node.node_id}"] = time.perf_counter() - t0
                metrics[f"error/{node.node_id}"] = 1.0
                sp.set(error=1)
                raise

    # ------------------------------------------------------------------ #
    def _num_buckets(self) -> int:
        if self.coordinator.num_buckets > 0:
            return self.coordinator.num_buckets
        dp = 1
        for name, size in self.ctx.mesh.shape.items():
            if name != "model":
                dp *= size
        return dp

    def _balance_rollouts(self) -> Dict[str, float]:
        """Length-aware load balancing (paper §6.2): permute the just-rolled-
        out batch so contiguous DP shards carry near-equal token counts
        before the MODEL_INFERENCE / MODEL_TRAIN stages consume it. GRPO
        prompt groups move as units, so group-relative advantages are
        unaffected. Every worker computes the identical permutation from the
        replicated response mask — no coordinator."""
        nb = self._num_buckets()
        if nb <= 1 or "response_mask" not in self.buffer.keys():
            return {}
        skipped = {"balance/skipped": 1.0}
        from repro.rl import algorithms

        mask = self.buffer.get("response_mask")
        lengths = np.asarray(jnp.sum(mask, axis=1))
        g = algorithms.resolve(self.ctx).group_size(self.ctx.rl)
        B = len(lengths)
        # groups must divide evenly into buckets: the DP sharding splits rows
        # evenly, so uneven group capacities would balance token totals over
        # shard boundaries that don't exist on the hardware. The skip metric
        # keeps a misconfigured num_buckets from disabling balancing invisibly.
        if B % g or (B // g) % nb:
            return skipped
        # fleet meshes balance hierarchically: bin within a host first, swap
        # across the slow pod axis only when host totals exceed tolerance
        H = dict(self.ctx.mesh.shape).get("pod", 1)
        hier = H > 1 and nb % H == 0 and (B // g) % H == 0
        before = straggler.bucket_token_ratio(lengths, nb)
        perm = straggler.balance_by_length(
            lengths, nb, group_size=g, hosts=H if hier else 1
        )
        after = straggler.bucket_token_ratio(lengths, nb, perm)
        if after < before:  # only repack when it helps
            dperm = jnp.asarray(perm)
            for key in self.buffer.keys():
                value = self.buffer.get(key)
                if value.ndim >= 1 and value.shape[0] == B:
                    # re-put under the producer's sharding: a bare jnp.take
                    # replicates its output on multi-device meshes, which
                    # would park the full global batch on every device
                    spec = getattr(value.sharding, "spec", None)
                    self.buffer.put(key, jnp.take(value, dperm, axis=0), spec)
        achieved = min(after, before)
        out = {
            "balance/token_ratio_before": before,
            "balance/token_ratio_after": achieved,
            "balance/repacked": float(after < before),
            # 1.0 when even the repacked batch exceeds the tolerance — i.e. a
            # single sequence/group dominates and only max-len bounding helps
            "balance/over_tolerance": float(
                achieved > self.coordinator.balance_tolerance
            ),
        }
        if hier:
            out["balance/cross_host_row_moves"] = float(
                straggler.cross_host_rows(perm, H) if after < before else 0
            )
        return out
