# DistFlow core: the paper's primary contribution in JAX.
from repro.core.dag import DAG, Node, NodeType, Role
from repro.core.planner import DAGPlanner, ExecutionPlan, validate_serialization
from repro.core.databuffer import (
    CentralizedDatabuffer,
    DistributedDatabuffer,
    DoubleBufferedDatabuffer,
)
from repro.core.registry import Registry, default_registry
from repro.core.worker import DAGWorker, WorkerContext
from repro.core.pipeline import Pipeline, build_pipeline, grpo_dag, ppo_dag
from repro.core.async_worker import AsyncDAGWorker, PipelinedDAGWorker
