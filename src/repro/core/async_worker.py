"""One-step-off-policy pipelined DAG worker (beyond-paper extension).

The paper's related work (StreamRL, AReaL) revisits disaggregation with
asynchronous pipelines: generation for iteration i+1 overlaps training of
iteration i. This worker implements the SEMANTICS of that overlap inside the
DistFlow execution model with bounded staleness 1:

  * the rollout/eval stages of iteration i+1 run under the actor SNAPSHOT
    taken before iteration i's update (the behaviour policy is one step
    stale);
  * the train stages consume the PREVIOUS iteration's buffered trajectories;
  * the PPO/GRPO importance ratio exp(logpi_new - logpi_behaviour) corrects
    the off-policyness, so the objective stays valid (ratios now deviate
    from 1 on the first minibatch — that is the off-policy signature).

On real hardware the two halves run concurrently on disjoint resources (or
interleaved streams); here they run sequentially with identical data and
staleness semantics, which is what the convergence test checks. The expected
wall-clock win is max(t_gen, t_train) instead of t_gen + t_train.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.dag import NodeType
from repro.core.worker import DAGWorker


class PipelinedDAGWorker(DAGWorker):
    def __init__(self, ctx, plan, registry, buffer, coordinator=None):
        super().__init__(ctx, plan, registry, buffer, coordinator)
        self._rollout_state = None  # actor snapshot for the behaviour policy
        self._pending: Optional[Dict] = None  # buffered trajectories
        # split the chain at the first MODEL_TRAIN node
        self.gen_queue = [
            (n, f) for n, f in self.queue if n.type != NodeType.MODEL_TRAIN
        ]
        self.train_queue = [
            (n, f) for n, f in self.queue if n.type == NodeType.MODEL_TRAIN
        ]

    def run_iteration(self) -> Dict[str, float]:
        metrics: Dict[str, float] = {}
        # --- generation + eval under the STALE snapshot -------------------
        live_state = self.ctx.actor_state
        if self._rollout_state is not None:
            self.ctx.actor_state = self._rollout_state
        for node, fn in self.gen_queue:
            self.execute_node(node, fn, metrics)
        self.ctx.actor_state = live_state
        fresh = {k: self.buffer.pop(k) for k in list(self.buffer.keys())}

        # --- train on the PREVIOUS iteration's trajectories ----------------
        if self._pending is not None:
            for k, v in self._pending.items():
                self.buffer.put(k, v)
            for node, fn in self.train_queue:
                self.execute_node(node, fn, metrics)
            self.buffer.clear()
        self._pending = fresh
        # snapshot the (just-updated) actor as the next behaviour policy:
        # generation i+1 overlaps training i+1 on real hardware, so its
        # freshest available policy is the one that produced _pending
        self._rollout_state = self.ctx.actor_state
        metrics["pipeline/staleness"] = 1.0 if self._pending else 0.0
        return metrics
