"""Async off-policy pipeline v2: staleness-bounded generation/training overlap.

The paper decouples control dispatch from data movement so DAG stages execute
independently (§5, §6.2); related systems (AsyncFlow, LlamaRL, StreamRL,
AReaL) go one step further and overlap rollout generation for iteration t+1
with the trainer's update for iteration t, accepting bounded off-policyness
in exchange for hiding the smaller of the two stage times. This module is
that scheduler on the DistFlow execution model:

  * the serialized chain splits at MODEL_TRAIN into a generation half and a
    training half;
  * generated batches queue as :class:`PendingRollout`, each tagged with the
    behaviour policy's weight version (``distributed.weight_sync.
    WeightVersionStore`` — the trainer publishes a new version per update);
  * a batch consumed at trainer version v must satisfy
    ``v - behavior_version <= max_staleness``. Generation dispatch is GATED
    on that bound: with one update per queued batch, a batch dispatched while
    ``len(inflight) <= max_staleness`` is consumed at staleness exactly
    ``len(inflight)``, so the gate is ``len(inflight) <= max_staleness`` —
    when the trainer falls behind, rollouts stall rather than go staler than
    the window;
  * specs with ``is_correction == "truncated"`` get the decoupled
    importance-ratio correction on stale batches: ``old_logprob`` is
    recomputed under the train-time (proximal) policy, the gen-time logprobs
    ride along as ``behavior_logprob``, and the trainer truncates
    ``exp(proximal - behaviour)`` at ``rl.is_rho_max``
    (``trainer.apply_is_correction``).

``max_staleness=0`` runs the identical machinery in lockstep — generate,
train the same batch, publish — and is bitwise-identical to the synchronous
:class:`~repro.core.worker.DAGWorker` (asserted by the test suite).
``max_staleness=1`` reproduces the one-step-off-policy pipelining of the
previous ``PipelinedDAGWorker`` (kept below as a thin alias).

On real hardware the two halves run concurrently on disjoint meshes (or
interleaved streams); here they run sequentially with identical data and
staleness semantics. Each iteration reports what the overlap would hide:
``async/overlap_s = min(t_gen, t_train)`` whenever the trained batch is not
the one generated this iteration, and the benchmark arm
(``benchmarks/async_pipeline.py``) turns that into overlap ratio / projected
speedup vs the sync arm.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, Optional

from repro.configs.base import AsyncPipelineConfig
from repro.core.dag import NodeType
from repro.core.worker import DAGWorker
from repro.distributed.weight_sync import WeightVersionStore
from repro.obs.trace import get_tracer


@dataclass
class PendingRollout:
    """One generated batch waiting for the trainer: the popped buffer
    contents, the weight version of the behaviour policy that produced it,
    and the wall-clock the generation half took (for overlap accounting)."""

    data: Dict[str, Any]
    behavior_version: int
    gen_seconds: float = 0.0


class AsyncDAGWorker(DAGWorker):
    """Staleness-bounded off-policy scheduler over the serialized DAG chain.

    ``clock`` is injectable (defaults to ``time.perf_counter``) so tests can
    drive the scheduler under a fake clock; the staleness gate itself is
    count-based and independent of time. ``dispatch_generation`` /
    ``consume_and_train`` are public so a driver (or test) can decouple the
    two halves — e.g. a slow trainer that stops consuming while generation
    keeps dispatching until the gate stalls it.
    """

    def __init__(
        self,
        ctx,
        plan,
        registry,
        buffer,
        coordinator=None,
        *,
        async_cfg: Optional[AsyncPipelineConfig] = None,
        clock=None,
    ):
        super().__init__(ctx, plan, registry, buffer, coordinator)
        self.async_cfg = async_cfg or AsyncPipelineConfig(
            enabled=True, max_staleness=1
        )
        self.clock = clock or time.perf_counter
        # split the chain at MODEL_TRAIN: everything else is the rollout half.
        # The split only preserves execution order (and the max_staleness=0
        # bitwise-identity contract) when the train nodes close the serialized
        # chain — reject DAGs with post-update nodes instead of silently
        # reordering them ahead of the update.
        types = [n.type for n, _ in self.queue]
        if NodeType.MODEL_TRAIN in types:
            first_train = types.index(NodeType.MODEL_TRAIN)
            trailing = [
                n.node_id for (n, _) in self.queue[first_train:]
                if n.type != NodeType.MODEL_TRAIN
            ]
            if trailing:
                raise ValueError(
                    "async pipeline requires MODEL_TRAIN nodes to close the "
                    f"serialized chain; nodes {trailing} run after a train "
                    "node and would be reordered — run this DAG with the "
                    "synchronous worker (async_pipeline disabled)"
                )
        self.gen_queue = [
            (n, f) for n, f in self.queue if n.type != NodeType.MODEL_TRAIN
        ]
        self.train_queue = [
            (n, f) for n, f in self.queue if n.type == NodeType.MODEL_TRAIN
        ]
        self._inflight: Deque[PendingRollout] = deque()
        self.train_steps = 0
        # version 0 = the pre-update weights, published lazily at the first
        # dispatch (not here: callers replace ctx.actor_state between
        # construction and the first iteration — checkpoint resume, elastic
        # restart — and generation must follow)
        self.weights = WeightVersionStore()

    # ------------------------------------------------------------------ #
    @property
    def max_staleness(self) -> int:
        return self.async_cfg.max_staleness

    def can_dispatch_generation(self) -> bool:
        """The staleness gate. FIFO consumption trains the batch dispatched
        now after one update per batch already queued ahead of it, i.e. at
        staleness ``len(inflight)`` — dispatch is allowed only while that
        cannot exceed the bound."""
        return len(self._inflight) <= self.max_staleness

    def _behavior_weights(self):
        """The behaviour policy for the next dispatch: the latest published
        weights. Version 0 is published lazily here, not at construction, so
        an externally replaced ctx.actor_state — checkpoint resume, elastic
        restart — is what the first generation runs, instead of the
        discarded init weights."""
        if self.weights.current is None:
            self.weights.publish(
                self.ctx.actor_state.params
                if self.ctx.actor_state is not None else None
            )
        return self.weights.current

    def dispatch_generation(
        self, metrics: Optional[Dict[str, float]] = None
    ) -> Optional[PendingRollout]:
        """Run the generation half under the latest published weights and
        queue the batch, unless the staleness gate stalls it (returns None)."""
        metrics = {} if metrics is None else metrics
        if not self.can_dispatch_generation():
            metrics["async/gen_stalled"] = 1.0
            return None
        t0 = self.clock()
        behavior = self._behavior_weights()
        live = self.ctx.actor_state
        if (
            behavior is not None
            and behavior.params is not None
            and live is not None
            and behavior.params is not live.params
        ):
            # generation always runs the published snapshot, not the live
            # trainer state (they coincide in this sequential simulation)
            self.ctx.actor_state = live._replace(params=behavior.params)
        try:
            # one span over the whole generation half: on a trace timeline
            # its width against async/train makes overlap_ratio visually
            # checkable against the async/* metrics
            with get_tracer().span("async/generate", cat="async",
                                   behavior_version=self.weights.version,
                                   inflight=len(self._inflight)):
                for node, fn in self.gen_queue:
                    self.execute_node(node, fn, metrics)
        finally:
            self.ctx.actor_state = live
        # continuous rollout engine (rl/rollout_engine): its measured
        # generation throughput is the async arm's gen-side capacity — what
        # the staleness window is buying overlap against
        stats = getattr(self.ctx.engines.get("generate"), "last_stats", None)
        if stats:
            metrics["async/gen_tokens_per_s"] = stats.get("tokens_per_s", 0.0)
            metrics["async/gen_slot_occupancy"] = stats.get(
                "slot_occupancy", 1.0)
        data = {k: self.buffer.pop(k) for k in list(self.buffer.keys())}
        pending = PendingRollout(
            data=data,
            behavior_version=self.weights.version,
            gen_seconds=self.clock() - t0,
        )
        self._inflight.append(pending)
        metrics["async/inflight"] = float(len(self._inflight))
        return pending

    def train_ready(self) -> bool:
        """A batch is consumed only once the pipeline is ``max_staleness``
        deep, so warmup iterations are generation-only and steady-state
        consumption runs at exactly the configured staleness."""
        return len(self._inflight) > self.max_staleness

    def consume_and_train(
        self, metrics: Optional[Dict[str, float]] = None
    ) -> Optional[PendingRollout]:
        """Train on the oldest queued batch, publish the new weight version,
        and report the batch's realized staleness."""
        metrics = {} if metrics is None else metrics
        if not self._inflight:
            return None
        pending = self._inflight.popleft()
        staleness = self.weights.version - pending.behavior_version
        if staleness > self.max_staleness:
            raise RuntimeError(
                f"staleness bound violated: batch generated at version "
                f"{pending.behavior_version} consumed at version "
                f"{self.weights.version} (max_staleness={self.max_staleness})"
            )
        t0 = self.clock()
        data = dict(pending.data)
        from repro.rl import algorithms

        spec = algorithms.resolve(self.ctx)
        corrected = (
            spec.is_correction == "truncated"
            and staleness > 0
            and "old_logprob" in data
            and "tokens" in data
            and "response_mask" in data
        )
        if corrected:
            # decoupled correction: old_logprob becomes the train-time
            # (proximal) policy's logprobs; the behaviour policy's move to
            # behavior_logprob for the truncated-IS weight
            lp, _ = self.ctx.engines["logprobs"](
                self.ctx.actor_state.params, data["tokens"]
            )
            data["behavior_logprob"] = data["old_logprob"]
            data["old_logprob"] = lp * data["response_mask"]
        for k, v in data.items():
            self.buffer.put(k, v)
        with get_tracer().span("async/train", cat="async",
                               staleness=staleness,
                               is_corrected=corrected):
            for node, fn in self.train_queue:
                self.execute_node(node, fn, metrics)
        # self-clean the consumed batch: run_iteration clears (rotates) per
        # tick anyway, but a driver using the decoupled dispatch/consume API
        # must not have this batch's keys — behavior_logprob in particular —
        # leak into the next dispatch's pop and poison another batch
        for k in data:
            if k in self.buffer.keys():
                self.buffer.pop(k)
        self.train_steps += 1
        self.weights.publish(
            self.ctx.actor_state.params
            if self.ctx.actor_state is not None
            else None
        )
        metrics["async/t_train"] = self.clock() - t0
        metrics["async/staleness"] = float(staleness)
        metrics["async/weight_version"] = float(self.weights.version)
        metrics["async/is_corrected"] = float(corrected)
        return pending

    # ------------------------------------------------------------------ #
    def run_iteration(self) -> Dict[str, float]:
        """One scheduler tick: dispatch generation if the gate allows, then
        train on the oldest batch once the pipeline is deep enough. With
        max_staleness=0 this is generate-then-train on the same batch (the
        synchronous schedule); with W>=1 the trained batch predates the one
        just generated, and on concurrent hardware the two halves overlap."""
        metrics: Dict[str, float] = {}
        dispatched = self.dispatch_generation(metrics)
        consumed = None
        if self.train_ready():
            consumed = self.consume_and_train(metrics)
        t_gen = dispatched.gen_seconds if dispatched is not None else 0.0
        t_train = metrics.get("async/t_train", 0.0)
        metrics["async/t_gen"] = t_gen
        # overlap the concurrent schedule would realize this tick: gen(i+W)
        # and train(i) run on disjoint resources iff they are different
        # batches, hiding the smaller of the two stage times
        pipelined = consumed is not None and consumed is not dispatched
        hidden = min(t_gen, t_train) if pipelined else 0.0
        busy = t_gen + t_train
        metrics["async/overlap_s"] = hidden
        metrics["async/overlap_ratio"] = hidden / busy if busy > 0 else 0.0
        # back-compat with the pre-v2 PipelinedDAGWorker metric
        metrics["pipeline/staleness"] = metrics.get("async/staleness", 0.0)
        self.buffer.clear()  # intermediate data is transient (paper §6)
        if self.ctx.obs is not None:
            self.ctx.obs.registry.record_dict(metrics)
        return metrics


class PipelinedDAGWorker(AsyncDAGWorker):
    """The pre-v2 one-step-off-policy worker: AsyncDAGWorker pinned at
    ``max_staleness=1`` (kept for API compatibility)."""

    def __init__(self, ctx, plan, registry, buffer, coordinator=None):
        super().__init__(
            ctx, plan, registry, buffer, coordinator,
            async_cfg=AsyncPipelineConfig(enabled=True, max_staleness=1),
        )
