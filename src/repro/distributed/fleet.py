"""Multi-host fleet runtime: coordinator plane + DP gradient exchange.

DistFlow's multi-controller scale-out (paper §5, ROADMAP item 4): every host
runs the IDENTICAL SPMD program over the same global ``(pod, data, model)``
mesh (``launch.mesh.make_fleet_mesh``); what differs per process is its
``process_id`` — which gradient slices it owns on the wire, where its
heartbeats go, which artifacts it writes. In the CPU-simulated fleet each
host process forces ``num_hosts * devices_per_host`` local devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``) so the global mesh
exists in every process and the pipeline is bitwise-identical to a
single-host run of the same mesh — the parity invariant tests/test_fleet.py
asserts. On real multi-host hardware the same code runs under
``jax.distributed`` with per-process local devices.

The pieces:

* :class:`FleetContext` — membership + failure detection over a shared
  coordinator directory (the "file plane"): atomic tmp+rename heartbeat
  files feed a :class:`repro.ft.straggler.HeartbeatMonitor`; a blocked
  survivor detects a killed peer by wall-clock staleness, raises
  :class:`HostsLost`, and membership transitions are serialized through
  first-writer-wins epoch files so every survivor adopts the same view.
* :class:`GradExchange` — the DP gradient exchange in reduce-scatter /
  all-gather shape: the flat gradient vector is cut into ``num_hosts``
  contiguous slices; each live host publishes the slices it owns (ownership
  from :func:`repro.ft.straggler.rebalance`, so a dead host's slices are
  re-assigned deterministically) and every peer reconstructs the vector
  from the published slices. ``grad_compression="none"`` ships raw fp32 —
  reconstruction is bitwise. ``"int8_ef"`` ships the
  :mod:`repro.distributed.compression` wire form (int8 blocks + fp32
  scales) with a per-slice error-feedback accumulator; every host decodes
  the same bytes, so hosts stay bitwise-identical to *each other* while
  paying only bounded quantization noise against the exact arm.
* :func:`fleet_actor_step` — composes a jitted grad fn + exchange + jitted
  apply fn into the worker's ``actor_step`` engine contract (the split is
  bitwise-equivalent to the fused ``trainer.make_actor_step``).

Exchange payloads live under ``<coordinator>/xchg/s<step>.e<epoch>/`` —
epoch in the path keeps post-recovery traffic disjoint from a dead epoch's
files. Payload files are never deleted mid-run (readers may lag); the
coordinator directory is ephemeral per run.
"""
from __future__ import annotations

import io
import json
import os
import tempfile
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import DistributedConfig
from repro.distributed import compression
from repro.ft import straggler
from repro.obs.trace import get_tracer


class HostsLost(RuntimeError):
    """Raised out of a blocked exchange/barrier when peers are declared dead.

    The driver should ``declare_dead(exc.hosts)``, restore from the last
    checkpoint, rebuild its engines, and resume (docs/multihost.md)."""

    def __init__(self, hosts: Sequence[int]):
        self.hosts = sorted(hosts)
        super().__init__(f"hosts lost: {self.hosts}")


def _atomic_write(path: str, data: bytes) -> None:
    """Write-then-rename so readers never observe a partial file."""
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp.")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _read_json(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None  # missing, or a reader raced a (non-atomic) writer


class FleetContext:
    """Per-process view of the fleet: membership, heartbeats, file waits."""

    def __init__(self, cfg: DistributedConfig):
        if not cfg.enabled:
            raise ValueError("FleetContext needs num_hosts > 1")
        self.cfg = cfg
        self.root = cfg.coordinator
        self.num_hosts = cfg.num_hosts
        self.process_id = cfg.process_id
        self.members: List[int] = list(range(cfg.num_hosts))
        self.epoch = 0
        self.iteration = 0
        self.monitor = straggler.HeartbeatMonitor(
            cfg.num_hosts, patience=cfg.heartbeat_patience
        )
        self._hb_thread: Optional[threading.Thread] = None
        self._hb_stop: Optional[threading.Event] = None
        os.makedirs(os.path.join(self.root, "hosts"), exist_ok=True)
        os.makedirs(os.path.join(self.root, "membership"), exist_ok=True)
        self.sync_membership()  # adopt transitions from before we (re)started

    # -------------------------------------------------------------- #
    # heartbeats
    # -------------------------------------------------------------- #
    def _hb_path(self, host: int) -> str:
        return os.path.join(self.root, "hosts", f"host{host}.json")

    def heartbeat(self, iteration: Optional[int] = None) -> None:
        """Publish liveness. Call at least once per training iteration."""
        if iteration is not None:
            self.iteration = iteration
        payload = {"iteration": self.iteration, "time": time.time(),
                   "pid": os.getpid()}
        _atomic_write(self._hb_path(self.process_id),
                      json.dumps(payload).encode())
        self.monitor.beat(self.process_id, self.iteration, now=payload["time"])

    def start_heartbeats(self, interval: float = 0.5) -> None:
        """Background daemon thread beating every ``interval`` seconds —
        liveness keeps publishing while the main thread is inside a long
        jit/compile, and stops the instant the process is killed (which is
        exactly the wall-clock staleness signal survivors key off)."""
        if self._hb_thread is not None:
            return
        self._hb_stop = threading.Event()

        def loop():
            while not self._hb_stop.wait(interval):
                try:
                    self.heartbeat()
                except OSError:
                    pass  # coordinator dir going away at shutdown

        self._hb_thread = threading.Thread(
            target=loop, daemon=True, name="fleet-heartbeat"
        )
        self._hb_thread.start()

    # -------------------------------------------------------------- #
    # observability snapshots (repro.obs.aggregate)
    # -------------------------------------------------------------- #
    def publish_metrics(self, iteration: int, metrics: Dict) -> str:
        """Ship one iteration's metrics snapshot over the file plane for
        fleet-wide aggregation (``obs/aggregate.collect_snapshots`` /
        ``launch/obs_report.py``). Same atomic-write discipline as
        heartbeats; returns the snapshot path."""
        path = os.path.join(self.root, "obs", f"host{self.process_id}",
                            f"it{int(iteration):06d}.json")
        payload = {
            "host": self.process_id,
            "iteration": int(iteration),
            "time": time.time(),
            "metrics": {k: float(v) for k, v in metrics.items()},
        }
        _atomic_write(path, json.dumps(payload).encode())
        return path

    def stop_heartbeats(self) -> None:
        if self._hb_thread is not None:
            self._hb_stop.set()
            self._hb_thread.join(timeout=5)
            self._hb_thread = None
            self._hb_stop = None

    def poll_peers(self) -> List[int]:
        """Feed peer heartbeats to the monitor; return members now considered
        dead (never excluding self). Wall-clock staleness is what lets a host
        *blocked* at the exchange (its own iteration frozen) still notice."""
        for h in self.members:
            hb = _read_json(self._hb_path(h))
            if hb is not None:
                self.monitor.beat(h, int(hb["iteration"]), now=float(hb["time"]))
        dead = self.monitor.dead(
            self.iteration, now=time.time(), stale_s=self.cfg.dead_after_s
        )
        return [h for h in dead if h in self.members and h != self.process_id]

    # -------------------------------------------------------------- #
    # membership epochs (first-writer-wins, so survivors agree)
    # -------------------------------------------------------------- #
    def _epoch_path(self, epoch: int) -> str:
        return os.path.join(self.root, "membership", f"epoch{epoch}.json")

    def sync_membership(self) -> bool:
        """Adopt any membership transition another survivor already
        published. Returns True if the epoch advanced."""
        advanced = False
        while True:
            rec = _read_json(self._epoch_path(self.epoch + 1))
            if rec is None:
                return advanced
            self.epoch += 1
            self.members = list(rec["members"])
            advanced = True

    def declare_dead(self, hosts: Sequence[int]) -> None:
        """Publish (or adopt) the next membership epoch without ``hosts``."""
        self.sync_membership()
        targets = [h for h in hosts if h in self.members]
        if not targets:
            return
        members = [m for m in self.members if m not in set(targets)]
        if self.process_id not in members:
            raise RuntimeError("cannot declare self dead")
        path = self._epoch_path(self.epoch + 1)
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            with os.fdopen(fd, "w") as f:
                json.dump({"members": members, "dead": sorted(targets)}, f)
        except FileExistsError:
            pass  # another survivor won the race; adopt its record
        self.sync_membership()

    @property
    def dead_hosts(self) -> List[int]:
        return [h for h in range(self.num_hosts) if h not in self.members]

    def partition(self) -> Dict[int, List[int]]:
        """Current shard-ownership map (host -> slice/shard ids): every
        member computes the identical map from the identical membership."""
        return straggler.rebalance([1.0] * self.num_hosts, dead=self.dead_hosts)

    def slice_owner(self) -> Dict[int, int]:
        return {s: h for h, shards in self.partition().items() for s in shards}

    # -------------------------------------------------------------- #
    # file waits + barrier
    # -------------------------------------------------------------- #
    def wait_files(self, paths: Sequence[str], *,
                   timeout: Optional[float] = None, poll: float = 0.05,
                   detect: bool = True) -> None:
        """Block until every path exists. While blocked (``detect=True``):
        keep our own heartbeat fresh, watch peers, adopt membership epochs
        other survivors publish, and raise :class:`HostsLost` the moment a
        peer whose file we may be waiting on is declared dead. ``detect=
        False`` is the bootstrap mode (startup barrier): peers that have not
        launched yet must not be mistaken for dead ones."""
        timeout = self.cfg.exchange_timeout_s if timeout is None else timeout
        deadline = time.monotonic() + timeout
        last_beat = 0.0
        while True:
            if all(os.path.exists(p) for p in paths):
                return
            now = time.monotonic()
            if now - last_beat > 0.5:
                self.heartbeat()
                last_beat = now
            if detect:
                before = set(self.members)
                if self.sync_membership():
                    raise HostsLost(before - set(self.members))
                lost = self.poll_peers()
                if lost:
                    raise HostsLost(lost)
            if now > deadline:
                missing = [p for p in paths if not os.path.exists(p)]
                raise TimeoutError(f"fleet wait timed out; missing {missing}")
            time.sleep(poll)

    def barrier(self, name: str, *, timeout: Optional[float] = None) -> None:
        """All current members rendezvous. No failure detection: used at
        bootstrap, where a slow-to-launch peer is not a dead peer."""
        d = os.path.join(self.root, "barrier", f"{name}.e{self.epoch}")
        _atomic_write(os.path.join(d, f"host{self.process_id}"), b"")
        self.wait_files([os.path.join(d, f"host{h}") for h in self.members],
                        timeout=timeout, detect=False)


# ------------------------------------------------------------------ #
# module-global context (set by launch.mesh.init_distributed)
# ------------------------------------------------------------------ #
_CONTEXT: Optional[FleetContext] = None


def set_context(ctx: Optional[FleetContext]) -> None:
    global _CONTEXT
    _CONTEXT = ctx


def get_context() -> Optional[FleetContext]:
    return _CONTEXT


def ensure_context(cfg: DistributedConfig) -> FleetContext:
    """The registered context if it matches ``cfg``, else a fresh one.
    Reuse is what preserves membership epochs across a post-recovery
    pipeline rebuild."""
    ctx = get_context()
    if (ctx is not None and ctx.root == cfg.coordinator
            and ctx.num_hosts == cfg.num_hosts
            and ctx.process_id == cfg.process_id):
        return ctx
    ctx = FleetContext(cfg)
    set_context(ctx)
    return ctx


# ------------------------------------------------------------------ #
# host <-> device geometry
# ------------------------------------------------------------------ #
def host_device_groups(mesh) -> List[List[int]]:
    """Device ids per host. A ``pod`` mesh axis defines the host grouping
    (simulated fleets: contiguous device blocks); otherwise devices group by
    their ``process_index`` (real multi-host); a flat single-process mesh is
    one host."""
    devs = np.asarray(mesh.devices)
    if "pod" in mesh.axis_names:
        ax = list(mesh.axis_names).index("pod")
        moved = np.moveaxis(devs, ax, 0)
        return [[d.id for d in moved[h].ravel()] for h in range(moved.shape[0])]
    by_proc: Dict[int, List[int]] = {}
    for d in devs.ravel():
        by_proc.setdefault(d.process_index, []).append(d.id)
    return [by_proc[k] for k in sorted(by_proc)]


# ------------------------------------------------------------------ #
# gradient exchange
# ------------------------------------------------------------------ #
class GradExchange:
    """File-plane DP gradient exchange (reduce-scatter/all-gather shape).

    ``__call__`` takes the jitted grad fn's gradient pytree, publishes this
    host's owned contiguous slices of the flattened fp32 vector, waits for
    every slice, and returns the reconstructed pytree + wire metrics. Slice
    boundaries are fixed by the ORIGINAL ``num_hosts`` so they never move
    when membership shrinks — only ownership does (``FleetContext.
    partition``). ``wire_bytes`` counts published payload bytes per round
    (one copy per slice), the apples-to-apples number between the exact and
    compressed arms; ``wire_saved_bytes`` is the fp32 baseline minus that.
    """

    def __init__(self, fleet: FleetContext, mode: str = "none"):
        if mode not in ("none", "int8_ef"):
            raise ValueError(f"unknown grad_compression {mode!r}")
        self.fleet = fleet
        self.mode = mode
        self._step = -1
        self._errors: Dict[int, np.ndarray] = {}  # slice id -> EF accumulator
        self.stats = {"exchanges": 0, "wire_bytes": 0, "exact_bytes": 0,
                      "wire_saved_bytes": 0}

    # ---------------- wire format ---------------- #
    def _slice_bounds(self, total: int) -> List[Tuple[int, int]]:
        H = self.fleet.num_hosts
        base, extra = divmod(total, H)
        bounds, lo = [], 0
        for i in range(H):
            hi = lo + base + (1 if i < extra else 0)
            bounds.append((lo, hi))
            lo = hi
        return bounds

    def _encode_slice(self, sid: int, seg: np.ndarray) -> bytes:
        buf = io.BytesIO()
        if self.mode == "none":
            np.savez(buf, v=seg)
        else:
            q, scale, err = compression.encode(
                jax.numpy.asarray(seg), self._errors.get(sid)
            )
            self._errors[sid] = np.asarray(err)
            np.savez(buf, q=np.asarray(q), s=np.asarray(scale),
                     n=np.int64(seg.size))
        return buf.getvalue()

    def _decode_slice(self, data: bytes) -> np.ndarray:
        with np.load(io.BytesIO(data)) as z:
            if "v" in z:
                return z["v"]
            n = int(z["n"])
            return np.asarray(
                compression.decode(z["q"], z["s"], (n,), n), dtype=np.float32
            )

    def _payload_bytes(self, seg: np.ndarray) -> int:
        exact, comp = compression.wire_bytes(seg)
        return exact if self.mode == "none" else comp

    # ---------------- the exchange ---------------- #
    def __call__(self, grads) -> Tuple[object, Dict[str, float]]:
        with get_tracer().span("fleet/grad_exchange", cat="fleet",
                               step=self._step + 1,
                               members=len(self.fleet.members),
                               compression=self.mode) as sp:
            out, metrics = self._exchange(grads)
            sp.set(wire_bytes=metrics["fleet/wire_bytes"])
            return out, metrics

    def _exchange(self, grads) -> Tuple[object, Dict[str, float]]:
        fleet = self.fleet
        self._step = max(self._step + 1, fleet.iteration)
        step = self._step
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        shapes = [l.shape for l in leaves]
        sizes = [int(np.prod(s, dtype=np.int64)) for s in shapes]
        vector = np.concatenate(
            [np.asarray(l, dtype=np.float32).ravel() for l in leaves]
        ) if leaves else np.zeros(0, np.float32)
        bounds = self._slice_bounds(vector.size)
        owner = fleet.slice_owner()

        xdir = os.path.join(fleet.root, "xchg", f"s{step}.e{fleet.epoch}")
        published = 0
        for sid, (lo, hi) in enumerate(bounds):
            if owner[sid] != fleet.process_id:
                continue
            _atomic_write(os.path.join(xdir, f"slice{sid}.npz"),
                          self._encode_slice(sid, vector[lo:hi]))
            published += self._payload_bytes(vector[lo:hi])

        paths = [os.path.join(xdir, f"slice{sid}.npz")
                 for sid in range(len(bounds))]
        fleet.wait_files(paths)

        out = np.empty_like(vector)
        wire = exact = 0
        for sid, (lo, hi) in enumerate(bounds):
            with open(paths[sid], "rb") as f:
                seg = self._decode_slice(f.read())
            out[lo:hi] = seg
            wire += self._payload_bytes(vector[lo:hi])
            exact += (hi - lo) * 4

        self.stats["exchanges"] += 1
        self.stats["wire_bytes"] += wire
        self.stats["exact_bytes"] += exact
        self.stats["wire_saved_bytes"] += exact - wire
        metrics = {
            "fleet/wire_bytes": float(wire),
            "fleet/wire_saved_bytes": float(exact - wire),
            "fleet/published_bytes": float(published),
            "fleet/epoch": float(fleet.epoch),
            "fleet/members": float(len(fleet.members)),
        }
        rebuilt = []
        pos = 0
        for shape, size in zip(shapes, sizes):
            rebuilt.append(out[pos:pos + size].reshape(shape))
            pos += size
        new_leaves = [
            jax.numpy.asarray(r, dtype=l.dtype)
            for r, l in zip(rebuilt, leaves)
        ]
        return jax.tree_util.tree_unflatten(treedef, new_leaves), metrics


def fleet_actor_step(grad_fn: Callable, apply_fn: Callable,
                     exchange: GradExchange) -> Callable:
    """Compose grad -> exchange -> apply into the worker's ``actor_step``
    engine contract. The split is bitwise-equivalent to the fused
    ``trainer.make_actor_step`` (asserted in tests/test_fleet.py): the
    exchange sits exactly where a real deployment's DP psum would."""

    def step(state, batch):
        grads, metrics = grad_fn(state.params, batch)
        grads, xmetrics = exchange(grads)
        state, apply_metrics = apply_fn(state, grads)
        return state, {**metrics, **apply_metrics, **xmetrics}

    return step
