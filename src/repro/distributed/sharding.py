"""Sharding rules: FSDP x TP PartitionSpecs for every architecture.

Param rules are name-based over the last two dims (stacked leading dims —
layer groups, experts — are left-padded with None / FSDP as divisibility
allows). Conventions (DESIGN.md §6):

  * TP over `model`: attention heads (padded to 16), d_ff, SSD heads, vocab.
  * FSDP over (`pod`,`data`): the d_model-sized dim of every matrix, so
    params + optimizer state scale 1/(pod*data) — ZeRO-3 semantics.
  * GQA kv_heads < tp  -> K/V projections replicated over `model`
    (transient; the decode cache is SEQUENCE-sharded over `model` instead).
  * MoE expert dim (8/16/40, never 16-divisible) -> experts replicated over
    `model`, their f dim TP-sharded ("expert tensor parallelism").
  * batch < dp  (long_500k B=1) -> batch replicated, decode caches
    context-sharded over ALL axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _divisible(n: int, mesh: Mesh, axes) -> bool:
    if not axes:
        return True
    size = 1
    for a in axes if isinstance(axes, tuple) else (axes,):
        size *= mesh.shape[a]
    return n % size == 0


def _maybe(n: int, mesh: Mesh, axes):
    """axes if they divide n else None (replicate)."""
    return axes if _divisible(n, mesh, axes) else None


# --------------------------------------------------------------------------- #
# param specs
# --------------------------------------------------------------------------- #
def _base_rule(cfg: ModelConfig, mesh: Mesh, name: str, shape,
               mode: str = "train") -> Tuple:
    """PartitionSpec entries for the TRAILING dims that the rule understands;
    leading (stack) dims are padded by the caller.

    mode="serve": decode weights stay TP-sharded over `model` but REPLICATED
    over the data axes (no FSDP) — a decode step would otherwise all-gather
    the FSDP-sharded weights EVERY token (measured: 15.6 GB/step on
    deepseek-67b:decode_32k). Full-2D TP was tried first and REFUTED (44
    GB/step: the weights' data-axis sharding fights the batch's, §Perf
    A-it3a); replicated-over-data weights cost N*2/16 bytes of HBM per device
    and drop the per-step wire to tiny activation reductions. build_serve
    picks this mode only when the weights+cache fit the HBM budget."""
    if mode == "serve":
        tp_all = ("model",)
        if name == "embed":  # (V, d)
            return (_maybe(shape[0], mesh, tp_all), None)
        if name in ("w_q", "w_dt", "w_k", "w_v", "w_in", "w_gate", "w_z",
                    "w_x", "lm_head"):
            return (None, _maybe(shape[-1], mesh, tp_all))
        if name in ("w_o", "w_out"):
            return (_maybe(shape[-2], mesh, tp_all), None)
        if name in ("b_q", "b_k", "b_v", "b_in"):
            return (_maybe(shape[-1], mesh, tp_all),)
        if name in ("A_log", "D", "dt_bias", "norm_w"):
            return (_maybe(shape[-1], mesh, tp_all),)
        if name == "conv_x":
            return (None, _maybe(shape[-1], mesh, tp_all))
        if name in ("w_B", "w_C", "router", "conv_bc", "b_o", "b_out",
                    "w", "b", "v_head"):
            return tuple(None for _ in shape[-2:]) if len(shape) >= 2 else (None,)
        return tuple(None for _ in shape)

    fsdp = fsdp_axes(mesh)
    tp_ok_kv = cfg.num_kv_heads and _divisible(
        cfg.num_kv_heads, mesh, ("model",)
    )
    if name == "embed":  # (V, d)
        return (_maybe(shape[0], mesh, "model"), _maybe(shape[1], mesh, fsdp))
    if name == "lm_head":  # (d, V)
        return (_maybe(shape[-2], mesh, fsdp), _maybe(shape[-1], mesh, "model"))
    if name == "v_head":  # (d, 1)
        return (_maybe(shape[-2], mesh, fsdp), None)
    if name in ("w_q", "w_dt"):  # (d, Hp*hd) / (d, nh)
        return (_maybe(shape[-2], mesh, fsdp), _maybe(shape[-1], mesh, "model"))
    if name in ("w_k", "w_v"):  # (d, kvh*hd): TP only when kvh | tp
        tp = "model" if tp_ok_kv else None
        return (_maybe(shape[-2], mesh, fsdp), tp)
    if name == "w_o":  # (Hp*hd, d)
        return (_maybe(shape[-2], mesh, "model"), _maybe(shape[-1], mesh, fsdp))
    if name in ("w_in", "w_gate", "w_z", "w_x"):  # (d, f) / (d, din)
        return (_maybe(shape[-2], mesh, fsdp), _maybe(shape[-1], mesh, "model"))
    if name == "w_out":  # (f|din, d)
        return (_maybe(shape[-2], mesh, "model"), _maybe(shape[-1], mesh, fsdp))
    if name in ("w_B", "w_C"):  # (d, g*n): tiny -> replicate cols
        return (_maybe(shape[-2], mesh, fsdp), None)
    if name == "router":  # (d, E)
        return (_maybe(shape[-2], mesh, fsdp), None)
    if name == "conv_x":  # (kw, din)
        return (None, _maybe(shape[-1], mesh, "model"))
    if name == "conv_bc":
        return (None, None)
    if name in ("A_log", "D", "dt_bias", "norm_w"):  # (nh,) / (din,)
        return (_maybe(shape[-1], mesh, "model"),)
    if name in ("b_q", "b_in"):  # (Hp*hd,) / (f,)
        return (_maybe(shape[-1], mesh, "model"),)
    if name in ("b_k", "b_v"):
        return ("model" if tp_ok_kv and _divisible(shape[-1], mesh, "model") else None,)
    if name in ("b_o", "b_out", "w", "b"):  # biases to d / norm scales
        return (None,)
    # fallback: replicate
    return tuple(None for _ in shape)


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape,
                mode: str = "train") -> Any:
    """Pytree of PartitionSpecs matching ``params_shape`` (a pytree of
    ShapeDtypeStructs or arrays). mode: "train" (FSDP x TP) | "serve"
    (full 2D TP, weights resident — see _base_rule)."""
    fsdp = fsdp_axes(mesh)

    def rule(path, leaf):
        keys = [e.key for e in path if isinstance(e, jax.tree_util.DictKey)]
        name = keys[-1] if keys else None
        shape = leaf.shape
        is_moe = "moe" in keys
        if is_moe and name in ("w_in", "w_gate", "w_out") and mode == "train":
            # (..., E, d, f) or (..., E, f, d): expert dim at -3.
            base = _base_rule(cfg, mesh, name, shape)  # covers last 2 dims
            e_dim = shape[-3]
            if _divisible(e_dim, mesh, fsdp):
                # FSDP the expert dim; drop fsdp from the trailing dims
                base = tuple(None if b == fsdp else b for b in base)
                lead = [None] * (len(shape) - 3) + [fsdp]
            else:
                lead = [None] * (len(shape) - 2)
            return P(*lead, *base)
        base = _base_rule(cfg, mesh, name, shape, mode)
        lead = [None] * (len(shape) - len(base))
        return P(*lead, *base)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# --------------------------------------------------------------------------- #
# batch / cache specs
# --------------------------------------------------------------------------- #
def batch_axes(mesh: Mesh, global_batch: int):
    """Largest prefix of the data axes that divides the batch."""
    axes = []
    size = 1
    for a in fsdp_axes(mesh):
        if global_batch % (size * mesh.shape[a]) == 0:
            axes.append(a)
            size *= mesh.shape[a]
    return tuple(axes) or None


def train_batch_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> Dict[str, P]:
    dp = batch_axes(mesh, global_batch)
    specs = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.is_encoder_decoder:
        specs["frames"] = P(dp, None, None)
    if cfg.num_prefix_embeds > 1:
        specs["prefix_embeds"] = P(dp, None, None)
    return specs


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, caches_shape) -> Any:
    """Decode caches: batch over data axes; KV sequence over `model`.
    B=1 (long_500k): context over ALL axes instead."""
    dp = batch_axes(mesh, batch)
    ctx_axes = ("model",) if dp else tuple(mesh.axis_names)

    def rule(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = entry.key
                break
        shape = leaf.shape
        if name in ("k", "v", "mk", "mv"):
            # (N, B, W, KVH, hd) or (L, B, W, KVH, hd)
            seq = shape[-3]
            seq_ax = ctx_axes if seq % _size(mesh, ctx_axes) == 0 else None
            return P(*[None] * (len(shape) - 4), dp, seq_ax, None, None)
        if name in ("k_scale", "v_scale"):  # (N, B, W, KVH)
            seq = shape[-2]
            seq_ax = ctx_axes if seq % _size(mesh, ctx_axes) == 0 else None
            return P(*[None] * (len(shape) - 3), dp, seq_ax, None)
        if name == "ssm":  # (N, B, nh, hd, ds)
            nh_ax = "model" if shape[-3] % mesh.shape["model"] == 0 else None
            return P(*[None] * (len(shape) - 4), dp, nh_ax, None, None)
        if name in ("conv_x", "conv_bc"):  # (N, B, kw-1, C)
            c_ax = "model" if shape[-1] % mesh.shape["model"] == 0 else None
            return P(*[None] * (len(shape) - 3), dp, None, c_ax)
        return P(*[None] * len(shape))

    return jax.tree_util.tree_map_with_path(rule, caches_shape)


def _size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    s = 1
    for a in axes if isinstance(axes, tuple) else (axes,):
        s *= mesh.shape[a]
    return s


def opt_state_specs(pspecs) -> Any:
    """AdamW state mirrors params: (step P(), m/v like params)."""
    from repro.optim.adamw import AdamWState

    return AdamWState(step=P(), m=pspecs, v=pspecs)


def named(mesh: Mesh, tree) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )
