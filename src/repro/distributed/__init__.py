from repro.distributed import collectives, compression, fleet, sharding
