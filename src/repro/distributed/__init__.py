from repro.distributed import collectives, compression, sharding
