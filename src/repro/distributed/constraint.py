"""Activation sharding constraints with logical axis names.

Model code annotates activations with *logical* entries ('dp' = all non-model
mesh axes, 'tp' = the `model` axis); the ambient mesh (set via
``jax.sharding.set_mesh`` by the launcher / dry-run) resolves them. With no
ambient mesh (unit tests, CPU examples) every call is a no-op, so model code
stays mesh-agnostic.

These constraints are what steer GSPMD to the FSDP execution we want: weights
are ALL-GATHERED at use (ZeRO-3) instead of activations being resharded onto
the weights' FSDP axis — without them, GSPMD happily un-shards the batch to
contract over an FSDP-sharded d_model dim (observed: a 16 GB fp32 all-reduce
in the CE loss).
"""
from __future__ import annotations

import os

import jax
from jax.sharding import PartitionSpec as P


def sequence_parallel() -> bool:
    """Megatron-SP toggle: shard the residual stream's seq dim over `model`
    between blocks, turning TP all-reduces into reduce-scatter/all-gather
    pairs (half the wire bytes) and sharding norm work.

    Default OFF: measured on the production mesh, GSPMD turned this
    constraint into full-activation resharding storms (15.6 TB/step vs
    976 GB/step collectives on deepseek-67b:train_4k — §Perf C-it1,
    REFUTED). Set REPRO_SP=1 to reproduce that arm."""
    return os.environ.get("REPRO_SP", "0") == "1"


def residual_entries():
    return ("dp", "tp", None) if sequence_parallel() else ("dp", None, None)


def constrain(x: jax.Array, *entries) -> jax.Array:
    """entries: 'dp' | 'tp' | None per dim (trailing dims may be omitted).

    No-op without an ambient mesh, and inside shard_map manual regions
    (with_sharding_constraint only accepts Auto axes — the manual caller has
    already fixed the layout)."""
    from repro.utils.jax_compat import ambient_mesh

    mesh = ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    dp = tuple(a for a in mesh.axis_names if a != "model")
    sizes = dict(mesh.shape)
    spec = []
    for dim, e in zip(x.shape, entries):
        if e == "dp":
            n = 1
            axes = []
            for a in dp:
                if dim % (n * sizes[a]) == 0:
                    axes.append(a)
                    n *= sizes[a]
            spec.append(tuple(axes) if axes else None)
        elif e == "tp":
            spec.append("model" if dim % sizes["model"] == 0 else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
