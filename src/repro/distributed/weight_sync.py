"""Train <-> serve weight switching (the colocated-architecture tax).

In a colocated RL framework the SAME actor weights serve two engines with
different optimal layouts: FSDPxTP for the train stage, TP-resident for the
generation stage (see sharding.param_specs modes and §Perf cell A). The
paper's related-work section calls out "optimizing the efficiency of model
weight switching across different stages" as a core colocated-design cost —
this module is that switch, measured.

``switch`` is a pure resharding: jax.device_put to the target NamedShardings
(GSPMD all-gather/all-to-all among peers — no host round-trip, no
controller). ``switch_bytes`` prices it: moving FSDP-sharded bf16 weights to
TP-resident costs each device the weights it doesn't yet hold, once per RL
iteration — amortized over the whole generation stage.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shr


def specs_for(cfg: ModelConfig, mesh: Mesh, params, mode: str):
    return shr.param_specs(cfg, mesh, params, mode=mode)


def switch(mesh: Mesh, params, target_specs) -> Any:
    """Reshard a param pytree to the target stage layout (peer collectives)."""
    shardings = shr.named(mesh, target_specs)
    return jax.tree.map(jax.device_put, params, shardings)


# --------------------------------------------------------------------------- #
# Weight-version tagging (async off-policy pipeline v2).
#
# In the staleness-bounded scheduler the trainer and the rollout engine no
# longer share one implicit "current" set of weights: the trainer PUBLISHES a
# new version after every update, and every rollout batch is tagged with the
# version it was generated under, so the scheduler can measure and bound the
# off-policy staleness (trainer_version - behaviour_version). On disaggregated
# hardware the publish IS the train->serve ``switch`` above; the store threads
# the version tag through that reshard so tags stay attached to the weights
# they describe.
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class VersionedWeights:
    """A param pytree plus the monotone version tag it was published under."""

    params: Any
    version: int


class WeightVersionStore:
    """Single-writer, monotone-version weight publication point.

    The trainer calls :meth:`publish` once per update; generation reads
    :attr:`current` (params + tag). Versions must strictly increase — a
    regression means two writers or a re-publish of stale weights, both of
    which would silently corrupt staleness accounting, so the store raises.
    """

    def __init__(self):
        self._current: Optional[VersionedWeights] = None

    @property
    def current(self) -> Optional[VersionedWeights]:
        return self._current

    @property
    def version(self) -> int:
        """The latest published version; -1 before the first publish."""
        return -1 if self._current is None else self._current.version

    def publish(
        self,
        params,
        *,
        version: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        target_specs=None,
    ) -> VersionedWeights:
        """Publish ``params`` under the next version (or an explicit one).

        With ``mesh`` + ``target_specs`` the params are resharded to the serve
        layout via :func:`switch` on the way — the colocated train->serve
        weight switch with the version tag riding along.
        """
        v = self.version + 1 if version is None else version
        if v <= self.version:
            raise ValueError(
                f"weight versions must be strictly monotone: "
                f"got {v} after {self.version}"
            )
        if target_specs is not None:
            if mesh is None:
                raise ValueError("target_specs requires a mesh")
            params = switch(mesh, params, target_specs)
        self._current = VersionedWeights(params=params, version=v)
        return self._current


def switch_bytes(cfg: ModelConfig, mesh: Mesh, params_shape,
                 src_mode: str = "train", dst_mode: str = "serve") -> dict:
    """Analytic per-device cost of one train->serve switch: bytes each device
    must RECEIVE = its destination-resident bytes minus what it already holds
    under the source layout (overlap lower-bounds to the smaller shard)."""
    src = shr.param_specs(cfg, mesh, params_shape, mode=src_mode)
    dst = shr.param_specs(cfg, mesh, params_shape, mode=dst_mode)
    sizes = dict(mesh.shape)

    def shard_frac(spec, shape):
        n = 1
        for dim, entry in zip(shape, tuple(spec) + (None,) * 8):
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                if a is not None:
                    n *= sizes[a]
        return 1.0 / n

    recv = total_dst = 0.0
    for (leaf, s_spec, d_spec) in zip(
        jax.tree.leaves(params_shape),
        jax.tree.leaves(src, is_leaf=lambda x: isinstance(x, P)),
        jax.tree.leaves(dst, is_leaf=lambda x: isinstance(x, P)),
    ):
        nbytes = leaf.size * leaf.dtype.itemsize
        f_src = shard_frac(s_spec, leaf.shape)
        f_dst = shard_frac(d_spec, leaf.shape)
        total_dst += nbytes * f_dst
        recv += nbytes * max(f_dst - min(f_src, f_dst), 0.0)
    return {
        "recv_bytes_per_device": recv,
        "resident_bytes_per_device_dst": total_dst,
        # ICI seconds (3 links x 50 GB/s), amortized once per RL iteration
        "switch_seconds": recv / 150e9,
    }
