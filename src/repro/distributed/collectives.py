"""Explicit collective helpers (shard_map) for paths where GSPMD's automatic
choice is not what we want on real hardware.

``seq_sharded_decode_attention`` is the TPU decode path for GQA archs whose
kv_heads don't divide TP: the KV cache is sequence-sharded over `model`, each
shard computes partial flash-decode (o, lse) with its absolute position
offset, and shards combine with the exact log-sum-exp merge. On the CPU
dry-run the pjit/ref path is used instead (same math, GSPMD-inserted
collectives).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import ops
from repro.utils.jax_compat import shard_map


def seq_sharded_decode_attention(
    mesh: Mesh,
    q: jax.Array,  # (B, H, D) replicated over `model`
    k: jax.Array,  # (B, S, KVH, D) sequence-sharded over `model`
    v: jax.Array,
    cache_len: jax.Array,  # (B,)
    *,
    axis: str = "model",
    window: Optional[int] = None,
):
    n = mesh.shape[axis]
    S = k.shape[1]
    assert S % n == 0
    shard_s = S // n

    def body(q, k, v, cache_len):
        idx = jax.lax.axis_index(axis)
        # absolute offset of this shard's slot 0
        o, lse = _offset_decode(q, k, v, cache_len, idx * shard_s, window)
        o_all = jax.lax.all_gather(o, axis)  # (n, B, H, D)
        lse_all = jax.lax.all_gather(lse, axis)
        return ops.combine_decode_shards(o_all, lse_all)

    spec_q = P(None, None, None)
    spec_kv = P(None, axis, None, None)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(spec_q, spec_kv, spec_kv, P(None)),
        out_specs=spec_q,
        check_vma=False,  # output replication over `axis` is by construction
    )(q, k, v, cache_len)


def _offset_decode(q, k, v, cache_len, pos_offset, window):
    # pos_offset is traced (axis_index); the kernel API takes a static int,
    # so apply the offset by shifting the valid-length comparison instead:
    # positions in this shard are [pos_offset, pos_offset + S_local).
    eff_len = jnp.clip(cache_len - pos_offset, 0, k.shape[1])
    # NOTE: window!=None is unused on this path — SWA archs bound the cache
    # with a ring buffer (W slots total) instead of sequence-sharding it, so
    # seq-sharded decode only serves full-attention GQA caches.
    del window
    return ops.decode_attention(q, k, v, eff_len)


def repartition(mesh: Mesh, x: jax.Array, spec: P) -> jax.Array:
    """The databuffer's redistribution primitive as a standalone helper."""
    return jax.device_put(x, NamedSharding(mesh, spec))
