"""Gradient compression for the data-parallel all-reduce (DESIGN.md §9).

``compressed_psum`` replaces an exact psum with: per-block int8 quantize ->
all_gather(quantized + scales) -> local dequantize-sum. Wire bytes drop to
~1/4 of fp32 (1/2 of bf16) at the price of quantization noise; an error-
feedback accumulator (``ef_update``) keeps the bias bounded, which is the
standard trick that makes low-bit gradient exchange trainable.

Two call sites use these primitives:

* ``compressed_psum`` — inside ``shard_map`` over the data/pod axes of an
  in-process fleet mesh (the dense pjit path keeps exact reductions).
* ``encode``/``decode`` — the wire form of the multi-host DP gradient
  exchange (``repro.distributed.fleet.GradExchange``): each host publishes
  the int8 blocks + fp32 scales of its owned gradient slice and every peer
  decodes them, which is exactly the all-gather + local-dequantize shape of
  ``compressed_psum`` routed over the fleet's data plane.

``wire_bytes`` is the byte accounting both paths report: the padded int8
block payload plus one fp32 scale per block — byte-exact for what
``_quantize`` actually puts on the wire, for any input dtype.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256  # quantization block (per-block scale)


def num_blocks(size: int) -> int:
    """Quantization blocks covering ``size`` elements (>= 1: the empty
    array still ships one scale so the wire format is self-describing)."""
    return max((size + BLOCK - 1) // BLOCK, 1)


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    out = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return out.reshape(shape)


def compressed_psum(x: jax.Array, axis) -> jax.Array:
    """Inside shard_map: int8 all-gather + local dequant-sum over ``axis``."""
    q, scale = _quantize(x)
    q_all = jax.lax.all_gather(q, axis)  # (n, blocks, BLOCK) int8
    s_all = jax.lax.all_gather(scale, axis)
    total = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)  # (blocks, BLOCK)
    return total.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)


def ef_update(grad: jax.Array, error: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Error feedback: compress (grad + carried error); carry the residual."""
    target = grad.astype(jnp.float32) + error
    q, scale = _quantize(target)
    decoded = _dequantize(q, scale, grad.shape, grad.size)
    new_error = target - decoded
    return decoded.astype(grad.dtype), new_error


def encode(x: jax.Array, error=None) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Wire form of one gradient slice: ``(q, scale, new_error)``.

    With ``error`` (the error-feedback accumulator, fp32, same shape) the
    residual of the previous rounds is folded in before quantizing and the
    new residual is returned — ``decode(q, scale, ...)`` on the receiver
    then telescopes to the true gradient sum over time (the property the
    hypothesis suite asserts). ``error=None`` encodes memorylessly."""
    target = x.astype(jnp.float32)
    if error is not None:
        target = target + error
    q, scale = _quantize(target)
    new_error = target - _dequantize(q, scale, target.shape, target.size)
    return q, scale, new_error


def decode(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    """Inverse of :func:`encode` (up to quantization error)."""
    return _dequantize(q, scale, shape, size)


def wire_bytes(x: jax.Array) -> Tuple[int, int]:
    """(exact bytes, compressed bytes) for one exchange of ``x``.

    Exact is the raw payload at the array's own dtype width; compressed is
    byte-exact for the ``_quantize`` wire format: ``num_blocks * BLOCK``
    padded int8 lanes plus one fp32 scale per block."""
    exact = x.size * x.dtype.itemsize
    nb = num_blocks(x.size)
    comp = nb * BLOCK * 1 + nb * 4
    return exact, comp
