"""Gradient compression for the data-parallel all-reduce (DESIGN.md §9).

``compressed_psum`` replaces an exact psum with: per-block int8 quantize ->
all_gather(quantized + scales) -> local dequantize-sum. Wire bytes drop to
~1/4 of fp32 (1/2 of bf16) at the price of quantization noise; an error-
feedback accumulator (``ef_update``) keeps the bias bounded, which is the
standard trick that makes low-bit gradient exchange trainable.

Used opt-in by wrapping the grad computation in ``shard_map`` over the data
axes; the dense pjit path keeps exact reductions.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256  # quantization block (per-block scale)


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    out = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return out.reshape(shape)


def compressed_psum(x: jax.Array, axis) -> jax.Array:
    """Inside shard_map: int8 all-gather + local dequant-sum over ``axis``."""
    q, scale = _quantize(x)
    q_all = jax.lax.all_gather(q, axis)  # (n, blocks, BLOCK) int8
    s_all = jax.lax.all_gather(scale, axis)
    total = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)  # (blocks, BLOCK)
    return total.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)


def ef_update(grad: jax.Array, error: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Error feedback: compress (grad + carried error); carry the residual."""
    target = grad.astype(jnp.float32) + error
    q, scale = _quantize(target)
    decoded = _dequantize(q, scale, grad.shape, grad.size)
    new_error = target - decoded
    return decoded.astype(grad.dtype), new_error


def wire_bytes(x: jax.Array) -> Tuple[int, int]:
    """(exact fp32 bytes, compressed bytes) for one all-reduce of ``x``."""
    exact = x.size * 4
    comp = x.size * 1 + (x.size // BLOCK + 1) * 4
    return exact, comp
