"""Production RL training driver.

Compiles an :class:`repro.api.ExperimentSpec` for ``--arch`` on the requested
mesh, runs ``--iters`` RL iterations with periodic sharded checkpoints, and
resumes (elastically — any topology) from ``--resume``. A full experiment can
also be loaded from a JSON file (``--experiment spec.json``, the
``ExperimentSpec.to_json`` form) and dumped with ``--dump-experiment``.

On real hardware this runs once per host under ``jax.distributed``; on this
CPU container it drives the same code path on a local mesh (used by the
examples and the convergence benchmark).

Usage:
  python -m repro.launch.train --arch qwen2.5-7b --algorithm grpo \
      --iters 500 --ckpt-dir ckpts/ [--resume ckpts/] [--smoke]
  python -m repro.launch.train --experiment exp.json --iters 100
  python -m repro.launch.train --smoke --max-staleness 1   # async pipeline v2
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from repro.api import ExperimentSpec
from repro.configs import (
    AsyncPipelineConfig,
    DistributedConfig,
    EnvConfig,
    RolloutEngineConfig,
    get_config,
    reduced,
)
from repro.distributed import sharding as shr
from repro.ft import checkpoint
from repro.launch.mesh import init_distributed, make_fleet_mesh, make_local_mesh
from repro.rl import RLConfig, list_algorithms
from repro.rl.trainer import TrainState
from repro.utils.jax_compat import use_mesh


def build_experiment(args) -> ExperimentSpec:
    """CLI flags -> ExperimentSpec (or load one wholesale from JSON)."""
    if args.experiment:
        with open(args.experiment) as f:
            exp = ExperimentSpec.from_json(f.read())
        if args.max_staleness is not None:
            # CLI overrides the file, like the usage line documents — don't
            # let the flag be silently swallowed by the JSON's setting
            exp = dataclasses.replace(
                exp,
                async_pipeline=AsyncPipelineConfig(
                    enabled=True, max_staleness=args.max_staleness
                ),
            )
        return exp
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg, vocab_size=260, num_layers=2)
    rl = RLConfig(
        algorithm=args.algorithm,
        group_size=args.group_size,
        max_new_tokens=args.max_new_tokens,
        lr=args.lr,
    )
    dag = None
    if args.dag_json:
        from repro.core import DAG

        dag = DAG.from_json(args.dag_json).to_spec()
    async_pipeline = AsyncPipelineConfig()
    if args.max_staleness is not None:
        async_pipeline = AsyncPipelineConfig(
            enabled=True, max_staleness=args.max_staleness
        )
    rollout = RolloutEngineConfig()
    if args.rollout_slots is not None:
        rollout = RolloutEngineConfig(
            engine="continuous", num_slots=args.rollout_slots
        )
    env = EnvConfig()
    if args.env:
        env = EnvConfig(name=args.env, max_turns=args.max_turns,
                        turn_budget=args.turn_budget)
        if env.max_turns > 1 and rollout.engine != "continuous":
            # the episode loop lives in the continuous engine; default the
            # slot pool to one slot per sequence unless --rollout-slots set
            rollout = RolloutEngineConfig(engine="continuous", num_slots=0)
    distributed = None
    if args.num_hosts > 1:
        distributed = DistributedConfig(
            num_hosts=args.num_hosts,
            process_id=args.process_id,
            coordinator=args.coordinator or "",
            grad_compression=args.grad_compression,
        )
    return ExperimentSpec(
        model=cfg,
        rl=rl,
        async_pipeline=async_pipeline,
        rollout=rollout,
        env=env,
        distributed=distributed,
        prompts_per_iter=args.prompts_per_iter,
        centralized=args.centralized_baseline,
        seed=args.seed,
        dag=dag,
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b")
    ap.add_argument("--algorithm", choices=list_algorithms(), default="grpo")
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--prompts-per-iter", type=int, default=8)
    ap.add_argument("--group-size", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--centralized-baseline", action="store_true",
                    help="run the single-controller arm (comparisons)")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="enable the async off-policy pipeline with this "
                         "staleness bound (0 = lockstep scheduler, bitwise-"
                         "identical to sync; see docs/async_pipeline.md)")
    ap.add_argument("--rollout-slots", type=int, default=None,
                    help="enable the continuous-batching rollout engine "
                         "with this many decode slots (0 = one per "
                         "sequence; see docs/rollout_engine.md)")
    ap.add_argument("--env", default=None,
                    help="registered environment name (repro.rl.envs: "
                         "function_reward | calculator | dialog); enables "
                         "the env/reward subsystem (docs/environments.md)")
    ap.add_argument("--max-turns", type=int, default=1,
                    help="episode turn cap for --env (>1 auto-enables the "
                         "continuous rollout engine's episode loop)")
    ap.add_argument("--turn-budget", type=int, default=0,
                    help="per-turn response-token cap for --env "
                         "(0 = --max-new-tokens)")
    ap.add_argument("--num-hosts", type=int, default=1,
                    help="host processes in the fleet; >1 enables the "
                         "multi-host runtime (docs/multihost.md) — launch "
                         "one copy of this driver per host")
    ap.add_argument("--process-id", type=int, default=0,
                    help="this host's rank in [0, --num-hosts)")
    ap.add_argument("--coordinator", default=None,
                    help="shared coordinator directory (simulated fleet) or "
                         "host:port (jax.distributed on real hardware)")
    ap.add_argument("--grad-compression", choices=["none", "int8_ef"],
                    default="none",
                    help="DP gradient exchange encoding: none = exact fp32 "
                         "(bitwise parity with single-host), int8_ef = "
                         "block-int8 + error feedback (~1/4 wire bytes)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model config (CPU-sized)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dag-json", default=None,
                    help="custom DAG config file (paper §4.1)")
    ap.add_argument("--experiment", default=None,
                    help="ExperimentSpec JSON file; overrides the arch/rl flags")
    ap.add_argument("--dump-experiment", default=None,
                    help="write the resolved ExperimentSpec JSON here and exit")
    ap.add_argument("--obs-trace", default=None, metavar="PATH",
                    help="enable telemetry and export a Chrome-trace JSON "
                         "here at the end of the run (docs/observability.md)")
    ap.add_argument("--obs-metrics", default=None, metavar="PATH",
                    help="enable telemetry and append per-iteration metrics "
                         "as JSONL here")
    args = ap.parse_args(argv)

    exp = build_experiment(args)
    if args.obs_trace or args.obs_metrics:
        # flags layer on top of whatever the spec (file or CLI) carries,
        # same precedence as --max-staleness
        exp = dataclasses.replace(exp, obs=dataclasses.replace(
            exp.obs,
            enabled=True,
            trace_path=args.obs_trace or exp.obs.trace_path,
            metrics_path=args.obs_metrics or exp.obs.metrics_path,
        ))
    if args.dump_experiment:
        with open(args.dump_experiment, "w") as f:
            f.write(exp.to_json())
        print(f"[train] wrote {args.dump_experiment}")
        return
    cfg = exp.model
    dist = exp.distributed
    fleet_ctx = None
    if dist is not None and dist.enabled:
        fleet_ctx = init_distributed(
            dist.coordinator, dist.num_hosts, dist.process_id,
            grad_compression=dist.grad_compression,
        )
        mesh = make_fleet_mesh(dist.num_hosts, dist.devices_per_host)
        if fleet_ctx is not None:
            fleet_ctx.start_heartbeats()
            fleet_ctx.barrier("startup")
    else:
        mesh = make_local_mesh()

    with use_mesh(mesh):
        pipe = exp.compile(mesh=mesh)
        start = 0
        if args.resume:
            state = pipe.ctx.actor_state
            pspecs = shr.param_specs(cfg, mesh, state.params)
            specs = TrainState(params=pspecs, opt=shr.opt_state_specs(pspecs))
            restored, start = checkpoint.restore(
                args.resume, state, mesh=mesh, specs=specs
            )
            pipe.ctx.actor_state = restored
            print(f"[train] resumed from {args.resume} at iteration {start}")

        from repro.obs import JSONLSink, StdoutSink, iteration_record

        obs_rt = getattr(pipe.ctx, "obs", None)
        stdout_sink = StdoutSink()
        jsonl_sink = (JSONLSink(obs_rt.cfg.metrics_path)
                      if obs_rt is not None and obs_rt.cfg.metrics_path
                      else None)
        for it in range(start, args.iters):
            if fleet_ctx is not None:
                fleet_ctx.heartbeat(it)
            t0 = time.perf_counter()
            metrics = pipe.worker.run_iteration()
            dt = time.perf_counter() - t0
            if it % 5 == 0 or it == args.iters - 1:
                stdout_sink.emit_iteration(it, metrics, dt)
            if obs_rt is not None:
                obs_rt.registry.histogram("train/step_s").record(dt)
                if jsonl_sink is not None:
                    jsonl_sink.write(iteration_record(it, metrics, dt))
                if fleet_ctx is not None and obs_rt.cfg.fleet_snapshots:
                    fleet_ctx.publish_metrics(it, metrics)
            if args.ckpt_dir and (it + 1) % args.ckpt_every == 0:
                checkpoint.save(args.ckpt_dir, pipe.ctx.actor_state, step=it + 1)
                print(f"[train] checkpoint @ {it + 1} -> {args.ckpt_dir}")
        if jsonl_sink is not None:
            jsonl_sink.close()
        if obs_rt is not None and obs_rt.cfg.trace_path:
            obs_rt.tracer.export_chrome(obs_rt.cfg.trace_path)
            print(f"[train] wrote trace {obs_rt.cfg.trace_path} "
                  f"({obs_rt.tracer.num_events} events)")
        print(f"[train] done; buffer stats: {pipe.buffer.stats}")


if __name__ == "__main__":
    main()
