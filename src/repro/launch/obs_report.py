"""Fleet straggler report: aggregate per-host obs snapshots + merge traces.

Reads the per-iteration metrics snapshots the hosts published over the
FleetContext file plane (``<coordinator>/obs/host*/it*.json`` — enable with
``ObsConfig(enabled=True)``, or ``FLEET_OBS=1`` under the test harness) and
prints a straggler report: a per-iteration step-time timeline, a per-host
summary table with slowest-node attribution, and the fleet-wide step-time
percentiles from the exact cross-host histogram merge. Optionally merges the
hosts' per-host Chrome traces into one Perfetto-loadable timeline.

Usage:
  python -m repro.launch.obs_report --coordinator /tmp/fleet-coord
  python -m repro.launch.obs_report --coordinator /tmp/fleet-coord \
      --merge-traces /tmp/fleet-coord/trace.host*.json --out merged.json
  python -m repro.launch.obs_report --coordinator c/ --json   # raw report
"""
from __future__ import annotations

import argparse
import glob
import json

from repro.obs.aggregate import (
    collect_snapshots,
    merge_traces,
    render_report,
    straggler_report,
)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", required=True,
                    help="the fleet coordinator directory snapshots were "
                         "published under")
    ap.add_argument("--json", action="store_true",
                    help="print the raw report dict as JSON instead of the "
                         "rendered timeline/table")
    ap.add_argument("--merge-traces", nargs="*", default=None,
                    metavar="GLOB",
                    help="per-host Chrome-trace JSON files (globs ok) to "
                         "merge into one multi-host timeline")
    ap.add_argument("--out", default=None,
                    help="output path for the merged trace "
                         "(default: merged_trace.json under --coordinator)")
    args = ap.parse_args(argv)

    snapshots = collect_snapshots(args.coordinator)
    report = straggler_report(snapshots)
    if args.json:
        print(json.dumps(report, indent=2, sort_keys=True, default=str))
    else:
        print(render_report(report), end="")

    if args.merge_traces:
        paths = sorted(p for g in args.merge_traces for p in glob.glob(g))
        if paths:
            out = args.out or f"{args.coordinator.rstrip('/')}/merged_trace.json"
            merged = merge_traces(paths, out)
            print(f"[obs] merged {len(paths)} traces "
                  f"({len(merged['traceEvents'])} events) -> {out}")
        else:
            print("[obs] --merge-traces matched no files")


if __name__ == "__main__":
    main()
