"""Production mesh builders (deliverable e).

Defined as FUNCTIONS so importing this module never touches jax device state.
Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — `pod` is the
extra data-parallel dimension whose gradient reduction crosses the
inter-pod links.

``make_compat_mesh`` is the version-tolerant constructor every caller should
use: newer jax releases want explicit ``axis_types=(AxisType.Auto, ...)``,
older ones (<= 0.4.x) have neither the kwarg nor ``jax.sharding.AxisType``.
"""
from __future__ import annotations

import jax

from repro.utils.jax_compat import auto_axis_types, make_compat_mesh, use_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_local_mesh(shape=None, axes=("data", "model")):
    """Whatever the current backend offers (tests / CPU examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return make_compat_mesh(shape, axes)
