"""Production mesh builders (deliverable e) + multi-host fleet bring-up.

Defined as FUNCTIONS so importing this module never touches jax device state.
Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — `pod` is the
extra data-parallel dimension whose gradient reduction crosses the
inter-pod links.

``make_compat_mesh`` is the version-tolerant constructor every caller should
use: newer jax releases want explicit ``axis_types=(AxisType.Auto, ...)``,
older ones (<= 0.4.x) have neither the kwarg nor ``jax.sharding.AxisType``.

Fleet bring-up (docs/multihost.md): :func:`init_distributed` resolves the
``coordinator`` string — ``host:port`` means real multi-process jax
(``jax.distributed.initialize``); a filesystem path means the CPU-simulated
fleet, where every host process forces ``num_hosts * devices_per_host``
local host-platform devices (``XLA_FLAGS=--xla_force_host_platform_
device_count=N``, set BEFORE jax import) and coordinates through the shared
directory (``repro.distributed.fleet``). Either way,
:func:`make_fleet_mesh` then builds the global ``(pod, data, model)`` mesh
every process agrees on.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.utils.jax_compat import auto_axis_types, make_compat_mesh, use_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_local_mesh(shape=None, axes=("data", "model")):
    """Whatever the current backend offers (tests / CPU examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return make_compat_mesh(shape, axes)


def init_distributed(coordinator: str, num_processes: int = 1,
                     process_id: int = 0, **overrides):
    """Bring up the multi-host runtime; returns the registered
    :class:`repro.distributed.fleet.FleetContext` (None when single-host).

    ``coordinator`` ``"host:port"`` -> ``jax.distributed.initialize`` (real
    hardware; jax then exposes the other hosts' devices and there is no file
    plane to manage). Anything else is a shared DIRECTORY -> the simulated
    fleet: a FleetContext is built from a validated ``DistributedConfig``
    (``overrides`` forward extra fields, e.g. ``grad_compression``,
    ``dead_after_s``) and registered as the process-global context that
    ``build_pipeline`` picks up.
    """
    if num_processes <= 1:
        return None
    from repro.configs.base import DistributedConfig
    from repro.distributed import fleet

    if ":" in coordinator and "/" not in coordinator:
        jax.distributed.initialize(
            coordinator_address=coordinator,
            num_processes=num_processes,
            process_id=process_id,
        )
        return None
    cfg = DistributedConfig(
        num_hosts=num_processes, process_id=process_id,
        coordinator=coordinator, **overrides,
    )
    ctx = fleet.ensure_context(cfg)
    ctx.heartbeat(0)
    return ctx


def make_fleet_mesh(num_hosts: int, devices_per_host: int = 0,
                    *, model_parallel: int = 1, devices=None):
    """Global ``(pod, data, model)`` mesh over the fleet's devices.

    Every process must call this with identical arguments and derive the
    identical mesh — the multi-controller SPMD contract. The ``pod`` axis
    has one row per host (row-major ``jax.make_mesh`` ordering puts each
    host's devices in one contiguous block, which is also how
    ``fleet.host_device_groups`` recovers the host groups); ``data`` x
    ``model`` tile within a host. In the CPU-simulated mode each process
    sees all ``num_hosts * devices_per_host`` forced host-platform devices;
    under ``jax.distributed`` the same global device list spans processes.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    if devices_per_host == 0:
        if len(devices) % num_hosts:
            raise ValueError(
                f"{len(devices)} devices not divisible by {num_hosts} hosts")
        devices_per_host = len(devices) // num_hosts
    need = num_hosts * devices_per_host
    if need > len(devices):
        raise ValueError(
            f"fleet needs {need} devices, backend offers {len(devices)} "
            "(simulated fleets must set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before jax import)")
    if devices_per_host % model_parallel:
        raise ValueError(
            f"devices_per_host {devices_per_host} not divisible by "
            f"model_parallel {model_parallel}")
    shape = (num_hosts, devices_per_host // model_parallel, model_parallel)
    axes = ("pod", "data", "model")
    types = auto_axis_types(len(axes))
    if types is not None:
        try:
            return jax.make_mesh(shape, axes, devices=devices[:need],
                                 axis_types=types)
        except TypeError:
            pass
    return jax.make_mesh(shape, axes, devices=devices[:need])
