"""Production mesh builders (deliverable e).

Defined as FUNCTIONS so importing this module never touches jax device state.
Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — `pod` is the
extra data-parallel dimension whose gradient reduction crosses the
inter-pod links.
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh(shape=None, axes=("data", "model")):
    """Whatever the current backend offers (tests / CPU examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))
