"""Workload builders for the dry-run / benchmarks: the lowered programs.

For each (arch x shape) cell this module provides
  * ``input_specs(cfg, shape, mesh)`` — ShapeDtypeStruct stand-ins for every
    model input (weak-type-correct, shardable, no device allocation), plus
    the matching PartitionSpec trees;
  * ``build_workload(...)`` — the jit'd step with explicit in/out shardings:
    train_4k   -> train_step   (loss+grads+AdamW; donates state)
    prefill_*  -> prefill_step (prompt pass emitting decode caches)
    decode_* / long_* -> serve_step (one token vs filled caches; donates them)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shr
from repro.models import get_model
from repro.models import lm as lm_mod
from repro.optim import adamw
from repro.rl import trainer


class Workload(NamedTuple):
    fn: Any  # jit'd step
    args: Tuple  # ShapeDtypeStruct pytrees to lower with
    donate: Tuple[int, ...]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# --------------------------------------------------------------------------- #
# input specs per shape kind
# --------------------------------------------------------------------------- #
def train_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    B, S = shape.global_batch, shape.seq_len
    dp = shr.batch_axes(mesh, B)
    if cfg.is_encoder_decoder:
        half = S // 2
        batch = {
            "frames": _sds((B, half, cfg.d_model), jnp.bfloat16),
            "tokens": _sds((B, half), jnp.int32),
            "labels": _sds((B, half), jnp.int32),
        }
        specs = {"frames": P(dp, None, None), "tokens": P(dp, None), "labels": P(dp, None)}
    elif cfg.num_prefix_embeds > 1:
        pre = cfg.num_prefix_embeds
        batch = {
            "prefix_embeds": _sds((B, pre, cfg.d_model), jnp.bfloat16),
            "tokens": _sds((B, S - pre), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        specs = {
            "prefix_embeds": P(dp, None, None),
            "tokens": P(dp, None),
            "labels": P(dp, None),
        }
    else:
        batch = {"tokens": _sds((B, S), jnp.int32), "labels": _sds((B, S), jnp.int32)}
        specs = {"tokens": P(dp, None), "labels": P(dp, None)}
    return batch, specs


def prompt_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Prefill inputs (prompt tokens [+ modality prefix])."""
    B, S = shape.global_batch, shape.seq_len
    dp = shr.batch_axes(mesh, B)
    if cfg.is_encoder_decoder:
        args = {
            "tokens": _sds((B, S), jnp.int32),
            "frames": _sds((B, cfg.encoder_len, cfg.d_model), jnp.bfloat16),
        }
        specs = {"tokens": P(dp, None), "frames": P(dp, None, None)}
    elif cfg.num_prefix_embeds > 1:
        pre = cfg.num_prefix_embeds
        args = {
            "tokens": _sds((B, S - pre), jnp.int32),
            "prefix_embeds": _sds((B, pre, cfg.d_model), jnp.bfloat16),
        }
        specs = {"tokens": P(dp, None), "prefix_embeds": P(dp, None, None)}
    else:
        args = {"tokens": _sds((B, S), jnp.int32)}
        specs = {"tokens": P(dp, None)}
    return args, specs


def state_shapes(cfg: ModelConfig, key=None):
    """ShapeDtypeStructs for TrainState without allocating."""
    model = get_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda p: adamw.init(p), params)
    return trainer.TrainState(params=params, opt=opt)


def caches_shapes(cfg: ModelConfig, batch: int, smax: int):
    model = get_model(cfg)
    return jax.eval_shape(lambda: model.init_caches(batch, smax))


# --------------------------------------------------------------------------- #
# workload builders
# --------------------------------------------------------------------------- #
def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                *, unroll: bool = False) -> Workload:
    model = get_model(cfg)
    state = state_shapes(cfg)
    pspecs = shr.param_specs(cfg, mesh, state.params)
    sspecs = trainer.TrainState(params=pspecs, opt=shr.opt_state_specs(pspecs))
    batch, bspecs = train_inputs(cfg, shape, mesh)
    step = trainer.make_lm_train_step(model, unroll=unroll)
    fn = jax.jit(
        step,
        in_shardings=(shr.named(mesh, sspecs), shr.named(mesh, bspecs)),
        out_shardings=(shr.named(mesh, sspecs), None),
        donate_argnums=(0,),
    )
    return Workload(fn=fn, args=(state, batch), donate=(0,))


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                  *, unroll: bool = False) -> Workload:
    model = get_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = shr.param_specs(cfg, mesh, params)
    args, aspecs = prompt_inputs(cfg, shape, mesh)
    B, S = shape.global_batch, shape.seq_len
    cshapes = caches_shapes(cfg, B, S)
    cspecs = shr.cache_specs(cfg, mesh, B, cshapes)
    dp = shr.batch_axes(mesh, B)

    def prefill_step(params, args):
        logits, caches, cache_len = model.prefill(params, **args, smax=S,
                                                  unroll=unroll)
        return jnp.argmax(logits, -1).astype(jnp.int32), caches, cache_len

    fn = jax.jit(
        prefill_step,
        in_shardings=(shr.named(mesh, pspecs), shr.named(mesh, aspecs)),
        out_shardings=(
            NamedSharding(mesh, P(dp)),
            shr.named(mesh, cspecs),
            NamedSharding(mesh, P(dp)),
        ),
    )
    return Workload(fn=fn, args=(params, args), donate=())


HBM_BUDGET = 15.3e9  # of 16GB v5e: deepseek decode_32k fits resident at
# 14.8GB (weights 8.4 + cache 6.4); int8 KV (future work) would add 3GB slack


def serve_param_mode(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> str:
    """Pick the decode weight layout: TP-replicated (weights resident, no
    per-step gathers) when weights + caches fit the HBM budget; otherwise
    keep the FSDP layout and pay the per-step gather (the price of fitting,
    recorded in the roofline notes)."""
    tp = mesh.shape["model"]
    weight_bytes = cfg.num_params() * 2 / tp
    caches = caches_shapes(cfg, shape.global_batch, shape.seq_len)
    n_dev = 1
    for v in dict(mesh.shape).values():
        n_dev *= v
    cache_bytes = sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(caches)) / n_dev
    return "serve" if weight_bytes + cache_bytes < HBM_BUDGET else "train"


def build_serve(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                *, unroll: bool = False, param_mode: str = None) -> Workload:
    """One decode step against a seq_len-deep cache (decode_* / long_*).

    ``param_mode`` overrides the weight-layout decision — callers compiling
    DEPTH-REDUCED configs (the roofline extrapolation) must pass the decision
    made on the FULL config, or a 1-layer model always "fits" resident."""
    model = get_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = shr.param_specs(cfg, mesh, params,
                             mode=param_mode or serve_param_mode(cfg, shape, mesh))
    B, S = shape.global_batch, shape.seq_len
    cshapes = caches_shapes(cfg, B, S)
    cspecs = shr.cache_specs(cfg, mesh, B, cshapes)
    dp = shr.batch_axes(mesh, B)
    tok = _sds((B,), jnp.int32)
    clen = _sds((B,), jnp.int32)

    def serve_step(params, token, caches, cache_len):
        logits, caches, cache_len = model.decode_step(
            params, token, caches, cache_len, unroll=unroll
        )
        return jnp.argmax(logits, -1).astype(jnp.int32), caches, cache_len

    fn = jax.jit(
        serve_step,
        in_shardings=(
            shr.named(mesh, pspecs),
            NamedSharding(mesh, P(dp)),
            shr.named(mesh, cspecs),
            NamedSharding(mesh, P(dp)),
        ),
        out_shardings=(
            NamedSharding(mesh, P(dp)),
            shr.named(mesh, cspecs),
            NamedSharding(mesh, P(dp)),
        ),
        donate_argnums=(2,),
    )
    return Workload(fn=fn, args=(params, tok, cshapes, clen), donate=(2,))


def build_workload(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   *, unroll: bool = False, serve_mode: str = None) -> Workload:
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, unroll=unroll)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, unroll=unroll)
    return build_serve(cfg, shape, mesh, unroll=unroll, param_mode=serve_mode)
