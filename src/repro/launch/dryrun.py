import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e).

Two measurement modes per (architecture x input-shape) cell:

compile   — the full-depth model with scan-over-layers is lowered + compiled
            on the single-pod (16,16) AND multi-pod (2,16,16) meshes. This is
            the pass/fail sharding proof and the memory_analysis() fit proof
            (params/caches at full depth). XLA prices a while-loop body once,
            so cost numbers from this mode are NOT used.

roofline  — the model is compiled UNROLLED at reduced depths L=P and L=2P
            (P = the layer-pattern length); per-layer-linear quantities
            (FLOPs, bytes accessed, collective bytes) are extrapolated
            exactly to full depth:  m(L) = m(P) + (m(2P)-m(P)) * (L-P)/P.
            Verified against a full-depth unrolled compile (gemma-2b: 0.2%
            off; see EXPERIMENTS.md §Dry-run). Single-pod mesh (the roofline
            table's mesh).

NOTE: the XLA_FLAGS line above MUST run before any other import — jax locks
the device count at first init.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all --mode compile --out compile.json
  python -m repro.launch.dryrun --all --mode roofline --out roofline.json
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

from repro.configs import ARCHS, ASSIGNED, applicable_shapes, get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.workloads import build_workload
from repro.models.lm import pattern_length
from repro.utils.hlo import collective_bytes, cost_summary
from repro.utils.jax_compat import use_mesh


def _resolve_config(arch: str, cfg=None):
    """The cell's ModelConfig: an explicit override (from --experiment's
    ExperimentSpec) or the registry entry for ``arch``."""
    return cfg if cfg is not None else get_config(arch)


def _compile(cfg, shape, mesh, *, unroll, serve_mode=None):
    wl = build_workload(cfg, shape, mesh, unroll=unroll, serve_mode=serve_mode)
    t0 = time.time()
    lowered = wl.fn.lower(*wl.args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return compiled, round(t1 - t0, 1), round(t2 - t1, 1)


def run_compile_cell(arch: str, shape_name: str, *, multi_pod: bool,
                     cfg=None) -> dict:
    """Full-depth scan compile: sharding pass/fail + memory proof."""
    cfg = _resolve_config(arch, cfg)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    with use_mesh(mesh):
        compiled, t_lower, t_compile = _compile(cfg, shape, mesh, unroll=False)
        mem = compiled.memory_analysis()
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(mesh.devices.size),
        "mode": "compile",
        "ok": True,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }


def _reduced_depth(cfg, k: int):
    """cfg with num_layers = k * pattern_length (and encoder to k layers)."""
    P = pattern_length(cfg)
    upd = {"num_layers": k * P}
    if cfg.is_encoder_decoder:
        upd["num_encoder_layers"] = k
    return dataclasses.replace(cfg, **upd), P


def _metrics(compiled):
    cost = cost_summary(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes_accessed", 0.0),
        "transcendentals": cost.get("transcendentals", 0.0),
        "coll_total": float(coll["total_bytes"]),
        "coll_per_kind": coll["per_kind_bytes"],
        "coll_count": coll["total_count"],
    }


def run_roofline_cell(arch: str, shape_name: str, cfg=None) -> dict:
    """Depth-reduced unrolled compiles -> exact per-layer-linear extrapolation."""
    cfg = _resolve_config(arch, cfg)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=False)
    cfg1, P = _reduced_depth(cfg, 1)
    cfg2, _ = _reduced_depth(cfg, 2)
    k1, k2 = 1, 2
    # the serve weight-layout decision must come from the FULL config: a
    # depth-reduced model always fits the resident-weights budget
    smode = None
    if shape.kind == "decode":
        from repro.launch.workloads import serve_param_mode
        smode = serve_param_mode(cfg, shape, mesh)
    with use_mesh(mesh):
        c1, _, t1 = _compile(cfg1, shape, mesh, unroll=True, serve_mode=smode)
        m1 = _metrics(c1)
        del c1
        c2, _, t2 = _compile(cfg2, shape, mesh, unroll=True, serve_mode=smode)
        m2 = _metrics(c2)
        del c2
        if m2["bytes"] < m1["bytes"] or m2["flops"] < m1["flops"]:
            # non-monotone boundary fusion at tiny depth (seen once:
            # seamless prefill): fall back to the (2P, 4P) pair
            cfg4, _ = _reduced_depth(cfg, 4)
            c4, _, t4 = _compile(cfg4, shape, mesh, unroll=True,
                                 serve_mode=smode)
            m1, m2, k1, k2 = m2, _metrics(c4), 2, 4
            t2 += t4
            del c4

    L = cfg.num_layers
    scale = (L - k1 * P) / ((k2 - k1) * P)  # groups beyond the m1 depth

    def extra(a, b):
        return a + (b - a) * scale

    per_kind = {
        k: extra(m1["coll_per_kind"].get(k, 0), m2["coll_per_kind"].get(k, 0))
        for k in set(m1["coll_per_kind"]) | set(m2["coll_per_kind"])
    }
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "16x16",
        "chips": int(mesh.devices.size),
        "mode": "roofline",
        "ok": True,
        "compile_s": t1 + t2,
        "depths": [k1 * P, k2 * P, L],
        "flops": extra(m1["flops"], m2["flops"]),
        "bytes": extra(m1["bytes"], m2["bytes"]),
        "transcendentals": extra(m1["transcendentals"], m2["transcendentals"]),
        "coll_total": extra(m1["coll_total"], m2["coll_total"]),
        "coll_per_kind": per_kind,
        "raw": {"L1": m1, "L2": m2},
    }


def run_quad_cell(arch: str, shape_name: str, cfg=None) -> dict:
    """Quadratic-in-S byte extraction (the flash-attention correction).

    The pure-jnp attention lowered on CPU materializes (B,H,S,S) score/prob
    tensors that the Pallas kernel keeps in VMEM on the real TPU. Their HBM
    bytes are a quadratic-in-S component of the per-layer bytes: compile the
    cell UNROLLED at depths L=P,2P and seqs S/4,S/2,S; the per-layer byte
    curve layer(S) = a + b S + c S^2 is fitted exactly through 3 points, and
    c*S^2*(L/P) is the S^2 materialization the kernel removes
    (memory_flash = memory_raw - that)."""
    import numpy as np

    cfg = _resolve_config(arch, cfg)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=False)
    cfg1, P = _reduced_depth(cfg, 1)
    cfg2, _ = _reduced_depth(cfg, 2)
    seqs = [shape.seq_len // 4, shape.seq_len // 2, shape.seq_len]
    layer_bytes = []
    with use_mesh(mesh):
        for S in seqs:
            sh = dataclasses.replace(shape, seq_len=S)
            c1, _, _ = _compile(cfg1, sh, mesh, unroll=True)
            b1 = _metrics(c1)["bytes"]
            del c1
            c2, _, _ = _compile(cfg2, sh, mesh, unroll=True)
            b2 = _metrics(c2)["bytes"]
            del c2
            layer_bytes.append(b2 - b1)  # bytes of one extra pattern group
    A = np.stack([np.ones(3), np.array(seqs, float),
                  np.array(seqs, float) ** 2], 1)
    a, b, c = np.linalg.solve(A, np.array(layer_bytes))
    groups = cfg.num_layers // P
    s2_total = float(c) * shape.seq_len**2 * groups
    return {
        "arch": arch,
        "shape": shape_name,
        "mode": "quad",
        "ok": True,
        "seqs": seqs,
        "layer_bytes": layer_bytes,
        "quad_coeff_per_group": float(c),
        "s2_bytes_total": s2_total,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mode", choices=["compile", "roofline", "quad"],
                    default="compile")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--skip", type=int, default=0, help="skip first N cells")
    ap.add_argument("--experiment", type=str, default=None,
                    help="ExperimentSpec JSON; its model config replaces "
                         "--arch for single-cell runs")
    args = ap.parse_args(argv)

    cfg_override = None
    if args.experiment:
        assert not args.all, "--experiment overrides one model; drop --all"
        from repro.api import ExperimentSpec

        with open(args.experiment) as f:
            exp = ExperimentSpec.from_json(f.read())
        cfg_override = exp.model
        args.arch = args.arch or cfg_override.name
        if exp.async_pipeline.enabled:
            # the async scheduler changes the iteration schedule, not any
            # per-cell compile/memory cost — note it so the operator knows
            # which arm prices the overlap (benchmarks/async_pipeline.py)
            print(
                f"[dryrun] experiment enables async pipeline "
                f"(max_staleness={exp.async_pipeline.max_staleness}); "
                "per-cell costs below are schedule-independent — "
                "benchmarks/async_pipeline.py prices the overlap",
                flush=True,
            )

    cells = []
    if args.all:
        for arch in ASSIGNED:
            for shape in applicable_shapes(ARCHS[arch]):
                if args.mode == "compile":
                    cells.append((arch, shape.name, False))
                    cells.append((arch, shape.name, True))
                else:
                    cells.append((arch, shape.name, False))
    else:
        assert (args.arch or cfg_override) and args.shape, \
            "--arch/--experiment and --shape (or --all)"
        cells.append((args.arch, args.shape, args.multi_pod))
    cells = cells[args.skip:]

    results, n_fail = [], 0
    for arch, shape, mp in cells:
        tag = f"{arch}:{shape}:{'2x16x16' if mp else '16x16'}:{args.mode}"
        try:
            if args.mode == "compile":
                r = run_compile_cell(arch, shape, multi_pod=mp,
                                     cfg=cfg_override)
                print(
                    f"[dryrun] OK   {tag}  peak/device={_fmt(r['memory']['peak_bytes'])}"
                    f"  (lower {r['lower_s']}s compile {r['compile_s']}s)",
                    flush=True,
                )
            elif args.mode == "quad":
                r = run_quad_cell(arch, shape, cfg=cfg_override)
                print(
                    f"[dryrun] OK   {tag}  s2_bytes={_fmt(r['s2_bytes_total'])}"
                    f"  coeff={r['quad_coeff_per_group']:.3e}", flush=True)
            else:
                r = run_roofline_cell(arch, shape, cfg=cfg_override)
                print(
                    f"[dryrun] OK   {tag}  flops/dev={r['flops']:.3e}"
                    f"  bytes/dev={r['bytes']:.3e}  coll/dev={_fmt(r['coll_total'])}"
                    f"  (compile {r['compile_s']:.0f}s)",
                    flush=True,
                )
        except Exception as e:  # noqa
            n_fail += 1
            r = {"arch": arch, "shape": shape,
                 "mesh": "2x16x16" if mp else "16x16", "mode": args.mode,
                 "ok": False, "error": f"{type(e).__name__}: {e}"}
            print(f"[dryrun] FAIL {tag}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
        results.append(r)
        if args.out:  # incremental write (long runs)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    print(f"[dryrun] {len(results) - n_fail}/{len(results)} cells OK")
    return 1 if n_fail else 0


def _fmt(b):
    if b is None:
        return "?"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


if __name__ == "__main__":
    sys.exit(main())
