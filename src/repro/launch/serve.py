"""Batched serving driver: prefill + decode loop over request batches.

The serving-side counterpart of the rollout engine: requests are grouped
into fixed-shape batches (one compiled executable), prefilled, then decoded
token-slab by token-slab. ``--arch`` selects any assigned architecture.

Usage:
  python -m repro.launch.serve --arch gemma-2b --smoke --batch 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.data.tokenizer import ByteTokenizer
from repro.launch.mesh import make_local_mesh
from repro.models import get_model
from repro.rl.rollout import generate
from repro.utils.jax_compat import use_mesh


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=3, help="batches to serve")
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg, vocab_size=260, num_layers=2)
    tok = ByteTokenizer()
    model = get_model(cfg)
    mesh = make_local_mesh()
    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(args.seed))
        texts = [f"{i:02d}+{i + 1:02d}=" for i in range(args.batch)]
        prompt = jnp.asarray(np.stack([tok.encode(t) for t in texts]))
        kw = {}
        if cfg.is_encoder_decoder:
            kw["frames"] = jnp.zeros((args.batch, cfg.encoder_len, cfg.d_model),
                                     jnp.bfloat16)
        if cfg.num_prefix_embeds > 1:
            kw["prefix_embeds"] = jnp.zeros(
                (args.batch, cfg.num_prefix_embeds, cfg.d_model), jnp.bfloat16)

        served = 0
        t0 = time.perf_counter()
        for r in range(args.requests):
            key = jax.random.PRNGKey(args.seed + r + 1)
            res = generate(model, params, prompt, key, max_new=args.max_new,
                           temperature=args.temperature, eos_id=tok.eos_id, **kw)
            served += int(jnp.sum(res.lengths))
            if r == 0:
                for text, row in zip(texts, np.asarray(res.tokens)):
                    print(f"[serve] {text!r} -> {tok.decode(row[len(text):])!r}")
        dt = time.perf_counter() - t0
        print(f"[serve] {served} tokens in {dt:.2f}s "
              f"({served / dt:.1f} tok/s incl. first-batch compile)")


if __name__ == "__main__":
    main()
