"""Serving driver: request-streaming server (default) or lockstep batches.

The default path runs the :class:`repro.serving.ServingEngine`: requests
arrive on a Poisson clock, stream token deltas as they decode, share prompt
KV through the radix prefix cache, and (when a weight store is wired in)
keep decoding across live weight hot-swaps. ``--lockstep`` keeps the old
fixed-batch driver — requests grouped into one-shape batches through
``generate()`` — as the fallback for archs the streaming engine gates out
(SSM mixers, SWA rings, int8 KV, enc-dec) and as the goodput baseline
``benchmarks/serving.py`` measures against.

Usage:
  python -m repro.launch.serve --arch qwen2.5-7b --smoke --num-requests 16
  python -m repro.launch.serve --arch gemma-2b --smoke --lockstep --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ServingConfig, get_config, reduced
from repro.data.tokenizer import ByteTokenizer
from repro.launch.mesh import make_local_mesh
from repro.models import get_model
from repro.rl.rollout import generate
from repro.utils.jax_compat import use_mesh


def run_lockstep(model, params, tok, args) -> None:
    """Fixed-shape batched serving. One untimed warmup batch absorbs the
    compile, then per-batch wall latencies feed a registry histogram for
    the p50/p99 report."""
    from repro.obs import MetricsRegistry

    cfg = model.cfg
    texts = [f"{i:02d}+{i + 1:02d}=" for i in range(args.batch)]
    prompt = jnp.asarray(np.stack([tok.encode(t) for t in texts]))
    kw = {}
    if cfg.is_encoder_decoder:
        kw["frames"] = jnp.zeros((args.batch, cfg.encoder_len, cfg.d_model),
                                 jnp.bfloat16)
    if cfg.num_prefix_embeds > 1:
        kw["prefix_embeds"] = jnp.zeros(
            (args.batch, cfg.num_prefix_embeds, cfg.d_model), jnp.bfloat16)

    def one_batch(r: int) -> int:
        key = jax.random.PRNGKey(args.seed + r + 1)
        res = generate(model, params, prompt, key, max_new=args.max_new,
                       temperature=args.temperature, eos_id=tok.eos_id, **kw)
        return int(jnp.sum(res.lengths)), res

    _, res = one_batch(-1)  # warmup: compile + first execution, untimed
    for text, row in zip(texts, np.asarray(res.tokens)):
        print(f"[serve] {text!r} -> {tok.decode(row[len(text):])!r}")

    served = 0
    hist = MetricsRegistry().histogram("serve/batch_latency_s")
    t0 = time.perf_counter()
    for r in range(args.requests):
        tb = time.perf_counter()
        n, _ = one_batch(r)
        hist.record(time.perf_counter() - tb)
        served += n
    dt = time.perf_counter() - t0
    p = hist.percentiles((50, 99))
    print(f"[serve] {served} tokens in {dt:.2f}s ({served / dt:.1f} tok/s, "
          f"compile excluded; batch latency p50 {p['p50'] * 1e3:.1f}ms "
          f"p99 {p['p99'] * 1e3:.1f}ms)")


def run_streaming(model, params, args) -> None:
    """Request-streaming serving over a synthetic Poisson arrival stream."""
    from repro.obs import MetricsRegistry
    from repro.serving import ServingEngine, synthetic_requests

    scfg = ServingConfig(
        num_slots=args.slots, max_len=args.max_len, max_new=args.max_new,
        page_size=args.page_size, prefix_cache=not args.no_prefix_cache,
        decode_burst=args.burst, yield_quota=args.yield_quota)
    eng = ServingEngine(model, scfg, params=params, eos_id=args.eos_id,
                        key=jax.random.PRNGKey(args.seed),
                        registry=MetricsRegistry())
    reqs = synthetic_requests(
        args.num_requests, arrival_rate=args.rate, page_size=args.page_size,
        max_new=args.max_new, temperature=args.temperature, seed=args.seed)
    # warmup: replay the identical workload once, untimed, so every
    # per-shape executable is compiled; then reset (cache cleared) and time
    warm = synthetic_requests(
        args.num_requests, arrival_rate=args.rate, page_size=args.page_size,
        max_new=args.max_new, temperature=args.temperature, seed=args.seed)
    for w in warm:
        w.rid -= args.num_requests
    eng.serve(warm, realtime=False)
    eng.reset_stats()
    eng.registry = MetricsRegistry()  # drop warmup latencies too

    streams = eng.serve(reqs, realtime=not args.no_realtime)
    st = eng.stats()
    ttft = eng.registry.histogram("serving/ttft_s").percentiles((50, 99))
    tpot = eng.registry.histogram("serving/tpot_s").percentiles((50, 99))
    print(f"[serve] {int(st['requests_finished'])} requests, "
          f"{int(st['tokens'])} tokens, "
          f"goodput {st['goodput_tokens_per_s']:.1f} tok/s")
    print(f"[serve] TTFT p50 {ttft['p50'] * 1e3:.1f}ms "
          f"p99 {ttft['p99'] * 1e3:.1f}ms | per-token p50 "
          f"{tpot['p50'] * 1e3:.1f}ms p99 {tpot['p99'] * 1e3:.1f}ms")
    print(f"[serve] prefix-cache hit rate {st['prefix_hit_rate']:.0%} "
          f"({int(st['prefix_hit_tokens'])} of {int(st['prompt_tokens'])} "
          f"prompt tokens), occupancy {st['slot_occupancy']:.0%}, "
          f"parks {int(st['parks'])}, pool pages {int(st['pool_pages_used'])}")
    done = sum(s.finished for s in streams)
    if done != len(streams):
        print(f"[serve] WARNING: {len(streams) - done} streams unfinished")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--max-new", type=int, default=16)
    # streaming knobs
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--burst", type=int, default=8)
    ap.add_argument("--yield-quota", type=int, default=0)
    ap.add_argument("--no-prefix-cache", action="store_true")
    ap.add_argument("--no-realtime", action="store_true",
                    help="enqueue all arrivals up front (max pressure)")
    # lockstep fallback knobs
    ap.add_argument("--lockstep", action="store_true",
                    help="fixed-batch fallback driver")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=3,
                    help="batches to serve (lockstep)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg, vocab_size=260, num_layers=2)
    tok = ByteTokenizer()
    args.eos_id = tok.eos_id
    model = get_model(cfg)
    mesh = make_local_mesh()
    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(args.seed))
        if args.lockstep:
            run_lockstep(model, params, tok, args)
        else:
            run_streaming(model, params, args)


if __name__ == "__main__":
    main()
