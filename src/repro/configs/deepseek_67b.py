"""deepseek-67b — dense llama-style, 95 layers.

[dense] 95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400
[arXiv:2401.02954; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    vocab_size=102_400,
    norm_type="rmsnorm",
    mlp_type="swiglu",
    rope_theta=10_000.0,
    subquadratic=False,
)
