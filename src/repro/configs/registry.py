"""``--arch <id>`` registry over the assigned architectures (+ paper's own)."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES_BY_NAME, applicable_shapes, reduced

from repro.configs.mamba2_2_7b import CONFIG as MAMBA2_2_7B
from repro.configs.jamba_52b import CONFIG as JAMBA_52B
from repro.configs.seamless_m4t_medium import CONFIG as SEAMLESS_M4T_MEDIUM
from repro.configs.nemotron_4_15b import CONFIG as NEMOTRON_4_15B
from repro.configs.gemma_2b import CONFIG as GEMMA_2B
from repro.configs.deepseek_67b import CONFIG as DEEPSEEK_67B
from repro.configs.command_r_plus_104b import CONFIG as COMMAND_R_PLUS_104B
from repro.configs.granite_moe_3b import CONFIG as GRANITE_MOE_3B
from repro.configs.mixtral_8x7b import CONFIG as MIXTRAL_8X7B
from repro.configs.llava_next_34b import CONFIG as LLAVA_NEXT_34B
from repro.configs.qwen2_5_7b import CONFIG as QWEN2_5_7B

ARCHS: Dict[str, ModelConfig] = {
    "mamba2-2.7b": MAMBA2_2_7B,
    "jamba-v0.1-52b": JAMBA_52B,
    "seamless-m4t-medium": SEAMLESS_M4T_MEDIUM,
    "nemotron-4-15b": NEMOTRON_4_15B,
    "gemma-2b": GEMMA_2B,
    "deepseek-67b": DEEPSEEK_67B,
    "command-r-plus-104b": COMMAND_R_PLUS_104B,
    "granite-moe-3b-a800m": GRANITE_MOE_3B,
    "mixtral-8x7b": MIXTRAL_8X7B,
    "llava-next-34b": LLAVA_NEXT_34B,
    # the paper's own model family (not part of the assigned 10):
    "qwen2.5-7b": QWEN2_5_7B,
}

ASSIGNED = tuple(k for k in ARCHS if k != "qwen2.5-7b")


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def all_cells():
    """Every applicable (arch, shape) dry-run cell."""
    for arch in ASSIGNED:
        cfg = ARCHS[arch]
        for shape in applicable_shapes(cfg):
            yield arch, shape.name


__all__ = [
    "ARCHS",
    "ASSIGNED",
    "get_config",
    "get_shape",
    "all_cells",
    "applicable_shapes",
    "reduced",
]
