"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8.

[moe] 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

vocab padded 49155 -> 49408; 24 heads padded to 32 for 16-way TP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,  # per-expert FFN width (fine-grained experts)
    vocab_size=49_155,
    norm_type="rmsnorm",
    mlp_type="swiglu",
    num_experts=40,
    num_experts_per_tok=8,
    moe_layer_period=1,  # every layer MoE
    tie_embeddings=True,
    subquadratic=False,
)
