"""llava-next-34b — VLM; dense backbone, anyres-tiled vision frontend (stub).

[vlm] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Backbone only per the assignment: ``input_specs()`` supplies precomputed
anyres patch embeddings (B, 2880, d_model) = 5 tiles x 576 patches,
concatenated ahead of the text tokens. 56 heads padded to 64 for 16-way TP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    vocab_size=64_000,
    norm_type="rmsnorm",
    mlp_type="swiglu",
    num_prefix_embeds=2880,  # 5 anyres tiles x 576 CLIP patches
    subquadratic=False,
)
