"""Model / mesh / RL configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`. The config
is a *pure description*: layer-kind layout, head counts, MoE/SSM settings.
Model code (``repro.models``) interprets it; sharding rules
(``repro.distributed.sharding``) derive PartitionSpecs from it; the launcher
selects it via ``--arch <id>`` through :mod:`repro.configs.registry`.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

VOCAB_ALIGN = 256  # pad vocab to multiples of this (16-way TP x 16 MXU lanes)


def pad_to(x: int, align: int) -> int:
    return ((x + align - 1) // align) * align


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description covering dense / MoE / SSM / hybrid /
    enc-dec / VLM families."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int  # raw (pre-padding) vocabulary size

    # --- MLP / norm flavour ---
    mlp_type: str = "swiglu"  # swiglu | geglu | relu2 | gelu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    use_bias: bool = False
    parallel_block: bool = False  # command-r style parallel attn+mlp residual
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)

    # --- attention ---
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # mixtral SWA
    # Layer-kind layout for hybrid archs. attn_layer_period==0 -> all layers
    # attention (dense); period p with offset o -> layer i is attention iff
    # i % p == o, otherwise the SSM mixer.
    attn_layer_period: int = 0
    attn_layer_offset: int = 0

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_layer_period: int = 1  # layer i is MoE iff i % period == offset
    moe_layer_offset: int = 0

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_ngroups: int = 1

    # --- enc-dec (seamless) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_len: int = 4096  # encoder memory length used by decode shapes

    # --- modality frontend stubs ---
    # Number of prefix embedding slots supplied pre-computed by input_specs()
    # (ViT patches for VLM, audio frames for audio archs). 0 = pure text.
    num_prefix_embeds: int = 0

    # --- numerics / layout ---
    dtype: str = "bfloat16"
    # int8 KV cache (per-slot-per-head scales): halves decode-cache HBM; the
    # Pallas decode kernel dequantizes per tile in VMEM (the jnp ref path
    # dequantizes up front — correctness-equivalent, no byte saving on CPU)
    kv_quant: bool = False
    # Pad num_heads up to a multiple of this so attention stays TP-shardable
    # (16-way model axis). Reduced smoke configs set 1.
    pad_heads_to: int = 16
    # Sub-quadratic? (SSM/hybrid state, or bounded SWA window.) Drives the
    # long_500k applicability rule.
    subquadratic: bool = False

    # ------------------------------------------------------------------ #
    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, VOCAB_ALIGN)

    @property
    def padded_heads(self) -> int:
        return pad_to(self.num_heads, self.pad_heads_to)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        if self.ssm_state == 0:
            return 0
        return self.ssm_d_inner // self.ssm_headdim

    # layer-kind helpers ------------------------------------------------ #
    def is_attn_layer(self, i: int) -> bool:
        if self.ssm_state == 0:
            return True
        if self.attn_layer_period == 0:
            return False  # pure SSM
        return i % self.attn_layer_period == self.attn_layer_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        return i % self.moe_layer_period == self.moe_layer_offset

    def has_mlp(self) -> bool:
        """Pure Mamba2 blocks carry no separate MLP (d_ff == 0)."""
        return self.d_ff > 0

    def layer_kinds(self) -> Tuple[Tuple[str, str], ...]:
        """((mixer_kind, mlp_kind), ...) per layer.

        mixer_kind in {attn, ssm}; mlp_kind in {dense, moe, none}.
        """
        out = []
        for i in range(self.num_layers):
            mixer = "attn" if self.is_attn_layer(i) else "ssm"
            if not self.has_mlp():
                mlp = "none"
            elif self.is_moe_layer(i):
                mlp = "moe"
            else:
                mlp = "dense"
            out.append((mixer, mlp))
        return tuple(out)

    def num_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6 N D)."""
        d, v = self.d_model, self.padded_vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += d * v  # lm head
        kinds = self.layer_kinds()
        hp = self.padded_heads
        for mixer, mlp in kinds:
            if mixer == "attn":
                total += d * hp * self.head_dim  # W_q
                total += 2 * d * self.num_kv_heads * self.head_dim  # W_k, W_v
                total += hp * self.head_dim * d  # W_o
            else:  # ssm
                din, g, ds, nh = (
                    self.ssm_d_inner,
                    self.ssm_ngroups,
                    self.ssm_state,
                    self.ssm_nheads,
                )
                total += d * (2 * din + 2 * g * ds + nh)  # in_proj (z,x,B,C,dt)
                total += (din + 2 * g * ds) * self.ssm_conv  # conv
                total += 3 * nh + din  # A, D, dt_bias, gated-norm
                total += din * d  # out_proj
            if mlp == "dense":
                gated = self.mlp_type in ("swiglu", "geglu")
                total += d * self.d_ff * (3 if gated else 2)
            elif mlp == "moe":
                gated = self.mlp_type in ("swiglu", "geglu")
                total += self.num_experts * d * self.d_ff * (3 if gated else 2)
                total += d * self.num_experts  # router
            total += 2 * d  # two norms (approx; parallel block shares one)
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder already counted above,
            # add cross-attention per decoder layer.
            for _ in range(self.num_encoder_layers):
                total += 2 * d * hp * self.head_dim + 2 * d * self.num_kv_heads * self.head_dim
                gated = self.mlp_type in ("swiglu", "geglu")
                total += d * self.d_ff * (3 if gated else 2) + 2 * d
            total += self.num_layers * (
                d * hp * self.head_dim * 2
                + 2 * d * self.num_kv_heads * self.head_dim
            )  # cross-attn q,o,k,v
        return int(total)

    def num_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.num_experts == 0:
            return self.num_params()
        total = self.num_params()
        gated = self.mlp_type in ("swiglu", "geglu")
        per_expert = self.d_model * self.d_ff * (3 if gated else 2)
        n_moe = sum(1 for i in range(self.num_layers) if self.is_moe_layer(i))
        inactive = n_moe * (self.num_experts - self.num_experts_per_tok) * per_expert
        return int(total - inactive)


# --------------------------------------------------------------------------- #
# Data Coordinator (paper §6: Distributed Dataloader + Databuffer).
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class DataCoordinatorConfig:
    """Flags for Data Coordinator v2 (paper §6.2: "local caching, load
    balancing, and asynchronous double buffer"). All off by default — the
    defaults reproduce the synchronous v1 coordinator bit-for-bit."""

    # two rotating databuffer slots + spec prefetch: stage-boundary reshards
    # for iteration i+1 are dispatched while iteration i still computes
    double_buffer: bool = False
    # repack variable-length rollout batches into near-equal-token DP buckets
    # before MODEL_INFERENCE / MODEL_TRAIN stages (LPT binning,
    # ft.straggler.balance_by_length)
    load_balance: bool = False
    # dataloader look-ahead: materialize the next `prefetch` per-device
    # partitions one step ahead of the consumer (0 = synchronous)
    prefetch: int = 0
    # number of token buckets for the load balancer; 0 = the mesh's DP degree
    # (product of non-"model" axes). Values > DP degree create virtual
    # buckets, useful on small meshes / in tests.
    num_buckets: int = 0
    # alert threshold: balance metrics report when max/mean bucket tokens
    # exceeds this after repacking
    balance_tolerance: float = 1.25


# --------------------------------------------------------------------------- #
# Continuous-batching rollout engine (beyond-paper: vLLM/AsyncFlow-style
# in-flight batching for the GENERATE stage).
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class RolloutEngineConfig:
    """Flags for the GENERATE-stage generation engine
    (``rl/rollout_engine.ContinuousRolloutEngine``).

    ``engine="lockstep"`` (default) keeps the original ``rl.rollout.generate``
    path: every prompt padded to a common length, all ``max_new`` decode steps
    scanned even after every sequence emitted EOS. ``engine="continuous"``
    runs a fixed pool of ``num_slots`` decode slots over one persistent
    KV-cache arena: a slot whose sequence hits EOS is immediately refilled
    with the next queued prompt, and the decode loop early-exits (a
    ``lax.while_loop`` on ``all(done)``) once the prompt queue drains. Under
    a fixed slot schedule (``num_slots`` >= batch, one length bucket) the
    continuous engine is token-for-token identical to lockstep — asserted by
    the test suite. See ``docs/rollout_engine.md``.
    """

    engine: str = "lockstep"  # "lockstep" | "continuous"
    # decode-slot pool size; 0 = one slot per sequence in the batch (no
    # queueing — early-exit is then the only win). Values < batch enable
    # slot refill: the queue's remaining prompts backfill freed slots.
    num_slots: int = 0
    # chunked prefill: split each refill prompt into chunks of this many
    # tokens so one long prefill is broken into bounded pieces (0 = whole
    # prompt in one pass). Attention-only archs without KV rings.
    prefill_chunk: int = 0
    # length bucketing: round each prompt's true (non-pad) length up to a
    # multiple of this and prefill at the bucket length instead of the
    # batch's padded max (0 = single bucket at the padded length, which is
    # the lockstep-equivalent schedule).
    prefill_bucket: int = 0
    # minimum newly-freed slots before the decode loop hands control back
    # for a refill while prompts pend. 1 = refill eagerly (max occupancy);
    # 2-4 coalesces refill batches when dispatch overhead rivals a decode
    # step (CPU hosts).
    refill_threshold: int = 1

    def __post_init__(self):
        if self.engine not in ("lockstep", "continuous"):
            raise ValueError(
                f"engine must be 'lockstep' or 'continuous', got {self.engine!r}"
            )
        for name in ("num_slots", "prefill_chunk", "prefill_bucket"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0, got {getattr(self, name)}")
        if self.refill_threshold < 1:
            raise ValueError(
                f"refill_threshold must be >= 1, got {self.refill_threshold}")


# --------------------------------------------------------------------------- #
# Request-streaming serving front-end (beyond-paper: the production serve
# path over the continuous rollout engine — repro.serving, docs/serving.md).
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ServingConfig:
    """Flags for the streaming serving engine (``repro.serving``).

    The serving engine promotes the continuous rollout engine's slot pool
    into a request-streaming server: an admission queue of per-request
    arrival-stamped :class:`repro.serving.Request` objects, a paged KV arena
    (block tables over fixed-size pages, so resident KV — parked sequences
    plus cached prefixes — can outgrow the ``num_slots x max_len`` compute
    staging), a shared-prefix radix cache (a prompt prefix any request has
    prefilled is never prefilled again), and live weight hot-swap from a
    :class:`repro.distributed.weight_sync.WeightVersionStore` between decode
    bursts. See ``docs/serving.md`` for the request lifecycle and the
    metrics glossary.
    """

    # decode-slot pool size (compute lanes; queued requests wait without KV)
    num_slots: int = 8
    # per-slot KV width: prompt + response tokens a slot can hold. Must be a
    # multiple of page_size (slot rows are staged page-aligned).
    max_len: int = 256
    # response-token cap per request (requests may ask for less, never more)
    max_new: int = 64
    # KV page size in tokens: the block-table / prefix-cache granularity.
    # Admission buckets, chunked prefill, and cache commits all run at this
    # grain, which is what makes a cache hit bitwise-identical to the cold
    # prefill of the same request.
    page_size: int = 16
    # page-pool capacity; 0 = 2 x the slot arena (num_slots * max_len /
    # page_size pages), i.e. resident KV can be 3x the compute staging
    num_pages: int = 0
    # shared-prefix radix cache over committed pages (off = every request
    # prefills its full prompt)
    prefix_cache: bool = True
    # decode steps per burst between scheduler visits: each visit flushes
    # stream deltas, polls the weight store, and admits/parks requests
    decode_burst: int = 8
    # fair-share preemption: a request that has decoded this many tokens
    # since its last (re)admission is parked to pages — freeing its slot for
    # waiting arrivals — and re-queued; 0 disables parking
    yield_quota: int = 0
    # poll the WeightVersionStore between bursts and hot-swap to the newest
    # published version without dropping in-flight requests
    poll_weights: bool = True

    def __post_init__(self):
        if self.num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {self.num_slots}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.max_len < self.page_size or self.max_len % self.page_size:
            raise ValueError(
                f"max_len must be a positive multiple of page_size "
                f"({self.page_size}), got {self.max_len}")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")
        if self.max_new >= self.max_len:
            raise ValueError(
                f"max_new ({self.max_new}) must leave prompt room under "
                f"max_len ({self.max_len})")
        if self.num_pages < 0:
            raise ValueError(f"num_pages must be >= 0, got {self.num_pages}")
        if self.decode_burst < 1:
            raise ValueError(
                f"decode_burst must be >= 1, got {self.decode_burst}")
        if self.yield_quota < 0:
            raise ValueError(
                f"yield_quota must be >= 0, got {self.yield_quota}")

    @property
    def pages_per_slot(self) -> int:
        return self.max_len // self.page_size

    @property
    def pool_pages(self) -> int:
        """Effective page-pool capacity (resolves the num_pages=0 default).
        Active requests' KV lives in the pool too (decode attends pages
        directly through block tables), so the default budgets a full
        arena of active spans plus two arenas' worth of parked/prefix
        pages."""
        if self.num_pages:
            return self.num_pages
        return 3 * self.num_slots * self.pages_per_slot


# --------------------------------------------------------------------------- #
# Multi-turn agentic environments (beyond-paper: tool-use / dialog workloads
# on the DistFlow DAG — repro.rl.envs, docs/environments.md).
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class EnvConfig:
    """Flags for the environment/reward subsystem (``repro.rl.envs``).

    ``name=""`` (default) disables the subsystem entirely: the DAG keeps its
    (REWARD, COMPUTE) stage and the GENERATE path is bit-for-bit the pre-env
    code. A named env swaps the reward node for an (ENV, COMPUTE) stage and
    — when ``max_turns > 1`` — turns the continuous rollout engine's slot
    loop into an episode loop: a sequence finishing a turn re-enters the
    prompt queue with the env observation appended and its KV rows preserved
    (only observation tokens are prefilled on later turns).
    """

    # registered environment name (repro.rl.envs: function_reward |
    # calculator | dialog | anything added via register_env); "" = off
    name: str = ""
    # episode turn cap; the engine truncates episodes the env never ends
    max_turns: int = 1
    # per-turn response-token budget (0 = rl.max_new_tokens); multi-turn
    # runs usually want this well under max_new_tokens
    turn_budget: int = 0
    # cap on observation tokens appended per turn (envs may return fewer)
    obs_budget: int = 16
    # registered RewardSpec the env (and the plain REWARD stage) scores with
    reward: str = "math"

    def __post_init__(self):
        if self.max_turns < 1:
            raise ValueError(f"max_turns must be >= 1, got {self.max_turns}")
        if self.turn_budget < 0:
            raise ValueError(
                f"turn_budget must be >= 0, got {self.turn_budget}")
        if self.obs_budget < 1:
            raise ValueError(f"obs_budget must be >= 1, got {self.obs_budget}")

    @property
    def enabled(self) -> bool:
        return bool(self.name)


# --------------------------------------------------------------------------- #
# Async off-policy pipeline v2 (beyond-paper: AsyncFlow / LlamaRL-style
# staleness-bounded generation/training overlap on the DistFlow DAG).
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class AsyncPipelineConfig:
    """Flags for the staleness-bounded off-policy scheduler
    (``core/async_worker.AsyncDAGWorker``). Off by default; ``enabled=True``
    with ``max_staleness=0`` runs the scheduler in lockstep and is
    bitwise-identical to the synchronous path (a property the test suite
    asserts).

    ``max_staleness`` is the hard bound on how many actor updates the
    behaviour policy of a consumed batch may lag the trainer: the batch
    trained at weight version ``v`` must have been generated at version
    ``>= v - max_staleness``. Generation dispatch is *gated* on this bound —
    when the trainer falls behind, the rollout side stalls rather than let
    trajectories go staler than the window (see ``docs/async_pipeline.md``).
    """

    enabled: bool = False
    # staleness window: 0 = fully on-policy lockstep (bitwise-identical to
    # the sync path); 1 = one-step overlap (AsyncFlow/LlamaRL's sweet spot)
    max_staleness: int = 0

    def __post_init__(self):
        if self.max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {self.max_staleness}"
            )


# --------------------------------------------------------------------------- #
# Multi-host scale-out (paper §7.3: near-linear scaling to 512 GPUs —
# repro.distributed.fleet, launch/mesh.make_fleet_mesh, docs/multihost.md).
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class DistributedConfig:
    """Flags for multi-host execution (``repro.distributed.fleet``).

    ``num_hosts=1`` (default) disables the subsystem entirely: no fleet
    context, no gradient exchange, the pre-fleet single-process path
    bit-for-bit. With ``num_hosts > 1`` every host process runs the
    identical SPMD program over the global ``(pod, data, model)`` fleet
    mesh and the DP gradient exchange crosses the ``coordinator`` data
    plane: each host owns a contiguous slice of the flat gradient vector
    (reduce-scatter shape; ownership map from ``ft.straggler.rebalance``
    so a dead host's slices are re-assigned deterministically), publishes
    it — raw fp32, or int8 blocks + scales with an error-feedback
    accumulator when ``grad_compression="int8_ef"`` — and decodes every
    peer's slices. See ``docs/multihost.md`` for the coordinator /
    process-id contract and the CI fleet-simulation recipe.
    """

    # number of host processes in the fleet; 1 = subsystem off
    num_hosts: int = 1
    # this process's rank in [0, num_hosts)
    process_id: int = 0
    # local devices per host used for the fleet mesh's (data, model) plane;
    # 0 = whatever the backend offers divided by num_hosts (CPU simulation:
    # XLA_FLAGS=--xla_force_host_platform_device_count supplies them)
    devices_per_host: int = 0
    # data plane: a directory path (CPU-simulated file plane, the CI mode)
    # or a host:port coordinator address (jax.distributed on real fleets)
    coordinator: str = ""
    # DP gradient exchange encoding: "none" = raw fp32 slices (bitwise-
    # identical to single-host — test-asserted); "int8_ef" = per-block int8
    # + fp32 scales with error feedback (repro.distributed.compression)
    grad_compression: str = "none"
    # seconds a host waits for peers' exchange slices before consulting the
    # heartbeat monitor for dead hosts
    exchange_timeout_s: float = 60.0
    # iterations a host may lag the heartbeat monitor before it is declared
    # dead (ft.straggler.HeartbeatMonitor patience)
    heartbeat_patience: int = 2
    # wall-clock heartbeat staleness (seconds) that also declares a host
    # dead — catches a host killed after its last in-iteration beat
    dead_after_s: float = 30.0

    def __post_init__(self):
        if self.num_hosts < 1:
            raise ValueError(f"num_hosts must be >= 1, got {self.num_hosts}")
        if not 0 <= self.process_id < self.num_hosts:
            raise ValueError(
                f"process_id must be in [0, {self.num_hosts}), "
                f"got {self.process_id}")
        if self.devices_per_host < 0:
            raise ValueError(
                f"devices_per_host must be >= 0, got {self.devices_per_host}")
        if self.grad_compression not in ("none", "int8_ef"):
            raise ValueError(
                f"grad_compression must be 'none' or 'int8_ef', "
                f"got {self.grad_compression!r}")
        if self.num_hosts > 1 and not self.coordinator:
            raise ValueError("num_hosts > 1 needs a coordinator (a shared "
                             "directory for the CPU-simulated data plane, or "
                             "a host:port address on real fleets)")
        if self.exchange_timeout_s <= 0:
            raise ValueError(f"exchange_timeout_s must be > 0, "
                             f"got {self.exchange_timeout_s}")
        if self.heartbeat_patience < 1:
            raise ValueError(f"heartbeat_patience must be >= 1, "
                             f"got {self.heartbeat_patience}")
        if self.dead_after_s <= 0:
            raise ValueError(
                f"dead_after_s must be > 0, got {self.dead_after_s}")

    @property
    def enabled(self) -> bool:
        return self.num_hosts > 1


@dataclass(frozen=True)
class ObsConfig:
    """Telemetry flags (``repro.obs``; see ``docs/observability.md``).

    Disabled (the default) is the pre-obs pipeline bit-for-bit: no tracer
    installed, no registry allocated, every metrics dict untouched — the
    no-overhead contract is test-asserted. Enabled, ``build_pipeline``
    installs a process-global :class:`~repro.obs.Tracer` (host id =
    ``DistributedConfig.process_id``) and hangs an
    :class:`~repro.obs.ObsState` on the worker context, so spans flow from
    DAG nodes, the async worker, the rollout engine, serving, and the fleet
    gradient exchange into one Chrome-trace-exportable ring.
    """

    # master switch; False = zero-cost no-op everywhere
    enabled: bool = False
    # record spans (the ring buffer); metrics registry works regardless
    trace: bool = True
    # span ring capacity: newest N events kept, oldest overwritten
    ring_capacity: int = 65536
    # Chrome-trace JSON output path ("" = don't export automatically)
    trace_path: str = ""
    # per-iteration metrics JSONL output path ("" = no file sink)
    metrics_path: str = ""
    # on fleets: publish per-iteration snapshots over the FleetContext
    # file plane for launch/obs_report.py aggregation
    fleet_snapshots: bool = True

    def __post_init__(self):
        if self.ring_capacity < 1:
            raise ValueError(
                f"ring_capacity must be >= 1, got {self.ring_capacity}")


# --------------------------------------------------------------------------- #
# Input shapes (assigned): every LM arch carries the same four shape cells.
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The assigned shape cells applicable to ``cfg``.

    - ``long_500k`` only for sub-quadratic archs (SSM / hybrid / SWA).
    - encoder-only archs would skip decode shapes (none assigned here;
      seamless is enc-dec, so decode applies to its decoder).
    """
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        shapes.append(LONG_500K)
    return tuple(shapes)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        num_layers=max(2, min(4, cfg.num_layers)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=16,
        d_ff=128 if cfg.d_ff > 0 else 0,
        vocab_size=256,
        pad_heads_to=1,
        sliding_window=16 if cfg.sliding_window else None,
        num_experts=min(cfg.num_experts, 4),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        num_encoder_layers=2 if cfg.is_encoder_decoder else 0,
        encoder_len=32,
        num_prefix_embeds=8 if cfg.num_prefix_embeds else 0,
        name=cfg.name + "-smoke",
    )
    # keep layer-layout periods valid for the reduced depth
    if cfg.attn_layer_period:
        base["attn_layer_period"] = 4
        base["attn_layer_offset"] = 1
        base["num_layers"] = 4
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
