"""seamless-m4t-medium — encoder-decoder, multimodal (audio frontend stubbed).

[audio] 12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206
[arXiv:2308.11596; hf]

Backbone only: 12 encoder + 12 decoder layers. ``input_specs()`` supplies
precomputed audio frame embeddings (B, S_enc, d) — the speech frontend is a
stub per the assignment. Decoder: causal self-attn (cached) + cross-attn over
encoder memory. vocab padded 256206 -> 256256 for 16-way TP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,  # decoder layers
    num_encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,  # MHA
    head_dim=64,
    d_ff=4096,
    vocab_size=256_206,
    norm_type="layernorm",
    mlp_type="gelu",
    use_bias=True,
    is_encoder_decoder=True,
    encoder_len=4096,  # encoder memory length for decode shapes
    num_prefix_embeds=1,  # marker: encoder input arrives as embeddings
    subquadratic=False,  # full attention -> long_500k skipped
)
