"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[hybrid] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf]

Layer layout (period 8, matching the released Jamba v0.1): layer i uses
attention iff i % 8 == 4 (4 attention layers in 32 -> the paper's 1:7
attn:mamba ratio); layer i is MoE iff i % 2 == 1 (16 MoE layers).
SSM layers use the Mamba2/SSD formulation (TPU adaptation; see DESIGN.md §11).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=65_536,
    norm_type="rmsnorm",
    mlp_type="swiglu",
    attn_layer_period=8,
    attn_layer_offset=4,
    num_experts=16,
    num_experts_per_tok=2,
    moe_layer_period=2,
    moe_layer_offset=1,
    ssm_state=16,  # Jamba v0.1 d_state; SSD kernel pads internally
    ssm_headdim=64,  # d_inner = 8192 -> 128 SSD heads
    ssm_expand=2,
    ssm_conv=4,
    ssm_ngroups=1,
    subquadratic=True,  # hybrid: bounded attn share -> long_500k runs
)
