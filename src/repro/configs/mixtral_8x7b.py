"""mixtral-8x7b — MoE 8 experts top-2, sliding-window attention.

[moe] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2
[arXiv:2401.04088; hf]

SWA window 4096 bounds the decode KV cache -> sub-quadratic -> long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    norm_type="rmsnorm",
    mlp_type="swiglu",
    num_experts=8,
    num_experts_per_tok=2,
    moe_layer_period=1,
    sliding_window=4096,
    subquadratic=True,  # SWA-bounded cache
)
