"""gemma-2b — dense, MQA (kv=1), GeGLU, head_dim=256.

[dense] 18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=256000
[arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,  # MQA
    head_dim=256,
    d_ff=16_384,
    vocab_size=256_000,
    norm_type="rmsnorm",
    mlp_type="geglu",
    tie_embeddings=True,
    embed_scale=True,  # embeddings scaled by sqrt(d_model)
    rope_theta=10_000.0,
    # 8 heads not divisible by 16-way TP -> padded to 16 (zero heads; exact,
    # W_o columns zero). See DESIGN.md §6.
    pad_heads_to=16,
    subquadratic=False,
)
