"""nemotron-4-15b — dense, GQA, squared-ReLU MLP.

[dense] 32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000
[arXiv:2402.16819; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=256_000,
    norm_type="layernorm",
    mlp_type="relu2",  # squared ReLU, non-gated
    rope_theta=10_000.0,
    subquadratic=False,
)
