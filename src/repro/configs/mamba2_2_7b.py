"""mamba2-2.7b — SSD (state-space duality), attention-free.

[ssm] 64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,  # Mamba2 blocks carry no separate MLP
    vocab_size=50_280,
    norm_type="rmsnorm",
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,  # d_inner = 5120 -> 80 SSD heads
    ssm_conv=4,
    ssm_ngroups=1,
    attn_layer_period=0,  # pure SSM
    subquadratic=True,  # constant-size decode state -> long_500k runs
    tie_embeddings=True,
)
