"""qwen2.5-7b — the paper's own evaluation family (Qwen-2.5-Instruct).

Used by the RL pipeline benchmarks reproducing Figs. 9-14 (7B arm).
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    norm_type="rmsnorm",
    mlp_type="swiglu",
    use_bias=False,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    subquadratic=False,
)
