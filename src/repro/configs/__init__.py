from repro.configs.base import (
    AsyncPipelineConfig,
    DataCoordinatorConfig,
    DistributedConfig,
    EnvConfig,
    ModelConfig,
    ObsConfig,
    RolloutEngineConfig,
    ServingConfig,
    ShapeConfig,
    ALL_SHAPES,
    SHAPES_BY_NAME,
    applicable_shapes,
    reduced,
)
from repro.configs.registry import ARCHS, ASSIGNED, get_config, get_shape, all_cells
