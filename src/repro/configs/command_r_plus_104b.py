"""command-r-plus-104b — dense, GQA, no-bias, parallel residual blocks.

[dense] 64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12_288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33_792,
    vocab_size=256_000,
    norm_type="layernorm",
    mlp_type="swiglu",
    use_bias=False,
    parallel_block=True,  # Cohere parallel attn+MLP residual, shared norm
    rope_theta=10_000.0,
    subquadratic=False,
)
