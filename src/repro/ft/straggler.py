"""Straggler mitigation: work-stealing re-partition of dataloader shards.

The paper (§2.2) identifies rollout long-tails as the dominant utilization
loss. Two mitigations here:

1. **Max-len bounding** (structural): the rollout engine decodes fixed-size
   token slabs, so a single long sample cannot extend an iteration beyond
   max_new_tokens — the iteration-time distribution is bounded by design.
2. **Shard rebalancing** (reactive): between iterations, per-host step times
   are compared; hosts slower than ``threshold`` x median (or dead hosts,
   detected by missed heartbeats) hand their upcoming dataset partitions to
   the fastest hosts. ``rebalance`` is a pure function host_times ->
   partition map, so every worker computes the identical new assignment with
   no coordinator (multi-controller property preserved).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


def rebalance(
    host_times: Sequence[float],
    *,
    threshold: float = 1.5,
    dead: Sequence[int] = (),
) -> Dict[int, List[int]]:
    """Deterministic partition map: host -> list of dp-shard ids it loads.

    Healthy hosts keep their own shard; shards of slow/dead hosts are
    re-assigned round-robin to the fastest healthy hosts.
    """
    n = len(host_times)
    times = np.asarray(host_times, dtype=np.float64)
    healthy = [i for i in range(n) if i not in set(dead)]
    if not healthy:
        raise RuntimeError("no healthy hosts")
    med = float(np.median(times[healthy]))
    slow = {i for i in healthy if times[i] > threshold * med}
    donors = sorted(set(dead) | slow)
    receivers = sorted(
        (i for i in healthy if i not in slow), key=lambda i: times[i]
    ) or healthy

    out: Dict[int, List[int]] = {i: [] for i in range(n)}
    for i in healthy:
        if i not in slow:
            out[i].append(i)
    for j, shard in enumerate(donors):
        out[receivers[j % len(receivers)]].append(shard)
    return out


class HeartbeatMonitor:
    """Tracks last-seen iteration per host; hosts silent for ``patience``
    iterations are declared dead (drives ``rebalance(dead=...)``)."""

    def __init__(self, num_hosts: int, patience: int = 2):
        self.last_seen = np.zeros(num_hosts, np.int64)
        self.patience = patience

    def beat(self, host: int, iteration: int) -> None:
        self.last_seen[host] = iteration

    def dead(self, iteration: int) -> List[int]:
        return [
            i for i, seen in enumerate(self.last_seen)
            if iteration - seen >= self.patience
        ]
