"""Straggler mitigation: work-stealing re-partition of dataloader shards.

The paper (§2.2) identifies rollout long-tails as the dominant utilization
loss. Two mitigations here:

1. **Max-len bounding** (structural): the rollout engine decodes fixed-size
   token slabs, so a single long sample cannot extend an iteration beyond
   max_new_tokens — the iteration-time distribution is bounded by design.
2. **Shard rebalancing** (reactive): between iterations, per-host step times
   are compared; hosts slower than ``threshold`` x median (or dead hosts,
   detected by missed heartbeats) hand their upcoming dataset partitions to
   the fastest hosts. ``rebalance`` is a pure function host_times ->
   partition map, so every worker computes the identical new assignment with
   no coordinator (multi-controller property preserved).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


def rebalance(
    host_times: Sequence[float],
    *,
    threshold: float = 1.5,
    dead: Sequence[int] = (),
) -> Dict[int, List[int]]:
    """Deterministic partition map: host -> list of dp-shard ids it loads.

    Healthy hosts keep their own shard; shards of slow/dead hosts are
    re-assigned round-robin to the fastest healthy hosts.
    """
    n = len(host_times)
    times = np.asarray(host_times, dtype=np.float64)
    healthy = [i for i in range(n) if i not in set(dead)]
    if not healthy:
        raise RuntimeError("no healthy hosts")
    med = float(np.median(times[healthy]))
    slow = {i for i in healthy if times[i] > threshold * med}
    donors = sorted(set(dead) | slow)
    receivers = sorted(
        (i for i in healthy if i not in slow), key=lambda i: times[i]
    ) or healthy

    out: Dict[int, List[int]] = {i: [] for i in range(n)}
    for i in healthy:
        if i not in slow:
            out[i].append(i)
    for j, shard in enumerate(donors):
        out[receivers[j % len(receivers)]].append(shard)
    return out


def balance_by_length(
    lengths: Sequence[float],
    num_buckets: int,
    *,
    group_size: int = 1,
    capacities: Optional[Sequence[int]] = None,
) -> np.ndarray:
    """Length-aware load balancing (paper §6.2): permutation repacking a
    variable-length batch into ``num_buckets`` equal-row, near-equal-TOKEN
    buckets, so that contiguous DP shards of the reordered batch carry
    balanced work and one long rollout no longer stalls every peer.

    Greedy longest-processing-time binning at *group* granularity: groups of
    ``group_size`` consecutive rows (GRPO prompt groups; 1 for PPO) are kept
    intact — their member rows move together — sorted by token weight
    descending, each assigned to the non-full bucket with the smallest token
    total. Deterministic (ties break on group index), so every DAG Worker
    derives the identical permutation with no coordinator, exactly like
    :func:`rebalance`.

    ``capacities`` (rows-per-bucket in units of groups) defaults to an even
    split; pass the shard counts from a :func:`rebalance` partition map to
    skew capacity toward fast hosts (the two mitigations compose: rebalance
    decides WHO loads how much, balance_by_length decides WHICH sequences).

    Returns a permutation ``perm`` of ``len(lengths)`` row indices: bucket b
    owns rows ``perm[start_b : start_b + rows_b]``. Invert with
    :func:`inverse_permutation`.
    """
    w = np.asarray(lengths, dtype=np.float64)
    n = len(w)
    if n % group_size:
        raise ValueError(f"batch {n} not divisible by group_size {group_size}")
    n_groups = n // group_size
    gw = w.reshape(n_groups, group_size).sum(axis=1)

    if capacities is None:
        base, extra = divmod(n_groups, num_buckets)
        capacities = [base + (1 if b < extra else 0) for b in range(num_buckets)]
    capacities = list(capacities)
    if sum(capacities) != n_groups:
        raise ValueError(f"capacities {capacities} must sum to {n_groups} groups")

    order = sorted(range(n_groups), key=lambda g: (-gw[g], g))
    totals = np.zeros(num_buckets)
    fill = [0] * num_buckets
    buckets: List[List[int]] = [[] for _ in range(num_buckets)]
    for g in order:
        open_b = [b for b in range(num_buckets) if fill[b] < capacities[b]]
        b = min(open_b, key=lambda b: (totals[b], b))
        buckets[b].append(g)
        totals[b] += gw[g]
        fill[b] += 1

    perm = np.empty(n, dtype=np.int64)
    pos = 0
    for b in range(num_buckets):
        for g in sorted(buckets[b]):  # stable within-bucket order
            rows = np.arange(g * group_size, (g + 1) * group_size)
            perm[pos : pos + group_size] = rows
            pos += group_size
    return perm


def inverse_permutation(perm: np.ndarray) -> np.ndarray:
    """inv such that ``x[perm][inv] == x`` (restore original row order)."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    return inv


def bucket_token_ratio(
    lengths: Sequence[float], num_buckets: int, perm: Optional[np.ndarray] = None
) -> float:
    """max-bucket-tokens / mean-bucket-tokens for contiguous even-row buckets
    of (optionally permuted) ``lengths`` — the straggler factor a DP sharding
    of that batch would see (1.0 = perfectly balanced)."""
    w = np.asarray(lengths, dtype=np.float64)
    if perm is not None:
        w = w[perm]
    sums = np.array([c.sum() for c in np.array_split(w, num_buckets)])
    mean = sums.mean()
    return float(sums.max() / mean) if mean > 0 else 1.0


class HeartbeatMonitor:
    """Tracks last-seen iteration per host; hosts silent for ``patience``
    iterations are declared dead (drives ``rebalance(dead=...)``)."""

    def __init__(self, num_hosts: int, patience: int = 2):
        self.last_seen = np.zeros(num_hosts, np.int64)
        self.patience = patience

    def beat(self, host: int, iteration: int) -> None:
        self.last_seen[host] = iteration

    def dead(self, iteration: int) -> List[int]:
        return [
            i for i, seen in enumerate(self.last_seen)
            if iteration - seen >= self.patience
        ]
