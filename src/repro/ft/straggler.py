"""Straggler mitigation: work-stealing re-partition of dataloader shards.

The paper (§2.2) identifies rollout long-tails as the dominant utilization
loss. Two mitigations here:

1. **Max-len bounding** (structural): the rollout engine decodes fixed-size
   token slabs, so a single long sample cannot extend an iteration beyond
   max_new_tokens — the iteration-time distribution is bounded by design.
2. **Shard rebalancing** (reactive): between iterations, per-host step times
   are compared; hosts slower than ``threshold`` x median (or dead hosts,
   detected by missed heartbeats) hand their upcoming dataset partitions to
   the fastest hosts. ``rebalance`` is a pure function host_times ->
   partition map, so every worker computes the identical new assignment with
   no coordinator (multi-controller property preserved).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


def rebalance(
    host_times: Sequence[float],
    *,
    threshold: float = 1.5,
    dead: Sequence[int] = (),
) -> Dict[int, List[int]]:
    """Deterministic partition map: host -> list of dp-shard ids it loads.

    Healthy hosts keep their own shard; shards of slow/dead hosts are
    re-assigned round-robin to the fastest healthy hosts.
    """
    n = len(host_times)
    times = np.asarray(host_times, dtype=np.float64)
    healthy = [i for i in range(n) if i not in set(dead)]
    if not healthy:
        raise RuntimeError("no healthy hosts")
    med = float(np.median(times[healthy]))
    slow = {i for i in healthy if times[i] > threshold * med}
    donors = sorted(set(dead) | slow)
    receivers = sorted(
        (i for i in healthy if i not in slow), key=lambda i: times[i]
    ) or healthy

    out: Dict[int, List[int]] = {i: [] for i in range(n)}
    for i in healthy:
        if i not in slow:
            out[i].append(i)
    for j, shard in enumerate(donors):
        out[receivers[j % len(receivers)]].append(shard)
    return out


def balance_by_length(
    lengths: Sequence[float],
    num_buckets: int,
    *,
    group_size: int = 1,
    capacities: Optional[Sequence[int]] = None,
    hosts: int = 1,
    inter_host_tolerance: float = 1.25,
) -> np.ndarray:
    """Length-aware load balancing (paper §6.2): permutation repacking a
    variable-length batch into ``num_buckets`` equal-row, near-equal-TOKEN
    buckets, so that contiguous DP shards of the reordered batch carry
    balanced work and one long rollout no longer stalls every peer.

    Greedy longest-processing-time binning at *group* granularity: groups of
    ``group_size`` consecutive rows (GRPO prompt groups; 1 for PPO) are kept
    intact — their member rows move together — sorted by token weight
    descending, each assigned to the non-full bucket with the smallest token
    total. Deterministic (ties break on group index), so every DAG Worker
    derives the identical permutation with no coordinator, exactly like
    :func:`rebalance`.

    ``capacities`` (rows-per-bucket in units of groups) defaults to an even
    split; pass the shard counts from a :func:`rebalance` partition map to
    skew capacity toward fast hosts (the two mitigations compose: rebalance
    decides WHO loads how much, balance_by_length decides WHICH sequences).

    ``hosts > 1`` enables the **hierarchical mode** for multi-host fleet
    meshes (docs/multihost.md): rows start resident on the host that
    generated them (host h owns the contiguous block of ``n/hosts`` rows),
    and moving a token across the inter-pod links is far more expensive
    than moving it between a host's local devices. So groups are first
    binned *within* their resident host's local buckets; only when the
    per-host token totals exceed ``inter_host_tolerance`` x mean are
    equal-row-count group *swaps* made across the pod axis (heaviest host
    with lightest, the swap that best halves their gap), and the swap loop
    stops the moment the totals are back under tolerance — the repack
    permutation never crosses the slow axis unnecessarily. Count the
    crossings with :func:`cross_host_rows`.

    Returns a permutation ``perm`` of ``len(lengths)`` row indices: bucket b
    owns rows ``perm[start_b : start_b + rows_b]``. Invert with
    :func:`inverse_permutation`.
    """
    w = np.asarray(lengths, dtype=np.float64)
    n = len(w)
    if n % group_size:
        raise ValueError(f"batch {n} not divisible by group_size {group_size}")
    n_groups = n // group_size
    gw = w.reshape(n_groups, group_size).sum(axis=1)

    if hosts > 1:
        if capacities is not None:
            raise ValueError("hierarchical mode derives capacities from the "
                             "host layout; pass capacities only with hosts=1")
        if num_buckets % hosts or n_groups % hosts:
            raise ValueError(
                f"hierarchical mode needs buckets ({num_buckets}) and groups "
                f"({n_groups}) divisible by hosts ({hosts})")
        assign = _hierarchical_assign(gw, hosts, inter_host_tolerance)
        local_buckets = num_buckets // hosts
        perm = np.empty(n, dtype=np.int64)
        pos = 0
        for h in range(hosts):
            sub = assign[h]  # group ids resident on host h after swaps
            sub_perm = balance_by_length(
                w.reshape(n_groups, group_size)[sub].reshape(-1),
                local_buckets, group_size=group_size)
            # sub_perm indexes into sub's rows; lift back to global rows
            rows = (np.asarray(sub)[:, None] * group_size
                    + np.arange(group_size)[None, :]).reshape(-1)
            perm[pos : pos + len(rows)] = rows[sub_perm]
            pos += len(rows)
        return perm

    if capacities is None:
        base, extra = divmod(n_groups, num_buckets)
        capacities = [base + (1 if b < extra else 0) for b in range(num_buckets)]
    capacities = list(capacities)
    if sum(capacities) != n_groups:
        raise ValueError(f"capacities {capacities} must sum to {n_groups} groups")

    order = sorted(range(n_groups), key=lambda g: (-gw[g], g))
    totals = np.zeros(num_buckets)
    fill = [0] * num_buckets
    buckets: List[List[int]] = [[] for _ in range(num_buckets)]
    for g in order:
        open_b = [b for b in range(num_buckets) if fill[b] < capacities[b]]
        b = min(open_b, key=lambda b: (totals[b], b))
        buckets[b].append(g)
        totals[b] += gw[g]
        fill[b] += 1

    perm = np.empty(n, dtype=np.int64)
    pos = 0
    for b in range(num_buckets):
        for g in sorted(buckets[b]):  # stable within-bucket order
            rows = np.arange(g * group_size, (g + 1) * group_size)
            perm[pos : pos + group_size] = rows
            pos += group_size
    return perm


def _hierarchical_assign(
    gw: np.ndarray, hosts: int, tolerance: float
) -> List[List[int]]:
    """Group ids per host after cross-host swap migration.

    Host h starts owning the contiguous block of ``n_groups/hosts`` groups
    (residency). While ``max(host_tokens) / mean > tolerance``, swap one
    group between the heaviest and lightest hosts — the pair whose exchange
    best narrows their gap — so row counts per host never change (contiguous
    DP shards need equal rows). Deterministic: ties break on group index,
    and a swap is only taken if it strictly reduces the heavy host's total.
    """
    n_groups = len(gw)
    per = n_groups // hosts
    assign = [list(range(h * per, (h + 1) * per)) for h in range(hosts)]
    totals = np.array([gw[a].sum() for a in assign])
    mean = totals.mean()
    if mean <= 0:
        return assign
    for _ in range(n_groups):  # bounded; each swap strictly reduces max
        if totals.max() / mean <= tolerance:
            break
        hi = int(np.argmax(totals))
        lo = int(np.argmin(totals))
        gap = totals[hi] - totals[lo]
        # best swap: heavy group out, light group in, moving ~gap/2
        best = None
        for i, ga in enumerate(assign[hi]):
            for j, gb in enumerate(assign[lo]):
                delta = gw[ga] - gw[gb]
                if delta <= 0:
                    continue
                # post-swap gap magnitude; strict improvement required
                score = abs(gap - 2 * delta)
                if best is None or score < best[0]:
                    best = (score, i, j, delta)
        if best is None or best[0] >= gap:
            break
        _, i, j, delta = best
        assign[hi][i], assign[lo][j] = assign[lo][j], assign[hi][i]
        assign[hi].sort()
        assign[lo].sort()
        totals[hi] -= delta
        totals[lo] += delta
    return assign


def cross_host_rows(perm: np.ndarray, hosts: int) -> int:
    """Rows whose resident host (contiguous block of the ORIGINAL order)
    differs from the host slot of their position in ``perm`` — the count of
    rows the repack moves across the slow inter-pod axis."""
    n = len(perm)
    per = n // hosts
    dest = np.arange(n) // per  # host slot of each perm position
    src = np.asarray(perm) // per  # resident host of the row placed there
    return int(np.sum(dest != src))


def inverse_permutation(perm: np.ndarray) -> np.ndarray:
    """inv such that ``x[perm][inv] == x`` (restore original row order)."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    return inv


def bucket_token_ratio(
    lengths: Sequence[float], num_buckets: int, perm: Optional[np.ndarray] = None
) -> float:
    """max-bucket-tokens / mean-bucket-tokens for contiguous even-row buckets
    of (optionally permuted) ``lengths`` — the straggler factor a DP sharding
    of that batch would see (1.0 = perfectly balanced)."""
    w = np.asarray(lengths, dtype=np.float64)
    if perm is not None:
        w = w[perm]
    sums = np.array([c.sum() for c in np.array_split(w, num_buckets)])
    mean = sums.mean()
    return float(sums.max() / mean) if mean > 0 else 1.0


class HeartbeatMonitor:
    """Tracks last-seen iteration per host; hosts silent for ``patience``
    iterations are declared dead (drives ``rebalance(dead=...)``).

    A host that has NEVER beaten is dead at any query — ``last_seen`` starts
    at -inf, not 0, so silence from the start is not mistaken for a beat at
    iteration 0. Beats are monotone (``beat`` keeps the max, so a delayed
    out-of-order heartbeat cannot roll a host backwards), and queries at an
    iteration older than a host's last beat never report it dead. Each beat
    may also carry a wall-clock ``now``; ``dead(..., now=, stale_s=)`` then
    ORs in wall-clock staleness, which is what lets a survivor *blocked* at
    a collective (its own iteration frozen) still detect a killed peer.
    """

    def __init__(self, num_hosts: int, patience: int = 2):
        if num_hosts < 1:
            raise ValueError(f"num_hosts must be >= 1, got {num_hosts}")
        if patience < 1:
            raise ValueError(
                f"patience must be >= 1, got {patience} (patience=0 would "
                "declare every host dead the instant it beats)")
        self.last_seen = np.full(num_hosts, -np.inf)
        self.last_wall = np.full(num_hosts, -np.inf)
        self.patience = patience

    def beat(self, host: int, iteration: int, *, now: Optional[float] = None) -> None:
        if not 0 <= host < len(self.last_seen):
            raise ValueError(f"host {host} out of range [0, {len(self.last_seen)})")
        self.last_seen[host] = max(self.last_seen[host], iteration)
        if now is not None:
            self.last_wall[host] = max(self.last_wall[host], now)

    def dead(
        self,
        iteration: int,
        *,
        now: Optional[float] = None,
        stale_s: Optional[float] = None,
    ) -> List[int]:
        out = []
        for i, seen in enumerate(self.last_seen):
            lagged = iteration - seen >= self.patience
            stale = (
                now is not None
                and stale_s is not None
                and now - self.last_wall[i] >= stale_s
            )
            if lagged or stale:
                out.append(i)
        return out
