"""Sharded checkpoint save/restore with elastic resharding (DESIGN.md §9).

Layout::

    <dir>/manifest.json          # tree structure, shapes, dtypes, step, mesh
    <dir>/proc<k>.npz            # this process's addressable shards

Every leaf is stored as its addressable shards plus their global offsets
(orbax-lite). Restore rebuilds each leaf with ``jax.make_array_from_callback``
under the *target* mesh/sharding: the callback assembles any requested region
from intersecting saved chunks — so a checkpoint written on one topology
restores onto any other (elastic restart), and a process only reads the bytes
it will own.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    out = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, tree, *, step: int = 0, extra: Optional[Dict] = None) -> None:
    os.makedirs(ckpt_dir, exist_ok=True)
    proc = jax.process_index()
    manifest: Dict[str, Any] = {"step": step, "leaves": {}, "extra": extra or {}}
    shards: Dict[str, np.ndarray] = {}
    for key, leaf in _leaf_paths(tree):
        leaf = jnp.asarray(leaf)
        chunks = []
        seen = set()
        for i, shard in enumerate(leaf.addressable_shards):
            start = tuple(sl.indices(dim)[0] for sl, dim in zip(shard.index, leaf.shape))
            if start in seen:  # replicated shard (e.g. over `model`) — store once
                continue
            seen.add(start)
            name = f"{_safe(key)}__c{i}"
            shards[name] = np.asarray(shard.data)
            chunks.append({"start": list(start), "shape": list(shard.data.shape),
                           "file": f"proc{proc}.npz", "key": name})
        manifest["leaves"][key] = {
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
            "chunks": chunks,
        }
    np.savez(os.path.join(ckpt_dir, f"proc{proc}.npz"), **shards)
    if proc == 0:
        with open(os.path.join(ckpt_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f)


def _safe(key: str) -> str:
    return key.replace("/", "_").replace("[", "_").replace("]", "_").replace("'", "")


def restore(
    ckpt_dir: str,
    template,  # pytree of arrays or ShapeDtypeStructs (target structure)
    *,
    mesh: Optional[Mesh] = None,
    specs=None,  # pytree of PartitionSpec matching template (None = replicate)
) -> Tuple[Any, int]:
    """Restore onto ``mesh`` under ``specs`` — any topology (elastic)."""
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    files: Dict[str, Any] = {}

    def load_chunk(c, dtype) -> np.ndarray:
        f = c["file"]
        if f not in files:
            files[f] = np.load(os.path.join(ckpt_dir, f))
        data = files[f][c["key"]]
        if data.dtype.kind == "V":  # npz round-trips ml_dtypes (bf16) as raw void
            data = data.view(dtype)
        return data

    leaves = manifest["leaves"]
    flat_specs = dict(_leaf_paths_specs(specs)) if specs is not None else None

    def build(key: str, leaf_template):
        meta = leaves[key]
        shape = tuple(meta["shape"])
        dtype = np.dtype(meta["dtype"])

        def region(index) -> np.ndarray:
            out = np.zeros(
                tuple(sl.indices(d)[1] - sl.indices(d)[0] for sl, d in zip(index, shape)),
                dtype,
            )
            lo = tuple(sl.indices(d)[0] for sl, d in zip(index, shape))
            hi = tuple(sl.indices(d)[1] for sl, d in zip(index, shape))
            for c in meta["chunks"]:
                cs = tuple(c["start"])
                ce = tuple(s + e for s, e in zip(cs, c["shape"]))
                ilo = tuple(max(a, b) for a, b in zip(lo, cs))
                ihi = tuple(min(a, b) for a, b in zip(hi, ce))
                if any(a >= b for a, b in zip(ilo, ihi)):
                    continue
                data = load_chunk(c, dtype)
                src = tuple(slice(a - s, b - s) for a, b, s in zip(ilo, ihi, cs))
                dst = tuple(slice(a - o, b - o) for a, b, o in zip(ilo, ihi, lo))
                out[dst] = data[src]
            return out

        if mesh is None:
            return jnp.asarray(region(tuple(slice(0, d) for d in shape)))
        spec = flat_specs.get(key, P()) if flat_specs else P()
        sharding = NamedSharding(mesh, spec)
        return jax.make_array_from_callback(shape, sharding, region)

    restored = {}
    for key, leaf in _leaf_paths(template):
        restored[key] = build(key, leaf)
    # reassemble into the template's structure
    flat_template, treedef = jax.tree_util.tree_flatten(template)
    keys = [k for k, _ in _leaf_paths(template)]
    ordered = [restored[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, ordered), manifest["step"]


def _leaf_paths_specs(specs):
    return [
        (jax.tree_util.keystr(path), leaf)
        for path, leaf in jax.tree_util.tree_leaves_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)
        )
    ]
