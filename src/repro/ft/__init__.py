from repro.ft import checkpoint, straggler
