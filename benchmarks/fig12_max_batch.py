"""Fig. 12 + Table 1 — baseline-constrained maximum batch + the 7x gap.

Table 1's max-batch wall follows a power law max = C * n^-gamma (fitted in
log-log space on the paper's 7B row; gamma ~= 1.3: the controller's resident
set grows superlinearly because the global batch AND per-worker dispatch
buffers both grow with n). DistFlow's limit is per-DEVICE memory — constant
under weak scaling (our dry-run's memory_analysis proves multi-GB headroom
at 512 chips).

Fig. 12's up-to-7x: at the baseline's constrained batch, devices are starved
(batch/node shrinks ∝ n^-1.3) while the controller still serializes; the
distributed arm runs the FULL weak-scaled batch. Speedup = throughput ratio
at each scale."""
from __future__ import annotations

from benchmarks import paper_scale as ps
from benchmarks.common import bench_pipeline, emit, tiny_cfg
from repro.rl import RLConfig


def main() -> None:
    cfg = tiny_cfg()
    rl = RLConfig(algorithm="grpo", group_size=4, max_new_tokens=16, lr=1e-5)
    _, _, pipe, _ = bench_pipeline(cfg, rl, centralized=True, iters=2,
                                prompts_per_iter=4)
    res = pipe.buffer.controller_resident_bytes
    emit("fig12/measured_controller_resident", 0.0,
         f"{res}B at toy scale (grows with global batch; distflow: 0)")

    C, gamma = ps.fit_table1()
    emit("fig12/table1_power_law", 0.0,
         f"max_batch = {C:.0f} * n^-{gamma:.2f} (fit on paper 7B row)")
    for gpus, paper in ((32, 1024), (64, 512), (128, 256), (256, 64)):
        got = ps.baseline_max_batch(gpus)
        emit(f"fig12/baseline_max_batch_{gpus}gpu", 0.0,
             f"{got} (paper Table 1: {paper})")

    # throughput ratio at the constrained batch (VLM arm: ~3x bytes/token)
    for gpus in (64, 128, 256, 512):
        b_max = ps.baseline_max_batch(gpus)
        full = ps.BATCH_PER_NODE
        t_dist = ps.distflow_iter_s(gpus, ps.BPT_CAL * 3)  # full batch
        t_cent = ps.centralized_iter_s(gpus, ps.BPT_CAL * 3,
                                       batch_per_node=max(b_max * 8 // gpus, 1))
        # per-token throughput ratio: distflow moves full tokens/iter
        thr_d = full / t_dist
        thr_c = max(b_max * 8 / gpus, 1) / t_cent
        emit(f"fig12/constrained_speedup_{gpus}gpu", 0.0,
             f"{min(thr_d / thr_c, 9.9):.2f}x (paper: up to 7x)")


if __name__ == "__main__":
    main()
