"""Data Coordinator v2 arm: double-buffered + prefetching coordinator vs the
synchronous v1 path (paper §6.2 — "local caching, load balancing, and
asynchronous double buffer").

Reports, per arm: s/iteration, tokens/s, and the buffer-stats delta that
explains the gap (overlap hits = stage-boundary reshards whose dispatch was
hidden behind compute; sync waits = reshards issued on the critical path).
A third arm adds length-aware load balancing and reports the bucket token
ratio the repacking achieves on the rollout batches.
"""
from __future__ import annotations

from benchmarks.common import bench_pipeline, emit, tiny_cfg
from repro.configs import DataCoordinatorConfig
from repro.rl import RLConfig


def _bench(coord: DataCoordinatorConfig, *, iters: int = 5, seed: int = 0):
    # warmup iteration doubles as the v2 consumer-spec recording pass
    rl = RLConfig(algorithm="grpo", group_size=4, max_new_tokens=8, lr=1e-4)
    return bench_pipeline(tiny_cfg(), rl, coordinator=coord, iters=iters,
                          seed=seed)


def main() -> None:
    sync_dt, tokens, _, _ = _bench(DataCoordinatorConfig())
    emit("coordinator/sync_s_per_iter", sync_dt * 1e6,
         f"tokens_per_s={tokens / sync_dt:.0f}")

    v2 = DataCoordinatorConfig(double_buffer=True, prefetch=1)
    db_dt, tokens, db_pipe, _ = _bench(v2)
    s = db_pipe.buffer.stats
    emit("coordinator/double_buffered_s_per_iter", db_dt * 1e6,
         f"tokens_per_s={tokens / db_dt:.0f}")
    emit("coordinator/speedup_pct", (sync_dt / db_dt - 1.0) * 100.0,
         f"overlap_hits={s.overlap_hits} sync_waits={s.sync_waits} "
         f"prefetch_hits={db_pipe.ctx.dataloader.prefetch_hits}")
    emit("coordinator/overlap_hits_per_iter", s.overlap_hits / max(s.rotations, 1),
         f"redistributions={s.redistributions} bytes_moved={s.bytes_moved}")

    lb = DataCoordinatorConfig(double_buffer=True, prefetch=1,
                               load_balance=True, num_buckets=4)
    lb_dt, tokens, _, hist = _bench(lb)
    ratio = hist[-1].get("balance/token_ratio_after", 1.0)
    emit("coordinator/balanced_s_per_iter", lb_dt * 1e6,
         f"bucket_token_ratio={ratio:.3f}")


if __name__ == "__main__":
    main()
