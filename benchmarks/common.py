"""Shared benchmark plumbing: tiny-model pipeline construction, timing, CSV.

CPU-host benchmarking protocol (this container is CPU-only; TPU v5e is the
target): every figure is reproduced at reduced scale with REAL measured
wall-times, plus an analytic projection to the paper's cluster sizes driven
by the measured data volumes and the v5e/RoCE bandwidth constants. The
projection model is printed alongside so nothing is hidden.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core import build_pipeline
from repro.rl import RLConfig, get_algorithm

ROWS: List[Dict] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append({"name": name, "us": us_per_call, "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def tiny_cfg(arch: str = "qwen2.5-7b", **kw):
    base = dict(vocab_size=260, num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, head_dim=16, d_ff=128)
    base.update(kw)
    return reduced(ARCHS[arch], **base)


def bench_pipeline(cfg, rl: RLConfig, *, centralized: bool = False,
                   coordinator=None, async_pipeline=None, iters: int = 3,
                   prompts_per_iter: int = 8, warmup: int = 1, seed: int = 0):
    """Returns (s_per_iter, tokens_per_iter, pipeline, timed_history)."""
    pipe = build_pipeline(cfg, rl, prompts_per_iter=prompts_per_iter,
                          centralized=centralized, coordinator=coordinator,
                          async_pipeline=async_pipeline, seed=seed)
    for _ in range(warmup):
        pipe.run(1)
    pipe.buffer.stats.reset()
    t0 = time.perf_counter()
    hist = pipe.run(iters)
    dt = (time.perf_counter() - t0) / iters
    g = get_algorithm(rl.algorithm).group_size(rl)
    seqs = prompts_per_iter * g
    # paper metric: total tokens in the global batch / iteration time
    tokens = seqs * (6 + rl.max_new_tokens)  # prompt len 6 + responses
    return dt, tokens, pipe, hist


# hardware constants for projections (paper testbed + v5e target)
HOST_NIC_GBPS = 25e9 / 8 * 8  # 25 GB/s effective RoCE v2 per-host (bytes/s)
ICI_BPS = 50e9  # per-link ICI
HBM_BPS = 819e9
PEAK_FLOPS = 197e12
