"""Benchmark entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit)."""
from __future__ import annotations

import os
import sys
import traceback

# allow `python benchmarks/run.py` from the repo root: make the `benchmarks`
# package importable no matter how this file is invoked
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    from benchmarks import (
        algorithms,
        async_pipeline,
        coordinator,
        multiturn,
        rollout,
        serving,
        fig09_ppo_throughput,
        fig10_grpo_throughput,
        fig11_scalability,
        fig12_max_batch,
        fig13_long_context,
        fig14_convergence,
        roofline,
    )

    print("name,us_per_call,derived")
    sections = [
        ("fig09", fig09_ppo_throughput.main),
        ("fig10", fig10_grpo_throughput.main),
        ("fig11", fig11_scalability.main),
        ("fig12", fig12_max_batch.main),
        ("fig13", fig13_long_context.main),
        ("fig14", fig14_convergence.main),
        ("coordinator", coordinator.main),
        ("async_pipeline", async_pipeline.main),
        ("rollout", rollout.main),
        ("serving", serving.main),
        ("multiturn", multiturn.main),
        ("algorithms", algorithms.main),
        ("roofline", roofline.main),
    ]
    failed = []
    for name, fn in sections:
        try:
            fn()
        except Exception as e:  # noqa
            failed.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
