"""Serving arm: request-streaming engine vs lockstep batching on a
Poisson-arrival, shared-prefix-heavy workload.

The workload is ``repro.serving.scheduler.synthetic_requests``: requests
arrive on a Poisson clock, 80% open with one of two fixed multi-page system
prompts (the shape a prefix cache exists for), and response budgets follow
the skewed 70/20/10 short/medium/full mix of ``benchmarks/rollout.py``.
Both arms serve the SAME requests (same prompts, budgets, arrival stamps):

  * **lockstep** — requests grouped, in arrival order, into fixed batches
    of ``SLOTS`` through ``rl.rollout.generate``: a batch launches only
    once its last member has arrived, prompts are right-padded to one
    fixed width (one compiled executable), and every batch scans all
    ``MAX_NEW - 1`` decode steps regardless of budgets. A request's first
    token exists only when its whole batch completes — that is its TTFT.
    Arrival waits are virtual-clocked (no sleeping), the same waits the
    streaming arm absorbs for real.
  * **streaming** — the ``ServingEngine``: per-request admission into the
    slot pool the moment a lane frees, prefix-cache hits skip shared
    prompt pages, finished slots refill immediately, and token deltas
    stream out per decode burst.

Both arms are fully warmed (the streaming engine replays the identical
workload once, then resets with the prefix cache cleared, so the timed pass
pays cold-cache prefills but zero compiles).

Reported per arm (CSV rows via benchmarks.common.emit, and the committed
``results/BENCH_serving.json`` baseline via ``--json``):

  * goodput tok/s      — counted response tokens / wall (arrival waits in)
  * TTFT p50/p99       — arrival -> first streamed token
  * per-token p50/p99  — mean inter-token latency after the first token
  * prefix hit rate    — streaming only: cached / total prompt tokens
  * speedup            — streaming goodput over lockstep goodput
                         (acceptance floor: >= 1.5x on this workload)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks.common import emit, tiny_cfg
from repro.configs import ServingConfig
from repro.models import get_model
from repro.rl.rollout import generate
from repro.serving import Request, ServingEngine, percentiles, \
    synthetic_requests

N_REQUESTS = 48
RATE = 40.0  # Poisson arrivals/s — saturating for the tiny CPU model
SLOTS = 8  # streaming slot pool == lockstep batch width (lane parity)
PAGE = 16
MAX_LEN = 128
MAX_NEW = 64  # lockstep always scans all of it; budgets skew far below
BURST = 8


def _workload(seed: int) -> List[Request]:
    return synthetic_requests(
        N_REQUESTS, arrival_rate=RATE, page_size=PAGE,
        shared_prefix_pages=2, num_prefixes=2, shared_frac=0.8,
        max_new=MAX_NEW, temperature=1.0, seed=seed)


def _stream_metrics(streams) -> Dict[str, float]:
    ttft = percentiles([s.ttft for s in streams])
    tpot = percentiles([s.tpot for s in streams])
    return {"ttft_p50_s": ttft["p50"], "ttft_p99_s": ttft["p99"],
            "tpot_p50_s": tpot["p50"], "tpot_p99_s": tpot["p99"]}


def run_lockstep(model, params, reqs: List[Request], seed: int) -> Dict:
    width = max(len(r.prompt) for r in reqs)
    batches = [reqs[i:i + SLOTS] for i in range(0, len(reqs), SLOTS)]

    def one_batch(group, key):
        B = len(group)
        prompts = np.zeros((B, width), np.int32)
        budgets = np.zeros((B,), np.int32)
        for j, r in enumerate(group):
            prompts[j, : len(r.prompt)] = r.prompt
            budgets[j] = r.max_new
        res = generate(model, params, jax.numpy.asarray(prompts), key,
                       max_new=MAX_NEW, temperature=1.0,
                       budgets=jax.numpy.asarray(budgets))
        return int(np.asarray(res.lengths).sum())

    key = jax.random.PRNGKey(seed + 100)
    one_batch(batches[0], key)  # warmup: the single compiled shape

    # virtual clock: batch b starts at max(prev end, its last arrival);
    # its requests' first tokens exist only at batch end
    tokens, clock, ttfts, tpots = 0, 0.0, [], []
    t_wall = time.perf_counter()
    for b, group in enumerate(batches):
        clock = max(clock, max(r.arrival for r in group))
        tb = time.perf_counter()
        n = one_batch(group, jax.random.fold_in(key, b))
        dt = time.perf_counter() - tb
        clock += dt
        tokens += n
        per_step = dt / max(MAX_NEW - 1, 1)
        for r in group:
            ttfts.append(clock - r.arrival)
            tpots.append(per_step)
    busy = time.perf_counter() - t_wall
    return {
        "goodput_tokens_per_s": tokens / clock if clock else 0.0,
        "tokens": float(tokens),
        "wall_s": clock,
        "busy_s": busy,
        "batches": float(len(batches)),
        "decode_steps": float(len(batches) * (MAX_NEW - 1)),
        "ttft_p50_s": percentiles(ttfts)["p50"],
        "ttft_p99_s": percentiles(ttfts)["p99"],
        "tpot_p50_s": percentiles(tpots)["p50"],
        "tpot_p99_s": percentiles(tpots)["p99"],
    }


def run_streaming(model, params, reqs: List[Request], seed: int) -> Dict:
    scfg = ServingConfig(num_slots=SLOTS, max_len=MAX_LEN, max_new=MAX_NEW,
                         page_size=PAGE, decode_burst=BURST)
    eng = ServingEngine(model, scfg, params=params,
                        key=jax.random.PRNGKey(seed + 200))
    warm = _workload(seed)  # identical shapes -> compiles all executables
    for w in warm:
        w.rid -= N_REQUESTS
    eng.serve(warm, realtime=False)
    eng.reset_stats()  # prefix cache cleared: the timed pass starts cold

    streams = eng.serve(reqs, realtime=True)
    st = eng.stats()
    st.update(_stream_metrics(
        [s for s in streams if s.finish_reason != "rejected"]))
    return st


def run(seed: int = 0) -> Dict:
    cfg = tiny_cfg()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    lock = run_lockstep(model, params, _workload(seed), seed)
    stream = run_streaming(model, params, _workload(seed), seed)
    budgets = np.array([r.max_new for r in _workload(seed)])
    return {
        "workload": {
            "num_requests": N_REQUESTS, "arrival_rate": RATE,
            "num_slots": SLOTS, "page_size": PAGE, "max_len": MAX_LEN,
            "max_new": MAX_NEW, "decode_burst": BURST,
            "shared_prefix": "80% of prompts open with one of 2 fixed "
                             "2-page system prompts",
            "budget_mix": "70% 4-8 | 20% 12-20 | 10% 64",
            "mean_budget": float(budgets.mean()),
        },
        "lockstep": lock,
        "streaming": stream,
        "speedup": (stream["goodput_tokens_per_s"]
                    / lock["goodput_tokens_per_s"]),
    }


def main() -> None:
    r = run()
    wl, lk, st = r["workload"], r["lockstep"], r["streaming"]
    emit("serving/lockstep_goodput_tok_s", lk["goodput_tokens_per_s"],
         f"ttft_p50_ms={lk['ttft_p50_s'] * 1e3:.0f} "
         f"ttft_p99_ms={lk['ttft_p99_s'] * 1e3:.0f}")
    emit("serving/streaming_goodput_tok_s", st["goodput_tokens_per_s"],
         f"ttft_p50_ms={st['ttft_p50_s'] * 1e3:.0f} "
         f"ttft_p99_ms={st['ttft_p99_s'] * 1e3:.0f} "
         f"prefix_hit_pct={st['prefix_hit_rate'] * 100:.0f} "
         f"occupancy_pct={st['slot_occupancy'] * 100:.0f}")
    emit("serving/speedup_pct", (r["speedup"] - 1.0) * 100.0,
         f"slots={wl['num_slots']} requests={wl['num_requests']} "
         f"rate={wl['arrival_rate']:.0f}/s mean_budget={wl['mean_budget']:.1f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default=None,
                    help="write the BENCH_serving.json baseline here")
    args = ap.parse_args()
    result = run(seed=args.seed)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")
    print(json.dumps(result, indent=2))
