"""§Roofline — the three-term analysis per (arch x shape) cell on the
single-pod mesh, from the dry-run artifacts (deliverable g).

  compute    = HLO_FLOPs/device       / 197e12 FLOP/s
  memory     = HLO_bytes/device       / 819e9  B/s
  collective = coll_bytes/device      / (3 links x 50e9 B/s)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) and the useful-compute ratio.
Reads results/dryrun_roofline.json (+ memory from results/dryrun_compile.json).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs import ARCHS, get_shape

PEAK_FLOPS = 197e12
HBM_BPS = 819e9
ICI_BPS = 50e9
ICI_LINKS = 3  # v5e: 3 usable link-pairs per chip on a 2D torus

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results")


def model_flops(arch: str, shape_name: str) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for prefill, 2*N*D_token for decode.

    N excludes the input embedding table (a lookup, not a matmul); the tied
    or untied LM head IS counted (it is a per-token matmul). For enc-dec the
    token count is S/2 (both stacks see S/2 tokens/frames each)."""
    cfg = ARCHS[arch]
    shape = get_shape(shape_name)
    n = cfg.num_active_params()
    if not cfg.tie_embeddings:
        n -= cfg.padded_vocab * cfg.d_model  # input embedding lookup
    seq = shape.seq_len // 2 if cfg.is_encoder_decoder else shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * seq
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * seq
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze(rows: List[Dict], mem_rows: Optional[List[Dict]] = None) -> List[Dict]:
    mem_by_cell = {}
    for m in mem_rows or []:
        if m.get("ok") and m.get("mesh") == "16x16":
            mem_by_cell[(m["arch"], m["shape"])] = m["memory"]
    out = []
    for r in rows:
        if not r.get("ok"):
            out.append({"arch": r["arch"], "shape": r["shape"], "ok": False,
                        "error": r.get("error")})
            continue
        chips = r["chips"]
        t_comp = r["flops"] / PEAK_FLOPS  # per-device cost_analysis is local
        t_mem = r["bytes"] / HBM_BPS
        t_coll = r["coll_total"] / (ICI_LINKS * ICI_BPS)
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dom = max(terms, key=terms.get)
        mf = model_flops(r["arch"], r["shape"])
        hlo_global = r["flops"] * chips
        out.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "ok": True,
            "compute_s": t_comp,
            "memory_s": t_mem,
            "collective_s": t_coll,
            "dominant": dom,
            "step_s": max(terms.values()),
            "model_flops": mf,
            "hlo_flops_global": hlo_global,
            "useful_ratio": mf / hlo_global if hlo_global else 0.0,
            "roofline_frac": (
                t_comp / max(terms.values()) if max(terms.values()) else 0.0
            ),
            "peak_bytes": (mem_by_cell.get((r["arch"], r["shape"]), {}) or {}).get("peak_bytes"),
        })
    return out


def decode_attention_bytes(
    kv_lens: List[int],
    *,
    S: int,
    kvh: int,
    d: int,
    block_s: int,
    page_size: int,
    dtype_bytes: int = 2,
) -> Dict[str, float]:
    """Analytic HBM traffic per decode step for the three cache-read
    strategies, for a batch of per-slot fills ``kv_lens`` in an arena of
    width ``S`` (K and V both read once; q/o traffic is negligible against
    the cache and identical across variants, so it is omitted).

      dense:  the pre-ragged kernel swept every S-block of every slot —
              the full (B, S) cache regardless of fill.
      ragged: grid truncation via the clamped index map fetches only
              ceil(kv_len / block_s) blocks per slot (dead steps repeat a
              block index, so Pallas elides the copy).
      paged:  the block-table kernel fetches ceil(kv_len / page_size)
              pool pages per slot — the same truncation at page
              granularity, with no staging copy of the S-wide arena
              beforehand (``staging_bytes`` is that eliminated copy: the
              old serving burst wrote gather(pool)->slot rows and then
              read them back; dense already counts the read-back).
    """
    per_pos = 2 * kvh * d * dtype_bytes  # K + V, one position
    dense = len(kv_lens) * S * per_pos
    ragged = sum(-(-l // block_s) * block_s for l in kv_lens) * per_pos
    paged = sum(-(-l // page_size) * page_size for l in kv_lens) * per_pos
    return {
        "dense_bytes": float(dense),
        "ragged_bytes": float(ragged),
        "paged_bytes": float(paged),
        "staging_bytes": float(dense),  # gather(pool) write eliminated
        "ragged_vs_dense": ragged / dense if dense else 0.0,
        "paged_vs_dense": paged / dense if dense else 0.0,
        "dense_s_at_peak": dense / HBM_BPS,
        "ragged_s_at_peak": ragged / HBM_BPS,
        "paged_s_at_peak": paged / HBM_BPS,
    }


def _print_decode_kernels() -> None:
    """Achieved-vs-peak bytes for the ragged/paged decode kernels on the
    skewed 70/20/10 serving mix of benchmarks/rollout.py: 70% of slots
    short (S/8 filled), 20% medium (S/2), 10% full."""
    B, S, kvh, d, block_s, ps = 64, 2048, 8, 128, 512, 64
    mix = ([S // 8] * (7 * B // 10) + [S // 2] * (2 * B // 10))
    mix += [S] * (B - len(mix))
    r = decode_attention_bytes(mix, S=S, kvh=kvh, d=d,
                               block_s=block_s, page_size=ps)
    for name in ("dense", "ragged", "paged"):
        frac = r[f"{name}_bytes"] / r["dense_bytes"]
        print(
            f"roofline_decode/{name},{r[f'{name}_s_at_peak'] * 1e6:.1f},"
            f"bytes={r[f'{name}_bytes'] / 1e6:.1f}MB frac_of_dense={frac:.3f}"
            f" (B={B} S={S} kvh={kvh} d={d} 70/20/10 mix)"
        )
    print(
        f"roofline_decode/paged_staging_eliminated,"
        f"{r['staging_bytes'] / HBM_BPS * 1e6:.1f},"
        f"bytes={r['staging_bytes'] / 1e6:.1f}MB per-burst gather copy removed"
    )


def _print_table(tag: str, suffix: str) -> None:
    path = os.path.join(RESULTS, f"dryrun_roofline{suffix}.json")
    cpath = os.path.join(RESULTS, f"dryrun_compile{suffix}.json")
    if not os.path.exists(path):
        print(f"{tag}/missing,0.0,run `python -m repro.launch.dryrun --all "
              "--mode roofline` first")
        return
    rows = json.load(open(path))
    mem_rows = json.load(open(cpath)) if os.path.exists(cpath) else []
    table = analyze(rows, mem_rows)
    for t in table:
        if not t["ok"]:
            print(f"{tag}/{t['arch']}:{t['shape']},0.0,FAILED {t['error']}")
            continue
        print(
            f"{tag}/{t['arch']}:{t['shape']},{t['step_s'] * 1e6:.1f},"
            f"dom={t['dominant']} comp={t['compute_s'] * 1e3:.2f}ms "
            f"mem={t['memory_s'] * 1e3:.2f}ms coll={t['collective_s'] * 1e3:.2f}ms "
            f"useful={t['useful_ratio']:.2f} frac={t['roofline_frac']:.2f}"
        )


def main() -> None:
    _print_table("roofline_baseline", "")  # paper-faithful arm
    _print_table("roofline_optimized", "_opt")  # post-§Perf arm
    _print_decode_kernels()  # analytic ragged/paged decode cache traffic


if __name__ == "__main__":
    main()
