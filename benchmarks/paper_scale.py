"""Paper-scale projection model shared by fig09-13.

Philosophy: we measure what we can on this CPU host (real pipeline, real
byte volumes, real stage structure) and project to the paper's cluster sizes
with a model CALIBRATED against exactly ONE published number — the 7B PPO
speedup at 128 GPUs (1.64x, Fig. 9). Everything else (other scales, GRPO,
long-context growth, Table 1's wall, Fig. 11 retention) is then PREDICTED
and compared against the paper's values in the benchmark output.

Iteration-time model (weak scaling: per-node batch fixed, global batch ∝ n):

  centralized: t(n) = t_comp + V_global(n) * stages / BW_ctrl
               the controller serializes the GLOBAL batch's trajectories at
               python/Ray-serialization throughput BW_ctrl (calibrated);
               V_global grows with n, so overhead grows ∝ n.
  distflow:    t(n) = t_comp + V_node * stages / ICI + t_fsdp(n)
               per-node volume over the node's own links (constant in n);
               t_fsdp is the paper's own residual (FSDP backend, §7.3),
               calibrated to the 80.5%-at-512 retention of Fig. 11.

Table 1's shrinking max batch follows a power law fitted in log-log space.
"""
from __future__ import annotations

import numpy as np

GPUS_PER_NODE = 8
ICI_BPS = 150e9  # intra-node NVLink-class / TPU 3x50GB/s
SEQ_TOKENS = 2048 + 4096
BATCH_PER_NODE = 1024  # 7B arm
ROLLOUT_TOKS_PER_GPU = 3500.0  # vLLM-class 7B tok/s amortized over the iter
STAGES = 4  # gen -> (ref, reward) -> adv -> train boundaries
CAL_POINT = (128, 1.64)  # paper Fig. 9: 7B, 128 GPUs -> 1.64x


def compute_time_s(batch_per_node=BATCH_PER_NODE, seq_tokens=SEQ_TOKENS,
                   toks_per_gpu=ROLLOUT_TOKS_PER_GPU) -> float:
    return batch_per_node * seq_tokens / (toks_per_gpu * GPUS_PER_NODE)


def node_traffic_bytes(bytes_per_token: float, batch_per_node=BATCH_PER_NODE,
                       seq_tokens=SEQ_TOKENS) -> float:
    return bytes_per_token * seq_tokens * batch_per_node * STAGES


def fsdp_alpha(t_comp: float) -> float:
    """Calibrate t_fsdp = alpha*log2(n_gpus) to Fig. 11's 80.5% at 512 (ref
    64). BOTH arms pay this (verl trains with FSDP too)."""
    r = 0.805
    return t_comp * (1 - r) / (r * np.log2(512) - np.log2(64))


def _base_time(n_gpus, batch_per_node, seq_tokens):
    t_comp = compute_time_s(batch_per_node, seq_tokens)
    return t_comp + fsdp_alpha(t_comp) * np.log2(max(n_gpus, 2))


BPT_CAL = 20.0  # bytes/token measured from the real pipeline's trajectories


def calibrated_controller_bps() -> float:
    """Solve BW_ctrl ONCE from the single calibration point (Fig. 9, 7B PPO,
    128 GPUs -> 1.64x). All other scales/algorithms/contexts are predictions
    at this fixed bandwidth."""
    n_gpus, s = CAL_POINT
    base = _base_time(n_gpus, BATCH_PER_NODE, SEQ_TOKENS)
    overhead = (s - 1.0) * base  # controller seconds per iteration
    v_global = node_traffic_bytes(BPT_CAL) * (n_gpus // GPUS_PER_NODE)
    return v_global / overhead


def centralized_iter_s(n_gpus: int, bytes_per_token: float = BPT_CAL,
                       batch_per_node=BATCH_PER_NODE,
                       seq_tokens=SEQ_TOKENS, pad_tokens=None) -> float:
    """``pad_tokens``: trajectories are PADDED to this length on the wire
    (the paper pads to max response length), while compute follows the true
    ``seq_tokens``. The controller moves padded bytes — the long-context
    amplifier of Fig. 13."""
    n = max(n_gpus // GPUS_PER_NODE, 1)
    bw = calibrated_controller_bps()
    v_global = node_traffic_bytes(
        bytes_per_token, batch_per_node, pad_tokens or seq_tokens) * n
    return _base_time(n_gpus, batch_per_node, seq_tokens) + v_global / bw


def distflow_iter_s(n_gpus: int, bytes_per_token: float = BPT_CAL,
                    batch_per_node=BATCH_PER_NODE,
                    seq_tokens=SEQ_TOKENS, pad_tokens=None) -> float:
    v_node = node_traffic_bytes(
        bytes_per_token, batch_per_node, pad_tokens or seq_tokens)
    return _base_time(n_gpus, batch_per_node, seq_tokens) + v_node / ICI_BPS


def speedup(n_gpus: int, bytes_per_token: float = BPT_CAL,
            batch_per_node=BATCH_PER_NODE, seq_tokens=SEQ_TOKENS,
            pad_tokens=None) -> float:
    args = (n_gpus, bytes_per_token, batch_per_node, seq_tokens, pad_tokens)
    return centralized_iter_s(*args) / distflow_iter_s(*args)


def retention(n_gpus: int, batch_per_node=512,
              toks_per_gpu=800.0) -> float:
    """DistFlow per-GPU throughput retention vs the 64-GPU reference
    (Fig. 11, 32B arm)."""
    t_comp = compute_time_s(batch_per_node, toks_per_gpu=toks_per_gpu)
    a = fsdp_alpha(t_comp)
    t0 = t_comp + a * np.log2(64)
    t = t_comp + a * np.log2(max(n_gpus, 2))
    return t0 / t


# ---- Table 1 (baseline max batch): power-law fit -------------------------- #
TABLE1_7B = {32: 1024, 64: 512, 128: 256, 256: 64}


def fit_table1():
    xs = np.log(np.array(sorted(TABLE1_7B), float))
    ys = np.log(np.array([TABLE1_7B[k] for k in sorted(TABLE1_7B)], float))
    A = np.stack([np.ones_like(xs), xs], 1)
    (b, m), *_ = np.linalg.lstsq(A, ys, rcond=None)
    return np.exp(b), -m  # C, gamma:  max = C * n^-gamma


def baseline_max_batch(n_gpus: int) -> int:
    C, gamma = fit_table1()
    return max(int(C * n_gpus ** (-gamma)), 1)
