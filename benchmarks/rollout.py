"""Rollout-engine arm: lockstep vs continuous-batching generation on a
skewed response-length workload.

The workload fixes per-sequence response budgets drawn from a skewed mixture
(70% short, 20% medium, 10% at the full ``max_new`` budget — the shape of
mixed short-answer / long-CoT RL batches). Both arms run the SAME model,
prompts, and budgets, so they produce the same token counts; the tiny random
model's next-token distribution is near-uniform, so EOS is left to the
budgets rather than to a token the model would essentially never sample.
Lockstep must still scan all ``max_new - 1`` decode steps at full batch
width; the continuous engine frees each slot at its budget and refills it
from the queue.

Reported per arm (CSV rows via benchmarks.common.emit, and the committed
``results/BENCH_rollout.json`` baseline via ``--json``):

  * tokens/sec        — counted response tokens / measured wall-clock
  * padding-waste %   — fraction of decode lane-steps that produced no
                        counted token (lockstep: B x (max_new-1) lane-steps;
                        engine: num_slots x executed decode steps)
  * slot occupancy    — engine only: active-slot-steps / lane-steps
  * speedup           — engine tokens/sec over lockstep tokens/sec
                        (acceptance floor: >= 1.5x on this workload)
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time
from typing import Dict

# allow `python benchmarks/rollout.py` from the repo root (same dance as
# benchmarks/run.py): make the `benchmarks` package importable
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks.common import emit, tiny_cfg
from repro.models import get_model
from repro.rl.rollout import generate
from repro.rl.rollout_engine import ContinuousRolloutEngine, lockstep_waste

B = 64  # sequences per iteration
LP = 6  # prompt length
MAX_NEW = 64  # response budget (lockstep always scans all of it)
SLOTS = 16  # engine decode-slot pool
REFILL_THRESHOLD = 2  # coalesce refills: dispatch overhead rivals a step on CPU


def skewed_budgets(seed: int = 0) -> np.ndarray:
    """Per-sequence response caps: 70% short (4-8), 20% medium (12-20),
    10% the full budget."""
    rng = np.random.default_rng(seed)
    out = np.empty(B, np.int32)
    for i in range(B):
        u = rng.random()
        if u < 0.7:
            out[i] = rng.integers(4, 9)
        elif u < 0.9:
            out[i] = rng.integers(12, 21)
        else:
            out[i] = MAX_NEW
    return out


def _length_stats(lengths: np.ndarray) -> Dict[str, float]:
    return {
        "mean_len": float(lengths.mean()),
        "p50_len": float(np.percentile(lengths, 50)),
        "p90_len": float(np.percentile(lengths, 90)),
        "max_len": float(lengths.max()),
    }


def run(iters: int = 3, seed: int = 0) -> Dict:
    cfg = tiny_cfg()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    prompts = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (B, LP), 3, 200)
    budgets = skewed_budgets(seed)

    gen_kw = dict(max_new=MAX_NEW, temperature=1.0, pad_id=0)
    lock = jax.jit(functools.partial(generate, model, **gen_kw))
    keys = [jax.random.fold_in(jax.random.PRNGKey(seed + 3), i)
            for i in range(iters + 1)]
    bud_dev = jax.numpy.asarray(budgets)

    # ---- lockstep arm ---------------------------------------------------- #
    jax.block_until_ready(
        lock(params, prompts, keys[-1], budgets=bud_dev).tokens)  # warmup
    lock_tokens, lock_lens = 0, []
    t0 = time.perf_counter()
    for i in range(iters):
        res = lock(params, prompts, keys[i], budgets=bud_dev)
        jax.block_until_ready(res.tokens)
        lens = np.asarray(res.lengths)
        lock_lens.append(lens)
        lock_tokens += int(lens.sum())
    lock_dt = time.perf_counter() - t0
    lock_lens = np.concatenate(lock_lens)

    # ---- continuous engine arm ------------------------------------------ #
    eng = ContinuousRolloutEngine(
        model, num_slots=SLOTS, refill_threshold=REFILL_THRESHOLD, **gen_kw)
    eng(params, prompts, keys[-1], budgets=budgets)  # warmup (compiles)
    eng_tokens, eng_lens = 0, []
    occ, waste, steps = [], [], 0
    t0 = time.perf_counter()
    for i in range(iters):
        res = eng(params, prompts, keys[i], budgets=budgets)
        lens = np.asarray(res.lengths)
        eng_lens.append(lens)
        eng_tokens += int(lens.sum())
        occ.append(eng.last_stats["slot_occupancy"])
        waste.append(eng.last_stats["padding_waste"])
        steps += eng.last_stats["decode_steps"]
    eng_dt = time.perf_counter() - t0
    eng_lens = np.concatenate(eng_lens)

    lock_tps = lock_tokens / lock_dt
    eng_tps = eng_tokens / eng_dt
    return {
        "workload": {
            "batch": B, "prompt_len": LP, "max_new": MAX_NEW,
            "num_slots": SLOTS, "iters": iters,
            "refill_threshold": REFILL_THRESHOLD,
            "budget_mix": "70% 4-8 | 20% 12-20 | 10% 64",
            **_length_stats(budgets),
        },
        "lockstep": {
            "s_per_iter": lock_dt / iters,
            "tokens_per_s": lock_tps,
            "padding_waste": lockstep_waste(lock_lens, MAX_NEW),
            "decode_steps_per_iter": float(MAX_NEW - 1),
            **_length_stats(lock_lens),
        },
        "engine": {
            "s_per_iter": eng_dt / iters,
            "tokens_per_s": eng_tps,
            "padding_waste": float(np.mean(waste)),
            "slot_occupancy": float(np.mean(occ)),
            "decode_steps_per_iter": steps / iters,
            **_length_stats(eng_lens),
        },
        "speedup": eng_tps / lock_tps,
    }


def main() -> None:
    r = run()
    wl, lk, en = r["workload"], r["lockstep"], r["engine"]
    emit("rollout/lockstep_s_per_iter", lk["s_per_iter"] * 1e6,
         f"tokens_per_s={lk['tokens_per_s']:.0f} "
         f"padding_waste_pct={lk['padding_waste'] * 100:.1f}")
    emit("rollout/engine_s_per_iter", en["s_per_iter"] * 1e6,
         f"tokens_per_s={en['tokens_per_s']:.0f} "
         f"padding_waste_pct={en['padding_waste'] * 100:.1f} "
         f"slot_occupancy_pct={en['slot_occupancy'] * 100:.1f}")
    emit("rollout/speedup_pct", (r["speedup"] - 1.0) * 100.0,
         f"slots={wl['num_slots']} batch={wl['batch']} "
         f"mean_len={wl['mean_len']:.1f} max_new={wl['max_new']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default=None,
                    help="write the BENCH_rollout.json baseline here")
    args = ap.parse_args()
    result = run(iters=args.iters, seed=args.seed)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")
    print(json.dumps(result, indent=2))
