"""Fig. 13 — long-context throughput: the speedup grows with context length.

Mechanism (paper §7.4 + its padding protocol): trajectories are padded to
the context window on the wire, so controller volume grows ∝ ctx while true
compute grows with realized response length (sub-proportional; we use
len ∝ ctx^0.7 and disclose it). Measured arm: rising response lengths on CPU
show the same slope direction at toy scale."""
from __future__ import annotations

from benchmarks import paper_scale as ps
from benchmarks.common import bench_pipeline, emit, tiny_cfg
from repro.rl import RLConfig


def main() -> None:
    cfg = tiny_cfg()
    speeds = {}
    for max_new in (16, 48):
        rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=max_new,
                      lr=1e-5)
        dt_d, _, _, _ = bench_pipeline(cfg, rl, centralized=False, iters=2,
                                    prompts_per_iter=4)
        dt_c, _, _, _ = bench_pipeline(cfg, rl, centralized=True, iters=2,
                                    prompts_per_iter=4)
        speeds[max_new] = dt_c / dt_d
        emit(f"fig13/measured_speedup_len{max_new}", dt_d * 1e6,
             f"{dt_c / dt_d:.2f}x")

    for ctx, paper in ((8192, "1.48x"), (16384, "~1.6x"), (32768, "~1.8x"),
                       (65536, "2.03x")):
        true_tokens = int(6144 * (ctx / 8192) ** 0.7)
        s = ps.speedup(64, seq_tokens=true_tokens, pad_tokens=ctx)
        emit(f"fig13/projected_speedup_ctx{ctx}", 0.0,
             f"{s:.2f}x (paper 7B: {paper})")


if __name__ == "__main__":
    main()
