"""Async pipeline v2 arm: staleness-bounded generation/training overlap vs
the synchronous scheduler, on the 2-stage demo DAG (GRPO's chain — one
generation macro-stage of GENERATE/INFERENCE/COMPUTE nodes, one training
macro-stage of MODEL_TRAIN nodes).

This container runs both halves sequentially, so the async arm's wall-clock
matches sync; what the arm reports is the overlap a concurrent deployment
realizes, measured from the scheduler's own per-iteration accounting:

  * overlap ratio  = hidden / (t_gen + t_train), hidden = min(t_gen, t_train)
    on every iteration whose trained batch predates the batch it generated
    (always, after warmup, for max_staleness >= 1; never for the sync arm);
  * idle recovered = the per-iteration seconds the generation mesh would
    otherwise sit idle during the update (and vice versa);
  * projected s/iter = sum(max(t_gen, t_train)) / iters — the concurrent
    schedule's critical path.

See docs/async_pipeline.md for the semantics and docs/benchmarks.md for how
to read the output.
"""
from __future__ import annotations

from benchmarks.common import bench_pipeline, emit, tiny_cfg
from repro.configs import AsyncPipelineConfig
from repro.rl import RLConfig


def _arms(iters: int = 6, seed: int = 0):
    rl = RLConfig(algorithm="grpo", group_size=4, max_new_tokens=8, lr=1e-4)
    cfg = tiny_cfg()
    sync = bench_pipeline(cfg, rl, iters=iters, seed=seed)
    # warmup=2: iteration 0 is the generation-only pipeline fill, so the
    # trainer's jit compile only happens on iteration 1 — keep both out of
    # the timed region
    a = bench_pipeline(
        cfg, rl, iters=iters, seed=seed, warmup=2,
        async_pipeline=AsyncPipelineConfig(enabled=True, max_staleness=1),
    )
    return sync, a


def main() -> None:
    (sync_dt, tokens, _, _), (a_dt, _, _, hist) = _arms()
    emit("async_pipeline/sync_s_per_iter", sync_dt * 1e6,
         f"tokens_per_s={tokens / sync_dt:.0f}")
    emit("async_pipeline/async_s_per_iter", a_dt * 1e6,
         f"tokens_per_s={tokens / a_dt:.0f} max_staleness=1")

    t_gen = [h.get("async/t_gen", 0.0) for h in hist]
    t_train = [h.get("async/t_train", 0.0) for h in hist]
    hidden = sum(h.get("async/overlap_s", 0.0) for h in hist)
    busy = sum(tg + tt for tg, tt in zip(t_gen, t_train))
    ratio = hidden / busy if busy else 0.0
    critical = sum(max(tg, tt) for tg, tt in zip(t_gen, t_train))
    stale = [h.get("async/staleness") for h in hist
             if "async/staleness" in h]
    emit("async_pipeline/overlap_ratio_pct", ratio * 100.0,
         f"idle_recovered_s={hidden:.4f} staleness_max={max(stale):.0f}")
    emit("async_pipeline/projected_s_per_iter", critical / len(hist) * 1e6,
         f"projected_speedup_pct={(busy / critical - 1.0) * 100.0:.1f}")


if __name__ == "__main__":
    main()
