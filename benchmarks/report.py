"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from results/*.json.

    PYTHONPATH=src python -m benchmarks.report > results/tables.md
"""
from __future__ import annotations

import json
import os

from benchmarks.roofline import ICI_BPS, ICI_LINKS, HBM_BPS, PEAK_FLOPS, analyze

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results")


def gib(b):
    return "-" if b is None else f"{b / 2**30:.2f}"


def dryrun_table() -> str:
    rows = json.load(open(os.path.join(RESULTS, "dryrun_compile.json")))
    out = ["| arch | shape | mesh | peak GiB/dev | args GiB/dev | compile s | ok |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("ok"):
            m = r["memory"]
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {gib(m['peak_bytes'])} "
                f"| {gib(m['argument_bytes'])} | {r['compile_s']} | OK |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - "
                       f"| FAIL: {r['error'][:60]} |")
    n_ok = sum(1 for r in rows if r.get("ok"))
    out.append(f"\n**{n_ok}/{len(rows)} cells compile** "
               f"(33 applicable cells x 2 meshes; skips per DESIGN.md §5).")
    return "\n".join(out)


def roofline_table() -> str:
    path = os.path.join(RESULTS, "dryrun_roofline.json")
    rows = json.load(open(path))
    mem = json.load(open(os.path.join(RESULTS, "dryrun_compile.json")))
    table = analyze(rows, mem)
    out = ["| arch | shape | compute ms | memory ms | collective ms | dominant "
           "| step ms | useful (6ND/HLO) | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for t in table:
        if not t["ok"]:
            out.append(f"| {t['arch']} | {t['shape']} | FAIL {t.get('error','')[:50]} "
                       "| | | | | | |")
            continue
        out.append(
            f"| {t['arch']} | {t['shape']} | {t['compute_s']*1e3:.2f} "
            f"| {t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} "
            f"| **{t['dominant']}** | {t['step_s']*1e3:.2f} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_frac']:.2f} |")
    return "\n".join(out)


def rollout_table() -> str:
    """Render the committed rollout-engine baseline (BENCH_rollout.json):
    lockstep vs continuous-batching tokens/sec, padding waste, occupancy."""
    path = os.path.join(RESULTS, "BENCH_rollout.json")
    if not os.path.exists(path):
        return ""
    r = json.load(open(path))
    wl, lk, en = r["workload"], r["lockstep"], r["engine"]
    out = [
        f"## Rollout engine (batch {wl['batch']}, max_new {wl['max_new']}, "
        f"{wl['num_slots']} slots, mean len {wl['mean_len']:.1f})\n",
        "| arm | s/iter | tokens/s | padding waste | slot occupancy |",
        "|---|---|---|---|---|",
        f"| lockstep | {lk['s_per_iter']:.4f} | {lk['tokens_per_s']:.0f} "
        f"| {lk['padding_waste'] * 100:.1f}% | - |",
        f"| continuous | {en['s_per_iter']:.4f} | {en['tokens_per_s']:.0f} "
        f"| {en['padding_waste'] * 100:.1f}% "
        f"| {en['slot_occupancy'] * 100:.1f}% |",
        f"\n**{r['speedup']:.2f}x tokens/sec over lockstep** on the skewed "
        f"workload ({wl['budget_mix']}).",
    ]
    return "\n".join(out)


def serving_table() -> str:
    """Render the committed serving baseline (BENCH_serving.json):
    streaming engine vs lockstep batching goodput, TTFT, prefix hit rate."""
    path = os.path.join(RESULTS, "BENCH_serving.json")
    if not os.path.exists(path):
        return ""
    r = json.load(open(path))
    wl, lk, st = r["workload"], r["lockstep"], r["streaming"]
    out = [
        f"## Serving ({wl['num_requests']} requests at "
        f"{wl['arrival_rate']:.0f}/s Poisson, {wl['num_slots']} slots, "
        f"mean budget {wl['mean_budget']:.1f} of max_new {wl['max_new']})\n",
        "| arm | goodput tok/s | TTFT p50 | TTFT p99 | per-token p50 "
        "| prefix hits |",
        "|---|---|---|---|---|---|",
        f"| lockstep | {lk['goodput_tokens_per_s']:.0f} "
        f"| {lk['ttft_p50_s'] * 1e3:.0f}ms | {lk['ttft_p99_s'] * 1e3:.0f}ms "
        f"| {lk['tpot_p50_s'] * 1e3:.1f}ms | - |",
        f"| streaming | {st['goodput_tokens_per_s']:.0f} "
        f"| {st['ttft_p50_s'] * 1e3:.0f}ms | {st['ttft_p99_s'] * 1e3:.0f}ms "
        f"| {st['tpot_p50_s'] * 1e3:.1f}ms "
        f"| {st['prefix_hit_rate'] * 100:.0f}% |",
        f"\n**{r['speedup']:.2f}x goodput over lockstep** "
        f"({wl['shared_prefix']}; {wl['budget_mix']} budgets).",
    ]
    return "\n".join(out)


def multiturn_table() -> str:
    """Render the committed multi-turn env baseline (BENCH_multiturn.json):
    single-turn vs 3-turn calculator throughput, turn-overlap occupancy, and
    KV-reuse savings."""
    path = os.path.join(RESULTS, "BENCH_multiturn.json")
    if not os.path.exists(path):
        return ""
    r = json.load(open(path))
    wl, st, mt = r["workload"], r["single_turn"], r["multi_turn"]
    out = [
        f"## Multi-turn environments ({wl['env']}, batch {wl['batch']}, "
        f"max_new {wl['max_new']}, {wl['num_slots']} slots)\n",
        "| arm | s/iter | action tok/s | turns/ep | slot occupancy "
        "| turn2+ prefill tok |",
        "|---|---|---|---|---|---|",
        f"| single-turn | {st['s_per_iter']:.4f} | {st['tokens_per_s']:.0f} "
        f"| {st['turns_per_episode']:.2f} | {st['slot_occupancy'] * 100:.1f}% "
        f"| {st['prefill_turn2plus_tokens']:.0f} |",
        f"| {wl['max_turns']}-turn | {mt['s_per_iter']:.4f} "
        f"| {mt['tokens_per_s']:.0f} | {mt['turns_per_episode']:.2f} "
        f"| {mt['slot_occupancy'] * 100:.1f}% "
        f"| {mt['prefill_turn2plus_tokens']:.0f} |",
        f"\n**KV reuse saves ~{r['kv_reuse_saved_tokens_per_iter']:.0f} "
        f"re-prefill tokens/iter**; continuations overlap other episodes' "
        f"turns at {r['turn_overlap_occupancy'] * 100:.1f}% occupancy.",
    ]
    return "\n".join(out)


def fleet_table() -> str:
    """Render the committed simulated-fleet baseline (BENCH_fleet.json):
    weak scaling over (pod, data, model) meshes, the file-plane gradient
    exchange exact vs int8_ef, and compressed_psum fidelity."""
    path = os.path.join(RESULTS, "BENCH_fleet.json")
    if not os.path.exists(path):
        return ""
    r = json.load(open(path))
    out = ["## Simulated fleet (docs/multihost.md; CPU device counts)\n",
           "| devices | hosts | s/iter | per-device tok/s | retention "
           "| controller bytes |",
           "|---|---|---|---|---|---|"]
    for p in r["weak_scaling"]:
        out.append(
            f"| {p['devices']} | {p['hosts']} | {p['s_per_iter']:.2f} "
            f"| {p['per_device_tokens_per_s']:.1f} "
            f"| {p['retention'] * 100:.1f}% | {p['controller_bytes']} |")
    x = r["grad_exchange"]
    out += [
        f"\nDP gradient exchange ({x['hosts']} hosts, "
        f"{x['params'] / 1e6:.1f}M params):\n",
        "| arm | s/exchange | wire bytes | saved | rel err |",
        "|---|---|---|---|---|",
        f"| exact fp32 | {x['exact']['s_per_exchange']:.3f} "
        f"| {x['exact']['wire_bytes_per_exchange']} | 0 | 0 (bitwise) |",
        f"| int8_ef | {x['int8_ef']['s_per_exchange']:.3f} "
        f"| {x['int8_ef']['wire_bytes_per_exchange']} "
        f"| {x['int8_ef']['wire_saved_bytes_per_exchange']} "
        f"({(1 - x['int8_ef']['wire_ratio']) * 100:.0f}%) "
        f"| {x['int8_ef']['rel_err']:.2e} |",
    ]
    c = r["compressed_psum"]
    out.append(
        f"\ncompressed_psum over the pod axis ({c['devices']} devices, "
        f"{c['hosts']} hosts): rel err {c['rel_err']:.2e} at "
        f"{c['wire_ratio']:.3f}x the exact wire volume.")
    return "\n".join(out)


def obs_table() -> str:
    """Render the committed sample trace (SAMPLE_trace.json, exported by an
    obs-enabled smoke train run): spans and busy time per host x subsystem
    track — the at-a-glance where-does-time-go summary."""
    path = os.path.join(RESULTS, "SAMPLE_trace.json")
    if not os.path.exists(path):
        return ""
    tr = json.load(open(path))
    evs = tr.get("traceEvents", [])
    spans = [e for e in evs if e.get("ph") == "X"]
    tracks = {}
    for e in spans:
        k = (e["pid"], e.get("cat", ""))
        n, busy = tracks.get(k, (0, 0.0))
        tracks[k] = (n + 1, busy + e.get("dur", 0.0))
    out = [
        "## Telemetry sample trace (docs/observability.md; "
        f"{len(spans)} spans, load in Perfetto)\n",
        "| host | subsystem | spans | busy ms |",
        "|---|---|---|---|",
    ]
    for (pid, cat), (n, busy) in sorted(tracks.items()):
        out.append(f"| host{pid} | {cat} | {n} | {busy / 1e3:.1f} |")
    return "\n".join(out)


def main() -> None:
    import sys

    suffix = "_opt" if "--opt" in sys.argv else ""
    rt = rollout_table()
    if rt:
        print(rt + "\n")
    ot = obs_table()
    if ot:
        print(ot + "\n")
    ft = fleet_table()
    if ft:
        print(ft + "\n")
    sv = serving_table()
    if sv:
        print(sv + "\n")
    mtt = multiturn_table()
    if mtt:
        print(mtt + "\n")
    print(f"## Dry-run{suffix} (single-pod 16x16 = 256 chips, "
          "multi-pod 2x16x16 = 512)\n")
    rows = json.load(open(os.path.join(RESULTS, f"dryrun_compile{suffix}.json")))
    out = ["| arch | shape | mesh | peak GiB/dev | args GiB/dev | compile s | ok |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("ok"):
            m = r["memory"]
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {gib(m['peak_bytes'])} "
                f"| {gib(m['argument_bytes'])} | {r['compile_s']} | OK |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - "
                       f"| FAIL: {r['error'][:60]} |")
    n_ok = sum(1 for r in rows if r.get("ok"))
    out.append(f"\n**{n_ok}/{len(rows)} cells compile**.")
    print("\n".join(out))

    rl = os.path.join(RESULTS, f"dryrun_roofline{suffix}.json")
    if os.path.exists(rl):
        print(f"\n## Roofline{suffix} (single-pod, v5e: 197 TF/s bf16, "
              "819 GB/s HBM, 3x50 GB/s ICI)\n")
        rows = json.load(open(rl))
        mem = json.load(open(os.path.join(RESULTS, f"dryrun_compile{suffix}.json")))
        table = analyze(rows, mem)
        out = ["| arch | shape | compute ms | memory ms | collective ms | dominant "
               "| step ms | useful (6ND/HLO) | roofline frac |",
               "|---|---|---|---|---|---|---|---|---|"]
        for t in table:
            if not t["ok"]:
                out.append(f"| {t['arch']} | {t['shape']} | FAIL | | | | | | |")
                continue
            out.append(
                f"| {t['arch']} | {t['shape']} | {t['compute_s']*1e3:.2f} "
                f"| {t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} "
                f"| **{t['dominant']}** | {t['step_s']*1e3:.2f} "
                f"| {t['useful_ratio']:.2f} | {t['roofline_frac']:.2f} |")
        print("\n".join(out))


if __name__ == "__main__":
    main()
