"""Fig. 11 — near-linear scalability to 1024 GPUs.

Per-GPU throughput retention under weak scaling. DistFlow's data plane adds
a CONSTANT per-node cost (measured: the databuffer moves only per-node
volume, zero controller bytes), so the only degradation is the FSDP gradient
sync the paper itself reports (80.5% at 512, their §7.3) — our model uses
that single point as calibration and predicts the rest of the curve. The
centralized arm's retention collapses as the controller serializes the
growing global batch.

The **simulated-fleet arm** (``--fleet``, committed baseline
``results/BENCH_fleet.json``) measures the multi-host machinery itself on
CPU-simulated fleets (docs/multihost.md):

* weak scaling over 8/16/32-device ``(pod, data, model)`` fleet meshes —
  per-device throughput retention with the prompt batch scaled to the
  device count, plus the databuffer's per-host staging volume (no
  controller bytes, no full-array gathers);
* the file-plane DP gradient exchange (``fleet.GradExchange``) driven by
  one thread per host: seconds per exchange and wire bytes for the exact
  fp32 arm vs the int8 error-feedback arm (wire_bytes saved is the number
  the compressed exchange exists for);
* ``compressed_psum`` over the pod axis: quantization rel-err and wire
  ratio for the in-process collective the fleet exchange mirrors.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np

from benchmarks import paper_scale as ps
from benchmarks.common import bench_pipeline, emit, tiny_cfg
from repro.rl import RLConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    cfg = tiny_cfg()
    rl = RLConfig(algorithm="grpo", group_size=4, max_new_tokens=16, lr=1e-5)
    dt_d, tok, pipe_d, _ = bench_pipeline(cfg, rl, centralized=False, iters=3,
                                       prompts_per_iter=4)
    emit("fig11/measured_controller_bytes", 0.0,
         f"{pipe_d.buffer.stats.bytes_through_controller}B (distflow: must be 0)")
    emit("fig11/measured_per_iter_s", dt_d * 1e6, "per-node unit at toy scale")

    base_c = None
    for gpus in (64, 128, 256, 512, 1024):
        r_d = ps.retention(gpus)
        emit(f"fig11/distflow_retention_{gpus}gpu", 0.0,
             f"{100 * r_d:.1f}% (paper: 80.5% @512 [cal], 32B arm)")
        t_c = ps.centralized_iter_s(gpus, batch_per_node=512)
        base_c = base_c or t_c
        emit(f"fig11/centralized_retention_{gpus}gpu", 0.0,
             f"{100 * base_c / t_c:.1f}% (baseline OOMs before here, Table 1)")


# ------------------------------------------------------------------ #
# simulated-fleet arm
# ------------------------------------------------------------------ #
def _fleet_point(num_hosts: int, devices_per_host: int, iters: int) -> dict:
    """One weak-scaling cell, in a subprocess with its own forced device
    count: the tiny GRPO pipeline on the global fleet mesh, prompts scaled
    to the device count (constant per-device batch)."""
    devices = num_hosts * devices_per_host
    body = textwrap.dedent(f"""
        import json, time
        import jax
        from benchmarks.common import tiny_cfg
        from repro.configs.base import DataCoordinatorConfig
        from repro.core import build_pipeline
        from repro.launch.mesh import make_fleet_mesh
        from repro.rl import RLConfig

        cfg = tiny_cfg()
        rl = RLConfig(algorithm="grpo", group_size=4, max_new_tokens=8,
                      lr=1e-5)
        mesh = make_fleet_mesh({num_hosts}, {devices_per_host})
        pipe = build_pipeline(cfg, rl, mesh=mesh,
                              prompts_per_iter={devices}, seed=0)
        pipe.run(1)  # warmup/compile
        pipe.buffer.stats.reset()
        t0 = time.perf_counter()
        pipe.run({iters})
        dt = (time.perf_counter() - t0) / {iters}
        st = pipe.buffer.stats
        print("RESULT " + json.dumps({{
            "s_per_iter": dt,
            "controller_bytes": st.bytes_through_controller,
            "max_host_inbound_bytes": st.max_host_inbound_bytes,
            "redistributions": st.redistributions,
        }}))
    """)
    out = _run_forced(body, devices)
    rec = json.loads(out.split("RESULT ", 1)[1])
    tokens = devices * 4 * (6 + 8)  # prompts * group * (prompt + response)
    rec.update({
        "hosts": num_hosts, "devices": devices,
        "tokens_per_s": tokens / rec["s_per_iter"],
        "per_device_tokens_per_s": tokens / rec["s_per_iter"] / devices,
    })
    return rec


def _run_forced(body: str, devices: int) -> str:
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        "import sys\n"
        f"sys.path.insert(0, {os.path.join(REPO, 'src')!r})\n"
        f"sys.path.insert(0, {REPO!r})\n"
        + body
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=900, env={**os.environ, "JAX_PLATFORMS": "cpu"}, cwd=REPO,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"fleet point failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


def _bench_exchange(workdir: str, hosts: int, params: int,
                    rounds: int) -> dict:
    """Time the file-plane GradExchange, one driver thread per host, for the
    exact and int8_ef arms on the same gradient vector."""
    import jax.numpy as jnp

    from repro.configs.base import DistributedConfig
    from repro.distributed.fleet import FleetContext, GradExchange

    rng = np.random.default_rng(0)
    grads = jnp.asarray(rng.standard_normal(params).astype(np.float32))
    result = {"hosts": hosts, "params": params, "rounds": rounds}
    for mode in ("none", "int8_ef"):
        root = os.path.join(workdir, f"xchg-{mode}")
        ctxs = [FleetContext(DistributedConfig(
            num_hosts=hosts, process_id=h, coordinator=root))
            for h in range(hosts)]
        for c in ctxs:
            c.heartbeat(0)
        exs = [GradExchange(c, mode) for c in ctxs]
        outs: dict = {}

        def drive(h):
            for _ in range(rounds):
                outs[h] = exs[h](grads)[0]

        t0 = time.perf_counter()
        ts = [threading.Thread(target=drive, args=(h,)) for h in range(hosts)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        dt = (time.perf_counter() - t0) / rounds
        st = exs[0].stats
        rel_err = float(np.linalg.norm(np.asarray(outs[0]) - np.asarray(grads))
                        / np.linalg.norm(np.asarray(grads)))
        key = "exact" if mode == "none" else "int8_ef"
        result[key] = {
            "s_per_exchange": dt,
            "wire_bytes_per_exchange": st["wire_bytes"] // rounds,
            "wire_saved_bytes_per_exchange": st["wire_saved_bytes"] // rounds,
            "wire_ratio": st["wire_bytes"] / st["exact_bytes"],
            "rel_err": rel_err,
        }
    return result


def _bench_compressed_psum(devices: int, hosts: int) -> dict:
    body = textwrap.dedent(f"""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import compression
        from repro.launch.mesh import make_fleet_mesh
        from repro.utils.jax_compat import shard_map, use_mesh
        mesh = make_fleet_mesh({hosts})
        x = jax.random.normal(jax.random.PRNGKey(0), ({hosts}, 64, 256))
        def body(v):
            return (jax.lax.psum(v, 'pod'),
                    compression.compressed_psum(v, 'pod'))
        with use_mesh(mesh):
            exact, approx = jax.jit(shard_map(
                body, mesh=mesh, in_specs=(P('pod', None, None),),
                out_specs=(P('pod', None, None), P('pod', None, None)),
                check_vma=False))(x)
        exact, approx = np.asarray(exact), np.asarray(approx)
        rel = float(np.linalg.norm(exact - approx) / np.linalg.norm(exact))
        ex_b, comp_b = compression.wire_bytes(np.asarray(x[0], np.float32))
        print("RESULT " + json.dumps({{
            "devices": {devices}, "hosts": {hosts}, "rel_err": rel,
            "wire_ratio": comp_b / ex_b,
        }}))
    """)
    out = _run_forced(body, devices)
    return json.loads(out.split("RESULT ", 1)[1])


def fleet(iters: int = 2, workdir: str = "/tmp/bench_fleet") -> dict:
    os.makedirs(workdir, exist_ok=True)
    points = []
    for hosts, dph in ((2, 4), (4, 4), (8, 4)):
        points.append(_fleet_point(hosts, dph, iters))
        p = points[-1]
        emit(f"fig11/fleet_{p['devices']}dev_s_per_iter", p["s_per_iter"] * 1e6,
             f"hosts={hosts} per_device_tps={p['per_device_tokens_per_s']:.0f} "
             f"controller_bytes={p['controller_bytes']}")
    base = points[0]["per_device_tokens_per_s"]
    for p in points:
        p["retention"] = p["per_device_tokens_per_s"] / base
    xchg = _bench_exchange(workdir, hosts=4, params=1_000_000, rounds=2)
    emit("fig11/fleet_exchange_exact_s", xchg["exact"]["s_per_exchange"] * 1e6,
         f"wire={xchg['exact']['wire_bytes_per_exchange']}B")
    emit("fig11/fleet_exchange_int8_s",
         xchg["int8_ef"]["s_per_exchange"] * 1e6,
         f"wire={xchg['int8_ef']['wire_bytes_per_exchange']}B "
         f"saved={xchg['int8_ef']['wire_saved_bytes_per_exchange']}B "
         f"rel_err={xchg['int8_ef']['rel_err']:.2e}")
    cpsum = _bench_compressed_psum(devices=32, hosts=8)
    emit("fig11/fleet_compressed_psum", 0.0,
         f"rel_err={cpsum['rel_err']:.2e} wire_ratio={cpsum['wire_ratio']:.3f}")
    return {"weak_scaling": points, "grad_exchange": xchg,
            "compressed_psum": cpsum}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", action="store_true",
                    help="run the simulated-fleet arm instead of the "
                    "projection table")
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--json", type=str, default=None,
                    help="write the BENCH_fleet.json baseline here")
    args = ap.parse_args()
    if not args.fleet:
        main()
    else:
        result = fleet(iters=args.iters)
        if args.json:
            os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
            with open(args.json, "w") as f:
                json.dump(result, f, indent=2)
            print(f"wrote {args.json}")
        print(json.dumps(result, indent=2))
