"""Fig. 11 — near-linear scalability to 1024 GPUs.

Per-GPU throughput retention under weak scaling. DistFlow's data plane adds
a CONSTANT per-node cost (measured: the databuffer moves only per-node
volume, zero controller bytes), so the only degradation is the FSDP gradient
sync the paper itself reports (80.5% at 512, their §7.3) — our model uses
that single point as calibration and predicts the rest of the curve. The
centralized arm's retention collapses as the controller serializes the
growing global batch."""
from __future__ import annotations

from benchmarks import paper_scale as ps
from benchmarks.common import bench_pipeline, emit, tiny_cfg
from repro.rl import RLConfig


def main() -> None:
    cfg = tiny_cfg()
    rl = RLConfig(algorithm="grpo", group_size=4, max_new_tokens=16, lr=1e-5)
    dt_d, tok, pipe_d, _ = bench_pipeline(cfg, rl, centralized=False, iters=3,
                                       prompts_per_iter=4)
    emit("fig11/measured_controller_bytes", 0.0,
         f"{pipe_d.buffer.stats.bytes_through_controller}B (distflow: must be 0)")
    emit("fig11/measured_per_iter_s", dt_d * 1e6, "per-node unit at toy scale")

    base_c = None
    for gpus in (64, 128, 256, 512, 1024):
        r_d = ps.retention(gpus)
        emit(f"fig11/distflow_retention_{gpus}gpu", 0.0,
             f"{100 * r_d:.1f}% (paper: 80.5% @512 [cal], 32B arm)")
        t_c = ps.centralized_iter_s(gpus, batch_per_node=512)
        base_c = base_c or t_c
        emit(f"fig11/centralized_retention_{gpus}gpu", 0.0,
             f"{100 * base_c / t_c:.1f}% (baseline OOMs before here, Table 1)")


if __name__ == "__main__":
    main()
