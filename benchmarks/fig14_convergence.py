"""Fig. 14 — convergence: DistFlow's dataflow does not change training math.

REAL training run (no projection): a tiny LM is GRPO-trained on the synthetic
math task twice — once with the distributed databuffer, once with the
centralized baseline buffer — with identical seeds. The reward/entropy
trajectories must coincide (the dataflow arm only moves data), and the reward
must improve over training (learning happens)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, tiny_cfg
from repro.core import build_pipeline
from repro.rl import RLConfig


def run_curve(centralized: bool, iters: int):
    from repro.data.dataset import SyntheticMathDataset

    cfg = tiny_cfg(num_layers=2, d_model=128, d_ff=256)
    rl = RLConfig(algorithm="grpo", group_size=8, max_new_tokens=3,
                  lr=1e-3, temperature=1.0, kl_coef=0.0)
    # single-digit sums: learnable from scratch within the benchmark budget
    ds = SyntheticMathDataset(4096, seed=1234, max_operand=4)
    pipe = build_pipeline(cfg, rl, prompts_per_iter=8, centralized=centralized,
                          seed=1234, dataset=ds)
    hist = pipe.run(iters)
    rewards = np.array([h["reward/mean"] for h in hist])
    entropy = np.array([h["actor/entropy"] for h in hist])
    return rewards, entropy


def main(iters: int = 60) -> None:
    r_dist, e_dist = run_curve(False, iters)
    r_cent, e_cent = run_curve(True, iters)
    # identical trajectories (same math, same seed)
    dr = float(np.abs(r_dist - r_cent).max())
    de = float(np.abs(e_dist - e_cent).max())
    emit("fig14/max_reward_curve_gap", 0.0, f"{dr:.2e} (must be ~0)")
    emit("fig14/max_entropy_curve_gap", 0.0, f"{de:.2e} (must be ~0)")
    # learning signal: late-window reward above early-window
    early = float(r_dist[:8].mean())
    late = float(r_dist[-8:].mean())
    emit("fig14/reward_early", 0.0, f"{early:.3f}")
    emit("fig14/reward_late", 0.0, f"{late:.3f} (improvement {late - early:+.3f})")
    emit("fig14/entropy_first_last", 0.0,
         f"{e_dist[0]:.3f} -> {e_dist[-1]:.3f}")


if __name__ == "__main__":
    main()
