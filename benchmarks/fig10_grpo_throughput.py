"""Fig. 10 — GRPO end-to-end throughput: DistFlow vs single-controller.

GRPO multiplies the trajectory volume (group_size rollouts per prompt +
per-token group stats), which the paper observes as a larger speedup (up to
2.62x). We measure the GRPO/PPO volume ratio from the real pipeline's buffer
accounting and feed it through the calibrated paper-scale model — the 2.6x
at 128 GPUs is then a PREDICTION (the calibration point is PPO's 1.64x)."""
from __future__ import annotations

from benchmarks import paper_scale as ps
from benchmarks.common import bench_pipeline, emit, tiny_cfg
from repro.rl import RLConfig


def main() -> None:
    cfg = tiny_cfg()
    rl_g = RLConfig(algorithm="grpo", group_size=8, max_new_tokens=16, lr=1e-5)
    rl_p = RLConfig(algorithm="ppo", max_new_tokens=16, lr=1e-5)

    dt_d, tok, pipe_d, _ = bench_pipeline(cfg, rl_g, centralized=False, iters=3,
                                       prompts_per_iter=4)
    dt_c, _, pipe_c, _ = bench_pipeline(cfg, rl_g, centralized=True, iters=3,
                                     prompts_per_iter=4)
    emit("fig10/grpo_distflow_tokens_per_s", dt_d * 1e6, f"{tok / dt_d:.1f} tok/s")
    emit("fig10/grpo_centralized_tokens_per_s", dt_c * 1e6, f"{tok / dt_c:.1f} tok/s")
    emit("fig10/grpo_measured_speedup_1host", 0.0, f"{dt_c / dt_d:.2f}x")

    # measured volume ratio GRPO vs PPO at equal prompt counts
    _, _, pipe_p, _ = bench_pipeline(cfg, rl_p, centralized=True, iters=2,
                                  prompts_per_iter=4)
    vol_g = pipe_c.buffer.stats.bytes_through_controller / 3
    vol_p = pipe_p.buffer.stats.bytes_through_controller / 2
    ratio = vol_g / max(vol_p, 1)
    emit("fig10/grpo_volume_ratio_vs_ppo", 0.0, f"{ratio:.2f}x (group_size=8)")

    for gpus, paper in ((32, "~1.4x"), (64, "~1.9x"), (128, "2.62x")):
        s = ps.speedup(gpus, ps.BPT_CAL * min(ratio, 2.5))
        emit(f"fig10/grpo_projected_speedup_{gpus}gpu", 0.0,
             f"{s:.2f}x (paper {paper})")


if __name__ == "__main__":
    main()
