"""Multi-turn environment arm: single-turn vs 3-turn CalculatorToolEnv
generation on the continuous engine.

Both arms run the SAME tiny model, prompts, and slot pool through the
episode loop (``repro.rl.envs``); the multi-turn arm's episodes continue
through KV-preserving continuations — the saved rows are scattered back over
a freed slot and ONLY the feed tokens (observation + one carried response
token) run through the decode path. The tiny random model essentially never
emits a digit-leading answer, so calculator episodes run the full 3 turns:
the arm exercises the continuation machinery at full tilt.

Reported per arm (CSV rows via benchmarks.common.emit, and the committed
``results/BENCH_multiturn.json`` baseline via ``--json``):

  * tokens/sec            — counted ACTION tokens / measured wall-clock
                            (observation tokens are env output, not policy
                            throughput)
  * turns/episode         — mean env turns actually taken
  * slot occupancy        — active-slot-steps / lane-steps: the turn-overlap
                            measure (continuations from one episode decode
                            while other episodes' turns are mid-flight)
  * prefill turn2+ tokens — tokens fed on later turns; the KV-reuse ratio
                            compares this against what full re-prefill of
                            every continuation's prefix would have cost
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict

# allow `python benchmarks/multiturn.py` from the repo root (same dance as
# benchmarks/run.py): make the `benchmarks` package importable
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks.common import emit, tiny_cfg
from repro.configs.base import EnvConfig
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_model
from repro.rl import envs as envs_mod
from repro.rl.reward import make_math_prompts
from repro.rl.rollout_engine import ContinuousRolloutEngine

B = 32  # episodes per iteration
MAX_NEW = 16  # per-turn response budget
SLOTS = 8  # engine decode-slot pool
TURNS = 3  # multi-turn arm's episode cap
OBS_BUDGET = 8  # observation clip ("<result>=" / ";aa+bb=" both fit)


def _make_engine(model, tok, max_turns: int) -> ContinuousRolloutEngine:
    cfg = EnvConfig(name="calculator", max_turns=max_turns,
                    obs_budget=OBS_BUDGET)
    rt = envs_mod.EnvRuntime(envs_mod.get_env("calculator"), cfg, tok)
    return ContinuousRolloutEngine(
        model, max_new=MAX_NEW, temperature=1.0, eos_id=tok.eos_id,
        pad_id=tok.pad_id, num_slots=SLOTS, refill_threshold=2,
        env=rt, max_turns=max_turns, turn_budget=0, obs_budget=OBS_BUDGET,
    )


def _run_arm(model, params, tok, prompts, keys, iters, max_turns) -> Dict:
    eng = _make_engine(model, tok, max_turns)
    eng(params, prompts, keys[-1])  # warmup (compiles)
    tokens = 0
    turns, occ, cont_tok, obs_tok, prefix_cost = [], [], 0, 0, 0
    t0 = time.perf_counter()
    for i in range(iters):
        res = eng(params, prompts, keys[i])
        tokens += int(np.asarray(res.lengths).sum())
        s = eng.last_stats
        turns.append(s["turns_mean"])
        occ.append(s["slot_occupancy"])
        cont_tok += int(s["prefill_tokens_turn2plus"])
        obs_tok += int(s["obs_tokens"])
        # what re-prefilling every continuation's full prefix would have
        # cost: role_mask rows give per-episode prefix sizes per turn
        rm = np.asarray(res.role_mask)
        ep_turns = np.asarray(eng.last_env["turns"])
        Lp = prompts.shape[1]
        nonpad = (rm > 0).sum(axis=1) + Lp
        # conservative estimate: each continuation would re-prefill at least
        # the prompt plus roughly half of what the episode generated (its
        # running prefix); episodes that never continued cost nothing
        cont_ep = ep_turns > 1
        prefix_cost += int(((ep_turns - 1) * Lp).sum()) + int(
            ((nonpad - Lp) * cont_ep).sum() // 2)
    dt = time.perf_counter() - t0
    return {
        "s_per_iter": dt / iters,
        "tokens_per_s": tokens / dt,
        "action_tokens_per_iter": tokens / iters,
        "turns_per_episode": float(np.mean(turns)),
        "slot_occupancy": float(np.mean(occ)),
        "prefill_turn2plus_tokens": cont_tok / iters,
        "obs_tokens_per_iter": obs_tok / iters,
        "reprefill_cost_estimate": prefix_cost / iters,
    }


def run(iters: int = 3, seed: int = 0) -> Dict:
    cfg = tiny_cfg()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    tok = ByteTokenizer()
    rng = np.random.default_rng(seed + 1)
    prompts, _ = make_math_prompts(rng, B, tok)
    prompts = jax.numpy.asarray(prompts)
    keys = [jax.random.fold_in(jax.random.PRNGKey(seed + 3), i)
            for i in range(iters + 1)]

    single = _run_arm(model, params, tok, prompts, keys, iters, max_turns=1)
    multi = _run_arm(model, params, tok, prompts, keys, iters,
                     max_turns=TURNS)
    kv_saved = multi["reprefill_cost_estimate"] - \
        multi["prefill_turn2plus_tokens"]
    return {
        "workload": {
            "batch": B, "max_new": MAX_NEW, "num_slots": SLOTS,
            "max_turns": TURNS, "obs_budget": OBS_BUDGET, "iters": iters,
            "env": "calculator",
        },
        "single_turn": single,
        "multi_turn": multi,
        # continuation tokens per iter the KV-reuse path avoided
        # re-prefilling (vs a conservative full-reprefill estimate)
        "kv_reuse_saved_tokens_per_iter": kv_saved,
        "turn_overlap_occupancy": multi["slot_occupancy"],
    }


def main() -> None:
    r = run()
    st, mt = r["single_turn"], r["multi_turn"]
    emit("multiturn/single_s_per_iter", st["s_per_iter"] * 1e6,
         f"tokens_per_s={st['tokens_per_s']:.0f} "
         f"occupancy_pct={st['slot_occupancy'] * 100:.1f}")
    emit("multiturn/multi3_s_per_iter", mt["s_per_iter"] * 1e6,
         f"tokens_per_s={mt['tokens_per_s']:.0f} "
         f"turns={mt['turns_per_episode']:.2f} "
         f"occupancy_pct={mt['slot_occupancy'] * 100:.1f}")
    emit("multiturn/kv_reuse_saved_tokens", r["kv_reuse_saved_tokens_per_iter"],
         f"prefill_turn2plus={mt['prefill_turn2plus_tokens']:.0f} "
         f"obs_tokens={mt['obs_tokens_per_iter']:.0f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default=None,
                    help="write the BENCH_multiturn.json baseline here")
    args = ap.parse_args()
    result = run(iters=args.iters, seed=args.seed)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")
    print(json.dumps(result, indent=2))
