"""Algorithm-plugin arm: every registered algorithm compiled through
``ExperimentSpec.compile()`` and timed end-to-end at unit scale.

This is the workload-diversity proof for the plugin API: one loop over the
registry, no per-algorithm wiring. Reports s/iteration and tokens/s per
algorithm plus the DAG node count (critic algorithms carry two extra nodes).
"""
from __future__ import annotations

import time

from benchmarks.common import emit, tiny_cfg
from repro.api import ExperimentSpec
from repro.rl import RLConfig, get_algorithm, list_algorithms


def main() -> None:
    for name in list_algorithms():
        spec = get_algorithm(name)
        rl = RLConfig(algorithm=name, group_size=4, max_new_tokens=8,
                      lr=1e-4, critic_lr=1e-4)
        exp = ExperimentSpec(model=tiny_cfg(), rl=rl, prompts_per_iter=4)
        pipe = exp.compile()
        pipe.run(1)  # warmup / jit
        iters = 3
        t0 = time.perf_counter()
        pipe.run(iters)
        dt = (time.perf_counter() - t0) / iters
        seqs = 4 * spec.group_size(rl)
        tokens = seqs * (6 + rl.max_new_tokens)
        emit(f"algorithms/{name}_s_per_iter", dt * 1e6,
             f"tokens_per_s={tokens / dt:.0f} nodes={len(pipe.dag.nodes)} "
             f"critic={int(spec.uses_critic)}")
