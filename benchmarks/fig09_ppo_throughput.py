"""Fig. 9 — PPO end-to-end throughput: DistFlow vs the single-controller
baseline.

Measured: tokens/s for both dataflow arms on CPU at reduced scale (same
pipeline code; only the databuffer arm differs) + the per-token trajectory
bytes. Projected: iteration-time model of benchmarks/paper_scale.py,
calibrated on exactly one published number (1.64x @ 128 GPUs); the other
scales are predictions compared against the paper's 1.09-1.64x band.
"""
from __future__ import annotations

from benchmarks import paper_scale as ps
from benchmarks.common import bench_pipeline, emit, tiny_cfg
from repro.rl import RLConfig


def main() -> None:
    cfg = tiny_cfg()
    rl = RLConfig(algorithm="ppo", max_new_tokens=16, lr=1e-5)

    dt_d, tok, pipe_d, _ = bench_pipeline(cfg, rl, centralized=False, iters=3)
    dt_c, _, pipe_c, _ = bench_pipeline(cfg, rl, centralized=True, iters=3)
    emit("fig09/ppo_distflow_tokens_per_s", dt_d * 1e6, f"{tok / dt_d:.1f} tok/s")
    emit("fig09/ppo_centralized_tokens_per_s", dt_c * 1e6, f"{tok / dt_c:.1f} tok/s")
    emit("fig09/ppo_measured_speedup_1host", 0.0, f"{dt_c / dt_d:.2f}x")

    # measured trajectory bytes/token (sanity vs the model's BPT_CAL)
    seqs = 8 * 1  # prompts x group
    bpt = pipe_c.buffer.stats.bytes_through_controller / 3 / seqs / 22 / 2
    emit("fig09/measured_traj_bytes_per_token", 0.0, f"{bpt:.1f}B (model {ps.BPT_CAL}B)")

    emit("fig09/controller_bw_calibrated", 0.0,
         f"{ps.calibrated_controller_bps() / 1e6:.0f} MB/s from 1.64x@128gpu")
    for gpus, paper in ((32, "1.09-1.2x"), (64, "~1.35x"), (128, "1.64x [cal]")):
        emit(f"fig09/ppo_projected_speedup_{gpus}gpu", 0.0,
             f"{ps.speedup(gpus):.2f}x (paper {paper})")


if __name__ == "__main__":
    main()
