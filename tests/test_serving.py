"""Serving subsystem tests: bitwise prefix-cache hits, park/resume
invariance, paged-arena roundtrips, admission ordering, and live weight
hot-swap mid-stream."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ServingConfig, reduced
from repro.distributed.weight_sync import WeightVersionStore
from repro.models import get_model
from repro.serving import (
    AdmissionQueue,
    ArenaOutOfPages,
    PagedKVArena,
    Request,
    RequestStream,
    ServingEngine,
)

PS = 8  # page size used throughout


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260, num_layers=2)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _scfg(**kw):
    base = dict(num_slots=4, max_len=64, max_new=12, page_size=PS,
                decode_burst=4)
    base.update(kw)
    return ServingConfig(**base)


def _prompt(rng, n):
    return rng.integers(3, 200, n).astype(np.int32)


# --------------------------------------------------------------------------- #
# config
# --------------------------------------------------------------------------- #
def test_serving_config_validation():
    assert _scfg().pool_pages == 3 * 4 * (64 // PS)
    with pytest.raises(ValueError):
        _scfg(max_len=60)  # not a page multiple
    with pytest.raises(ValueError):
        _scfg(max_new=64)  # no prompt room left
    with pytest.raises(ValueError):
        _scfg(num_slots=0)


# --------------------------------------------------------------------------- #
# admission queue
# --------------------------------------------------------------------------- #
def test_admission_queue_fifo_and_oldest_head():
    rng = np.random.default_rng(0)
    q = AdmissionQueue(bucket=PS, max_len=64)
    # bucket A gets rids 0,1; bucket B gets rid 2; then A gets rid 3
    for rid, n in [(0, 5), (1, 6), (2, 12), (3, 7)]:
        q.push(Request(rid=rid, prompt=_prompt(rng, n), max_new=4))
    kind, lb, items = q.pop_work(2)
    assert kind == "fresh" and lb == PS
    assert [r.rid for r in items] == [0, 1], "FIFO within the bucket"
    # bucket B's head (rid 2) is now older than A's head (rid 3)
    _, lb2, items2 = q.pop_work(4)
    assert lb2 == 2 * PS and [r.rid for r in items2] == [2]
    _, _, items3 = q.pop_work(4)
    assert [r.rid for r in items3] == [3]
    assert len(q) == 0
    with pytest.raises(IndexError):
        q.pop_work(1)


# --------------------------------------------------------------------------- #
# paged arena
# --------------------------------------------------------------------------- #
def test_paged_arena_alloc_free_and_roundtrip(tiny_model):
    cfg, model, params = tiny_model
    arena = PagedKVArena(model, num_pages=6, page_size=PS)
    a = arena.alloc(4)
    assert arena.num_free == 2 and arena.num_used == 4
    with pytest.raises(ArenaOutOfPages):
        arena.alloc(3)
    arena.free(a[:2])
    assert arena.num_free == 4

    # KV roundtrip: prefill a slot row, save 2 pages out, wipe, load back
    caches = model.init_caches(2, 4 * PS)
    toks = jnp.asarray(np.arange(2 * 2 * PS).reshape(2, 2 * PS) % 200 + 3)
    _, rows = model.prefill_chunk(params, toks, model.init_caches(2, 4 * PS),
                                  offset=0)
    caches = model.scatter_cache_rows(caches, rows, jnp.asarray([0, 1]))
    ids = arena.alloc(2)
    arena.save_rows(caches, 1, ids)
    wiped = jax.tree.map(jnp.zeros_like, caches)
    loaded = jax.tree.map(jnp.copy, wiped)
    loaded = arena.load_rows(loaded, [0], [ids])
    got = model.gather_cache_pages(loaded, jnp.asarray([0]),
                                   num_pages=2, page_size=PS)
    want = model.gather_cache_pages(caches, jnp.asarray([1]),
                                    num_pages=2, page_size=PS)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# --------------------------------------------------------------------------- #
# bitwise prefix-cache contract
# --------------------------------------------------------------------------- #
def test_prefix_hit_bitwise_identical_to_cold(tiny_model):
    """The tentpole contract: a request admitted over a prefix-cache hit
    must produce byte-for-byte the tokens it produces on a cold engine with
    the cache disabled. Same per-request seed, different cache states."""
    cfg, model, params = tiny_model
    rng = np.random.default_rng(7)
    prefix = _prompt(rng, 3 * PS)
    prompt = np.concatenate([prefix, _prompt(rng, 5)])

    cold = ServingEngine(model, _scfg(prefix_cache=False), params=params,
                         eos_id=2)
    s_cold = cold.serve([Request(rid=0, prompt=prompt, max_new=12, seed=42)],
                        realtime=False)[0]

    warm = ServingEngine(model, _scfg(), params=params, eos_id=2)
    sibling = np.concatenate([prefix, _prompt(rng, 7)])
    warm.serve([Request(rid=1, prompt=sibling, max_new=4, seed=9)],
               realtime=False)
    s_warm = warm.serve([Request(rid=0, prompt=prompt, max_new=12, seed=42)],
                        realtime=False)[0]

    assert s_warm.matched_prefix_tokens == 3 * PS, "hit expected"
    assert s_cold.matched_prefix_tokens == 0
    assert s_warm.tokens == s_cold.tokens, "prefix hit changed the output"
    warm.prefix_cache.check_invariants()


def test_full_prompt_match_still_computes_last_chunk(tiny_model):
    """A prompt whose every page is cached must still prefill its final
    page: the first sampled token needs fresh last-position logits."""
    cfg, model, params = tiny_model
    rng = np.random.default_rng(3)
    prompt = _prompt(rng, 2 * PS)  # exactly 2 pages, no tail
    eng = ServingEngine(model, _scfg(), params=params, eos_id=2)
    a = eng.serve([Request(rid=0, prompt=prompt, max_new=4, seed=5)],
                  realtime=False)[0]
    chunks_cold = eng.prefill_chunks
    b = eng.serve([Request(rid=1, prompt=prompt, max_new=4, seed=5)],
                  realtime=False)[0]
    assert b.matched_prefix_tokens == PS, "match capped below full prompt"
    assert eng.prefill_chunks == chunks_cold + 1, "one chunk recomputed"
    assert a.tokens == b.tokens, "same seed, same prompt, same tokens"


def test_park_resume_and_placement_invariance(tiny_model):
    """yield_quota parks a long request under queue pressure; its resumed
    stream must be identical to the uncontended run — decoding is invariant
    to slot placement, co-residents, and park/resume timing."""
    cfg, model, params = tiny_model
    rng = np.random.default_rng(11)
    prompt = _prompt(rng, 10)
    solo = ServingEngine(model, _scfg(prefix_cache=False), params=params,
                         eos_id=2)
    s_solo = solo.serve([Request(rid=0, prompt=prompt, max_new=12, seed=1)],
                        realtime=False)[0]

    cont = ServingEngine(
        model, _scfg(num_slots=2, decode_burst=2, yield_quota=3,
                     prefix_cache=False), params=params, eos_id=2)
    reqs = [Request(rid=0, prompt=prompt, max_new=12, seed=1)] + [
        Request(rid=i, prompt=_prompt(rng, 9), max_new=12, seed=i)
        for i in range(1, 6)]
    s_cont = cont.serve(reqs, realtime=False)[0]
    assert cont.parks > 0, "contention must actually park something"
    assert cont.resumes == cont.parks
    assert s_cont.tokens == s_solo.tokens, "park/resume changed the output"
    assert cont.arena.num_used == 0, "parked pages must all recycle"


def test_resident_kv_outgrows_slot_arena(tiny_model):
    """The paged pool decouples residency from compute: cached prefixes +
    parked sequences can exceed num_slots x max_len worth of KV."""
    cfg, model, params = tiny_model
    scfg = _scfg(num_slots=1, max_len=32, max_new=4)
    assert scfg.pool_pages * PS > scfg.num_slots * scfg.max_len
    eng = ServingEngine(model, _scfg(num_slots=1, max_len=32, max_new=4),
                        params=params, eos_id=2)
    rng = np.random.default_rng(5)
    # distinct prompts, each committing 2 pages to the cache
    reqs = [Request(rid=i, prompt=_prompt(rng, 2 * PS + 3), max_new=2)
            for i in range(4)]
    eng.serve(reqs, realtime=False)
    slot_capacity_pages = (eng.scfg.num_slots * eng.scfg.max_len) // PS
    assert eng.arena.num_used > slot_capacity_pages, \
        "resident cached KV should exceed the whole slot arena"
    eng.prefix_cache.check_invariants()


def test_rejected_and_finish_reasons(tiny_model):
    cfg, model, params = tiny_model
    eng = ServingEngine(model, _scfg(), params=params, eos_id=2)
    rng = np.random.default_rng(9)
    too_long = eng.submit(Request(rid=0, prompt=_prompt(rng, 64), max_new=4))
    assert too_long.finished and too_long.finish_reason == "rejected"
    ok = eng.serve([Request(rid=1, prompt=_prompt(rng, 6), max_new=3)],
                   realtime=False)[0]
    assert ok.finished and ok.finish_reason in ("eos", "budget")
    assert len(ok.tokens) <= 3
    assert ok.ttft is not None and ok.ttft >= 0


# --------------------------------------------------------------------------- #
# live weight hot-swap
# --------------------------------------------------------------------------- #
def test_hot_swap_mid_stream_keeps_streams_intact(tiny_model):
    """Publishing new weights mid-decode must not drop or restart in-flight
    requests: the stream keeps growing across the swap, token count hits
    the budget exactly, and version tags are monotone with one segment per
    version actually decoded under."""
    cfg, model, params = tiny_model
    p1 = model.init(jax.random.PRNGKey(1))
    store = WeightVersionStore()
    store.publish(params)
    eng = ServingEngine(model, _scfg(num_slots=2, max_new=24, decode_burst=2),
                        weight_store=store, eos_id=None)
    rng = np.random.default_rng(3)
    stream = eng.submit(Request(rid=0, prompt=_prompt(rng, 10), max_new=24))
    for _ in range(3):
        eng.step()
    before_swap = list(stream.tokens)
    assert 0 < len(before_swap) < 24, "swap must land mid-stream"
    store.publish(p1)
    while eng.step():
        pass
    assert stream.finished and len(stream.tokens) == 24
    assert stream.tokens[: len(before_swap)] == before_swap, \
        "swap must not rewrite already-streamed tokens"
    assert eng.weight_swaps == 1
    versions = stream.weight_versions
    assert versions == sorted(versions), "version tags must be monotone"
    assert len(set(versions)) == 2, "both versions must appear"
    # the version store refuses regressions outright
    with pytest.raises(ValueError):
        store.publish(params, version=0)


def test_hot_swap_clears_prefix_cache(tiny_model):
    """Cached pages are weight-version-scoped: after a swap, a previously
    cached prompt must miss (its KV under the old weights is invalid)."""
    cfg, model, params = tiny_model
    p1 = model.init(jax.random.PRNGKey(1))
    store = WeightVersionStore()
    store.publish(params)
    eng = ServingEngine(model, _scfg(), weight_store=store, eos_id=2)
    rng = np.random.default_rng(8)
    prompt = _prompt(rng, 2 * PS + 4)
    eng.serve([Request(rid=0, prompt=prompt, max_new=2)], realtime=False)
    assert eng.prefix_cache.num_pages > 0
    store.publish(p1)
    s = eng.serve([Request(rid=1, prompt=prompt, max_new=2)],
                  realtime=False)[0]
    assert s.matched_prefix_tokens == 0, "stale-version page served"
    assert eng.prefix_cache.num_pages > 0, "recommitted under new version"


# --------------------------------------------------------------------------- #
# stream bookkeeping
# --------------------------------------------------------------------------- #
def test_request_stream_metrics():
    r = Request(rid=0, prompt=np.array([5, 6, 7]), max_new=8, arrival=1.0)
    s = RequestStream(r)
    assert s.ttft is None and s.tpot is None
    s.append([11], 1.5, 0)
    s.append([12, 13], 2.5, 1)
    assert s.ttft == pytest.approx(0.5)
    assert s.tpot == pytest.approx(0.5)  # (2.5 - 1.5) / 2
    assert s.version_segments == [(0, 0), (1, 1)]
    assert s.tokens == [11, 12, 13]
    with pytest.raises(ValueError):
        Request(rid=1, prompt=np.array([]), max_new=4)
    with pytest.raises(ValueError):
        Request(rid=2, prompt=np.array([5]), max_new=0)


def test_engine_gates_unsupported_archs(tiny_model):
    bad = reduced(ARCHS["mixtral-8x7b"], vocab_size=260, num_layers=2)
    model = get_model(bad)
    assert model.cfg.sliding_window is not None
    with pytest.raises(ValueError, match="serving engine"):
        ServingEngine(model, _scfg(), params={})
