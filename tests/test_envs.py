"""Environment/reward subsystem tests: registries, the three built-in envs,
the engine's multi-turn episode loop (KV reuse, role masking, teacher-forcing
consistency), the single-turn bitwise-equivalence contract, observation-token
masking across every registered algorithm, and the full-stack wiring
(EnvConfig -> ExperimentSpec -> pipeline -> learning)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec
from repro.configs import ARCHS, EnvConfig, RolloutEngineConfig, reduced
from repro.core import build_pipeline
from repro.core.dag import NodeType, Role
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_model
from repro.rl import RLConfig, envs
from repro.rl.reward import make_math_prompts, math_reward
from repro.rl.rollout_engine import ContinuousRolloutEngine

TOK = ByteTokenizer()


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260, num_layers=2)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _math_prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    prompts, answers = make_math_prompts(rng, n, TOK)
    return jnp.asarray(prompts), answers


def _runtime(name, **kw):
    cfg = EnvConfig(name=name, **kw)
    return envs.EnvRuntime(envs.get_env(name), cfg, TOK)


# --------------------------------------------------------------------------- #
# registries
# --------------------------------------------------------------------------- #
def test_env_registry_contents():
    for name in ("function_reward", "calculator", "dialog"):
        assert name in envs.list_envs()
        assert envs.get_env(name).name == name
    assert "math" in envs.list_rewards()
    assert not envs.get_env("function_reward").multi_turn
    assert envs.get_env("calculator").multi_turn


def test_env_registry_nearest_match_errors():
    with pytest.raises(KeyError, match="calculator"):
        envs.get_env("calculater")  # typo -> nearest-match hint
    with pytest.raises(KeyError, match="Registered"):
        envs.get_env("no_such_env")
    with pytest.raises(KeyError, match="math"):
        envs.get_reward("matth")
    with pytest.raises(KeyError, match="already registered"):
        envs.register_env(envs.get_env("calculator"))
    # override is allowed and idempotent
    envs.register_env(envs.get_env("calculator"), override=True)
    with pytest.raises(KeyError, match="already registered"):
        envs.register_reward(envs.get_reward("math"))


def test_runtime_rejects_multi_turn_on_single_turn_env():
    with pytest.raises(ValueError, match="single-turn"):
        _runtime("function_reward", max_turns=3)


def test_env_config_validation():
    with pytest.raises(ValueError, match="max_turns"):
        EnvConfig(name="dialog", max_turns=0)
    with pytest.raises(ValueError, match="turn_budget"):
        EnvConfig(name="dialog", turn_budget=-1)
    with pytest.raises(ValueError, match="obs_budget"):
        EnvConfig(name="dialog", obs_budget=0)
    assert not EnvConfig().enabled
    assert EnvConfig(name="dialog").enabled


# --------------------------------------------------------------------------- #
# built-in environments (host protocol)
# --------------------------------------------------------------------------- #
def test_function_reward_env_matches_host_reward():
    rt = _runtime("function_reward")
    prompts, answers = _math_prompts(6, seed=3)
    for b in range(6):
        env = rt.make_episode()
        env.reset(np.asarray(prompts[b]))
        resp = np.concatenate(
            [TOK.encode(str(int(answers[b]))), [TOK.eos_id]])
        obs, r, done, _ = env.step(resp)
        assert done and len(obs) == 0
        want = math_reward([str(int(answers[b]))], answers[b:b + 1])[0]
        assert r == pytest.approx(float(want)) == 1.0


def test_calculator_env_protocol():
    rt = _runtime("calculator", max_turns=3)
    env = rt.make_episode()
    env.reset(TOK.encode("12+34="))
    # well-formed tool call: the env evaluates the called expression
    obs, r, done, info = env.step(TOK.encode("CALL 12+34"))
    assert not done and info["tool_call"] and r == 0.0
    assert TOK.decode(obs) == "46="
    # final digit-leading turn is the scored answer
    obs, r, done, info = env.step(
        np.concatenate([TOK.encode("46"), [TOK.eos_id]]))
    assert done and r == 1.0 and info["answered"]
    assert info["tool_calls"] == 1


def test_calculator_env_malformed_call_and_junk():
    rt = _runtime("calculator", max_turns=3)
    env = rt.make_episode()
    env.reset(TOK.encode("03+04="))
    # malformed CALL falls back to the prompt's own expression
    obs, r, done, _ = env.step(TOK.encode("CALL banana"))
    assert not done and TOK.decode(obs) == "7="
    # junk burns a turn; the env re-asks
    env2 = rt.make_episode()
    env2.reset(TOK.encode("03+04="))
    obs, r, done, info = env2.step(TOK.encode("xyz"))
    assert not done and info["malformed"] and TOK.decode(obs) == ";03+04="


def test_dialog_env_per_turn_partial_rewards():
    rt = _runtime("dialog", max_turns=3)
    env = rt.make_episode()
    env.reset(TOK.encode("02+03="))
    right = np.concatenate([TOK.encode("5"), [TOK.eos_id]])
    obs, r1, d1, _ = env.step(right)  # turn 1: half credit
    assert not d1 and r1 == pytest.approx(0.5) and len(obs) > 0
    obs, r2, d2, _ = env.step(TOK.encode("9"))  # turn 2: wrong
    assert not d2 and r2 == 0.0
    obs, r3, d3, _ = env.step(right)  # final turn: full credit
    assert d3 and r3 == pytest.approx(1.0) and len(obs) == 0


# --------------------------------------------------------------------------- #
# engine episode loop
# --------------------------------------------------------------------------- #
def test_single_turn_env_bitwise_identical_to_pre_env_path(tiny_model):
    """The equivalence contract: a single-turn env only *scores* — the
    generation schedule (keys, shapes, refills) is untouched, so tokens,
    masks, logprobs, and lengths are bit-for-bit the env-off engine's (which
    is itself token-identical to the pre-PR lockstep path under a fixed slot
    schedule)."""
    cfg, model, params = tiny_model
    prompts, answers = _math_prompts(8, seed=1)
    key = jax.random.PRNGKey(9)
    kw = dict(max_new=10, temperature=2.0, eos_id=TOK.eos_id, pad_id=0)
    ref = ContinuousRolloutEngine(model, **kw)(params, prompts, key)
    eng = ContinuousRolloutEngine(
        model, env=_runtime("function_reward"), max_turns=1, **kw)
    got = eng(params, prompts, key)
    np.testing.assert_array_equal(np.asarray(got.tokens), np.asarray(ref.tokens))
    np.testing.assert_array_equal(
        np.asarray(got.response_mask), np.asarray(ref.response_mask))
    np.testing.assert_array_equal(
        np.asarray(got.old_logprob), np.asarray(ref.old_logprob))
    np.testing.assert_array_equal(
        np.asarray(got.lengths), np.asarray(ref.lengths))
    # role_mask marks every counted token an action, nothing an observation
    rm = np.asarray(got.role_mask)
    np.testing.assert_array_equal(rm == 1, np.asarray(ref.response_mask))
    assert not (rm == 2).any()
    # and the env scored each episode exactly once
    assert eng.last_env is not None
    np.testing.assert_array_equal(eng.last_env["turns"], np.ones(8))


def test_multi_turn_kv_reuse_prefill_metric(tiny_model):
    """Acceptance criterion: continuation prefill for turn >= 2 counts ONLY
    observation tokens (plus the one carried response token per turn) —
    never the shared prompt/response prefix."""
    cfg, model, params = tiny_model
    prompts, _ = _math_prompts(6, seed=2)
    eng = ContinuousRolloutEngine(
        model, max_new=8, temperature=2.0, eos_id=TOK.eos_id, pad_id=0,
        num_slots=3, env=_runtime("dialog", max_turns=3, obs_budget=8),
        max_turns=3, turn_budget=4, obs_budget=8,
    )
    got = eng(params, prompts, jax.random.PRNGKey(4))
    s = eng.last_stats
    turns = eng.last_env["turns"]
    np.testing.assert_array_equal(turns, np.full(6, 3))  # dialog always runs 3
    n_cont = int((turns - 1).sum())
    rm = np.asarray(got.role_mask)
    n_obs = int((rm == 2).sum())
    # the KV-reuse contract: later-turn prefill == observations + one carried
    # token per continuation
    assert s["prefill_tokens_turn2plus"] == n_obs + n_cont
    assert s["obs_tokens"] == n_obs
    assert s["prefill_tokens"] == s["prefill_tokens_turn1"] + \
        s["prefill_tokens_turn2plus"]
    # re-prefilling full prefixes would cost at least prompt-width per
    # continuation on top of the observations — assert we stayed well under
    assert s["prefill_tokens_turn2plus"] < n_obs + n_cont + \
        n_cont * prompts.shape[1]
    assert s["cont_refills"] >= 1


def test_multi_turn_teacher_forcing_consistency(tiny_model):
    """The assembled multi-turn sequence must be consistent with its own
    behaviour logprobs: recomputing full-sequence logprobs at every action
    position agrees with what the engine recorded turn by turn — the
    end-to-end proof that continuations resumed from the right KV state."""
    cfg, model, params = tiny_model
    prompts, _ = _math_prompts(6, seed=5)
    eng = ContinuousRolloutEngine(
        model, max_new=8, temperature=2.0, eos_id=TOK.eos_id, pad_id=0,
        num_slots=3, env=_runtime("dialog", max_turns=3, obs_budget=8),
        max_turns=3, turn_budget=4, obs_budget=8,
    )
    got = eng(params, prompts, jax.random.PRNGKey(8))
    lp, _ = model.logprobs(params, got.tokens)
    m = np.asarray(got.response_mask)
    assert m.sum() > 0
    np.testing.assert_allclose(
        np.asarray(got.old_logprob)[m], np.asarray(lp)[m], atol=5e-2)
    # observations and prompt tokens carry zero behaviour logprob
    assert np.all(np.asarray(got.old_logprob)[~m] == 0.0)


def test_multi_turn_role_mask_structure(tiny_model):
    """role_mask partitions every sequence: prompt/pad 0, actions 1 (exactly
    response_mask), observations 2; actions and observations never overlap,
    and each continuing episode has at least one observation token."""
    cfg, model, params = tiny_model
    prompts, _ = _math_prompts(4, seed=6)
    eng = ContinuousRolloutEngine(
        model, max_new=6, temperature=2.0, eos_id=TOK.eos_id, pad_id=0,
        env=_runtime("dialog", max_turns=2, obs_budget=8),
        max_turns=2, turn_budget=3, obs_budget=8,
    )
    got = eng(params, prompts, jax.random.PRNGKey(2))
    rm = np.asarray(got.role_mask)
    mask = np.asarray(got.response_mask)
    np.testing.assert_array_equal(rm == 1, mask)
    assert set(np.unique(rm)) <= {0, 1, 2}
    assert ((rm == 2).sum(axis=1) >= 1).all()  # every episode continued once
    Lp = prompts.shape[1]
    assert not (rm[:, :Lp] != 0).any()  # prompt region is role 0


# --------------------------------------------------------------------------- #
# observation-token masking across every registered algorithm
# --------------------------------------------------------------------------- #
def _masked_batch(seed=0):
    """A synthetic 4-sequence batch with interleaved action/observation
    tokens: 2 prompt, 3 action, 2 obs, 2 action positions."""
    rng = np.random.default_rng(seed)
    B, L = 4, 9
    roles = np.zeros((B, L), np.int8)
    roles[:, 2:5] = 1
    roles[:, 5:7] = 2
    roles[:, 7:9] = 1
    mask = jnp.asarray(roles == 1)
    lp = jnp.asarray(rng.normal(size=(B, L)).astype(np.float32) * 0.1)
    old_lp = jnp.asarray(rng.normal(size=(B, L)).astype(np.float32) * 0.1)
    adv = jnp.asarray(rng.normal(size=(B, L)).astype(np.float32))
    return roles, mask, lp, old_lp, adv


@pytest.mark.parametrize("algo", ["grpo", "ppo", "rloo", "reinforce_pp"])
def test_obs_tokens_excluded_from_actor_loss(algo):
    """Perturbing logprobs at observation positions must not change any
    registered algorithm's actor loss: the loss only reads response_mask
    positions, and response_mask == (role_mask == 1)."""
    from repro.rl import algorithms

    spec = algorithms.get_algorithm(algo)
    rl = RLConfig(algorithm=algo, group_size=2)
    roles, mask, lp, old_lp, adv = _masked_batch()
    batch = {
        "old_logprob": old_lp,
        "ref_logprob": old_lp * 0.5,
        "advantages": adv,
        "response_mask": mask,
    }
    base = spec.actor_loss(rl, lp, batch)["loss"]
    obs = jnp.asarray(roles == 2)
    lp_perturbed = jnp.where(obs, lp + 37.0, lp)
    batch_perturbed = dict(
        batch,
        old_logprob=jnp.where(obs, old_lp - 11.0, old_lp),
        advantages=jnp.where(obs, adv + 100.0, adv),
    )
    got = spec.actor_loss(rl, lp_perturbed, batch_perturbed)["loss"]
    np.testing.assert_allclose(float(got), float(base), rtol=1e-6)


def test_obs_tokens_excluded_hand_computed_reference():
    """Hand-computed PPO surrogate on a 1-sequence batch: the loss equals
    the masked-mean over the 3 action tokens only — the 2 observation tokens
    contribute nothing even with huge advantages."""
    from repro.rl import loss as losses

    lp = jnp.asarray([[0.0, -0.1, -0.2, -0.3, -0.4]])
    old = jnp.asarray([[0.0, -0.2, -0.2, -0.1, -0.2]])
    adv = jnp.asarray([[9e9, 1.0, -2.0, 9e9, 0.5]])  # positions 0,3 are obs
    mask = jnp.asarray([[False, True, True, False, True]])
    out = losses.ppo_policy_loss(lp, old, adv, mask, clip_eps=0.2)
    ratio = np.exp(np.asarray(lp) - np.asarray(old))[0]
    clipped = np.clip(ratio, 0.8, 1.2)
    a = np.asarray(adv)[0]
    surr = np.minimum(ratio * a, clipped * a)
    want = -(surr[1] + surr[2] + surr[4]) / 3.0
    np.testing.assert_allclose(float(out["loss"]), want, rtol=1e-5)


def test_obs_tokens_excluded_from_advantage_and_is_weights():
    """Broadcast advantages and truncated-IS weights are zero at observation
    positions (mask excludes them), for the grouped and global estimators."""
    from repro.rl import advantage as adv_mod
    from repro.rl import loss as losses

    roles, mask, lp, old_lp, _ = _masked_batch(seed=1)
    rewards = jnp.asarray([1.0, 0.0, 0.5, 0.25])
    obs = np.asarray(roles == 2)
    for fn in (
        lambda: adv_mod.grpo(rewards, mask, group_size=2),
        lambda: adv_mod.rloo(rewards, mask, group_size=2),
        lambda: adv_mod.reinforce_pp(rewards, mask),
    ):
        a = np.asarray(fn())
        assert np.all(a[obs] == 0.0)
        assert np.any(a[np.asarray(mask)] != 0.0)
    w = losses.truncated_is_weights(lp, old_lp, mask, rho_max=2.0)
    rho = np.asarray(w["rho"])
    assert np.all(rho[obs] == 0.0)
    assert np.all(rho[np.asarray(mask)] > 0.0)


# --------------------------------------------------------------------------- #
# stack wiring
# --------------------------------------------------------------------------- #
def test_with_env_stage_retargets_reward_node():
    from repro.rl import algorithms

    dag = envs.with_env_stage(algorithms.grpo_dag())
    assert "env_compute" in dag.nodes and "reward_compute" not in dag.nodes
    node = dag.nodes["env_compute"]
    assert node.role == Role.ENV and node.type == NodeType.COMPUTE
    assert dag.nodes["advantage_compute"].deps == ("env_compute",)
    # validate_dag accepts ENV in place of REWARD
    algorithms.get_algorithm("grpo").validate_dag(dag)
    # a DAG with no reward node passes through untouched
    assert envs.with_env_stage(dag) is dag


def test_experiment_spec_env_round_trip_and_back_compat():
    exp = ExperimentSpec(
        model=reduced(ARCHS["qwen2.5-7b"], vocab_size=260),
        rl=RLConfig(algorithm="grpo", group_size=2, max_new_tokens=6),
        rollout=RolloutEngineConfig(engine="continuous", num_slots=4),
        env=EnvConfig(name="calculator", max_turns=3, turn_budget=4),
    )
    assert ExperimentSpec.from_json(exp.to_json()) == exp
    # back-compat: dicts without the env key default to env-off
    d = exp.to_dict()
    del d["env"]
    restored = ExperimentSpec.from_dict(d)
    assert restored.env == EnvConfig() and not restored.env.enabled


def test_multi_turn_gated_for_ssm_archs():
    """Multi-turn continuations are attention-only: a done slot keeps
    stepping (fed PAD) until the burst exits, which corrupts SSM recurrent
    state irreversibly — the engine must refuse rather than silently resume
    episodes from a wrong state. Single-turn env on SSM stays allowed."""
    cfg = reduced(ARCHS["mamba2-2.7b"], vocab_size=260)
    model = get_model(cfg)
    with pytest.raises(ValueError, match="attention-only"):
        ContinuousRolloutEngine(
            model, max_new=4, env=_runtime("dialog", max_turns=2),
            max_turns=2)
    ContinuousRolloutEngine(  # single-turn env is fine on SSM
        model, max_new=4, env=_runtime("function_reward"), max_turns=1)


def test_multi_turn_no_global_turn_barrier(tiny_model):
    """With a drained fresh-prompt queue but continuable episodes in flight,
    the burst must hand control back as slots finish their turns instead of
    holding them at an all-slots barrier: with S == B and max_turns > 1 the
    engine needs more than one burst per turn wave (the barrier failure mode
    executed exactly max_turns bursts)."""
    cfg, model, params = tiny_model
    prompts, _ = _math_prompts(8, seed=11)
    eng = ContinuousRolloutEngine(
        model, max_new=8, temperature=2.0, eos_id=TOK.eos_id, pad_id=0,
        env=_runtime("dialog", max_turns=3, obs_budget=8),
        max_turns=3, turn_budget=6, obs_budget=8,
    )
    got = eng(params, prompts, jax.random.PRNGKey(13))
    lens = np.asarray(got.lengths)
    # varied per-turn lengths at temperature 2.0 -> turn waves desynchronize;
    # the engine must have interleaved refills rather than run 3 barriers
    assert eng.last_stats["bursts"] > 3.0 or len(set(lens.tolist())) == 1
    np.testing.assert_array_equal(eng.last_env["turns"], np.full(8, 3))


def test_multi_turn_requires_continuous_engine():
    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260)
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4)
    with pytest.raises(ValueError, match="continuous"):
        build_pipeline(cfg, rl, prompts_per_iter=2,
                       env=EnvConfig(name="dialog", max_turns=2))


def test_single_turn_env_through_lockstep_pipeline():
    """Single-turn envs run on the lockstep engine too: the ENV stage steps
    each episode post-hoc over the finished rollout, and the computed
    rewards match the REWARD stage's token-path scoring."""
    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260)
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=6, lr=1e-4)
    base = build_pipeline(cfg, rl, prompts_per_iter=4, seed=7)
    with_env = build_pipeline(
        cfg, rl, prompts_per_iter=4, seed=7,
        env=EnvConfig(name="function_reward"))
    assert "env_compute" in with_env.dag.nodes
    m0 = base.worker.run_iteration()
    m1 = with_env.worker.run_iteration()
    # same seed, same generation path -> same rollout, same reward
    assert m1["reward/mean"] == pytest.approx(m0["reward/mean"])


def test_calculator_pipeline_runs_and_reports_env_metrics():
    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260)
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=6, lr=1e-4)
    pipe = build_pipeline(
        cfg, rl, prompts_per_iter=4,
        rollout=RolloutEngineConfig(engine="continuous", num_slots=4),
        env=EnvConfig(name="calculator", max_turns=3, turn_budget=4,
                      obs_budget=8),
    )
    hist = pipe.run(2)
    for m in hist:
        assert m["rollout/tokens"] > 0
        assert 1.0 <= m["env/turns_mean"] <= 3.0
        assert m["rollout/prefill_tokens_turn2plus"] >= 0.0
        assert "reward/mean" in m
        assert any(k.startswith("actor/") for k in m)


def test_calculator_grpo_learning_improves_reward():
    """Acceptance criterion: a smoke-scale 3-turn CalculatorToolEnv GRPO run
    through ExperimentSpec.compile() lifts mean reward above the
    random-policy floor (mirrors test_learning_improves_reward)."""
    from repro.data.dataset import SyntheticMathDataset

    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260, num_layers=2,
                  d_model=128, d_ff=256)
    exp = ExperimentSpec(
        model=cfg,
        rl=RLConfig(algorithm="grpo", group_size=8, max_new_tokens=3,
                    lr=1e-3, kl_coef=0.0),
        rollout=RolloutEngineConfig(engine="continuous"),
        env=EnvConfig(name="calculator", max_turns=3, obs_budget=8),
        prompts_per_iter=8,
        seed=1234,
    )
    ds = SyntheticMathDataset(4096, seed=1234, max_operand=4)
    pipe = exp.compile(dataset=ds)
    hist = pipe.run(90)
    early = np.mean([h["reward/mean"] for h in hist[:8]])
    late = np.mean([h["reward/mean"] for h in hist[-8:]])
    assert late > early + 0.05, (early, late)
    # as the policy learns to answer, episodes shorten toward single-turn
    assert hist[-1]["env/turns_mean"] <= hist[0]["env/turns_mean"] + 1.0
