"""Algorithm-plugin API tests: AlgorithmSpec registry, ExperimentSpec facade,
and the redesign's equivalence contract — the spec-driven grpo/ppo paths must
be bitwise-identical to the pre-redesign string-dispatch code (whose exact
formulas are inlined here as the reference)."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec
from repro.configs import ARCHS, DataCoordinatorConfig, reduced
from repro.core import DAG, Node, NodeType, Role, build_pipeline
from repro.core.dag import DAGError
from repro.models import get_model
from repro.rl import (
    AlgorithmSpec,
    RLConfig,
    get_algorithm,
    list_algorithms,
    register_algorithm,
)
from repro.rl import advantage as adv_mod
from repro.rl import loss as losses
from repro.rl import trainer
from repro.rl.algorithms import critic_free_dag, grpo_dag, ppo_dag


def small_cfg(**kw):
    base = dict(vocab_size=260, num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, head_dim=16, d_ff=128)
    base.update(kw)
    return reduced(ARCHS["qwen2.5-7b"], **base)


# --------------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------------- #
def test_builtin_algorithms_registered():
    assert {"grpo", "ppo", "rloo", "reinforce_pp"} <= set(list_algorithms())
    assert get_algorithm("ppo").uses_critic
    assert not get_algorithm("grpo").uses_critic
    assert get_algorithm("grpo").group_size(RLConfig(group_size=8)) == 8
    assert get_algorithm("ppo").group_size(RLConfig(group_size=8)) == 1


def test_unknown_algorithm_lists_registered_and_nearest():
    with pytest.raises(KeyError) as ei:
        get_algorithm("gropo")
    msg = str(ei.value)
    assert "grpo" in msg and "Registered" in msg


def test_duplicate_registration_requires_override():
    spec = get_algorithm("grpo")
    with pytest.raises(KeyError):
        register_algorithm(spec)
    assert register_algorithm(spec, override=True) is spec


# --------------------------------------------------------------------------- #
# equivalence contract: spec callables == pre-redesign inline branches
# --------------------------------------------------------------------------- #
def _fake_batch(key, B=8, T=12, prompt=5):
    ks = jax.random.split(key, 4)
    lp = -jnp.abs(jax.random.normal(ks[0], (B, T)))
    mask = jnp.concatenate(
        [jnp.zeros((B, prompt), bool), jnp.ones((B, T - prompt), bool)], 1)
    return {
        "old_logprob": lp * mask,
        "ref_logprob": (lp + 0.1 * jax.random.normal(ks[1], (B, T))) * mask,
        "advantages": jax.random.normal(ks[2], (B, T)) * mask,
        "response_mask": mask,
        "old_values": jax.random.normal(ks[3], (B, T)) * mask,
    }


def test_grpo_actor_loss_bitwise_matches_pre_redesign():
    rl = RLConfig(algorithm="grpo", clip_eps=0.2, kl_coef=0.003)
    batch = _fake_batch(jax.random.PRNGKey(0))
    logprob = batch["old_logprob"] + 0.05
    # pre-redesign: trainer.actor_loss_fn's `if rl.algorithm == "grpo"` arm
    want = losses.grpo_loss(
        logprob, batch["old_logprob"], batch["ref_logprob"],
        batch["advantages"], batch["response_mask"],
        clip_eps=rl.clip_eps, kl_coef=rl.kl_coef)
    got = get_algorithm("grpo").actor_loss(rl, logprob, batch)
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]))


def test_ppo_actor_loss_bitwise_matches_pre_redesign():
    rl = RLConfig(algorithm="ppo", clip_eps=0.2)
    batch = _fake_batch(jax.random.PRNGKey(1))
    logprob = batch["old_logprob"] - 0.03
    # pre-redesign: the `else` arm
    want = losses.ppo_policy_loss(
        logprob, batch["old_logprob"], batch["advantages"],
        batch["response_mask"], clip_eps=rl.clip_eps)
    got = get_algorithm("ppo").actor_loss(rl, logprob, batch)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]))


def test_grpo_advantage_engine_bitwise_matches_pre_redesign():
    rl = RLConfig(algorithm="grpo", group_size=4)
    rewards = jax.random.uniform(jax.random.PRNGKey(2), (8,))
    mask = jnp.ones((8, 6), bool)
    want = adv_mod.grpo(rewards, mask, group_size=rl.group_size)
    got = get_algorithm("grpo").make_advantage(rl)(rewards, mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ppo_advantage_engine_bitwise_matches_pre_redesign():
    rl = RLConfig(algorithm="ppo", kl_coef=0.002, gamma=0.99, gae_lambda=0.9)
    key = jax.random.PRNGKey(3)
    batch = _fake_batch(key)
    rewards = jax.random.uniform(key, (8,))
    mask, old_lp = batch["response_mask"], batch["old_logprob"]
    ref_lp, values = batch["ref_logprob"], batch["old_values"]

    # pre-redesign: _ppo_adv as it appeared inline in pipeline._build_engines
    B, T = mask.shape
    kl = old_lp - ref_lp
    m = mask.astype(jnp.float32)
    last = jnp.maximum(jnp.sum(m, axis=1) - 1, 0).astype(jnp.int32)
    first = jnp.argmax(mask, axis=1)
    pos = jnp.clip(first + last, 0, T - 1)
    tok_rewards = -rl.kl_coef * kl * m
    tok_rewards = tok_rewards.at[jnp.arange(B), pos].add(rewards)
    want_adv, want_ret = adv_mod.gae(
        tok_rewards, values * m, m, gamma=rl.gamma, lam=rl.gae_lambda)
    want_adv = adv_mod.whiten(want_adv, m)

    got_adv, got_ret = get_algorithm("ppo").make_advantage(rl)(
        rewards, mask, old_lp, ref_lp, values)
    np.testing.assert_array_equal(np.asarray(got_adv), np.asarray(want_adv))
    np.testing.assert_array_equal(np.asarray(got_ret), np.asarray(want_ret))


@pytest.mark.parametrize("algo", ["grpo", "ppo"])
def test_experimentspec_compile_bitwise_matches_build_pipeline(algo):
    """The facade is a pure compiler: ExperimentSpec.compile() must reproduce
    a direct build_pipeline() run bitwise (same seeds, same engines)."""
    cfg = small_cfg()
    rl = RLConfig(algorithm=algo, group_size=4, max_new_tokens=4, lr=1e-4,
                  critic_lr=1e-4)
    h_direct = build_pipeline(cfg, rl, prompts_per_iter=4, seed=5).run(3)
    exp = ExperimentSpec(model=cfg, rl=rl, prompts_per_iter=4, seed=5)
    pipe = exp.compile()
    h_spec = pipe.run(3)
    for a, b in zip(h_direct, h_spec):
        for k in a:
            if k.startswith("time/"):
                continue
            assert a[k] == b[k], k  # exact, not approx


# --------------------------------------------------------------------------- #
# new algorithms: estimator math + end-to-end smoke
# --------------------------------------------------------------------------- #
def test_rloo_advantage_hand_calc():
    rewards = jnp.array([1.0, 0.0, 0.5, 0.5])  # two groups of 2
    mask = jnp.ones((4, 3))
    adv = adv_mod.rloo(rewards, mask, group_size=2)
    # leave-one-out baseline: group 0 -> [1-0, 0-1]; group 1 -> [0, 0]
    np.testing.assert_allclose(np.asarray(adv[:, 0]),
                               [1.0, -1.0, 0.0, 0.0], atol=1e-6)
    # group-mean of LOO advantages is zero
    assert abs(float(jnp.sum(adv[:2, 0]))) < 1e-6


def test_rloo_scales_grpo_centering():
    """RLOO advantages are the group-centered rewards scaled by G/(G-1)."""
    rewards = jax.random.uniform(jax.random.PRNGKey(0), (8,))
    mask = jnp.ones((8, 4))
    g = 4
    adv = adv_mod.rloo(rewards, mask, group_size=g)
    centered = rewards.reshape(2, g) - jnp.mean(rewards.reshape(2, g), 1,
                                                keepdims=True)
    want = (centered * g / (g - 1)).reshape(8)[:, None] * mask
    np.testing.assert_allclose(np.asarray(adv), np.asarray(want), atol=1e-6)


def test_reinforce_pp_advantage_is_global_batch_normalized():
    rewards = jnp.array([1.0, 0.0, 3.0, 0.0])
    mask = jnp.ones((4, 2))
    adv = adv_mod.reinforce_pp(rewards, mask)
    col = np.asarray(adv[:, 0])
    assert abs(col.mean()) < 1e-5
    np.testing.assert_allclose(col.std(), 1.0, atol=1e-3)
    # NOT per-group: two identical-reward pairs would all be 0 under grpo
    assert not np.allclose(col, 0.0)


@pytest.mark.parametrize("algo", ["rloo", "reinforce_pp"])
def test_new_algorithms_train_end_to_end(algo):
    """Acceptance: rloo and reinforce_pp train via ExperimentSpec.compile()."""
    exp = ExperimentSpec(
        model=small_cfg(),
        rl=RLConfig(algorithm=algo, group_size=4, max_new_tokens=4, lr=1e-3,
                    kl_coef=0.0),
        prompts_per_iter=4,
        seed=0,
    )
    pipe = exp.compile()
    spec = get_algorithm(algo)
    assert not spec.uses_critic
    assert "critic_step" not in pipe.ctx.engines
    hist = pipe.run(3)
    for m in hist:
        assert np.isfinite(m["actor/loss"])
        assert m["rollout/tokens"] > 0
    # grouped rollouts: 4 prompts x group 4
    assert pipe.ctx.counters["gen_tokens"] > 0
    if algo == "reinforce_pp":
        assert "actor/kl" not in hist[-1]  # no reference model in the loss
        assert "reference_inference" not in pipe.plan.order


def test_custom_algorithm_registration_under_50_loc():
    """The docs' pluggability claim: a working custom algorithm (constant
    baseline REINFORCE) registers and trains without touching the core."""
    def make_adv(rl):
        return lambda rewards, mask: (
            (rewards - 0.5)[:, None] * mask.astype(jnp.float32))

    spec = AlgorithmSpec(
        name="reinforce_const",
        dag_factory=critic_free_dag,
        make_advantage=make_adv,
        actor_loss=get_algorithm("reinforce_pp").actor_loss,
        grouped_rollouts=True,
    )
    register_algorithm(spec, override=True)
    try:
        exp = ExperimentSpec(
            model=small_cfg(),
            rl=RLConfig(algorithm="reinforce_const", group_size=2,
                        max_new_tokens=4, lr=1e-3),
            prompts_per_iter=4,
        )
        m = exp.compile().run(2)[-1]
        assert np.isfinite(m["actor/loss"])
    finally:
        from repro.rl.algorithms import _ALGORITHMS

        _ALGORITHMS.pop("reinforce_const", None)


# --------------------------------------------------------------------------- #
# DAG validation errors
# --------------------------------------------------------------------------- #
def test_dag_cycle_raises():
    with pytest.raises(DAGError, match="cycle"):
        DAG.from_nodes([
            Node("a", Role.ACTOR, NodeType.COMPUTE, deps=("b",)),
            Node("b", Role.ACTOR, NodeType.COMPUTE, deps=("a",)),
        ])


def test_dag_unknown_dep_raises():
    with pytest.raises(DAGError, match="unknown dependency"):
        DAG.from_nodes([Node("a", Role.ACTOR, NodeType.COMPUTE,
                             deps=("nope",))])


def test_dag_duplicate_id_raises():
    with pytest.raises(DAGError, match="duplicate"):
        DAG.from_nodes([
            Node("a", Role.ACTOR, NodeType.COMPUTE),
            Node("a", Role.REWARD, NodeType.COMPUTE),
        ])


def test_missing_required_role_raises():
    """A PPO run on a critic-less DAG must fail fast with the missing roles."""
    with pytest.raises(DAGError, match="critic"):
        get_algorithm("ppo").validate_dag(grpo_dag())
    # and through the compile path
    exp = ExperimentSpec(
        model=small_cfg(),
        rl=RLConfig(algorithm="ppo", max_new_tokens=4),
        prompts_per_iter=4,
        dag=grpo_dag().to_spec(),
    )
    with pytest.raises(DAGError, match="required roles"):
        exp.compile()


def test_builtin_dags_satisfy_their_specs():
    for name in list_algorithms():
        spec = get_algorithm(name)
        spec.validate_dag(spec.dag_factory())


# --------------------------------------------------------------------------- #
# ExperimentSpec serialization
# --------------------------------------------------------------------------- #
def test_experimentspec_json_roundtrip():
    exp = ExperimentSpec(
        model=small_cfg(),
        rl=RLConfig(algorithm="rloo", group_size=4, lr=3e-5),
        coordinator=DataCoordinatorConfig(double_buffer=True, prefetch=2,
                                          load_balance=True),
        mesh_shape=(2, 4),
        mesh_axes=("data", "model"),
        prompts_per_iter=16,
        centralized=True,
        seed=42,
        dag=ppo_dag().to_spec(),
    )
    via_json = ExperimentSpec.from_json(exp.to_json())
    assert via_json == exp
    via_dict = ExperimentSpec.from_dict(
        json.loads(json.dumps(exp.to_dict())))
    assert via_dict == exp


def test_experimentspec_defaults_roundtrip():
    exp = ExperimentSpec(model=small_cfg())
    assert ExperimentSpec.from_json(exp.to_json()) == exp
    assert exp.algorithm.name == "grpo"


def test_experimentspec_compile_uses_embedded_dag():
    """The dag dict travels through JSON and drives the compiled plan."""
    custom = DAG.from_nodes([
        Node("actor_generation", Role.ACTOR, NodeType.GENERATE),
        Node("reward_compute", Role.REWARD, NodeType.COMPUTE,
             deps=("actor_generation",)),
        Node("advantage_compute", Role.ADVANTAGE, NodeType.COMPUTE,
             deps=("reward_compute",)),
        Node("actor_train", Role.ACTOR, NodeType.MODEL_TRAIN,
             deps=("advantage_compute",)),
    ])
    exp = ExperimentSpec(
        model=small_cfg(),
        rl=RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4,
                    kl_coef=0.0),
        prompts_per_iter=2,
        dag=custom.to_spec(),
    )
    pipe = ExperimentSpec.from_json(exp.to_json()).compile()
    assert pipe.plan.order == ["actor_generation", "reward_compute",
                               "advantage_compute", "actor_train"]
    assert "reference_inference" not in pipe.plan.order
    m = pipe.run(1)[-1]
    assert np.isfinite(m["actor/loss"])


# --------------------------------------------------------------------------- #
# trainer-level spec threading
# --------------------------------------------------------------------------- #
def test_make_actor_step_accepts_explicit_spec():
    cfg = small_cfg()
    model = get_model(cfg)
    rl = RLConfig(algorithm="grpo", lr=1e-3, group_size=4)
    params = model.init(jax.random.PRNGKey(0))
    batch = _fake_batch(jax.random.PRNGKey(1), B=4, T=10)
    batch["tokens"] = jax.random.randint(jax.random.PRNGKey(2), (4, 10), 3, 250)
    s_named, m_named = jax.jit(trainer.make_actor_step(model, rl))(
        trainer.init_state(params), batch)
    s_spec, m_spec = jax.jit(
        trainer.make_actor_step(model, rl, algorithm=get_algorithm("grpo")))(
        trainer.init_state(params), batch)
    for a, b in zip(jax.tree.leaves(s_named.params), jax.tree.leaves(s_spec.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m_named["loss"]) == float(m_spec["loss"])
