"""Telemetry subsystem tests (repro.obs; docs/observability.md).

Covers the tracer (null-object fast path, ring buffer, Chrome-trace schema,
thread safety), the metrics registry (bitwise flat-dict back-compat, exact
cross-host histogram merge, quantile accuracy vs numpy), the sinks (stdout
byte-compatibility with the historical train line, JSONL, in-memory), the
worker instrumentation (time/+error/ on a raising stage), fleet snapshot
aggregation + the straggler report, the launch flags, and the ci.sh chunk-
time emission. Property-test versions of the histogram laws live in
tests/test_obs_hypothesis.py (optional dep)."""
import json
import os
import pathlib
import subprocess
import threading

import numpy as np
import pytest

from repro.obs import (
    JSONLSink,
    MemorySink,
    MetricsRegistry,
    StdoutSink,
    Tracer,
    exponential_boundaries,
    get_tracer,
    iteration_record,
    set_tracer,
)
from repro.obs.aggregate import (
    collect_snapshots,
    merge_traces,
    render_report,
    straggler_report,
)
from repro.obs.metrics import Histogram
from repro.obs.trace import NULL_TRACER, _NULL_SPAN

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _isolated_tracer():
    """Every test starts from the disabled global tracer and restores it."""
    prev = set_tracer(None)
    yield
    set_tracer(prev)


# --------------------------------------------------------------------- #
# tracer: null-object path + overhead
# --------------------------------------------------------------------- #
def test_disabled_tracer_is_null_object():
    t = Tracer(enabled=False)
    sp = t.span("x", cat="dag", k=1)
    assert sp is _NULL_SPAN
    with sp as s:
        s.set(error=1)  # no-op, no raise
    t.instant("i")
    assert t.num_events == 0
    assert get_tracer() is NULL_TRACER  # module default is disabled


def test_disabled_tracer_overhead_is_negligible():
    """Acceptance: obs disabled adds no measurable overhead. 100k no-op
    spans must stay comfortably under 10us each even on a loaded CI box
    (the real cost is ~100ns: one method call + a singleton return)."""
    import time

    t = Tracer(enabled=False)
    n = 100_000
    t0 = time.perf_counter()
    for _ in range(n):
        with t.span("node/train", cat="dag", node="train"):
            pass
    per_op = (time.perf_counter() - t0) / n
    assert per_op < 10e-6, f"{per_op * 1e6:.2f}us per disabled span"


# --------------------------------------------------------------------- #
# tracer: recording, ring buffer, chrome export
# --------------------------------------------------------------------- #
def test_span_nesting_and_chrome_schema(tmp_path):
    t = Tracer(enabled=True, host=3)
    with t.span("outer", cat="dag", node="gen"):
        with t.span("inner", cat="rollout", lanes=4):
            pass
    t.instant("tick", cat="dag", it=0)
    assert t.num_events == 3

    path = tmp_path / "trace.json"
    t.export_chrome(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        assert e["ph"] in ("X", "M", "i")
        assert e["pid"] == 3
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert set(xs) == {"outer", "inner"}
    # inner completes first but is nested within outer's interval
    assert xs["outer"]["ts"] <= xs["inner"]["ts"]
    assert xs["outer"]["ts"] + xs["outer"]["dur"] >= (
        xs["inner"]["ts"] + xs["inner"]["dur"])
    assert xs["inner"]["args"]["lanes"] == 4
    inst = [e for e in evs if e["ph"] == "i"]
    assert inst and inst[0]["s"] == "p"
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert "host3" in names  # per-host process track
    # one thread track per category
    cats = {e.get("cat") for e in evs if e["ph"] == "X"}
    assert {"dag", "rollout"} <= (names | cats)


def test_ring_buffer_wraparound_drops_oldest():
    t = Tracer(enabled=True, capacity=4)
    for i in range(10):
        with t.span(f"s{i}", cat="dag"):
            pass
    assert t.num_events == 4  # retained = min(total, capacity)
    assert t.dropped == 6
    kept = [e["name"] for e in t.to_events()]
    assert kept == ["s6", "s7", "s8", "s9"]  # oldest-first after wrap


def test_tracer_thread_safety():
    t = Tracer(enabled=True, capacity=1 << 15)
    nthreads, per = 8, 500

    def work(k):
        for i in range(per):
            with t.span(f"t{k}/{i}", cat="dag"):
                pass

    ts = [threading.Thread(target=work, args=(k,)) for k in range(nthreads)]
    [th.start() for th in ts]
    [th.join() for th in ts]
    assert t.num_events == nthreads * per
    assert t.dropped == 0
    assert len(t.to_events()) == nthreads * per


def test_set_tracer_save_restore():
    mine = Tracer(enabled=True)
    prev = set_tracer(mine)
    assert get_tracer() is mine
    set_tracer(prev)
    assert get_tracer() is not mine


# --------------------------------------------------------------------- #
# metrics registry
# --------------------------------------------------------------------- #
def test_registry_flat_dict_is_bitwise_identity():
    """Acceptance: every existing metrics key survives the registry
    round-trip bitwise. Gauges store values verbatim — including numpy
    scalars and awkward floats — so as_flat_dict() == the input dict."""
    metrics = {
        "actor/loss": 0.1 + 0.2,  # 0.30000000000000004 — must not re-round
        "rollout/tokens": np.float32(16.0),
        "time/train": 1e-9,
        "reward/mean": -0.0,
    }
    reg = MetricsRegistry()
    reg.record_dict(metrics)
    flat = reg.as_flat_dict()
    assert flat == metrics
    for k in metrics:
        assert repr(flat[k]) == repr(metrics[k])  # bitwise, not just ==


def test_registry_counter_and_histogram_keys():
    reg = MetricsRegistry()
    reg.counter("requests").inc()
    reg.counter("requests").inc(2)
    h = reg.histogram("lat_s", boundaries=[1.0, 2.0, 3.0])
    for v in (0.5, 1.5, 2.5, 3.5):
        h.record(v)
    flat = reg.as_flat_dict()
    assert flat["requests"] == 3.0
    assert flat["lat_s/count"] == 4.0
    assert flat["lat_s/mean"] == pytest.approx(2.0)
    assert flat["lat_s/p50"] == pytest.approx(1.5)


def test_histogram_merge_equals_concatenation():
    """The law that makes cross-host aggregation exact: quantiles are a
    pure function of (boundaries, counts, min, max), so merging per-host
    histograms gives IDENTICAL quantiles to one histogram fed everything."""
    rng = np.random.default_rng(7)
    samples = rng.lognormal(mean=-2.0, sigma=1.5, size=3000)
    parts = np.array_split(samples, 3)
    merged = Histogram("h")
    for part in parts:
        h = Histogram("h")
        for v in part:
            h.record(float(v))
        merged.merge(h)
    single = Histogram("h")
    for v in samples:
        single.record(float(v))
    for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
        assert merged.quantile(q) == single.quantile(q)  # exact, not approx
    assert merged.count == single.count == len(samples)
    assert merged.sum == pytest.approx(single.sum)


def test_histogram_merge_rejects_mismatched_boundaries():
    a = Histogram("a", boundaries=[1.0, 2.0])
    b = Histogram("b", boundaries=[1.0, 3.0])
    with pytest.raises(ValueError):
        a.merge(b)


def test_histogram_quantile_within_one_bucket_of_numpy():
    """Dense uniform data: interpolated p50/p99 land within one bucket
    width of numpy's exact (linear-interpolation) quantile."""
    rng = np.random.default_rng(0)
    samples = rng.uniform(0.0, 10.0, size=10_000)
    bounds = list(np.linspace(0.0, 10.0, 101))  # width 0.1
    h = Histogram("u", boundaries=bounds)
    for v in samples:
        h.record(float(v))
    width = bounds[1] - bounds[0]
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(samples, q))
        assert abs(h.quantile(q) - exact) <= width + 1e-9


def test_histogram_quantile_within_one_bucket_of_numpy_lower_sparse():
    """Adversarial sparse data: the one-bucket-width bound holds against
    numpy's method="lower" (the order-statistic the counts actually
    locate; linear interpolation can jump a whole gap between clusters)."""
    samples = np.array([0.0, 0.0, 0.0, 10.0])
    bounds = list(np.linspace(0.0, 10.0, 11))  # width 1.0
    h = Histogram("s", boundaries=bounds)
    for v in samples:
        h.record(float(v))
    for q in (0.5, 0.75, 0.99):
        exact = float(np.quantile(samples, q, method="lower"))
        assert abs(h.quantile(q) - exact) <= 1.0 + 1e-9


def test_histogram_empty_and_clamping():
    h = Histogram("e", boundaries=[1.0, 2.0])
    assert h.quantile(0.5) == 0.0
    h.record(5.0)  # overflow bucket: clamped to observed max
    assert h.quantile(0.99) == 5.0
    assert h.quantile(0.0) == 5.0


def test_histogram_serialization_roundtrip():
    h = Histogram("h", boundaries=[1.0, 2.0])
    for v in (0.5, 1.5, 1.6, 2.5):
        h.record(v)
    h2 = Histogram.from_dict(h.to_dict())
    assert h2.quantile(0.5) == h.quantile(0.5)
    assert h2.count == h.count
    reg = MetricsRegistry()
    reg.counter("c").inc(3)
    reg.record_dict({"g": 1.25})
    reg.histogram("h", boundaries=[1.0, 2.0]).record(1.5)
    reg2 = MetricsRegistry.from_dict(reg.to_dict())
    assert reg2.as_flat_dict() == reg.as_flat_dict()


def test_exponential_boundaries_shape():
    b = exponential_boundaries(1e-3, 1e3, 60)
    assert len(b) == 60
    assert b[0] == pytest.approx(1e-3)
    assert b[-1] == pytest.approx(1e3)
    assert all(x < y for x, y in zip(b, b[1:]))


# --------------------------------------------------------------------- #
# sinks
# --------------------------------------------------------------------- #
def test_stdout_sink_byte_compatible(capsys):
    """Acceptance: the default train line is byte-for-byte the historical
    format (time/* keys stripped, 4-decimal rounding, compact separators
    from json.dumps defaults)."""
    metrics = {"actor/loss": 0.123456, "rollout/tokens": 16.0,
               "time/train": 0.5, "reward/mean": -0.0}
    StdoutSink().emit_iteration(7, metrics, 1.234)
    got = capsys.readouterr().out
    keep = {k: round(v, 4) for k, v in metrics.items()
            if not k.startswith("time/")}
    expected = f"[train] it=7 {1.234:.2f}s {json.dumps(keep)}\n"
    assert got == expected


def test_jsonl_sink_and_iteration_record(tmp_path):
    path = tmp_path / "m.jsonl"
    with JSONLSink(str(path)) as sink:
        sink.write(iteration_record(0, {"a": 1.0, "time/x": 0.1}, 0.5))
        sink.write({"kind": "ci_chunk", "chunk": "c1", "wall_s": 2.0})
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == 2
    assert lines[0]["kind"] == "iteration"
    assert lines[0]["iteration"] == 0
    assert lines[0]["metrics"] == {"a": 1.0, "time/x": 0.1}
    assert lines[1]["chunk"] == "c1"


def test_jsonl_sink_never_opens_unwritten(tmp_path):
    path = tmp_path / "sub" / "m.jsonl"
    sink = JSONLSink(str(path))
    sink.close()  # no write -> no file, no crash on missing parent dir
    assert not path.exists()


def test_memory_sink():
    s = MemorySink()
    s.write({"a": 1})
    s.write({"b": 2})
    assert s.records == [{"a": 1}, {"b": 2}]


# --------------------------------------------------------------------- #
# worker instrumentation: time/ + error/ on a raising stage
# --------------------------------------------------------------------- #
def _bare_worker():
    from repro.configs.base import DataCoordinatorConfig
    from repro.core.worker import DAGWorker

    w = object.__new__(DAGWorker)
    w.coordinator = DataCoordinatorConfig()
    w.buffer = None
    w.ctx = None
    return w


def test_execute_node_records_time_and_error_on_failure():
    """Regression (ISSUE 10 satellite): a raising stage must still record
    time/{node_id}, flag error/{node_id}=1, tag the span, and re-raise."""
    from repro.core.dag import Node, NodeType, Role

    t = Tracer(enabled=True)
    set_tracer(t)
    w = _bare_worker()
    node = Node(node_id="boom", role=Role.ACTOR, type=NodeType.COMPUTE)

    def fn(ctx, buf, node):
        raise RuntimeError("stage exploded")

    metrics = {}
    with pytest.raises(RuntimeError, match="stage exploded"):
        w.execute_node(node, fn, metrics)
    assert metrics["error/boom"] == 1.0
    assert metrics["time/boom"] >= 0.0
    (ev,) = t.to_events()
    assert ev["name"] == "node/boom"
    assert ev["args"]["error"] == 1


def test_execute_node_success_has_no_error_key():
    from repro.core.dag import Node, NodeType, Role

    w = _bare_worker()
    node = Node(node_id="ok", role=Role.ACTOR, type=NodeType.COMPUTE)
    metrics = {}
    w.execute_node(node, lambda c, b, n: {"x": 1.0}, metrics)
    assert metrics["x"] == 1.0
    assert "time/ok" in metrics
    assert not any(k.startswith("error/") for k in metrics)


# --------------------------------------------------------------------- #
# config + spec plumbing
# --------------------------------------------------------------------- #
def test_obs_config_validation():
    from repro.configs.base import ObsConfig

    with pytest.raises(ValueError):
        ObsConfig(ring_capacity=0)
    assert not ObsConfig().enabled  # off by default


def test_experiment_spec_obs_roundtrip_and_legacy():
    from repro.api import ExperimentSpec
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ObsConfig

    spec = ExperimentSpec(model=reduced(ARCHS["qwen2.5-7b"], vocab_size=260),
                          obs=ObsConfig(enabled=True, ring_capacity=128))
    d = spec.to_dict()
    back = ExperimentSpec.from_dict(d)
    assert back.obs == spec.obs
    legacy = spec.to_dict()
    del legacy["obs"]  # pre-obs spec dicts must still load
    assert ExperimentSpec.from_dict(legacy).obs == ObsConfig()


# --------------------------------------------------------------------- #
# pipeline integration: disabled obs is bitwise inert; enabled records
# --------------------------------------------------------------------- #
def _tiny_pipe(obs=None, seed=0):
    from repro.configs import ARCHS, reduced
    from repro.core import build_pipeline
    from repro.rl import RLConfig

    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260, num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                  d_ff=128)
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4, lr=1e-4)
    return build_pipeline(cfg, rl, prompts_per_iter=2, seed=seed, obs=obs)


def test_pipeline_obs_disabled_metrics_bitwise_unchanged():
    """Acceptance: with obs disabled (the default), iteration metrics are
    bitwise identical to a build that never heard of obs, and the global
    tracer stays the null tracer."""
    from repro.configs.base import ObsConfig

    m_off = _tiny_pipe(obs=None, seed=3).worker.run_iteration()
    assert get_tracer() is NULL_TRACER
    m_cfg = _tiny_pipe(obs=ObsConfig(enabled=False), seed=3).worker.run_iteration()
    assert get_tracer() is NULL_TRACER
    assert set(m_off) == set(m_cfg)
    for k in m_off:
        if k.startswith("time/"):
            continue  # wall times differ run to run by construction
        assert float(m_off[k]) == float(m_cfg[k]), k


def test_pipeline_obs_enabled_traces_and_registers(tmp_path):
    from repro.configs.base import ObsConfig

    pipe = _tiny_pipe(obs=ObsConfig(enabled=True), seed=3)
    assert pipe.ctx.obs is not None
    metrics = pipe.worker.run_iteration()
    # every stage produced a dag span
    names = {e["name"] for e in pipe.ctx.obs.tracer.to_events()}
    assert any(n.startswith("node/") for n in names)
    # run_iteration fed the registry: flat dict reproduces metrics bitwise
    assert pipe.ctx.obs.registry.as_flat_dict() == metrics
    path = tmp_path / "t.json"
    pipe.ctx.obs.tracer.export_chrome(str(path))
    doc = json.loads(path.read_text())
    assert len(doc["traceEvents"]) > 0


# --------------------------------------------------------------------- #
# launch flags: --obs-trace / --obs-metrics
# --------------------------------------------------------------------- #
def test_train_main_obs_flags(tmp_path, capsys):
    from repro.launch import train

    trace = tmp_path / "trace.json"
    mpath = tmp_path / "metrics.jsonl"
    train.main(["--smoke", "--iters", "2", "--prompts-per-iter", "2",
                "--group-size", "2", "--max-new-tokens", "4",
                "--obs-trace", str(trace), "--obs-metrics", str(mpath)])
    out = capsys.readouterr().out
    assert "[train] it=0 " in out  # historical line format intact
    doc = json.loads(trace.read_text())
    cats = {e.get("cat") for e in doc["traceEvents"] if e["ph"] == "X"}
    assert "dag" in cats
    recs = [json.loads(l) for l in mpath.read_text().splitlines()]
    assert [r["iteration"] for r in recs] == [0, 1]
    assert all(r["kind"] == "iteration" for r in recs)
    assert "train/step_s" not in recs[0]["metrics"]  # hist, not a gauge


# --------------------------------------------------------------------- #
# serving latency recording
# --------------------------------------------------------------------- #
def test_record_stream_latency():
    from repro.serving.scheduler import (Request, RequestStream,
                                         record_stream_latency)

    req = Request(rid=1, prompt=[1, 2, 3], max_new=8, arrival=10.0)
    st = RequestStream(req)
    st.append([5], when=10.5, version=0)
    st.append([6, 7], when=11.5, version=0)
    st.finish("eos")
    reg = MetricsRegistry()
    record_stream_latency(reg, st)
    assert reg.histogram("serving/ttft_s").count == 1
    assert reg.histogram("serving/ttft_s").sum == pytest.approx(0.5)
    assert reg.histogram("serving/tpot_s").sum == pytest.approx(0.5)

    rej = RequestStream(Request(rid=2, prompt=[1], max_new=4))
    rej.finish("rejected")
    record_stream_latency(reg, rej)  # rejected: not a latency sample
    assert reg.histogram("serving/ttft_s").count == 1
    record_stream_latency(None, st)  # registry=None is a no-op


# --------------------------------------------------------------------- #
# fleet snapshots + straggler aggregation
# --------------------------------------------------------------------- #
def _publish_synthetic_fleet(tmp_path, host_times):
    """Two FleetContexts over one coordinator dir, publishing per-iteration
    metrics whose time/* sums are the given per-host step times."""
    from repro.configs.base import DistributedConfig
    from repro.distributed.fleet import FleetContext

    coord = str(tmp_path / "coord")
    for h, steps in host_times.items():
        ctx = FleetContext(DistributedConfig(
            num_hosts=max(2, len(host_times)), process_id=h,
            coordinator=coord))
        for it, t in enumerate(steps):
            ctx.publish_metrics(it, {
                "time/generate": t * 0.75,
                "time/train": t * 0.25,
                "actor/loss": 0.5 - 0.01 * it,
            })
    return coord


def test_fleet_snapshot_aggregation_and_straggler_report(tmp_path):
    # host1 is the 2x straggler every iteration
    coord = _publish_synthetic_fleet(
        tmp_path, {0: [1.0, 1.2, 1.1], 1: [2.0, 2.4, 2.2]})
    snaps = collect_snapshots(coord)
    assert sorted(snaps) == [0, 1]
    assert sorted(snaps[0]) == [0, 1, 2]

    report = straggler_report(snaps)
    assert report["hosts"] == [0, 1]
    assert report["slowest_host"] == 1
    assert report["per_host"][1]["total_s"] == pytest.approx(6.6)
    assert report["per_host"][0]["slowest_node"] == "generate"
    it0 = report["per_iteration"][0]
    assert it0["slowest_host"] == 1
    assert it0["max_s"] == pytest.approx(2.0)
    assert it0["skew"] == pytest.approx(2.0 / 1.5)
    assert report["step_hist"]["count"] == 6
    assert report["max_skew"] >= 1.0

    text = render_report(report)
    assert "per-host summary" in text
    assert "host0" in text and "host1" in text
    assert "fleet step-time p50" in text


def test_snapshot_sum_matches_hosts_own_metrics(tmp_path):
    """Acceptance: the straggler table's per-host step time sums to the
    hosts' own time/* metrics exactly (the snapshot is the metrics dict)."""
    host_times = {0: [0.5, 0.7], 1: [0.9, 0.3]}
    coord = _publish_synthetic_fleet(tmp_path, host_times)
    report = straggler_report(collect_snapshots(coord))
    for h, steps in host_times.items():
        for it, t in enumerate(steps):
            assert report["per_host"][h]["step_times"][it] == pytest.approx(
                t, rel=1e-12)


def test_collect_snapshots_skips_torn_writes(tmp_path):
    coord = _publish_synthetic_fleet(tmp_path, {0: [1.0]})
    torn = pathlib.Path(coord) / "obs" / "host0" / "it000099.json"
    torn.write_text('{"host": 0, "iter')  # partial write
    snaps = collect_snapshots(coord)
    assert sorted(snaps[0]) == [0]  # torn file ignored, good one kept


def test_merge_traces(tmp_path):
    t0 = Tracer(enabled=True, host=0)
    with t0.span("a", cat="dag"):
        pass
    t1 = Tracer(enabled=True, host=1)
    with t1.span("b", cat="fleet"):
        pass
    p0, p1 = tmp_path / "t0.json", tmp_path / "t1.json"
    t0.export_chrome(str(p0))
    t1.export_chrome(str(p1))
    out = tmp_path / "merged.json"
    merged = merge_traces([str(p0), str(p1)], str(out))
    pids = {e["pid"] for e in merged["traceEvents"]}
    assert pids == {0, 1}
    assert json.loads(out.read_text()) == merged


def test_obs_report_cli(tmp_path, capsys):
    from repro.launch import obs_report

    coord = _publish_synthetic_fleet(tmp_path, {0: [1.0], 1: [3.0]})
    obs_report.main(["--coordinator", coord])
    out = capsys.readouterr().out
    assert "per-host summary" in out
    assert "host1" in out
    obs_report.main(["--coordinator", coord, "--json"])
    doc = json.loads(capsys.readouterr().out)
    assert doc["slowest_host"] == 1


# --------------------------------------------------------------------- #
# ci.sh chunk-time JSONL emission
# --------------------------------------------------------------------- #
def test_ci_sh_emits_chunk_times_jsonl(tmp_path):
    good = tmp_path / "test_good.py"
    good.write_text("def test_ok():\n    assert True\n")
    jsonl = tmp_path / "ci_times.jsonl"
    env = dict(os.environ, CI_CHUNKS=str(good), CI_OBS_JSONL=str(jsonl))
    env.pop("PYTHONPATH", None)
    res = subprocess.run(
        ["bash", str(REPO / "scripts" / "ci.sh")],
        capture_output=True, text=True, env=env, cwd=REPO,
    )
    assert res.returncode == 0, res.stdout + res.stderr
    assert "[ci] chunk times ->" in res.stdout
    recs = [json.loads(l) for l in jsonl.read_text().splitlines()]
    assert [r["chunk"] for r in recs] == ["chunk1"]
    assert recs[0]["kind"] == "ci_chunk"
    assert recs[0]["wall_s"] >= 0.0


# --------------------------------------------------------------------- #
# benchmarks/report.py obs table over the committed sample trace
# --------------------------------------------------------------------- #
def test_report_obs_table_renders_sample_trace():
    from benchmarks import report as bench_report

    table = bench_report.obs_table()
    assert "| host | subsystem | spans | busy ms |" in table
    assert "host0" in table and "dag" in table
