"""Hypothesis-driven shape/dtype sweeps for the Pallas kernels (interpret
mode vs ref oracles): randomized GQA geometry, block sizes, cache fills."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dep: pip install '.[test]' to run these"
)
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as da_pallas
from repro.kernels.decode_attention import decode_attention_quant as daq_pallas
from repro.kernels.flash_attention import flash_attention as fa_pallas
from repro.kernels.ssd import ssd as ssd_pallas

settings.register_profile("kernels", max_examples=12, deadline=None)
settings.load_profile("kernels")


@st.composite
def attn_geometry(draw):
    kvh = draw(st.sampled_from([1, 2, 4]))
    group = draw(st.sampled_from([1, 2, 4]))
    d = draw(st.sampled_from([16, 32, 64]))
    n_blocks = draw(st.integers(2, 4))
    block = draw(st.sampled_from([32, 64]))
    causal_extra = draw(st.booleans())
    return kvh, kvh * group, d, n_blocks * block, block, causal_extra


@given(attn_geometry(), st.integers(0, 2**31 - 1))
def test_flash_attention_random_geometry(geo, seed):
    kvh, h, d, s, block, use_window = geo
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, s, kvh, d), jnp.float32)
    window = (s // 2) if use_window else None
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    got = fa_pallas(q, k, v, causal=True, window=window,
                    block_q=block, block_k=block, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


@given(attn_geometry(), st.integers(0, 2**31 - 1), st.data())
def test_decode_attention_random_geometry(geo, seed, data):
    kvh, h, d, s, block, _ = geo
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, s, kvh, d), jnp.float32)
    cl = jnp.asarray(
        [data.draw(st.integers(1, s)) for _ in range(B)], jnp.int32)
    o_r, l_r = ref.decode_attention(q, k, v, cl, return_lse=True)
    o_p, l_p = da_pallas(q, k, v, cl, block_s=block, interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(l_p), np.asarray(l_r),
                               atol=1e-3, rtol=1e-3)


@given(attn_geometry(), st.integers(0, 2**31 - 1), st.data())
def test_ragged_decode_fetch_skip_random_geometry(geo, seed, data):
    """Property (the ragged fetch-skip): over randomized (B, S, kv_len,
    window, group), the grid-truncated kernel equals the full-sweep oracle.
    kv_len draws are edge-biased — 1 (one live slot: every later tile is a
    dead step) and S (no dead tiles: the clamp must be the identity) are
    always in the strategy."""
    kvh, h, d, s, block, use_window = geo
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B = 3
    q = jax.random.normal(ks[0], (B, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, s, kvh, d), jnp.float32)
    edge = st.one_of(st.just(1), st.just(s), st.integers(1, s))
    cl = jnp.asarray([data.draw(edge) for _ in range(B)], jnp.int32)
    window = data.draw(st.sampled_from([None, block, s // 2])) \
        if use_window else None
    o_r, l_r = ref.decode_attention(q, k, v, cl, window=window,
                                    return_lse=True)
    o_p, l_p = da_pallas(q, k, v, cl, window=window, block_s=block,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(l_p), np.asarray(l_r),
                               atol=1e-3, rtol=1e-3)


@given(st.sampled_from([1, 2, 4]),   # kv heads
       st.sampled_from([1, 2, 4]),   # group
       st.sampled_from([8, 16]),     # page size
       st.integers(2, 6),            # pages per sequence
       st.integers(0, 2**31 - 1), st.data())
def test_paged_decode_random_tables(kvh, group, ps, t, seed, data):
    """Property (the paged gather): for any scrambled block table over a
    pool with unowned garbage pages, the table-gather kernel equals the
    contiguous-cache oracle on the owned span."""
    from repro.kernels.decode_attention import (
        paged_decode_attention as pda_pallas,
    )

    d, B = 32, 2
    s = t * ps
    h = kvh * group
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, s, kvh, d), jnp.float32)
    P = B * t + 3
    perm = np.random.default_rng(seed & 0xFFFF).permutation(P)[: B * t]
    tables = jnp.asarray(perm.reshape(B, t).astype(np.int32))
    pool_k = jax.random.normal(ks[3], (P, ps, kvh, d), jnp.float32)
    pool_v = jax.random.normal(jax.random.fold_in(ks[3], 1),
                               (P, ps, kvh, d), jnp.float32)
    pool_k = pool_k.at[perm].set(k.reshape(B * t, ps, kvh, d))
    pool_v = pool_v.at[perm].set(v.reshape(B * t, ps, kvh, d))
    edge = st.one_of(st.just(1), st.just(s), st.integers(1, s))
    cl = jnp.asarray([data.draw(edge) for _ in range(B)], jnp.int32)
    o_r, l_r = ref.decode_attention(q, k, v, cl, return_lse=True)
    o_p, l_p = pda_pallas(q, pool_k, pool_v, tables, cl, interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(l_p), np.asarray(l_r),
                               atol=1e-3, rtol=1e-3)


@given(attn_geometry(), st.integers(0, 2**31 - 1), st.data())
def test_decode_attention_quant_random_geometry(geo, seed, data):
    """Fused int8-dequant decode kernel vs the dequantize-up-front oracle
    over random GQA geometry and per-slot cache fills."""
    from repro.models.lm import quant_kv

    kvh, h, d, s, block, _ = geo
    ks_ = jax.random.split(jax.random.PRNGKey(seed), 3)
    B = 2
    q = jax.random.normal(ks_[0], (B, h, d), jnp.float32)
    kq, kscale = quant_kv(jax.random.normal(ks_[1], (B, s, kvh, d), jnp.bfloat16))
    vq, vscale = quant_kv(jax.random.normal(ks_[2], (B, s, kvh, d), jnp.bfloat16))
    cl = jnp.asarray(
        [data.draw(st.integers(1, s)) for _ in range(B)], jnp.int32)
    o_r, l_r = ref.decode_attention_quant(
        q, kq, vq, kscale, vscale, cl, return_lse=True)
    o_p, l_p = daq_pallas(q, kq, vq, kscale, vscale, cl, block_s=block,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(l_p), np.asarray(l_r),
                               atol=1e-2, rtol=1e-2)


@given(
    st.sampled_from([2, 4]),       # heads
    st.sampled_from([8, 16, 32]),  # head dim P
    st.sampled_from([1, 2]),       # groups
    st.sampled_from([8, 16]),      # state N
    st.integers(2, 4),             # chunks
    st.integers(0, 2**31 - 1),
)
def test_ssd_random_geometry(nh, p, g, n, nc, seed):
    if nh % g:
        return
    chunk = 32
    s = nc * chunk
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (2, s, nh, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (2, s, g, n))
    Cm = jax.random.normal(ks[4], (2, s, g, n))
    D = jax.random.normal(ks[5], (nh,))
    y_r, h_r = ref.ssd_scan(x, dt, A, Bm, Cm, D, return_state=True)
    y_p, h_p = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r),
                               atol=2e-3, rtol=2e-3)


@st.composite
def reward_rollouts(draw):
    """Randomized well-formed rollouts for the math reward: per row an
    answer in [0, 198] (the synthetic task's range) and a response that is
    1-4 decimal digits followed by EOS — the correct answer, a digit-prefix
    corruption, or unrelated digits. Both reward implementations define
    their contract on exactly this EOS-terminated shape (a budget-truncated
    response with no EOS is scored exact-match by the host path but not by
    the token path — deliberately out of contract)."""
    B = draw(st.integers(1, 6))
    rows = []
    for _ in range(B):
        answer = draw(st.integers(0, 198))
        kind = draw(st.sampled_from(["exact", "prefix", "random"]))
        if kind == "exact":
            digits = str(answer)
        elif kind == "prefix":
            digits = str(answer)[: draw(st.integers(1, 3))] + draw(
                st.text("0123456789", min_size=0, max_size=2))
        else:
            digits = draw(st.text("0123456789", min_size=1, max_size=4))
        rows.append((answer, digits))
    return rows


@given(reward_rollouts(), st.integers(0, 2**31 - 1))
def test_math_reward_host_and_token_paths_agree(rows, seed):
    """Property (PR-5 satellite): the host-side ``math_reward`` and the
    jitted ``math_reward_tokens`` agree on every randomized EOS-terminated
    rollout — exact matches, digit-prefix partial credit, and misses."""
    from repro.data.tokenizer import ByteTokenizer
    from repro.rl.reward import math_reward, math_reward_tokens

    tok = ByteTokenizer()
    rng = np.random.default_rng(seed)
    B = len(rows)
    Lp, T = 6, 6  # prompt width, response budget (4 digits + EOS fits)
    L = Lp + T
    tokens = np.zeros((B, L), np.int32)
    mask = np.zeros((B, L), bool)
    answers = np.zeros(B, np.int32)
    texts = []
    for b, (answer, digits) in enumerate(rows):
        answers[b] = answer
        tokens[b, :Lp] = rng.integers(3, 200, Lp)  # arbitrary prompt bytes
        resp = np.concatenate([tok.encode(digits), [tok.eos_id]])
        tokens[b, Lp: Lp + len(resp)] = resp
        mask[b, Lp: Lp + len(resp)] = True
        texts.append(digits)
    want = math_reward(texts, answers)
    got = math_reward_tokens(
        jnp.asarray(tokens), jnp.asarray(mask), jnp.asarray(answers), tok)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-6)


# --------------------------------------------------------------------------- #
# serving: radix prefix-cache properties (host structure; no kernels, but the
# same optional-hypothesis harness)
# --------------------------------------------------------------------------- #
@st.composite
def radix_ops(draw):
    ps = draw(st.sampled_from([2, 4]))
    n_ops = draw(st.integers(1, 25))
    ops = []
    for _ in range(n_ops):
        kind = draw(st.sampled_from(
            ["insert", "insert", "match", "evict", "pin_evict"]))
        seq = draw(st.lists(st.integers(0, 3), min_size=1,
                            max_size=3 * ps + 1))
        ops.append((kind, tuple(seq)))
    return ps, ops


@given(radix_ops())
def test_radix_prefix_cache_properties(ops):
    """Random op streams against a brute-force mirror of the live
    page-aligned prefixes: `match` must return the LONGEST live page-aligned
    strict prefix (and its exact page ids), refcounts stay >= 0, pinned
    paths survive full eviction, and the trie's structural invariants hold
    after every operation."""
    from repro.serving import RadixPrefixCache

    ps, op_list = ops
    cache = RadixPrefixCache(page_size=ps)
    live = {}  # path (tuple of page-tuples) -> page id
    next_id = [0]

    def pages_of(seq):
        return tuple(tuple(seq[i * ps:(i + 1) * ps])
                     for i in range(len(seq) // ps))

    def ref_match(seq):
        limit = max(0, len(seq) - 1) // ps
        pgs = pages_of(seq)
        for k in range(limit, 0, -1):
            if pgs[:k] in live:
                return k * ps, [live[pgs[:i + 1]] for i in range(k)]
        return 0, []

    def drop_freed(freed):
        rev = {v: k for k, v in live.items()}
        for pid in freed:
            del live[rev[pid]]

    for kind, seq in op_list:
        if kind == "insert":
            path = pages_of(seq)

            def make_page(p):
                pid = next_id[0]
                next_id[0] += 1
                live[path[: p + 1]] = pid
                return pid

            cache.insert(seq, make_page)
        elif kind == "match":
            got_m, got_ids = cache.match(seq)
            want_m, want_ids = ref_match(seq)
            assert got_m == want_m, "not the longest live prefix"
            assert got_ids == want_ids, "wrong page ids for the match"
            assert got_m <= max(0, len(seq) - 1), "full-prompt match leaked"
        elif kind == "evict":
            before = cache.num_pages
            freed = cache.evict(1)
            drop_freed(freed)
            assert cache.num_pages == before - len(freed)
        else:  # pin_evict: pinned paths survive a full eviction sweep
            m, ids = cache.acquire(seq)
            freed = cache.evict(cache.num_pages)
            drop_freed(freed)
            assert not set(freed) & set(ids), "evicted a pinned page"
            again_m, again_ids = cache.match(seq)
            assert (again_m, again_ids) == (m, ids), \
                "pinned path lost by eviction"
            cache.release(seq, m)
        for n in cache._all_nodes():
            assert n.refcount >= 0, "negative refcount"
        cache.check_invariants()
        assert cache.num_pages == len(live), "mirror drifted from trie"


# --------------------------------------------------------------------------- #
# distributed: gradient-compression wire-format properties (the int8_ef
# exchange of repro.distributed.fleet.GradExchange)
# --------------------------------------------------------------------------- #
@given(st.integers(1, 700), st.integers(1, 5), st.integers(0, 2**31 - 1))
def test_compression_ef_telescoping(n, rounds, seed):
    """Error feedback telescopes: across T rounds, sum(decoded) ==
    sum(gradients) - final_residual (each round's quantization error is
    carried, never lost), and the residual stays bounded by one round's
    block scale — the property that makes the low-bit exchange trainable."""
    from repro.distributed import compression

    rng = np.random.default_rng(seed)
    err = None
    dec_sum = np.zeros(n, np.float64)
    g_sum = np.zeros(n, np.float64)
    last_target_max = 0.0
    for _ in range(rounds):
        g = (rng.standard_normal(n) * rng.uniform(0.1, 10.0)).astype(
            np.float32)
        last_target_max = float(
            np.abs(g.astype(np.float64) + (0 if err is None
                                           else np.asarray(err))).max())
        q, scale, err = compression.encode(jnp.asarray(g), err)
        dec_sum += np.asarray(compression.decode(q, scale, (n,), n),
                              np.float64)
        g_sum += g
    scale_mag = max(np.abs(g_sum).max(), 1.0)
    np.testing.assert_allclose(dec_sum + np.asarray(err), g_sum,
                               atol=1e-4 * scale_mag)
    # residual never exceeds half an lsb of the last round's quantization
    assert np.abs(np.asarray(err)).max() <= last_target_max / 254.0 + 1e-6


@given(st.integers(1, 700), st.integers(0, 2**31 - 1))
def test_ef_update_conserves_signal(n, seed):
    """One ef_update round: decoded + new_error == grad + carried_error (to
    fp32 rounding) — compression loses nothing, it only defers."""
    from repro.distributed.compression import ef_update

    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    e = jnp.asarray((rng.standard_normal(n) * 0.01).astype(np.float32))
    decoded, new_err = ef_update(g, e)
    np.testing.assert_allclose(
        np.asarray(decoded) + np.asarray(new_err),
        np.asarray(g) + np.asarray(e), rtol=1e-6, atol=1e-6)


@given(st.integers(1, 2000), st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_bounded_by_block_scale(n, seed):
    """Per element: |x - dequant(quant(x))| <= its block's scale / 2 (round-
    to-nearest int8 with per-block max/127 scales), including blocks of
    zeros (scale 0 -> exact) and heavy-tailed magnitudes across blocks."""
    from repro.distributed.compression import BLOCK, _dequantize, _quantize

    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n)
         * 10.0 ** rng.integers(-3, 4, size=n)).astype(np.float32)
    if n > BLOCK:  # force an all-zero block now and then
        x[:BLOCK] *= rng.integers(0, 2)
    q, scale = _quantize(jnp.asarray(x))
    y = np.asarray(_dequantize(q, scale, (n,), n))
    err = np.abs(x - y)
    s = np.asarray(scale).ravel()
    for b in range(len(s)):
        blk = err[b * BLOCK:(b + 1) * BLOCK]
        if blk.size:
            assert blk.max() <= s[b] / 2 + 1e-7 * max(s[b], 1.0), (b, s[b])


@given(st.integers(1, 3000),
       st.sampled_from(["float32", "bfloat16", "float16"]),
       st.integers(0, 2**31 - 1))
def test_wire_bytes_exact_for_mixed_dtypes(n, dtype, seed):
    """wire_bytes is byte-exact accounting, not an estimate: `exact` is the
    raw payload at the array's own dtype width, `comp` equals the actual
    nbytes of the int8 blocks + fp32 scales _quantize materializes."""
    from repro.distributed.compression import _quantize, wire_bytes

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32), dtype=dtype)
    exact, comp = wire_bytes(x)
    assert exact == n * x.dtype.itemsize
    q, scale = _quantize(x)
    assert q.dtype == jnp.int8 and scale.dtype == jnp.float32
    assert comp == (q.size * q.dtype.itemsize
                    + scale.size * scale.dtype.itemsize)
    assert comp < exact or n * x.dtype.itemsize <= comp  # tiny arrays may pad
