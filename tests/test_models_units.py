"""Model-layer unit tests: MoE dispatch equivalence, RoPE properties,
causal conv, norms, tokenizer, pattern compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ModelConfig
from repro.data.tokenizer import ByteTokenizer
from repro.models import lm, moe, ssm
from repro.models.layers import apply_norm, init_norm, rope


def moe_cfg(e=4, k=2):
    return reduced(ARCHS["mixtral-8x7b"], num_experts=e, num_experts_per_tok=k,
                   d_model=32, d_ff=16, vocab_size=256)


def test_moe_dense_equals_sparse_dispatch():
    """The GSPMD-friendly dense dispatch and the gather-based top-k dispatch
    must produce identical outputs."""
    cfg = moe_cfg()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    y1, a1 = moe.apply_moe(cfg, p, x)
    y2, a2 = moe.apply_moe_topk_sparse(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(float(a1), float(a2), atol=1e-5)


def test_moe_aux_loss_balanced_router():
    """Uniform router -> aux loss ~= num_experts * E * (1/E)*(1/E) * ... = 1."""
    cfg = moe_cfg(e=4, k=1)
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform logits
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    _, aux = moe.apply_moe(cfg, p, x)
    # density ~uniform over ties -> aux ~ E * sum(1/E * 1/E) = 1
    assert 0.8 < float(aux) < 1.3


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.arange(8)[None, :]
    y = rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # dot products depend only on relative distance
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def score(pq, pk):
        qr = rope(q, jnp.array([[pq]]), 1e4)
        kr = rope(k, jnp.array([[pk]]), 1e4)
        return float(jnp.sum(qr * kr))
    assert score(3, 1) == pytest.approx(score(10, 8), abs=1e-4)


def test_causal_conv_matches_explicit():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 10, 4))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 4))
    y, state = ssm._causal_conv(x, w)
    xp = np.concatenate([np.zeros((2, 2, 4)), np.asarray(x)], axis=1)
    want = sum(xp[:, i:i + 10] * np.asarray(w)[i] for i in range(3))
    np.testing.assert_allclose(np.asarray(y), want, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(x[:, -2:]), atol=1e-6)


def test_causal_conv_streaming_equals_batch():
    """Stepwise conv with carried state == full-sequence conv."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 4))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 4))
    y_full, _ = ssm._causal_conv(x, w)
    state = None
    outs = []
    for t in range(6):
        y_t, state = ssm._causal_conv(x[:, t:t + 1], w, state)
        outs.append(y_t)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full), atol=1e-5)


def test_norms():
    cfg_rms = reduced(ARCHS["deepseek-67b"], d_model=16)
    cfg_ln = dataclasses.replace(cfg_rms, norm_type="layernorm")
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 16)) * 5 + 2
    y_rms = apply_norm(cfg_rms, init_norm(cfg_rms), x)
    y_ln = apply_norm(cfg_ln, init_norm(cfg_ln), x)
    # layernorm removes the mean, rmsnorm does not
    assert abs(float(jnp.mean(y_ln))) < 1e-4
    assert abs(float(jnp.mean(y_rms))) > 1e-2
    np.testing.assert_allclose(
        np.mean(np.square(np.asarray(y_rms, np.float32)), -1), 1.0, rtol=0.05)


def test_pattern_compression():
    assert lm.pattern_length(ARCHS["deepseek-67b"]) == 1
    assert lm.pattern_length(ARCHS["mixtral-8x7b"]) == 1
    assert lm.pattern_length(ARCHS["jamba-v0.1-52b"]) == 8
    assert lm.pattern_length(ARCHS["mamba2-2.7b"]) == 1
    kinds = ARCHS["jamba-v0.1-52b"].layer_kinds()
    assert kinds[4][0] == "attn" and kinds[0][0] == "ssm"
    assert sum(1 for k in kinds if k[0] == "attn") == 4  # 1:7 interleave
    assert sum(1 for k in kinds if k[1] == "moe") == 16


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    for text in ("12+34=", "hello world", "ünïcødé"):
        ids = tok.encode(text, eos=True)
        assert tok.decode(ids) == text
    assert tok.decode(tok.encode("abc")) == "abc"
    assert tok.vocab_size == 259


def test_vocab_padding_exact():
    for arch, cfg in ARCHS.items():
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab - cfg.vocab_size < 256


def test_ring_cache_width():
    cfg = ARCHS["mixtral-8x7b"]
    caches = jax.eval_shape(
        lambda: lm.init_caches(cfg, batch=1, smax=524_288))
    k = caches[0]["k"]
    assert k.shape[2] == 4096  # bounded at the SWA window, not 524288
    cfg2 = ARCHS["deepseek-67b"]
    caches2 = jax.eval_shape(lambda: lm.init_caches(cfg2, batch=1, smax=8192))
    assert caches2[0]["k"].shape[2] == 8192  # full attention keeps smax
