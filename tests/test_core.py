"""DistFlow core behaviour tests: DAG, planner, registry, databuffer,
dataloader — the paper's §4-§6 mechanisms."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (
    DAG,
    DAGPlanner,
    DistributedDatabuffer,
    CentralizedDatabuffer,
    Node,
    NodeType,
    Role,
    default_registry,
    grpo_dag,
    ppo_dag,
    validate_serialization,
)
from repro.core.dag import DAGError
from repro.data.dataloader import DistributedDataloader
from repro.data.dataset import SyntheticMathDataset, SyntheticTextDataset
from repro.utils.jax_compat import make_compat_mesh


def mesh11():
    return make_compat_mesh((1, 1), ("data", "model"))


# --------------------------------------------------------------------------- #
# DAG
# --------------------------------------------------------------------------- #
def test_dag_cycle_detection():
    with pytest.raises(DAGError):
        DAG.from_nodes([
            Node("a", Role.ACTOR, NodeType.COMPUTE, deps=("b",)),
            Node("b", Role.ACTOR, NodeType.COMPUTE, deps=("a",)),
        ])


def test_dag_unknown_dep():
    with pytest.raises(DAGError):
        DAG.from_nodes([Node("a", Role.ACTOR, NodeType.COMPUTE, deps=("zzz",))])


def test_dag_json_roundtrip(tmp_path):
    dag = grpo_dag()
    p = tmp_path / "dag.json"
    p.write_text(dag.to_json())
    dag2 = DAG.from_json(str(p))
    assert set(dag2.nodes) == set(dag.nodes)
    assert dag2.nodes["actor_train"].deps == dag.nodes["actor_train"].deps


def test_dag_spec_and_loads_roundtrip():
    """to_json -> loads and to_spec -> from_spec are verified round-trips,
    including per-node parallelism (no file required)."""
    dag = DAG.from_nodes([
        Node("gen", Role.ACTOR, NodeType.GENERATE,
             parallelism={"dp": 16, "tp": 2}),
        Node("train", Role.ACTOR, NodeType.MODEL_TRAIN, deps=("gen",),
             parallelism={"dp": 4, "tp": 8}),
    ])
    for dag2 in (DAG.loads(dag.to_json()), DAG.from_spec(dag.to_spec())):
        assert set(dag2.nodes) == set(dag.nodes)
        for nid, n in dag.nodes.items():
            m = dag2.nodes[nid]
            assert (m.role, m.type, m.deps, m.parallelism) == (
                n.role, n.type, n.deps, n.parallelism)
    assert dag.to_spec() == DAG.from_spec(dag.to_spec()).to_spec()


def test_dag_from_spec_rejects_malformed():
    import pytest as _pytest

    with _pytest.raises(DAGError, match="nodes"):
        DAG.from_spec({"not_nodes": []})


# --------------------------------------------------------------------------- #
# planner (paper Fig. 4)
# --------------------------------------------------------------------------- #
def test_parallel_nodes_serialized():
    """Two same-depth inference nodes must be chained (only one active)."""
    dag = DAG.from_nodes([
        Node("gen", Role.ACTOR, NodeType.GENERATE),
        Node("inf1", Role.REFERENCE, NodeType.MODEL_INFERENCE, deps=("gen",)),
        Node("inf2", Role.CRITIC, NodeType.MODEL_INFERENCE, deps=("gen",)),
        Node("train", Role.ACTOR, NodeType.MODEL_TRAIN, deps=("inf1", "inf2")),
    ])
    plan = DAGPlanner().plan(dag)
    assert plan.order == ["gen", "inf1", "inf2", "train"]
    assert ("inf1", "inf2") in plan.injected_edges
    assert validate_serialization(plan)


def test_plan_respects_deps_across_levels():
    for dag in (grpo_dag(), ppo_dag()):
        plan = DAGPlanner().plan(dag)
        assert validate_serialization(plan)
        # exactly one node active at a time == chain length equals node count
        assert len(plan.order) == len(dag.nodes)


def test_plan_for_workers_replicates():
    plans = DAGPlanner().plan_for_workers(grpo_dag(), 8)
    assert len(plans) == 8
    assert all(p.order == plans[0].order for p in plans)


def test_registry_resolution_and_extension():
    reg = default_registry()
    for node in grpo_dag().nodes.values():
        assert callable(reg.resolve(node))
    calls = []
    reg.register(Role.REWARD, NodeType.MODEL_INFERENCE,
                 lambda ctx, buf, node: calls.append(node.node_id) or {})
    n = Node("rm", Role.REWARD, NodeType.MODEL_INFERENCE)
    reg.resolve(n)(None, None, n)
    assert calls == ["rm"]
    with pytest.raises(KeyError):
        reg.register(Role.REWARD, NodeType.MODEL_INFERENCE, lambda: None)


def test_registry_miss_lists_keys_and_nearest_match():
    """An unbound (Role, NodeType) lookup names the registered keys and the
    nearest match instead of a bare miss."""
    reg = default_registry()
    n = Node("dn", Role.DATA, NodeType.COMPUTE)  # DATA/COMPUTE is unbound
    with pytest.raises(KeyError) as ei:
        reg.resolve(n)
    msg = str(ei.value)
    assert "dn" in msg
    assert "Registered keys" in msg and "actor/generate" in msg
    assert "Nearest match" in msg  # e.g. reward/compute or advantage/compute


def test_registry_duplicate_register_error_is_actionable():
    reg = default_registry()
    with pytest.raises(KeyError) as ei:
        reg.register(Role.ACTOR, NodeType.GENERATE, lambda *a: {})
    msg = str(ei.value)
    assert "override=True" in msg
    assert "actor_generate" in msg  # names the currently-bound function
    assert "Registered keys" in msg


# --------------------------------------------------------------------------- #
# databuffer (paper Figs. 7-8)
# --------------------------------------------------------------------------- #
def test_databuffer_fast_path_and_redistribution():
    mesh = mesh11()
    buf = DistributedDatabuffer(mesh)
    x = jnp.arange(64.0).reshape(8, 8)
    buf.put("x", x, P("data", None))
    # same spec -> fast path
    y = buf.get("x", P("data", None))
    assert buf.stats.fast_path_hits == 1
    assert buf.stats.redistributions == 0
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    # different spec -> redistribution, value preserved
    z = buf.get("x", P(("data", "model"), None))
    assert buf.stats.redistributions == 1
    np.testing.assert_array_equal(np.asarray(z), np.asarray(x))
    assert buf.stats.bytes_through_controller == 0


def test_centralized_buffer_counts_controller_traffic():
    mesh = mesh11()
    buf = CentralizedDatabuffer(mesh)
    x = jnp.ones((16, 4), jnp.float32)
    buf.put("x", x, P("data", None))
    _ = buf.get("x", P(("data", "model"), None))
    # all-to-one + one-to-all: 2x the array bytes through the controller
    assert buf.stats.bytes_through_controller == 2 * x.size * 4
    assert buf.controller_resident_bytes == x.size * 4


def test_databuffer_clear():
    buf = DistributedDatabuffer(mesh11())
    buf.put("a", jnp.zeros((2,)))
    buf.clear()
    assert buf.keys() == []


# --------------------------------------------------------------------------- #
# distributed dataloader (paper Fig. 6)
# --------------------------------------------------------------------------- #
def test_dataloader_deterministic_and_epoch_shuffled():
    ds = SyntheticTextDataset(128, 16, 256, seed=3)
    mesh = mesh11()
    dl1 = DistributedDataloader(ds, mesh=mesh, global_batch=32, seed=7)
    dl2 = DistributedDataloader(ds, mesh=mesh, global_batch=32, seed=7)
    i1, i2 = dl1.batch_indices(0), dl2.batch_indices(0)
    np.testing.assert_array_equal(i1, i2)  # identical across workers
    # different epochs -> different permutation
    e0 = dl1.batch_indices(0)
    e1 = dl1.batch_indices(len(ds) // 32)
    assert not np.array_equal(e0, e1)


def test_dataloader_partition_only_loads_own_rows():
    """Fig. 6: with DP=2 over 512 samples, each dp group loads only its 256."""
    ds = SyntheticTextDataset(512, 8, 256, seed=0)
    mesh = mesh11()
    dl = DistributedDataloader(ds, mesh=mesh, global_batch=512, seed=0)
    seen = []

    def loader(rows):
        seen.append(rows.copy())
        return ds.get_rows(rows)

    arr = dl.make_sharded((512, 8), jnp.int32, P("data", None), loader)
    assert arr.shape == (512, 8)
    total_rows = np.concatenate(seen)
    # every row materialized exactly once per owning device (1 device here)
    assert len(total_rows) == 512
    assert dl.rows_loaded == 512


def test_math_dataset_rows_deterministic():
    ds = SyntheticMathDataset(100, seed=1)
    p1, a1 = ds.get_rows(np.array([3, 7]))
    p2, a2 = ds.get_rows(np.array([3, 7]))
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(a1, a2)
    text = ds.tok.decode(p1[0])
    a, b = text[:-1].split("+")
    assert int(a) + int(b) == a1[0]
