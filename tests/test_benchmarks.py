"""Sanity tests for the paper-scale projection model used by fig09-13:
the calibration must close exactly, and the predictions must stay inside
sane bounds around the paper's published values."""
import sys
import os

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import pytest

from benchmarks import paper_scale as ps


def test_calibration_point_closes():
    # the model must reproduce its own calibration input exactly
    assert ps.speedup(128) == pytest.approx(1.64, abs=1e-3)


def test_ppo_speedups_inside_paper_band():
    assert 1.05 < ps.speedup(32) < 1.35
    assert ps.speedup(32) < ps.speedup(64) < ps.speedup(128)  # grows with scale


def test_grpo_volume_amplifies():
    assert ps.speedup(128, ps.BPT_CAL * 2.5) > ps.speedup(128)
    assert 2.2 < ps.speedup(128, ps.BPT_CAL * 2.5) < 3.0  # paper: up to 2.62


def test_retention_calibration():
    assert ps.retention(512) == pytest.approx(0.805, abs=1e-6)
    assert ps.retention(64) == pytest.approx(1.0, abs=1e-6)
    assert 0.70 < ps.retention(1024) < ps.retention(512)


def test_table1_power_law_fit():
    C, gamma = ps.fit_table1()
    assert 1.1 < gamma < 1.5
    for gpus, paper in ps.TABLE1_7B.items():
        got = ps.baseline_max_batch(gpus)
        assert paper / 2 <= got <= paper * 2, (gpus, got, paper)
    # monotone decreasing
    vals = [ps.baseline_max_batch(g) for g in (32, 64, 128, 256, 512)]
    assert vals == sorted(vals, reverse=True)


def test_long_context_speedup_grows():
    prev = 0.0
    for ctx in (8192, 16384, 32768, 65536):
        true_tokens = int(6144 * (ctx / 8192) ** 0.7)
        s = ps.speedup(64, seq_tokens=true_tokens, pad_tokens=ctx)
        assert s > prev
        prev = s
    assert 1.3 < ps.speedup(64, seq_tokens=6144, pad_tokens=8192) < 1.7
