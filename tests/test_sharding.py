"""Sharding-rule unit tests: PartitionSpecs must divide every dim they name,
cover every arch's param tree, and express the documented layout."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.distributed import sharding as shr
from repro.launch.workloads import caches_shapes, state_shapes


class FakeMesh:
    """Shape-only stand-in (don't build 256 devices in unit tests)."""

    def __init__(self, shape_map):
        self.shape = dict(shape_map)
        self.axis_names = tuple(shape_map)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axis_size(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["pod1", "pod2"])
def test_param_specs_divisible(arch, mesh):
    cfg = ARCHS[arch]
    state = state_shapes(cfg)
    specs = shr.param_specs(cfg, mesh, state.params)
    leaves = jax.tree_util.tree_leaves_with_path(state.params)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for (path, leaf), spec in zip(leaves, spec_leaves):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
            size = _axis_size(mesh, entry)
            assert dim % size == 0, (
                f"{arch}: {jax.tree_util.keystr(path)} dim {dim} "
                f"not divisible by {entry} ({size})"
            )


@pytest.mark.parametrize("arch", ["deepseek-67b", "mixtral-8x7b", "mamba2-2.7b",
                                  "seamless-m4t-medium"])
def test_cache_specs_divisible(arch):
    cfg = ARCHS[arch]
    for B, S in [(128, 32_768), (1, 4096)]:
        shapes = caches_shapes(cfg, B, S)
        specs = shr.cache_specs(cfg, MESH1, B, shapes)
        for (path, leaf), spec in zip(
            jax.tree_util.tree_leaves_with_path(shapes),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
        ):
            for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
                assert dim % _axis_size(MESH1, entry) == 0, (arch, path, spec)


def test_fsdp_scaling_property():
    """Param bytes per device must scale ~1/devices for a dense arch."""
    cfg = ARCHS["deepseek-67b"]
    state = state_shapes(cfg)
    for mesh in (MESH1, MESH2):
        specs = shr.param_specs(cfg, mesh, state.params)
        total = 0
        for leaf, spec in zip(
            jax.tree.leaves(state.params),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
        ):
            shard = leaf.size
            for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
                shard //= _axis_size(mesh, entry)
            total += shard * leaf.dtype.itemsize
        n_chips = np.prod(list(mesh.shape.values()))
        # 67B bf16 params over the mesh: within 2x of N*2/chips (embeddings
        # and replicated norms add slack)
        ideal = cfg.num_params() * 2 / n_chips
        assert total < 2.2 * ideal, (n_chips, total, ideal)


def test_batch_axes_picks_divisible_prefix():
    assert shr.batch_axes(MESH1, 256) == ("data",)
    assert shr.batch_axes(MESH2, 256) == ("pod", "data")
    assert shr.batch_axes(MESH1, 1) is None
    assert shr.batch_axes(MESH2, 2) == ("pod",)


def test_gqa_kv_replicated_when_not_divisible():
    cfg = ARCHS["deepseek-67b"]  # kv=8 < tp=16
    state = state_shapes(cfg)
    specs = shr.param_specs(cfg, MESH1, state.params)
    flat = {jax.tree_util.keystr(p): s for p, s in
            jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))}
    wk = [v for k, v in flat.items() if "w_k" in k][0]
    wq = [v for k, v in flat.items() if "w_q" in k][0]
    assert "model" not in str(wk[-1])  # kv replicated over model
    assert wq[-1] == "model"  # q heads TP-sharded


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_serve_param_specs_divisible_and_no_fsdp(arch):
    """Serve layout: TP over `model` only, replicated over data (no per-step
    FSDP gathers), every named dim divisible."""
    cfg = ARCHS[arch]
    state = state_shapes(cfg)
    specs = shr.param_specs(cfg, MESH1, state.params, mode="serve")
    for (path, leaf), spec in zip(
        jax.tree_util.tree_leaves_with_path(state.params),
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
    ):
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
            assert dim % _axis_size(MESH1, entry) == 0, (arch, path, spec)
            assert entry in (None, "model", ("model",)), (arch, path, spec)


def test_serve_mode_selection_by_memory():
    from repro.configs.base import ShapeConfig
    from repro.launch.workloads import serve_param_mode

    decode = ShapeConfig("decode_32k", 32_768, 128, "decode")
    # 67B/16 = 8.4GB weights + ~1GB cache -> resident layout fits
    assert serve_param_mode(ARCHS["deepseek-67b"], decode, MESH1) == "serve"
    # 104B/16 = 13GB + cache -> over budget, falls back to FSDP gathers
    assert serve_param_mode(ARCHS["command-r-plus-104b"], decode, MESH1) == "train"


def test_moe_expert_dim_spec():
    cfg = ARCHS["mixtral-8x7b"]  # 8 experts, not 16-divisible
    state = state_shapes(cfg)
    specs = shr.param_specs(cfg, MESH1, state.params)
    flat = {jax.tree_util.keystr(p): s for p, s in
            jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))}
    w_in = [v for k, v in flat.items() if "moe" in k and "w_in" in k][0]
    # (N, E, d, f): E replicated, f TP
    assert w_in[-1] == "model"
    assert w_in[-3] is None
