"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dep: pip install '.[test]' to run these"
)
from hypothesis import given, settings, strategies as st

from repro.core.dag import DAG, Node, NodeType, Role
from repro.core.planner import DAGPlanner, validate_serialization
from repro.data.dataloader import DistributedDataloader
from repro.data.dataset import SyntheticTextDataset
from repro.ft.straggler import rebalance
from repro.utils.jax_compat import make_compat_mesh
from repro.kernels import ref
from repro.rl import advantage
from repro.distributed.compression import _dequantize, _quantize

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# --------------------------------------------------------------------------- #
# planner: any random DAG serializes to a valid total order covering all nodes
# --------------------------------------------------------------------------- #
@st.composite
def random_dag(draw):
    n = draw(st.integers(2, 12))
    nodes = []
    for i in range(n):
        deps = tuple(
            f"n{j}" for j in range(i)
            if draw(st.booleans()) and draw(st.integers(0, 2)) == 0
        )
        nodes.append(
            Node(f"n{i}", draw(st.sampled_from(list(Role))),
                 draw(st.sampled_from(list(NodeType))), deps=deps)
        )
    return DAG.from_nodes(nodes)


@given(random_dag())
def test_planner_total_order_invariants(dag):
    plan = DAGPlanner().plan(dag)
    assert sorted(plan.order) == sorted(dag.nodes)
    assert validate_serialization(plan)
    # serialization implies: each task's predecessor is exactly the previous
    for i, t in enumerate(plan.tasks):
        assert t.after == (plan.tasks[i - 1].node.node_id if i else None)


# --------------------------------------------------------------------------- #
# dataloader: partitions of any epoch cover the dataset exactly once
# --------------------------------------------------------------------------- #
@given(st.integers(1, 4), st.integers(0, 3))
def test_dataloader_epoch_partition(dp, epoch):
    ds = SyntheticTextDataset(64, 4, 128, seed=9)
    mesh = make_compat_mesh((1, 1), ("data", "model"))
    dl = DistributedDataloader(ds, mesh=mesh, global_batch=16, seed=5)
    perm = dl._epoch_perm(epoch)
    assert sorted(perm.tolist()) == list(range(64))
    # the dp partition of a batch covers the batch exactly once
    idx = dl.batch_indices(epoch * 4)
    parts = np.array_split(idx, dp)
    assert sorted(np.concatenate(parts).tolist()) == sorted(idx.tolist())


# --------------------------------------------------------------------------- #
# GRPO: advantages are group-mean-free and scale-invariant
# --------------------------------------------------------------------------- #
@given(
    st.integers(1, 4),
    st.integers(2, 8),
    st.floats(0.5, 10.0),
)
def test_grpo_invariants(groups, gsize, scale):
    rng = np.random.default_rng(groups * 100 + gsize)
    rewards = jnp.asarray(rng.normal(size=groups * gsize).astype(np.float32))
    mask = jnp.ones((groups * gsize, 3))
    adv = advantage.grpo(rewards, mask, group_size=gsize)
    per_group = np.asarray(adv[:, 0]).reshape(groups, gsize)
    np.testing.assert_allclose(per_group.mean(axis=1), 0.0, atol=1e-4)
    # affine shift of rewards leaves advantages unchanged
    adv2 = advantage.grpo(rewards + 7.0, mask, group_size=gsize)
    np.testing.assert_allclose(np.asarray(adv2), np.asarray(adv), atol=1e-4)


# --------------------------------------------------------------------------- #
# GAE reduces to discounted returns at lam=1, values=0
# --------------------------------------------------------------------------- #
@given(st.integers(1, 3), st.integers(2, 10), st.floats(0.8, 1.0))
def test_gae_lambda1_is_discounted_return(b, t, gamma):
    rng = np.random.default_rng(b * 31 + t)
    rewards = jnp.asarray(rng.normal(size=(b, t)).astype(np.float32))
    values = jnp.zeros((b, t))
    mask = jnp.ones((b, t))
    adv, ret = advantage.gae(rewards, values, mask, gamma=gamma, lam=1.0)
    want = np.zeros((b, t))
    acc = np.zeros(b)
    r = np.asarray(rewards)
    for i in reversed(range(t)):
        acc = r[:, i] + gamma * acc
        want[:, i] = acc
    np.testing.assert_allclose(np.asarray(adv), want, atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------- #
# decode-shard combine == unsharded decode for any split
# --------------------------------------------------------------------------- #
@given(st.integers(1, 4), st.sampled_from([2, 4, 8]))
def test_decode_shard_combine_any_split(seed, parts):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, S, H, D = 2, 64, 2, 8
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    cl = jnp.array([S // 3, S], jnp.int32)
    want = ref.decode_attention(q, k, v, cl)
    sz = S // parts
    os_, ls_ = [], []
    for i in range(parts):
        o, l = ref.decode_attention(
            q, k[:, i * sz:(i + 1) * sz], v[:, i * sz:(i + 1) * sz],
            cl, pos_offset=i * sz, return_lse=True)
        os_.append(o)
        ls_.append(l)
    got = ref.combine_decode_shards(jnp.stack(os_), jnp.stack(ls_))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# straggler rebalance: every shard assigned exactly once, never to dead hosts
# --------------------------------------------------------------------------- #
@given(
    st.lists(st.floats(0.5, 20.0), min_size=2, max_size=12),
    st.data(),
)
def test_rebalance_covers_all_shards(times, data):
    n = len(times)
    dead = data.draw(st.lists(st.integers(0, n - 1), max_size=n - 1, unique=True))
    if len(dead) >= n:
        return
    try:
        out = rebalance(times, dead=dead)
    except RuntimeError:
        return  # all hosts dead
    assigned = sorted(s for shards in out.values() for s in shards)
    assert assigned == list(range(n))
    for d in dead:
        assert out[d] == []


# --------------------------------------------------------------------------- #
# int8 quantization round-trip error bounded by scale/2
# --------------------------------------------------------------------------- #
@given(st.integers(0, 5), st.integers(1, 300))
def test_quantize_roundtrip_bound(seed, size):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=size).astype(np.float32) * 10)
    q, scale = _quantize(x)
    y = _dequantize(q, scale, x.shape, x.size)
    bound = np.repeat(np.asarray(scale)[:, 0], 256)[: x.size] * 0.5 + 1e-6
    assert np.all(np.abs(np.asarray(y) - np.asarray(x)) <= bound.reshape(x.shape))
