"""Fault-tolerance unit tests: straggler rebalancing + heartbeat detection."""
import numpy as np
import pytest

from repro.ft.straggler import HeartbeatMonitor, rebalance


def test_rebalance_no_stragglers_identity():
    out = rebalance([1.0, 1.0, 1.0, 1.0])
    assert out == {0: [0], 1: [1], 2: [2], 3: [3]}


def test_rebalance_slow_host_donates():
    out = rebalance([1.0, 1.0, 10.0, 1.0], threshold=1.5)
    assert 2 not in [s for i, ss in out.items() if i != 2 for s in ss] or True
    assert out[2] == []  # slow host keeps nothing
    all_shards = sorted(s for ss in out.values() for s in ss)
    assert all_shards == [0, 1, 2, 3]


def test_rebalance_dead_host():
    out = rebalance([1.0, 1.0, 1.0, 1.0], dead=[1])
    assert out[1] == []
    assert sorted(s for ss in out.values() for s in ss) == [0, 1, 2, 3]


def test_rebalance_fastest_receives():
    out = rebalance([5.0, 1.0, 100.0, 5.0], threshold=1.5, dead=[])
    # host 2 is slow; its shard goes to the fastest healthy host (1)
    assert 2 in out[1]


def test_rebalance_all_dead_raises():
    with pytest.raises(RuntimeError):
        rebalance([1.0, 1.0], dead=[0, 1])


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(3, patience=2)
    for it in range(3):
        hb.beat(0, it)
        hb.beat(1, it)
        # host 2 silent after iteration 0
        if it == 0:
            hb.beat(2, it)
    assert hb.dead(3) == [2]
    assert hb.dead(1) == []
