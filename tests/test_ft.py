"""Fault-tolerance unit tests: straggler rebalancing + heartbeat detection."""
import numpy as np
import pytest

from repro.ft.straggler import (
    HeartbeatMonitor,
    balance_by_length,
    bucket_token_ratio,
    cross_host_rows,
    rebalance,
)


def test_rebalance_no_stragglers_identity():
    out = rebalance([1.0, 1.0, 1.0, 1.0])
    assert out == {0: [0], 1: [1], 2: [2], 3: [3]}


def test_rebalance_slow_host_donates():
    out = rebalance([1.0, 1.0, 10.0, 1.0], threshold=1.5)
    assert 2 not in [s for i, ss in out.items() if i != 2 for s in ss] or True
    assert out[2] == []  # slow host keeps nothing
    all_shards = sorted(s for ss in out.values() for s in ss)
    assert all_shards == [0, 1, 2, 3]


def test_rebalance_dead_host():
    out = rebalance([1.0, 1.0, 1.0, 1.0], dead=[1])
    assert out[1] == []
    assert sorted(s for ss in out.values() for s in ss) == [0, 1, 2, 3]


def test_rebalance_fastest_receives():
    out = rebalance([5.0, 1.0, 100.0, 5.0], threshold=1.5, dead=[])
    # host 2 is slow; its shard goes to the fastest healthy host (1)
    assert 2 in out[1]


def test_rebalance_all_dead_raises():
    with pytest.raises(RuntimeError):
        rebalance([1.0, 1.0], dead=[0, 1])


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(3, patience=2)
    for it in range(3):
        hb.beat(0, it)
        hb.beat(1, it)
        # host 2 silent after iteration 0
        if it == 0:
            hb.beat(2, it)
    assert hb.dead(3) == [2]
    assert hb.dead(1) == []


# ---------------- HeartbeatMonitor edge cases ---------------- #
def test_heartbeat_never_beat_host_is_dead_at_any_query():
    """Regression: last_seen starts at -inf, not 0 — a host that never
    launched must not look like it beat at iteration 0."""
    hb = HeartbeatMonitor(2, patience=2)
    hb.beat(0, 0)
    assert hb.dead(0) == [1]
    assert hb.dead(-5) == [1]  # even queries "before the start"


def test_heartbeat_invalid_construction():
    with pytest.raises(ValueError):
        HeartbeatMonitor(0)
    with pytest.raises(ValueError):
        HeartbeatMonitor(2, patience=0)  # would kill a host the beat it beats
    with pytest.raises(ValueError):
        HeartbeatMonitor(2, patience=-1)


def test_heartbeat_host_bounds():
    hb = HeartbeatMonitor(2)
    with pytest.raises(ValueError):
        hb.beat(2, 0)
    with pytest.raises(ValueError):
        hb.beat(-1, 0)


def test_heartbeat_non_monotone_queries_and_beats():
    """A delayed out-of-order beat must not roll a host backwards, and a
    query at an iteration older than the last beat never reports dead."""
    hb = HeartbeatMonitor(1, patience=1)
    hb.beat(0, 5)
    hb.beat(0, 2)  # late arrival; max() keeps 5
    assert hb.dead(5) == []
    assert hb.dead(2) == []  # non-monotone query: 2 - 5 < patience
    assert hb.dead(6) == [0]


def test_heartbeat_wallclock_staleness():
    """Wall-clock staleness ORs with iteration lag: a survivor blocked at a
    collective (its own iteration frozen) still detects a killed peer."""
    hb = HeartbeatMonitor(2, patience=10)
    hb.beat(0, 0, now=100.0)
    hb.beat(1, 0, now=100.0)
    assert hb.dead(0, now=105.0, stale_s=30.0) == []
    hb.beat(0, 0, now=131.0)  # only host 0 keeps beating
    assert hb.dead(0, now=131.0, stale_s=30.0) == [1]
    # without the stale_s opt-in the lag rule alone says everyone is fine
    assert hb.dead(0) == []
    hb.beat(1, 0, now=90.0)  # stale wall-clock beat cannot roll back
    assert hb.dead(0, now=131.0, stale_s=30.0) == [1]


# ---------------- hierarchical length balancing ---------------- #
def _host_totals(lengths, perm, hosts):
    w = np.asarray(lengths, dtype=np.float64)[perm]
    return np.array([c.sum() for c in np.array_split(w, hosts)])


def test_hierarchical_balance_validation():
    with pytest.raises(ValueError):  # capacities only make sense flat
        balance_by_length([1.0] * 8, 4, hosts=2, capacities=[2, 2, 2, 2])
    with pytest.raises(ValueError):  # buckets must divide across hosts
        balance_by_length([1.0] * 8, 3, hosts=2)
    with pytest.raises(ValueError):  # groups must divide across hosts
        balance_by_length([1.0] * 6, 2, group_size=2, hosts=2)


def test_hierarchical_balance_is_permutation_and_deterministic():
    rng = np.random.default_rng(0)
    lengths = rng.integers(1, 100, size=32).astype(float)
    p1 = balance_by_length(lengths, 8, group_size=2, hosts=4)
    p2 = balance_by_length(lengths, 8, group_size=2, hosts=4)
    assert sorted(p1.tolist()) == list(range(32))
    np.testing.assert_array_equal(p1, p2)
    # grouped rows stay adjacent pairs
    pairs = p1.reshape(-1, 2)
    assert (pairs[:, 1] - pairs[:, 0] == 1).all()
    assert (pairs[:, 0] % 2 == 0).all()


def test_hierarchical_balance_balanced_input_never_crosses_hosts():
    """Already-balanced hosts: every row must stay on its resident host —
    the repack permutation never pays the inter-pod links for nothing."""
    lengths = np.tile([10.0, 2.0, 7.0, 5.0], 4)  # same mix on every host
    perm = balance_by_length(lengths, 8, hosts=4)
    assert cross_host_rows(perm, 4) == 0
    # and it still balances the local buckets
    assert bucket_token_ratio(lengths, 8, perm) <= bucket_token_ratio(
        lengths, 8)


def test_hierarchical_balance_swaps_reduce_host_imbalance():
    """One host generated all the long rollouts: swap migration must pull
    the max/mean host-token ratio under (or toward) tolerance with
    equal-row-count swaps."""
    lengths = np.array([100.0, 90, 80, 70] + [1.0] * 12)
    before = _host_totals(lengths, np.arange(16), 4)
    perm = balance_by_length(lengths, 4, hosts=4, inter_host_tolerance=1.25)
    after = _host_totals(lengths, perm, 4)
    assert sorted(perm.tolist()) == list(range(16))
    assert after.max() / after.mean() < before.max() / before.mean()
    assert cross_host_rows(perm, 4) > 0
    # swaps preserve equal rows per host
    assert all(len(c) == 4 for c in np.array_split(perm, 4))


def test_cross_host_rows_counts_block_crossings():
    assert cross_host_rows(np.arange(8), 2) == 0
    swapped = np.array([0, 1, 4, 5, 2, 3, 6, 7])  # two rows traded per host
    assert cross_host_rows(swapped, 2) == 4
    assert cross_host_rows(np.array([4, 5, 6, 7, 0, 1, 2, 3]), 2) == 8


def test_flat_balance_unchanged_by_hosts_1():
    """hosts=1 must be byte-identical to the pre-hierarchical behaviour."""
    rng = np.random.default_rng(3)
    lengths = rng.integers(1, 50, size=24).astype(float)
    np.testing.assert_array_equal(
        balance_by_length(lengths, 4, group_size=2),
        balance_by_length(lengths, 4, group_size=2, hosts=1))
