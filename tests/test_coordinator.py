"""Data Coordinator v2 tests (paper §6.2: local caching, load balancing,
asynchronous double buffer): double-buffer rotation correctness and overlap
accounting, length-aware load-balancer invariants, dataloader prefetch
determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, DataCoordinatorConfig, reduced
from repro.core import DoubleBufferedDatabuffer, build_pipeline
from repro.data.dataloader import DistributedDataloader
from repro.data.dataset import SyntheticMathDataset, SyntheticTextDataset
from repro.ft.straggler import (
    balance_by_length,
    bucket_token_ratio,
    inverse_permutation,
    rebalance,
)
from repro.rl import RLConfig
from repro.utils.jax_compat import make_compat_mesh


def mesh11():
    return make_compat_mesh((1, 1), ("data", "model"))


def small_cfg(**kw):
    base = dict(vocab_size=260, num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, head_dim=16, d_ff=128)
    base.update(kw)
    return reduced(ARCHS["qwen2.5-7b"], **base)


# --------------------------------------------------------------------------- #
# double buffer: unit behaviour
# --------------------------------------------------------------------------- #
def test_double_buffer_values_identical_to_sync_path():
    buf = DoubleBufferedDatabuffer(mesh11())
    x = jnp.arange(64.0).reshape(8, 8)
    buf.put("x", x, P("data", None))
    # first iteration: consumer spec unseen -> synchronous reshard
    y = buf.get("x", P(("data", "model"), None))
    assert buf.stats.sync_waits == 1 and buf.stats.overlap_hits == 0
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    buf.clear()  # rotation, not a drop
    assert buf.keys() == [] and buf.stats.rotations == 1
    # second iteration: put stages the reshard ahead of the get
    buf.put("x", x + 1.0, P("data", None))
    z = buf.get("x", P(("data", "model"), None))
    assert buf.stats.overlap_hits == 1
    np.testing.assert_array_equal(np.asarray(z), np.asarray(x) + 1.0)


def test_double_buffer_overwrite_invalidates_staged():
    buf = DoubleBufferedDatabuffer(mesh11())
    spec = P(("data", "model"), None)
    buf.put("x", jnp.zeros((4, 4)), P("data", None))
    buf.get("x", spec)  # learn the consumer spec
    buf.clear()
    buf.put("x", jnp.ones((4, 4)), P("data", None))   # staged: ones
    buf.put("x", jnp.full((4, 4), 7.0), P("data", None))  # must re-stage
    out = buf.get("x", spec)
    np.testing.assert_array_equal(np.asarray(out), np.full((4, 4), 7.0))


def test_double_buffer_fast_path_still_zero_copy():
    buf = DoubleBufferedDatabuffer(mesh11())
    x = jnp.ones((8, 4))
    buf.put("x", x, P("data", None))
    y = buf.get("x", P("data", None))
    assert y is buf._store["x"]
    assert buf.stats.fast_path_hits == 1
    assert buf.stats.overlap_hits == 0 and buf.stats.sync_waits == 0


@pytest.mark.parametrize("algo", ["grpo", "ppo"])
def test_double_buffered_pipeline_bitwise_identical(algo):
    """Acceptance: double-buffered coordinator produces bitwise-identical
    stage outputs to the synchronous path on the built-in PPO and GRPO DAGs,
    with >= 1 overlap hit per iteration once the access pattern is learned."""
    rl = RLConfig(algorithm=algo, group_size=4, max_new_tokens=6, lr=1e-4,
                  critic_lr=1e-4)
    cfg = small_cfg()
    coord = DataCoordinatorConfig(double_buffer=True, prefetch=1)
    h_sync = build_pipeline(cfg, rl, prompts_per_iter=4, seed=3).run(3)
    h_db = build_pipeline(cfg, rl, prompts_per_iter=4, seed=3,
                          coordinator=coord).run(3)
    for a, b in zip(h_sync, h_db):
        for k in a:
            if k.startswith("time/"):
                continue
            assert a[k] == b[k], k  # exact, not approx

    pipe = build_pipeline(cfg, rl, prompts_per_iter=4, seed=3, coordinator=coord)
    pipe.run(1)  # recording pass
    pipe.buffer.stats.reset()
    iters = 3
    pipe.run(iters)
    s = pipe.buffer.stats
    assert s.overlap_hits >= iters, s  # >= 1 overlap hit per iteration
    assert s.sync_waits == 0, s  # steady state: nothing left on the critical path
    assert s.rotations == iters


# --------------------------------------------------------------------------- #
# length-aware load balancer
# --------------------------------------------------------------------------- #
def test_balancer_bounds_skewed_batch():
    """Acceptance: per-DP-rank token counts within 1.25x of the mean on a
    skewed synthetic batch."""
    rng = np.random.default_rng(0)
    lengths = np.sort(rng.exponential(48.0, size=64).astype(np.int64) + 4)
    nb = 4
    before = bucket_token_ratio(lengths, nb)
    assert before > 1.25  # sorted batch: genuinely skewed across ranks
    perm = balance_by_length(lengths, nb)
    after = bucket_token_ratio(lengths, nb, perm)
    assert after <= 1.25, (before, after)
    assert after < before


def test_balancer_permutation_is_valid_and_round_trips():
    rng = np.random.default_rng(7)
    lengths = rng.integers(1, 100, size=48)
    perm = balance_by_length(lengths, 6)
    assert sorted(perm.tolist()) == list(range(48))
    inv = inverse_permutation(perm)
    x = rng.normal(size=(48, 3))
    np.testing.assert_array_equal(x[perm][inv], x)


def test_balancer_keeps_grpo_groups_contiguous():
    rng = np.random.default_rng(1)
    g = 8
    lengths = rng.integers(1, 64, size=64)
    perm = balance_by_length(lengths, 4, group_size=g)
    rows = perm.reshape(-1, g)
    # every group of g rows in the output is one original prompt group
    assert (rows // g == rows[:, :1] // g).all()
    assert (rows % g == np.arange(g)).all()  # within-group order preserved


def test_balancer_deterministic_across_workers():
    lengths = [5, 50, 5, 50, 30, 30, 7, 43]
    p1 = balance_by_length(lengths, 2)
    p2 = balance_by_length(list(lengths), 2)
    np.testing.assert_array_equal(p1, p2)


def test_balancer_composes_with_rebalance_capacities():
    """rebalance() decides how many shards each host loads; its per-host
    shard counts feed balance_by_length as bucket capacities."""
    assignment = rebalance([1.0, 1.0, 10.0, 1.0], threshold=1.5)
    caps = [len(assignment[h]) for h in sorted(assignment)]
    assert sum(caps) == 4 and caps[2] == 0  # slow host gets nothing
    lengths = np.repeat([10, 20, 30, 40], 4)  # 16 rows, 4 groups of 4
    perm = balance_by_length(lengths, 4, group_size=4, capacities=caps)
    assert sorted(perm.tolist()) == list(range(16))
    # the zero-capacity bucket receives zero rows: splitting by caps, bucket 2
    # is empty
    splits = np.split(perm, np.cumsum(np.asarray(caps[:-1]) * 4))
    assert len(splits[2]) == 0


def test_balancer_rejects_bad_shapes():
    with pytest.raises(ValueError):
        balance_by_length([1, 2, 3], 2, group_size=2)
    with pytest.raises(ValueError):
        balance_by_length([1, 2, 3, 4], 2, capacities=[1, 2])


def test_balancer_divisibility_skip_is_reported():
    """A num_buckets that can't evenly split the rollout groups must not
    disable balancing invisibly: the iteration reports balance/skipped."""
    coord = DataCoordinatorConfig(load_balance=True, num_buckets=3)
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4, lr=1e-4)
    pipe = build_pipeline(small_cfg(), rl, prompts_per_iter=4, seed=0,
                          coordinator=coord)
    m = pipe.run(1)[-1]  # 8 rollouts -> 4 groups, 4 % 3 != 0
    assert m.get("balance/skipped") == 1.0
    assert "balance/token_ratio_before" not in m


def test_balanced_pipeline_reports_metrics_and_learns():
    coord = DataCoordinatorConfig(load_balance=True, num_buckets=4)
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=8, lr=1e-4)
    pipe = build_pipeline(small_cfg(), rl, prompts_per_iter=8, seed=0,
                          coordinator=coord)
    m = pipe.run(2)[-1]
    assert "balance/token_ratio_before" in m
    assert m["balance/token_ratio_after"] <= m["balance/token_ratio_before"]
    assert np.isfinite(m["reward/mean"])


# --------------------------------------------------------------------------- #
# dataloader prefetch
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 13])
def test_prefetch_determinism_across_depths(seed):
    ds = SyntheticMathDataset(256, seed=seed)
    mesh = mesh11()
    loaders = [
        DistributedDataloader(ds, mesh=mesh, global_batch=16, seed=seed,
                              prefetch=k)
        for k in (0, 1, 3)
    ]
    for _ in range(6):
        batches = [dl.next_batch() for dl in loaders]
        for b in batches[1:]:
            for key in batches[0]:
                np.testing.assert_array_equal(
                    np.asarray(batches[0][key]), np.asarray(b[key]))
    assert loaders[1].prefetch_hits == 5  # all but the first call
    assert loaders[2].prefetch_hits == 5


def test_prefetch_builds_ahead():
    ds = SyntheticTextDataset(128, 8, 256, seed=2)
    dl = DistributedDataloader(ds, mesh=mesh11(), global_batch=16, seed=2,
                               prefetch=2)
    dl.next_batch()
    # one consumed + two banked => rows for three batches were loaded
    assert dl.rows_loaded == 3 * 16
    assert len(dl._ready) == 2
