"""Per-architecture smoke tests (deliverable f).

Each assigned arch is instantiated at a REDUCED same-family config and runs
one forward/train step on CPU, asserting output shapes and no NaNs. The FULL
configs are exercised only via the dry-run (ShapeDtypeStructs, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, applicable_shapes, reduced
from repro.models import get_model

B, S = 2, 32


def make_batch(r, key):
    tok = jax.random.randint(key, (B, S), 1, r.vocab_size)
    labels = jnp.where(jnp.arange(S)[None, :] < S - 1, jnp.roll(tok, -1, 1), -1)
    if r.is_encoder_decoder:
        return {
            "tokens": tok,
            "labels": labels,
            "frames": jax.random.normal(key, (B, r.encoder_len, r.d_model)),
        }
    if r.num_prefix_embeds > 1:
        P = r.num_prefix_embeds
        full_labels = jnp.concatenate(
            [jnp.full((B, P - 1), -1), tok, jnp.full((B, 1), -1)], axis=1
        )[:, : P + S]
        return {
            "tokens": tok,
            "labels": full_labels,
            "prefix_embeds": jax.random.normal(key, (B, P, r.d_model)),
        }
    return {"tokens": tok, "labels": labels}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_loss_finite(arch):
    r = reduced(ARCHS[arch])
    m = get_model(r)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    batch = make_batch(r, key)
    loss, metrics = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_updates_params(arch):
    """One SGD step decreases nothing structurally: grads finite, params move."""
    r = reduced(ARCHS[arch])
    m = get_model(r)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    batch = make_batch(r, key)

    def loss_only(p):
        return m.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_only))(params)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    loss2 = jax.jit(loss_only)(new_params)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_consistency(arch):
    """Step-wise decode logits must match a full-sequence prefill."""
    r = reduced(ARCHS[arch])
    m = get_model(r)
    key = jax.random.PRNGKey(2)
    params = m.init(key)
    tok = jax.random.randint(key, (B, 12), 1, r.vocab_size)
    kw = {}
    if r.is_encoder_decoder:
        kw["frames"] = jax.random.normal(key, (B, r.encoder_len, r.d_model))
    logits, caches, clen = m.prefill(params, tok, smax=24, **kw)
    assert logits.shape == (B, r.padded_vocab)
    nxt = jnp.argmax(logits, -1)
    lg, caches, clen = m.decode_step(params, nxt, caches, clen)
    full = jnp.concatenate([tok, nxt[:, None]], axis=1)
    logits_ref, _, _ = m.prefill(params, full, smax=24, **kw)
    valid = np.array(lg) > -1e29
    err = np.abs((np.array(lg) - np.array(logits_ref))[valid]).max()
    assert err < 0.1, f"{arch}: decode/prefill mismatch {err}"
    assert not np.any(np.isnan(np.array(lg)))


def test_shape_cells():
    """The assigned shape-cell table: 33 applicable cells, documented skips."""
    cells = [(a, s.name) for a in ASSIGNED for s in applicable_shapes(ARCHS[a])]
    assert len(cells) == 33
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"mamba2-2.7b", "jamba-v0.1-52b", "mixtral-8x7b"}


def test_param_counts_match_family_scale():
    """Analytic num_params should land near the arch's nameplate size."""
    expect = {
        "mamba2-2.7b": 2.7e9,
        "nemotron-4-15b": 15e9,
        "gemma-2b": 2.5e9,
        "deepseek-67b": 67e9,
        "mixtral-8x7b": 46.7e9,
        "command-r-plus-104b": 104e9,
        "jamba-v0.1-52b": 52e9,
        "llava-next-34b": 34e9,
    }
    for arch, n in expect.items():
        got = ARCHS[arch].num_params()
        assert 0.55 * n < got < 1.65 * n, f"{arch}: {got:.2e} vs nameplate {n:.2e}"
