"""RL math tests: advantages, losses, rollout semantics — vs hand calcs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_model
from repro.rl import advantage, loss
from repro.rl import reward as reward_mod
from repro.rl.rollout import generate


# --------------------------------------------------------------------------- #
# advantages
# --------------------------------------------------------------------------- #
def test_gae_matches_hand_rollout():
    rewards = jnp.array([[0.0, 0.0, 1.0]])
    values = jnp.array([[0.5, 0.6, 0.7]])
    mask = jnp.ones((1, 3))
    gamma, lam = 0.9, 0.8
    adv, ret = advantage.gae(rewards, values, mask, gamma=gamma, lam=lam)
    # hand computation (v_4 = 0)
    d2 = 1.0 + 0.0 - 0.7
    d1 = 0.0 + gamma * 0.7 - 0.6
    d0 = 0.0 + gamma * 0.6 - 0.5
    a2 = d2
    a1 = d1 + gamma * lam * a2
    a0 = d0 + gamma * lam * a1
    np.testing.assert_allclose(np.asarray(adv[0]), [a0, a1, a2], atol=1e-6)
    np.testing.assert_allclose(np.asarray(ret), np.asarray(adv + values), atol=1e-6)


def test_gae_respects_mask():
    rewards = jnp.array([[1.0, 5.0, 5.0]])
    values = jnp.zeros((1, 3))
    mask = jnp.array([[1.0, 0.0, 0.0]])  # only first token is response
    adv, _ = advantage.gae(rewards, values, mask)
    assert float(adv[0, 1]) == 0.0 and float(adv[0, 2]) == 0.0
    np.testing.assert_allclose(float(adv[0, 0]), 1.0, atol=1e-6)


def test_grpo_group_normalization():
    rewards = jnp.array([1.0, 0.0, 1.0, 1.0])  # two groups of 2
    mask = jnp.ones((4, 3))
    adv = advantage.grpo(rewards, mask, group_size=2)
    g0 = np.asarray(adv[:2, 0])
    np.testing.assert_allclose(g0, [(1 - 0.5) / 0.5, (0 - 0.5) / 0.5], atol=1e-4)
    # identical rewards in group -> zero advantage (std eps guarded)
    np.testing.assert_allclose(np.asarray(adv[2:, 0]), [0.0, 0.0], atol=1e-3)


def test_whiten():
    adv = jnp.array([[1.0, 2.0, 3.0, 4.0]])
    mask = jnp.ones((1, 4))
    w = advantage.whiten(adv, mask)
    assert abs(float(jnp.mean(w))) < 1e-5
    np.testing.assert_allclose(float(jnp.std(w)), 1.0, atol=1e-3)


# --------------------------------------------------------------------------- #
# losses
# --------------------------------------------------------------------------- #
def test_ppo_clip_behaviour():
    mask = jnp.ones((1, 1))
    adv = jnp.ones((1, 1))
    old = jnp.zeros((1, 1))
    # ratio within clip: gradient flows; far above clip with adv>0: clipped
    out_in = loss.ppo_policy_loss(jnp.full((1, 1), 0.1), old, adv, mask)
    out_hi = loss.ppo_policy_loss(jnp.full((1, 1), 1.0), old, adv, mask)
    assert float(out_hi["loss"]) == pytest.approx(-1.2, abs=1e-5)  # clipped at 1+eps
    assert float(out_in["loss"]) == pytest.approx(-np.exp(0.1), abs=1e-4)
    assert float(out_hi["clipfrac"]) == 1.0


def test_kl_k3_nonnegative_and_zero_at_equal():
    lp = jnp.array([[0.5, -0.3]])
    mask = jnp.ones((1, 2))
    assert float(loss.kl_penalty(lp, lp, mask)) == pytest.approx(0.0, abs=1e-7)
    ref = lp + jnp.array([[0.2, -0.4]])
    assert float(loss.kl_penalty(lp, ref, mask, kind="k3")) > 0


def test_value_loss_clipping():
    old_v = jnp.zeros((1, 1))
    ret = jnp.full((1, 1), 1.0)
    mask = jnp.ones((1, 1))
    # current value jumped far from old: clipped term dominates
    out = loss.value_loss(jnp.full((1, 1), 0.9), old_v, ret, mask, clip_eps=0.2)
    # clipped prediction = 0.2 -> err (0.2-1)^2 = .64; raw err = .01 -> max
    assert float(out["loss"]) == pytest.approx(0.5 * 0.64, abs=1e-5)


# --------------------------------------------------------------------------- #
# rollout engine
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260, num_layers=2)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_rollout_shapes_and_mask(tiny_model):
    cfg, model, params = tiny_model
    tok = ByteTokenizer()
    B, Lp, T = 4, 6, 8
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, Lp), 3, 200)
    res = generate(model, params, prompt, jax.random.PRNGKey(2),
                   max_new=T, temperature=1.0, eos_id=tok.eos_id)
    assert res.tokens.shape == (B, Lp + T)
    assert res.response_mask.shape == (B, Lp + T)
    assert not np.any(np.asarray(res.response_mask[:, :Lp]))  # prompt unmasked
    np.testing.assert_array_equal(np.asarray(res.tokens[:, :Lp]), np.asarray(prompt))
    assert np.all(np.asarray(res.lengths) >= 1)
    assert np.all(np.asarray(res.lengths) <= T)


def test_sample_token_top_p():
    from repro.rl.rollout import sample_token

    logits = jnp.log(jnp.array([[0.5, 0.3, 0.15, 0.05],
                                [0.05, 0.15, 0.3, 0.5]]))
    key = jax.random.PRNGKey(3)
    # default top_p=1.0 is bitwise the historical path (filter skipped at
    # the python level — same ops traced)
    a = sample_token(logits, key, 0.8)
    b = sample_token(logits, key, 0.8, top_p=1.0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # small top_p restricts support to the nucleus (top-2 here covers 0.8)
    for s in range(20):
        t = sample_token(logits, jax.random.PRNGKey(s), 1.0, top_p=0.75)
        assert int(t[0]) in (0, 1) and int(t[1]) in (3, 2)
    # greedy ignores top_p entirely
    g = sample_token(logits, key, 0.0, top_p=0.1)
    np.testing.assert_array_equal(np.asarray(g), [0, 3])


def test_generate_top_p_restricts_support(tiny_model):
    cfg, model, params = tiny_model
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 3, 200)
    res = generate(model, params, prompt, jax.random.PRNGKey(2), max_new=6,
                   temperature=1.0, top_p=1e-9)
    # top_p -> 0 degenerates to greedy (only the top-1 token survives)
    want = generate(model, params, prompt, jax.random.PRNGKey(7), max_new=6,
                    temperature=0.0)
    np.testing.assert_array_equal(np.asarray(res.tokens),
                                  np.asarray(want.tokens))


def test_rollout_greedy_deterministic(tiny_model):
    cfg, model, params = tiny_model
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 3, 200)
    r1 = generate(model, params, prompt, jax.random.PRNGKey(2), max_new=6,
                  temperature=0.0)
    r2 = generate(model, params, prompt, jax.random.PRNGKey(99), max_new=6,
                  temperature=0.0)
    np.testing.assert_array_equal(np.asarray(r1.tokens), np.asarray(r2.tokens))


def test_rollout_logprobs_match_teacher_forcing(tiny_model):
    """Behaviour logprobs from the decode loop == teacher-forced rescoring."""
    cfg, model, params = tiny_model
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 5), 3, 200)
    res = generate(model, params, prompt, jax.random.PRNGKey(4), max_new=6,
                   temperature=1.0)
    lp, _ = model.logprobs(params, res.tokens)
    m = np.asarray(res.response_mask)
    got = np.asarray(res.old_logprob)[m]
    want = np.asarray(lp)[m]
    np.testing.assert_allclose(got, want, atol=5e-2)  # bf16 cache tolerance


def test_eos_stops_counting(tiny_model):
    cfg, model, params = tiny_model
    tok = ByteTokenizer()
    # force EOS to be argmax-reachable: temperature 0 with a crafted prompt is
    # flaky for a random model; instead check that masked tokens are pad
    res = generate(model, params,
                   jax.random.randint(jax.random.PRNGKey(5), (8, 5), 3, 200),
                   jax.random.PRNGKey(6), max_new=12, temperature=2.0,
                   eos_id=3)  # low id -> likely sampled
    toks = np.asarray(res.tokens[:, 5:])
    mask = np.asarray(res.response_mask[:, 5:])
    lens = np.asarray(res.lengths)
    for b in range(8):
        # after the response ends, everything is pad
        assert np.all(toks[b, lens[b]:] == tok.pad_id) or lens[b] == 12


# --------------------------------------------------------------------------- #
# function reward
# --------------------------------------------------------------------------- #
def test_generate_max_new_1_zero_length_scan(tiny_model):
    """max_new=1 means the decode scan has zero steps: the response is the
    single prefill-sampled token, never pad-extended."""
    cfg, model, params = tiny_model
    B, Lp = 3, 5
    prompt = jax.random.randint(jax.random.PRNGKey(7), (B, Lp), 3, 200)
    res = generate(model, params, prompt, jax.random.PRNGKey(8), max_new=1,
                   temperature=1.0, eos_id=ByteTokenizer().eos_id)
    assert res.tokens.shape == (B, Lp + 1)
    assert np.all(np.asarray(res.lengths) == 1)
    assert np.all(np.asarray(res.response_mask[:, Lp]))


def test_generate_all_eos_at_step_0(tiny_model):
    """Zeroed params make logits constant (argmax = token 0); with eos_id=0
    every sequence is done at its first sampled token — mask counts exactly
    that token and everything after is pad with zero logprob."""
    cfg, model, params = tiny_model
    zeroed = jax.tree.map(jnp.zeros_like, params)
    B, Lp, T = 4, 5, 8
    prompt = jax.random.randint(jax.random.PRNGKey(9), (B, Lp), 3, 200)
    res = generate(model, zeroed, prompt, jax.random.PRNGKey(10), max_new=T,
                   temperature=0.0, eos_id=0, pad_id=0)
    assert np.all(np.asarray(res.lengths) == 1)
    toks = np.asarray(res.tokens[:, Lp:])
    assert np.all(toks == 0)  # eos then pad (both id 0)
    assert np.all(np.asarray(res.old_logprob[:, Lp + 1:]) == 0.0)


def test_generate_max_new_1_through_stage():
    """The GENERATE stage (and the whole DAG behind it) must run with a
    one-token response budget — the degenerate scan shape."""
    from repro.core import build_pipeline
    from repro.rl import RLConfig

    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260)
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=1, lr=1e-4)
    pipe = build_pipeline(cfg, rl, prompts_per_iter=4)
    metrics = pipe.worker.run_iteration()
    assert metrics["rollout/mean_len"] == 1.0
    assert metrics["rollout/tokens"] == 8.0  # 4 prompts x group 2 x 1 token
    assert any(k.startswith("actor/") for k in metrics)


def test_generate_all_eos_step0_through_stage():
    """All sequences EOS at their first token, through the GENERATE stage:
    zero the actor weights and rebind the generation engine with eos_id=0
    (constant logits argmax); the full iteration — reward, advantage, train —
    must consume the 1-token trajectories."""
    import functools

    from repro.core import build_pipeline
    from repro.models import get_model as _gm
    from repro.rl import RLConfig
    from repro.rl import rollout as rollout_mod

    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260)
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=6, lr=1e-4)
    pipe = build_pipeline(cfg, rl, prompts_per_iter=4)
    model = _gm(cfg)
    pipe.ctx.actor_state = pipe.ctx.actor_state._replace(
        params=jax.tree.map(jnp.zeros_like, pipe.ctx.actor_state.params)
    )
    pipe.ctx.engines["generate"] = jax.jit(functools.partial(
        rollout_mod.generate, model,
        max_new=rl.max_new_tokens, temperature=0.0, eos_id=0, pad_id=0,
    ))
    metrics = pipe.worker.run_iteration()
    assert metrics["rollout/mean_len"] == 1.0
    assert any(k.startswith("actor/") for k in metrics)


def test_math_reward_tokens_exact_and_partial():
    tok = ByteTokenizer()
    ds_prompt = tok.encode("12+34=")
    ans = 46
    Lp = len(ds_prompt)

    def build(resp_text):
        resp = list(tok.encode(resp_text)) + [tok.eos_id]
        toks = np.concatenate([ds_prompt, resp, [0] * (4 - len(resp) + 4)])
        mask = np.zeros_like(toks, bool)
        mask[Lp : Lp + len(resp)] = True
        return jnp.asarray(toks[None]), jnp.asarray(mask[None])

    t, m = build("46")
    r = reward_mod.math_reward_tokens(t, m, jnp.array([ans]), tok)
    assert float(r[0]) == 1.0
    t, m = build("41")  # first digit right
    r = reward_mod.math_reward_tokens(t, m, jnp.array([ans]), tok)
    assert float(r[0]) == pytest.approx(0.1)
    t, m = build("99")
    r = reward_mod.math_reward_tokens(t, m, jnp.array([ans]), tok)
    assert float(r[0]) == 0.0
    t, m = build("468")  # right digits but no EOS after -> not exact
    r = reward_mod.math_reward_tokens(t, m, jnp.array([ans]), tok)
    assert float(r[0]) < 1.0
