"""Multi-device behaviour tests. Each test runs a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest process
keeps seeing 1 device (per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str) -> str:
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        "import sys\n"
        f"sys.path.insert(0, {os.path.join(REPO, 'src')!r})\n"
        "from repro.utils.jax_compat import make_compat_mesh, use_mesh, shard_map, peak_memory_bytes\n"
        + textwrap.dedent(body)
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=420,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_databuffer_all_to_all_dp_resize():
    """Paper Fig. 7-8: gen stage DP=2 -> train stage DP=8. Values preserved,
    no controller traffic, redistribution counted."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import DistributedDatabuffer
        mesh = make_compat_mesh((2, 4), ('data', 'model'))
        buf = DistributedDatabuffer(mesh)
        x = jnp.arange(16 * 4.0).reshape(16, 4)
        buf.put('x', x, P('data', None))          # DP=2 (model-replicated)
        y = buf.get('x', P(('data', 'model'), None))  # DP=8
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
        assert buf.stats.redistributions == 1
        assert buf.stats.bytes_through_controller == 0
        assert len(y.sharding.device_set) == 8
        # fast path back
        z = buf.get('x', P('data'))
        assert buf.stats.fast_path_hits == 1
        print('OK')
    """)
    assert "OK" in out


def test_load_balance_repack_preserves_sharding():
    """The post-GENERATE length-aware repack must keep arrays under the
    producer's data sharding — a bare jnp.take would replicate the full
    global batch onto every device (invisible on the 1x1 CI mesh)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import DataCoordinatorConfig
        from repro.core import DistributedDatabuffer
        from repro.core.worker import DAGWorker
        mesh = make_compat_mesh((2, 4), ('data', 'model'))
        buf = DistributedDatabuffer(mesh)
        B = 8
        lengths = np.array([13, 9, 1, 1, 5, 3, 1, 1])
        mask = (np.arange(16)[None, :] < lengths[:, None]).astype(np.int32)
        buf.put('response_mask', jnp.asarray(mask), P('data', None))
        buf.put('tokens', jnp.arange(B * 16).reshape(B, 16), P('data', None))
        w = DAGWorker.__new__(DAGWorker)
        w.buffer = buf
        w.coordinator = DataCoordinatorConfig(load_balance=True, num_buckets=4)
        class C: pass
        class RL: algorithm = 'ppo'; group_size = 1
        w.ctx = C(); w.ctx.mesh = mesh; w.ctx.rl = RL()
        m = w._balance_rollouts()
        assert m['balance/repacked'] == 1.0, m
        assert m['balance/token_ratio_after'] < m['balance/token_ratio_before'], m
        for k in ('tokens', 'response_mask'):
            spec = buf.get(k).sharding.spec
            assert tuple(spec) and tuple(spec)[0] == 'data', (k, spec)
        print('OK')
    """)
    assert "OK" in out


def test_compressed_psum_close_to_exact():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum, ef_update
        mesh = make_compat_mesh((8,), ('data',))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32))

        def body(xs):
            exact = jax.lax.psum(xs[0], 'data')
            approx = compressed_psum(xs[0], 'data')
            return exact, approx
        exact, approx = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P('data', None, None),),
            out_specs=(P(), P()), check_vma=False))((x,))
        rel = np.abs(np.asarray(exact) - np.asarray(approx)).max() / np.abs(np.asarray(exact)).max()
        assert rel < 0.02, rel
        # error feedback drives bias down over repeats
        err = jnp.zeros((64, 32))
        g = x[0]
        total = jnp.zeros((64, 32))
        for _ in range(8):
            dec, err = ef_update(g, err)
            total = total + dec
        drift = np.abs(np.asarray(total/8) - np.asarray(g)).max()
        assert drift < 0.05, drift
        print('OK')
    """)
    assert "OK" in out


def test_checkpoint_elastic_restore(tmp_path):
    """Save on a (4,2) mesh, restore onto (2,2,2) multi-pod-style mesh AND a
    single device — bitwise identical params."""
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ft import checkpoint
        mesh = make_compat_mesh((4, 2), ('data', 'model'))
        tree = {{
            'w': jax.device_put(jnp.arange(64.0).reshape(8, 8),
                                NamedSharding(mesh, P('data', 'model'))),
            'b': jax.device_put(jnp.arange(8.0), NamedSharding(mesh, P('model'))),
            'step_scale': jnp.float32(3.5),
        }}
        checkpoint.save({str(tmp_path)!r}, tree, step=17)
        # elastic restore onto a different topology
        mesh2 = make_compat_mesh((2, 2, 2), ('pod', 'data', 'model'))
        specs = {{'w': P(('pod','data'), 'model'), 'b': P(None), 'step_scale': P()}}
        restored, step = checkpoint.restore({str(tmp_path)!r}, tree, mesh=mesh2, specs=specs)
        assert step == 17
        np.testing.assert_array_equal(np.asarray(restored['w']), np.asarray(tree['w']))
        np.testing.assert_array_equal(np.asarray(restored['b']), np.asarray(tree['b']))
        assert float(restored['step_scale']) == 3.5
        # host-only restore (no mesh)
        r2, _ = checkpoint.restore({str(tmp_path)!r}, tree)
        np.testing.assert_array_equal(np.asarray(r2['w']), np.asarray(tree['w']))
        print('OK')
    """)
    assert "OK" in out


def test_seq_sharded_decode_attention_matches_ref():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.collectives import seq_sharded_decode_attention
        from repro.kernels import ref
        mesh = make_compat_mesh((1, 8), ('data', 'model'))
        B, S, H, KVH, D = 2, 64, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, D))
        k = jax.random.normal(ks[1], (B, S, KVH, D))
        v = jax.random.normal(ks[2], (B, S, KVH, D))
        cl = jnp.array([40, 64], jnp.int32)
        want = ref.decode_attention(q, k, v, cl)
        got = seq_sharded_decode_attention(mesh, q, k, v, cl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)
        print('OK')
    """)
    assert "OK" in out


def test_grpo_pipeline_runs_on_multi_device_mesh():
    """End-to-end DistFlow iteration on a 2x4 mesh: per-stage DP sizes differ
    (model stages dp=2, compute stages dp=8) -> databuffer redistributes."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS, reduced
        from repro.core import build_pipeline
        from repro.rl import RLConfig
        mesh = make_compat_mesh((2, 4), ('data', 'model'))
        cfg = reduced(ARCHS['qwen2.5-7b'], vocab_size=260, num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=4, head_dim=16)
        rl = RLConfig(algorithm='grpo', group_size=4, max_new_tokens=8, lr=1e-4)
        with use_mesh(mesh):
            pipe = build_pipeline(cfg, rl, mesh=mesh, prompts_per_iter=4)
            hist = pipe.run(2)
        assert all(abs(h['actor/ratio_mean'] - 1.0) < 0.1 for h in hist)
        assert pipe.buffer.stats.redistributions > 0   # dp-resize exercised
        assert pipe.buffer.stats.bytes_through_controller == 0
        print('OK', pipe.buffer.stats)
    """)
    assert "OK" in out
