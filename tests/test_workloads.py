"""Dry-run machinery tests at reduced scale (subprocess, 8 host devices):
the same build_workload / lower / compile / analyze path as the production
dry-run, on a (2,4) mesh with reduced configs."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str) -> str:
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        "import sys\n"
        f"sys.path.insert(0, {os.path.join(REPO, 'src')!r})\n"
        "from repro.utils.jax_compat import make_compat_mesh, use_mesh, shard_map, peak_memory_bytes\n"
        + textwrap.dedent(body)
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=540, env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.parametrize("arch", ["deepseek-67b", "mixtral-8x7b", "mamba2-2.7b",
                                  "jamba-v0.1-52b"])
def test_workload_cells_compile_small_mesh(arch):
    out = run_py(f"""
        import jax
        from repro.configs import ARCHS, reduced
        from repro.configs.base import ShapeConfig
        from repro.launch.workloads import build_workload
        mesh = make_compat_mesh((2, 4), ('data', 'model'))
        cfg = reduced(ARCHS[{arch!r}], d_model=64, num_heads=4, num_kv_heads=4,
                      head_dim=16, vocab_size=256)
        with use_mesh(mesh):
            for kind, (S, B) in {{'train': (64, 8), 'prefill': (64, 8),
                                  'decode': (64, 8)}}.items():
                wl = build_workload(cfg, ShapeConfig('t', S, B, kind), mesh)
                compiled = wl.fn.lower(*wl.args).compile()
                mem = compiled.memory_analysis()
                assert peak_memory_bytes(mem) > 0
        print('OK')
    """)
    assert "OK" in out


def test_collective_parser_sees_spmd_collectives():
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.utils.hlo import collective_bytes
        mesh = make_compat_mesh((8,), ('data',))

        def f(x):  # force an all-reduce: contraction over a sharded dim
            return jnp.sum(x, axis=0)
        fn = jax.jit(f, in_shardings=NamedSharding(mesh, P('data', None)),
                     out_shardings=NamedSharding(mesh, P(None)))
        compiled = fn.lower(jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile()
        coll = collective_bytes(compiled.as_text())
        assert coll['total_count'] >= 1, compiled.as_text()[:2000]
        assert coll['total_bytes'] > 0
        print('OK', coll['per_kind_count'])
    """)
    assert "OK" in out


def test_roofline_extrapolation_consistency():
    """m(L) extrapolated from (P, 2P) must match a direct 4P-depth compile
    within 10% — the linearity assumption behind the roofline table."""
    out = run_py("""
        import dataclasses, jax
        from repro.configs import ARCHS, reduced
        from repro.configs.base import ShapeConfig
        from repro.launch.workloads import build_workload
        from repro.utils.hlo import collective_bytes, cost_summary
        mesh = make_compat_mesh((2, 4), ('data', 'model'))
        base = reduced(ARCHS['deepseek-67b'], d_model=64, num_heads=4,
                       num_kv_heads=4, head_dim=16, vocab_size=256)
        shape = ShapeConfig('t', 64, 8, 'train')

        def metrics(L):
            cfg = dataclasses.replace(base, num_layers=L)
            with use_mesh(mesh):
                wl = build_workload(cfg, shape, mesh, unroll=True)
                c = wl.fn.lower(*wl.args).compile()
            cost = cost_summary(c.cost_analysis())
            return cost['flops']
        f1, f2, f4 = metrics(1), metrics(2), metrics(4)
        pred4 = f1 + (f2 - f1) * 3
        rel = abs(pred4 - f4) / f4
        assert rel < 0.10, (f1, f2, f4, pred4, rel)
        print('OK rel=%.3f' % rel)
    """)
    assert "OK" in out
