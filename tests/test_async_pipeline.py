"""Async off-policy pipeline v2 tests (docs/async_pipeline.md): bitwise
equivalence of the lockstep scheduler vs the synchronous worker, the
staleness gate under a stalled trainer, monotone weight-version tags through
weight_sync, and the decoupled truncated-IS correction vs a hand-computed
reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.api import ExperimentSpec
from repro.configs import (
    ARCHS,
    AsyncPipelineConfig,
    DataCoordinatorConfig,
    reduced,
)
from repro.core import AsyncDAGWorker, build_pipeline
from repro.core.async_worker import PipelinedDAGWorker
from repro.distributed.weight_sync import WeightVersionStore
from repro.rl import RLConfig
from repro.rl import loss as losses
from repro.rl import trainer
from repro.rl.algorithms import AlgorithmSpec, get_algorithm
from repro.utils.jax_compat import make_compat_mesh


def mesh11():
    return make_compat_mesh((1, 1), ("data", "model"))


def small_cfg(**kw):
    base = dict(vocab_size=260, num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, head_dim=16, d_ff=128)
    base.update(kw)
    return reduced(ARCHS["qwen2.5-7b"], **base)


def _comparable(history):
    """Strip scheduler-only keys: timing and async accounting differ by
    construction; every value-bearing metric must match exactly."""
    out = []
    for m in history:
        out.append({
            k: v for k, v in m.items()
            if not k.startswith("time/") and not k.startswith("async/")
            and k != "pipeline/staleness"
        })
    return out


# --------------------------------------------------------------------------- #
# acceptance: max_staleness=0 (and disabled) are bitwise-identical to sync
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("algo", ["grpo", "ppo"])
def test_staleness0_and_disabled_bitwise_identical_to_sync(algo):
    rl = RLConfig(algorithm=algo, group_size=4, max_new_tokens=6, lr=1e-4,
                  critic_lr=1e-4)
    cfg = small_cfg()
    h_sync = _comparable(build_pipeline(cfg, rl, prompts_per_iter=4,
                                        seed=3).run(3))
    h_off = _comparable(build_pipeline(
        cfg, rl, prompts_per_iter=4, seed=3,
        async_pipeline=AsyncPipelineConfig()).run(3))
    h_s0 = _comparable(build_pipeline(
        cfg, rl, prompts_per_iter=4, seed=3,
        async_pipeline=AsyncPipelineConfig(enabled=True, max_staleness=0),
    ).run(3))
    assert h_sync == h_off  # disabled config -> the plain DAGWorker
    for a, b in zip(h_sync, h_s0):
        assert set(a) == set(b)
        for k in a:
            assert a[k] == b[k], k  # exact, not approx


def test_disabled_uses_sync_worker_and_s0_uses_scheduler():
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4)
    cfg = small_cfg()
    off = build_pipeline(cfg, rl, prompts_per_iter=2,
                         async_pipeline=AsyncPipelineConfig())
    assert not isinstance(off.worker, AsyncDAGWorker)
    on = build_pipeline(cfg, rl, prompts_per_iter=2,
                        async_pipeline=AsyncPipelineConfig(enabled=True))
    assert isinstance(on.worker, AsyncDAGWorker)
    assert on.worker.max_staleness == 0


def test_async_rejects_centralized_baseline():
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4)
    with pytest.raises(ValueError, match="centralized"):
        build_pipeline(
            small_cfg(), rl, centralized=True,
            async_pipeline=AsyncPipelineConfig(enabled=True, max_staleness=1),
        )


def test_async_config_validates():
    with pytest.raises(ValueError):
        AsyncPipelineConfig(max_staleness=-1)
    with pytest.raises(ValueError):
        AlgorithmSpec(
            name="bad", dag_factory=lambda: None,
            make_advantage=lambda rl: None, actor_loss=lambda rl, lp, b: {},
            is_correction="untruncated",
        )


# --------------------------------------------------------------------------- #
# the staleness bound under a stalled (slow) trainer
# --------------------------------------------------------------------------- #
def _fake_clock():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    return clock


@pytest.mark.parametrize("window", [0, 1, 2])
def test_staleness_bound_never_exceeded_with_stalled_trainer(window):
    """Generation must stall at the gate once the queue is window+1 deep —
    a trainer that stops consuming can never see a batch staler than the
    bound. Driven under a fake clock: the gate is count-based, not
    time-based."""
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4, lr=1e-3)
    pipe = build_pipeline(
        small_cfg(), rl, prompts_per_iter=2, seed=2,
        async_pipeline=AsyncPipelineConfig(enabled=True,
                                           max_staleness=window),
    )
    w = pipe.worker
    w.clock = _fake_clock()
    # slow trainer: dispatch only. Exactly window+1 dispatches succeed.
    for _ in range(window + 1):
        assert w.dispatch_generation({}) is not None
    stalled = {}
    assert w.dispatch_generation(stalled) is None
    assert stalled["async/gen_stalled"] == 1.0
    assert len(w._inflight) == window + 1

    # trainer catches up: every consumed batch obeys the bound, and each
    # consume frees exactly one dispatch slot
    seen = []
    while w._inflight:
        m = {}
        assert w.consume_and_train(m) is not None
        seen.append(m["async/staleness"])
        assert m["async/staleness"] <= window
    assert max(seen) <= window
    assert w.dispatch_generation({}) is not None  # gate reopens
    # weight versions advanced once per update, monotone
    assert w.weights.version == len(seen)


def test_interleaved_steady_state_staleness_is_exact():
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4, lr=1e-3)
    pipe = build_pipeline(
        small_cfg(), rl, prompts_per_iter=2, seed=5,
        async_pipeline=AsyncPipelineConfig(enabled=True, max_staleness=2),
    )
    hist = pipe.run(6)
    # warmup: the first 2 ticks are generation-only
    assert "actor/loss" not in hist[0] and "actor/loss" not in hist[1]
    assert "actor/loss" in hist[2]
    # steady state runs at exactly the configured staleness
    assert hist[4]["async/staleness"] == 2.0
    assert hist[5]["async/staleness"] == 2.0
    assert hist[5]["async/overlap_ratio"] > 0.0


# --------------------------------------------------------------------------- #
# weight-version tags through weight_sync
# --------------------------------------------------------------------------- #
def test_weight_version_tags_monotone_through_weight_sync():
    mesh = mesh11()
    params = {"w": jnp.arange(8.0).reshape(2, 4), "b": jnp.ones((4,))}
    store = WeightVersionStore()
    assert store.version == -1 and store.current is None

    v0 = store.publish(params)
    assert v0.version == 0 and store.current is v0
    # publish through the train->serve switch: the tag rides the reshard
    specs = {"w": P(), "b": P()}
    v1 = store.publish(params, mesh=mesh, target_specs=specs)
    assert v1.version == 1
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(v1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # regressions and duplicates are rejected — tags are strictly monotone
    with pytest.raises(ValueError, match="monotone"):
        store.publish(params, version=1)
    with pytest.raises(ValueError, match="monotone"):
        store.publish(params, version=0)
    store.publish(params, version=5)  # gaps are fine (skipped publishes)
    assert store.version == 5
    with pytest.raises(ValueError, match="mesh"):
        store.publish(params, target_specs=specs)


def test_pipeline_weight_versions_track_updates():
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4, lr=1e-3)
    pipe = build_pipeline(
        small_cfg(), rl, prompts_per_iter=2, seed=1,
        async_pipeline=AsyncPipelineConfig(enabled=True, max_staleness=1),
    )
    hist = pipe.run(4)
    versions = [h["async/weight_version"] for h in hist
                if "async/weight_version" in h]
    assert versions == [1.0, 2.0, 3.0]  # one publish per update, monotone
    assert pipe.worker.weights.version == 3
    # the published weights ARE the live trainer weights in this sim
    assert pipe.worker.weights.current.params is pipe.ctx.actor_state.params


# --------------------------------------------------------------------------- #
# decoupled truncated-IS correction
# --------------------------------------------------------------------------- #
def test_truncated_is_correction_matches_hand_reference():
    rng = np.random.default_rng(0)
    B, T = 4, 6
    mask = jnp.asarray(rng.integers(0, 2, (B, T)).astype(np.float32))
    old_lp = jnp.asarray(rng.normal(-1.0, 0.5, (B, T)).astype(np.float32))
    behavior_lp = jnp.asarray(rng.normal(-1.0, 0.5, (B, T)).astype(np.float32))
    adv = jnp.asarray(rng.normal(0.0, 1.0, (B, T)).astype(np.float32))
    rl = RLConfig(is_rho_max=1.5)
    spec = dataclasses.replace(get_algorithm("grpo"), name="grpo_tis_ref",
                               is_correction="truncated")
    batch = {"old_logprob": old_lp, "behavior_logprob": behavior_lp,
             "advantages": adv, "response_mask": mask}
    out, metrics = trainer.apply_is_correction(rl, spec, batch)

    # hand-computed reference: rho = min(exp(old - behaviour), rho_max),
    # masked, scaling the advantages
    rho_np = np.minimum(np.exp(np.asarray(old_lp) - np.asarray(behavior_lp)),
                        1.5)
    np.testing.assert_allclose(
        np.asarray(out["advantages"]),
        np.asarray(adv) * rho_np * np.asarray(mask), rtol=1e-6)
    m = np.asarray(mask)
    np.testing.assert_allclose(
        float(metrics["rho_mean"]), (rho_np * m).sum() / m.sum(), rtol=1e-6)
    np.testing.assert_allclose(
        float(metrics["rho_clipfrac"]),
        ((np.exp(np.asarray(old_lp) - np.asarray(behavior_lp)) > 1.5) * m
         ).sum() / m.sum(), rtol=1e-6)

    # on-policy batches (no behavior_logprob) and "none" specs pass through
    plain = {"old_logprob": old_lp, "advantages": adv, "response_mask": mask}
    assert trainer.apply_is_correction(rl, spec, plain)[0] is plain
    none_spec = get_algorithm("grpo")
    assert trainer.apply_is_correction(rl, none_spec, batch)[0] is batch


def test_truncated_is_weights_helper_bounds():
    mask = jnp.ones((2, 3))
    prox = jnp.zeros((2, 3))
    behav = jnp.asarray([[0.0, -10.0, 10.0]] * 2)  # rho = 1, e^10 (clipped), e^-10
    w = losses.truncated_is_weights(prox, behav, mask, rho_max=2.0)
    rho = np.asarray(w["rho"])
    assert rho.max() <= 2.0 and rho.min() >= 0.0
    np.testing.assert_allclose(rho[0], [1.0, 2.0, np.exp(-10.0)], rtol=1e-5)


def test_truncated_is_pipeline_applies_only_when_stale():
    rl = RLConfig(algorithm="grpo", group_size=4, max_new_tokens=6, lr=1e-3)
    spec = dataclasses.replace(get_algorithm("grpo"), name="grpo_tis_e2e",
                               is_correction="truncated")
    pipe = build_pipeline(
        small_cfg(), rl, prompts_per_iter=4, seed=0, algorithm=spec,
        async_pipeline=AsyncPipelineConfig(enabled=True, max_staleness=1),
    )
    h = pipe.run(4)
    # tick 1 consumes the warmup batch at staleness 0: no correction
    assert h[1]["async/staleness"] == 0.0
    assert h[1]["async/is_corrected"] == 0.0
    assert "actor/rho_mean" not in h[1]
    # steady state: stale batches are corrected, rho stats surface
    assert h[2]["async/is_corrected"] == 1.0
    assert h[3]["async/is_corrected"] == 1.0
    assert 0.0 < h[2]["actor/rho_mean"] <= rl.is_rho_max
    assert np.isfinite(h[3]["actor/loss"])


# --------------------------------------------------------------------------- #
# scheduler behaviour / integration
# --------------------------------------------------------------------------- #
def test_one_step_overlap_and_warmup_metrics():
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4, lr=1e-3)
    coord = DataCoordinatorConfig(double_buffer=True, prefetch=1)
    pipe = build_pipeline(
        small_cfg(), rl, prompts_per_iter=4, seed=4, coordinator=coord,
        async_pipeline=AsyncPipelineConfig(enabled=True, max_staleness=1),
    )
    hist = pipe.run(4)
    assert "actor/loss" not in hist[0]  # generation-only warmup
    assert hist[0]["async/overlap_ratio"] == 0.0
    assert "actor/loss" in hist[1]
    for h in hist[2:]:
        assert h["async/staleness"] == 1.0
        assert h["async/overlap_s"] > 0.0
        assert 0.0 < h["async/overlap_ratio"] < 0.5  # min/(gen+train) < 1/2
    # the double buffer rotated once per tick alongside the scheduler
    assert pipe.buffer.stats.rotations == 4


def test_legacy_pipelined_worker_is_staleness1_scheduler():
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4, lr=1e-3)
    pipe = build_pipeline(small_cfg(), rl, prompts_per_iter=2, seed=7)
    pipe.worker = PipelinedDAGWorker(pipe.ctx, pipe.plan,
                                     pipe.worker.registry, pipe.buffer)
    assert isinstance(pipe.worker, AsyncDAGWorker)
    assert pipe.worker.max_staleness == 1
    hist = pipe.run(3)
    assert "actor/loss" not in hist[0] and "actor/loss" in hist[1]
    assert hist[2]["pipeline/staleness"] == 1.0  # back-compat metric


def test_decoupled_driving_never_leaks_behavior_logprob():
    """Regression: under the decoupled dispatch/consume API (no per-tick
    buffer.clear), a corrected consume must not leave behavior_logprob in
    the buffer where the next dispatch's pop would pack it into an
    unrelated batch — an on-policy batch would then be IS-weighted against
    the wrong behaviour policy."""
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4, lr=1e-3)
    spec = dataclasses.replace(get_algorithm("grpo"), name="grpo_tis_leak",
                               is_correction="truncated")
    w = build_pipeline(
        small_cfg(), rl, prompts_per_iter=2, seed=11, algorithm=spec,
        async_pipeline=AsyncPipelineConfig(enabled=True, max_staleness=1),
    ).worker
    assert w.dispatch_generation({}) is not None
    assert w.dispatch_generation({}) is not None
    assert w.consume_and_train({}) is not None  # staleness 0
    m = {}
    assert w.consume_and_train(m) is not None   # staleness 1: corrected
    assert m["async/is_corrected"] == 1.0
    assert "behavior_logprob" not in w.buffer.keys()
    fresh = w.dispatch_generation({})
    assert fresh is not None
    assert "behavior_logprob" not in fresh.data


def test_async_rejects_post_train_nodes():
    """The gen/train split only preserves order when MODEL_TRAIN closes the
    chain; a DAG with a post-update node must fail fast, not silently
    reorder."""
    from repro.core import DAG, Node, NodeType, Role

    dag = DAG.from_nodes([
        Node("actor_generation", Role.ACTOR, NodeType.GENERATE),
        Node("reward_compute", Role.REWARD, NodeType.COMPUTE,
             deps=("actor_generation",)),
        Node("advantage_compute", Role.ADVANTAGE, NodeType.COMPUTE,
             deps=("reward_compute",)),
        Node("actor_train", Role.ACTOR, NodeType.MODEL_TRAIN,
             deps=("advantage_compute",)),
        Node("post_update_logprobs", Role.ACTOR, NodeType.MODEL_INFERENCE,
             deps=("actor_train",)),
    ])
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4)
    with pytest.raises(ValueError, match="post_update_logprobs"):
        build_pipeline(
            small_cfg(), rl, prompts_per_iter=2, dag=dag,
            async_pipeline=AsyncPipelineConfig(enabled=True, max_staleness=1),
        )
    # the same DAG still compiles on the synchronous worker
    build_pipeline(small_cfg(), rl, prompts_per_iter=2, dag=dag)


def test_resume_replaces_behavior_policy_before_first_publish():
    """Checkpoint-resume pattern (launch/train.py, elastic_restart):
    ctx.actor_state is replaced AFTER build_pipeline. The first dispatch
    must generate under the restored weights, not the discarded init
    snapshot."""
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4, lr=1e-3)
    pipe = build_pipeline(
        small_cfg(), rl, prompts_per_iter=2, seed=9,
        async_pipeline=AsyncPipelineConfig(enabled=True, max_staleness=1),
    )
    restored = pipe.ctx.actor_state._replace(
        params=jax.tree.map(jnp.copy, pipe.ctx.actor_state.params))
    pipe.ctx.actor_state = restored
    assert pipe.worker.dispatch_generation({}) is not None
    assert pipe.worker.weights.version == 0
    assert pipe.worker.weights.current.params is restored.params


def test_train_cli_max_staleness_overrides_experiment_file(tmp_path):
    import types

    from repro.launch import train as train_mod

    exp = ExperimentSpec(
        model=small_cfg(),
        rl=RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4),
    )
    path = tmp_path / "exp.json"
    path.write_text(exp.to_json())
    args = types.SimpleNamespace(experiment=str(path), max_staleness=1)
    loaded = train_mod.build_experiment(args)
    assert loaded.async_pipeline == AsyncPipelineConfig(enabled=True,
                                                        max_staleness=1)
    # without the flag, the file's (disabled) setting stands
    args = types.SimpleNamespace(experiment=str(path), max_staleness=None)
    assert train_mod.build_experiment(args).async_pipeline == \
        AsyncPipelineConfig()


def test_experiment_spec_round_trips_async_config():
    exp = ExperimentSpec(
        model=small_cfg(),
        rl=RLConfig(algorithm="grpo", group_size=2, max_new_tokens=4),
        async_pipeline=AsyncPipelineConfig(enabled=True, max_staleness=2),
        prompts_per_iter=2,
    )
    exp2 = ExperimentSpec.from_json(exp.to_json())
    assert exp2 == exp
    assert exp2.async_pipeline.max_staleness == 2
    pipe = exp2.compile()
    assert isinstance(pipe.worker, AsyncDAGWorker)
    assert pipe.worker.max_staleness == 2
    # legacy specs without the key deserialize to the disabled default
    d = exp.to_dict()
    del d["async_pipeline"]
    assert ExperimentSpec.from_dict(d).async_pipeline == AsyncPipelineConfig()
