"""Simulated multi-host fleet tests (docs/multihost.md).

Three layers, cheapest last:

1.  **Subprocess fleets** — ``tests/fleet/runner.FleetRunner`` spawns one
    ``train_host.py`` process per host (each forcing the whole fleet's CPU
    device count via XLA_FLAGS) against a shared coordinator directory, and
    the tests assert cross-host invariants on the per-host JSON artifacts:

    * bitwise single-host parity: a 2-host x 4-device fleet (and a 4-host x
      4-device, 16-device fleet) produces the identical params digest AND
      per-iteration metric history as a single-host run on the same device
      count;
    * the ``int8_ef`` compressed exchange keeps hosts bitwise-identical to
      each other, converges within tolerance of the exact arm, and ships
      strictly fewer wire bytes;
    * the Data Coordinator's hierarchical load balancing emits balanced
      token bins deterministically across hosts;
    * elastic recovery: SIGKILL one host mid-run; survivors detect it by
      heartbeat staleness, agree on the shrunk membership, restore from
      checkpoint, and finish with a trajectory bitwise-equal to an
      undisturbed single-host run.

2.  **In-process device probes** — subprocesses with their own forced 16-
    or 48-device backends exercising fleet mesh geometry, per-host
    databuffer staging, and ``compressed_psum`` over the ``pod`` axis.

3.  **File-plane unit tests** — FleetContext membership/epochs/waits and
    GradExchange slice mixing, run inline on the 1-device pytest process.
"""
import os
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from fleet.runner import FleetRunner

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FLEET_TIMEOUT = 600.0


def _clean(history):
    """The SPMD-invariant view of a metric history: drop wall-times and the
    fleet wire metrics (absent from solo runs by construction)."""
    return {
        it: {k: v for k, v in m.items()
             if "fleet/" not in k and not k.startswith("time/")}
        for it, m in history.items()
    }


# ================================================================== #
# layer 1: subprocess fleets
# ================================================================== #
@pytest.fixture(scope="module")
def fleet8(tmp_path_factory):
    """2-host x 4-device exact-exchange fleet + the single-host 8-device
    reference run (same seed, same iteration count). Runs with telemetry on
    (FLEET_OBS=1: span tracing + metrics snapshots), which doubles this
    fleet as the obs acceptance run — the parity assertions passing WITH
    obs enabled is itself the no-interference guarantee."""
    r = FleetRunner(tmp_path_factory.mktemp("fleet8"),
                    num_hosts=2, devices_per_host=4, iters=3,
                    extra_env={"FLEET_OBS": "1"})
    r.launch()
    r.wait(timeout=FLEET_TIMEOUT)
    arts = r.artifacts()
    solo = r.run_solo_reference(timeout=FLEET_TIMEOUT)
    return arts, solo


@pytest.fixture(scope="module")
def fleet8_comp(tmp_path_factory):
    """Same fleet, int8 error-feedback gradient compression on the wire."""
    r = FleetRunner(tmp_path_factory.mktemp("fleet8c"),
                    num_hosts=2, devices_per_host=4, iters=3,
                    compression="int8_ef")
    r.launch()
    r.wait(timeout=FLEET_TIMEOUT)
    return r.artifacts()


@pytest.fixture(scope="module")
def fleet8_balance(tmp_path_factory):
    """Same fleet with the Data Coordinator's length-aware load balancing
    enabled (hierarchical on the pod mesh)."""
    r = FleetRunner(tmp_path_factory.mktemp("fleet8b"),
                    num_hosts=2, devices_per_host=4, iters=3,
                    extra_env={"FLEET_BALANCE": "1"})
    r.launch()
    r.wait(timeout=FLEET_TIMEOUT)
    return r.artifacts()


@pytest.fixture(scope="module")
def fleet16(tmp_path_factory):
    """4-host x 4-device (16-device) fleet + its 16-device solo reference."""
    r = FleetRunner(tmp_path_factory.mktemp("fleet16"),
                    num_hosts=4, devices_per_host=4, iters=3)
    r.launch()
    r.wait(timeout=FLEET_TIMEOUT)
    arts = r.artifacts()
    solo = r.run_solo_reference(timeout=FLEET_TIMEOUT)
    return arts, solo


@pytest.fixture(scope="module")
def recovery16(tmp_path_factory):
    """16-device fleet where host 1 SIGKILLs itself at iteration 1; the
    three survivors must detect, rebalance, restore, and finish."""
    r = FleetRunner(tmp_path_factory.mktemp("recovery16"),
                    num_hosts=4, devices_per_host=4, iters=3,
                    dead_after_s=6.0)
    r.launch(die_at={1: 1})
    r.wait(hosts=[0, 2, 3], timeout=FLEET_TIMEOUT)
    r.wait(hosts=[1], expect_failure=(1,))
    return r.artifacts([0, 2, 3])


# ---------------- bitwise single-host parity ---------------- #
def test_fleet_parity_bitwise(fleet8):
    """The tentpole invariant: a 2-host fleet over the global (pod, data,
    model) mesh is bitwise-identical — params AND every per-iteration
    metric — to one process on a flat 8-device mesh."""
    arts, solo = fleet8
    assert solo["devices"] == 8
    for h, art in arts.items():
        assert art["params_sha256"] == solo["params_sha256"], f"host {h}"
        assert _clean(art["history"]) == _clean(solo["history"]), f"host {h}"


def test_fleet_parity_16_devices_4_hosts(fleet16):
    """Same invariant at fleet scale: 4 processes x 16 simulated devices."""
    arts, solo = fleet16
    assert len(arts) == 4 and solo["devices"] == 16
    shas = {h: a["params_sha256"] for h, a in arts.items()}
    assert set(shas.values()) == {solo["params_sha256"]}, shas
    for h, art in arts.items():
        assert _clean(art["history"]) == _clean(solo["history"]), f"host {h}"


def test_fleet_no_controller_traffic(fleet8):
    """Distributed dataflow: no stage output is ever gathered through a
    controller host (the scaling bottleneck the paper removes)."""
    arts, solo = fleet8
    for art in list(arts.values()) + [solo]:
        assert art["buffer"]["bytes_through_controller"] == 0


# ---------------- observability (docs/observability.md) ---------------- #
def test_fleet_obs_trace_schema(fleet8):
    """Each host exports a Chrome-trace JSON with the trace-event schema
    Perfetto loads: complete ("X") events with ts/dur, per-host pid tracks
    named by "M" metadata, per-subsystem tid tracks — and the merged fleet
    trace carries every host's pid."""
    import json

    from repro.obs.aggregate import merge_traces

    arts, _ = fleet8
    traces = []
    for h, art in arts.items():
        with open(art["obs"]["trace"]) as f:
            tr = json.load(f)
        evs = tr["traceEvents"]
        assert evs, f"host {h}: empty trace"
        for ev in evs:
            assert {"name", "ph", "pid", "tid"} <= set(ev)
            assert ev["ph"] in ("X", "M", "i")
            if ev["ph"] == "X":
                assert ev["ts"] > 0 and ev["dur"] >= 0
        assert {ev["pid"] for ev in evs} == {h}
        meta = {ev["args"]["name"] for ev in evs if ev["ph"] == "M"}
        assert f"host{h}" in meta  # the per-host process track
        cats = {ev["cat"] for ev in evs if ev["ph"] == "X"}
        # DAG node spans and the GradExchange rounds must both be on the
        # timeline; their thread tracks are named in the metadata
        assert {"dag", "fleet"} <= cats <= meta
        traces.append(tr)
    merged = merge_traces(traces)
    assert {e["pid"] for e in merged["traceEvents"]} == set(arts)


def test_fleet_obs_straggler_sum_match(fleet8):
    """launch/obs_report.py aggregation ground truth: the straggler
    report's per-host per-iteration step times equal the sums of the
    time/* metrics each host recorded in its own artifact history."""
    from repro.obs.aggregate import (collect_snapshots, render_report,
                                     straggler_report)

    arts, _ = fleet8
    coord = next(iter(arts.values()))["obs"]["snapshots_root"]
    report = straggler_report(collect_snapshots(coord))
    assert report["hosts"] == sorted(arts)
    for h, art in arts.items():
        steps = report["per_host"][h]["step_times"]
        assert sorted(steps) == sorted(int(i) for i in art["history"])
        for it, hist in art["history"].items():
            own = sum(v for k, v in hist.items() if k.startswith("time/"))
            assert steps[int(it)] == pytest.approx(own, rel=1e-12)
    # the merged fleet histogram counts every (host, iteration) step
    n_steps = sum(len(a["history"]) for a in arts.values())
    assert report["step_hist"]["count"] == n_steps
    rendered = render_report(report)
    assert "per-host summary" in rendered and "host0" in rendered
    assert "fleet step-time p50" in rendered


def test_fleet_clean_run_membership(fleet8):
    arts, _ = fleet8
    for art in arts.values():
        assert art["members"] == [0, 1]
        assert art["epoch"] == 0
        assert art["recoveries"] == 0
        assert art["dead_hosts"] == []
        assert art["monitor_dead"] == []


def test_fleet_exact_wire_accounting(fleet8):
    """grad_compression='none' ships raw fp32: wire bytes == exact bytes,
    nothing saved, one exchange per iteration."""
    arts, _ = fleet8
    for art in arts.values():
        ex = art["exchange"]
        assert ex["exchanges"] == art["iters"] == 3
        assert ex["wire_bytes"] == ex["exact_bytes"] > 0
        assert ex["wire_saved_bytes"] == 0


# ---------------- compressed exchange ---------------- #
def test_compressed_hosts_stay_identical(fleet8_comp):
    """Every host decodes the same published bytes, so compression never
    lets hosts drift from EACH OTHER — only (boundedly) from the exact arm."""
    arts = fleet8_comp
    assert arts[0]["params_sha256"] == arts[1]["params_sha256"]
    assert _clean(arts[0]["history"]) == _clean(arts[1]["history"])


def test_compressed_converges_within_tolerance(fleet8, fleet8_comp):
    arts, _ = fleet8
    comp = fleet8_comp
    # genuinely different trajectory...
    assert comp[0]["params_sha256"] != arts[0]["params_sha256"]
    # ...that stays within quantization-noise distance of the exact arm
    last = str(max(int(k) for k in arts[0]["history"]))
    exact_loss = arts[0]["history"][last]["actor/loss"]
    comp_loss = comp[0]["history"][last]["actor/loss"]
    assert abs(exact_loss - comp_loss) < 5e-3, (exact_loss, comp_loss)


def test_compressed_strictly_fewer_wire_bytes(fleet8, fleet8_comp):
    arts, _ = fleet8
    exact_ex = arts[0]["exchange"]
    comp_ex = fleet8_comp[0]["exchange"]
    assert comp_ex["exact_bytes"] == exact_ex["exact_bytes"]
    assert 0 < comp_ex["wire_bytes"] < comp_ex["exact_bytes"]
    # int8 blocks + one fp32 scale per 256 lanes vs fp32: ~0.25x
    ratio = comp_ex["wire_bytes"] / comp_ex["exact_bytes"]
    assert ratio < 0.3, ratio
    assert comp_ex["wire_saved_bytes"] == (
        comp_ex["exact_bytes"] - comp_ex["wire_bytes"])
    # per-iteration metric agrees with the cumulative counter
    hist_wire = sum(m["actor/fleet/wire_bytes"]
                    for m in fleet8_comp[0]["history"].values())
    assert hist_wire == comp_ex["wire_bytes"]


# ---------------- balanced token bins ---------------- #
def test_fleet_hierarchical_balance(fleet8_balance):
    """With load balancing on, every iteration reports token-bin balance,
    the repack never worsens the max/mean bucket ratio, the hierarchical
    (pod-aware) path is active, and both hosts compute the identical
    permutation (their metric histories match bitwise)."""
    arts = fleet8_balance
    assert _clean(arts[0]["history"]) == _clean(arts[1]["history"])
    assert arts[0]["params_sha256"] == arts[1]["params_sha256"]
    for m in arts[0]["history"].values():
        assert "balance/skipped" not in m, m
        assert m["balance/token_ratio_after"] <= (
            m["balance/token_ratio_before"] + 1e-9)
        # presence of the cross-host metric == the hierarchical path ran
        assert m["balance/cross_host_row_moves"] >= 0
        assert m["balance/repacked"] in (0.0, 1.0)


# ---------------- elastic recovery ---------------- #
def test_recovery_survivors_agree(recovery16):
    """All survivors adopt the same epoch-1 membership excluding the killed
    host, recover exactly once, and land on identical params."""
    arts = recovery16
    assert sorted(arts) == [0, 2, 3]
    shas = {h: a["params_sha256"] for h, a in arts.items()}
    assert len(set(shas.values())) == 1, shas
    for art in arts.values():
        assert art["steps"] == [0, 1, 2]  # step-count continuity, no gaps
        assert art["recoveries"] == 1
        assert art["epoch"] == 1
        assert art["members"] == [0, 2, 3]
        assert art["dead_hosts"] == [1]


def test_recovery_monitor_flags_killed_host(recovery16):
    for art in recovery16.values():
        assert 1 in art["monitor_dead"]


def test_recovery_bitwise_continuity(recovery16, fleet16):
    """Post-recovery trajectory == undisturbed single-host run, bit for bit:
    checkpoint restore + deterministic dataloader rewind + exact exchange
    leave no trace of the failure in params or losses."""
    _, solo = fleet16
    for h, art in recovery16.items():
        assert art["params_sha256"] == solo["params_sha256"], f"host {h}"
        assert _clean(art["history"]) == _clean(solo["history"]), f"host {h}"


# ================================================================== #
# layer 2: in-process device probes (own forced device counts)
# ================================================================== #
def run_py(body: str, devices: int = 16) -> str:
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        "import sys\n"
        f"sys.path.insert(0, {os.path.join(REPO, 'src')!r})\n"
        "from repro.utils.jax_compat import make_compat_mesh, use_mesh, shard_map\n"
        + textwrap.dedent(body)
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=420,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_fleet_mesh_geometry_48_devices():
    """make_fleet_mesh + host_device_groups on a 48-device simulated fleet:
    one contiguous device block per host, data x model tiling within."""
    out = run_py("""
        import jax
        from repro.launch.mesh import make_fleet_mesh
        from repro.distributed.fleet import host_device_groups
        assert len(jax.devices()) == 48
        mesh = make_fleet_mesh(4)
        assert dict(mesh.shape) == {'pod': 4, 'data': 12, 'model': 1}
        mesh2 = make_fleet_mesh(4, model_parallel=2)
        assert dict(mesh2.shape) == {'pod': 4, 'data': 6, 'model': 2}
        groups = host_device_groups(mesh2)
        assert groups == [list(range(h * 12, (h + 1) * 12)) for h in range(4)]
        mesh3 = make_fleet_mesh(3, devices_per_host=16)
        assert dict(mesh3.shape) == {'pod': 3, 'data': 16, 'model': 1}
        # a flat single-process mesh is one host
        flat = make_compat_mesh((48, 1), ('data', 'model'))
        assert host_device_groups(flat) == [list(range(48))]
        try:
            make_fleet_mesh(5)
            raise SystemExit('expected ValueError')
        except ValueError as e:
            assert 'divisible' in str(e)
        try:
            make_fleet_mesh(7, devices_per_host=7)  # needs 49 > 48
            raise SystemExit('expected ValueError')
        except ValueError as e:
            assert 'xla_force_host_platform_device_count=49' in str(e)
        print('OK')
    """, devices=48)
    assert "OK" in out


def test_databuffer_per_host_staging():
    """Cross-host-aware databuffer: every reshard charges each host only its
    own destination shard (balanced, never the full array); the centralized
    baseline gathers the full batch onto host 0 on every put."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import DistributedDatabuffer
        from repro.core.databuffer import CentralizedDatabuffer
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh(4)  # (4, 4, 1) over 16 devices
        buf = DistributedDatabuffer(mesh)
        x = jnp.arange(64 * 64, dtype=jnp.float32).reshape(64, 64)
        total = x.size * 4
        buf.put('x', x, P('pod'))
        # staging the producer's own shards is free
        assert buf.stats.max_host_inbound_bytes == 0
        buf.get('x', P(('pod', 'data')))  # 4-way -> 16-way split
        assert dict(buf.stats.host_inbound_bytes) == {
            h: total // 4 for h in range(4)}
        buf.get('x', P(None, 'pod'))  # transpose: rows-by-pod -> cols-by-pod
        assert dict(buf.stats.host_inbound_bytes) == {
            h: 2 * (total // 4) for h in range(4)}
        # balanced per-host inbound, and no host ever staged the full array
        assert buf.stats.max_host_inbound_bytes < total
        assert buf.stats.bytes_through_controller == 0

        cbuf = CentralizedDatabuffer(mesh)
        cbuf.put('x', x, P('pod'))
        assert dict(cbuf.stats.host_inbound_bytes) == {0: total}
        assert cbuf.stats.bytes_through_controller == total
        print('OK')
    """, devices=16)
    assert "OK" in out


def test_compressed_psum_over_pod_axis():
    """compressed_psum inside shard_map over the fleet's pod axis on a
    48-device mesh: every pod row ends with the (approximate) global sum,
    within int8-per-block quantization distance of the exact psum."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import compression
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh(4)  # (4, 12, 1)
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))

        def body(v):
            return (jax.lax.psum(v, 'pod'),
                    compression.compressed_psum(v, 'pod'))

        with use_mesh(mesh):
            exact, approx = jax.jit(shard_map(
                body, mesh=mesh, in_specs=(P('pod', None, None),),
                out_specs=(P('pod', None, None), P('pod', None, None)),
                check_vma=False))(x)
        exact, approx = np.asarray(exact), np.asarray(approx)
        # psum replicates the true sum into every pod row
        np.testing.assert_allclose(
            exact, np.tile(np.asarray(x).sum(0), (4, 1, 1)), rtol=1e-5)
        rel = np.abs(exact - approx).max() / np.abs(exact).max()
        assert rel < 0.05, rel
        ex_b, comp_b = compression.wire_bytes(np.asarray(x[0], np.float32))
        assert comp_b < ex_b / 3
        print('OK')
    """, devices=48)
    assert "OK" in out


# ================================================================== #
# layer 3: file-plane units (1 device, no subprocess)
# ================================================================== #
def _mk_ctx(root, pid, hosts=2, **overrides):
    from repro.configs.base import DistributedConfig
    from repro.distributed.fleet import FleetContext

    cfg = DistributedConfig(num_hosts=hosts, process_id=pid,
                            coordinator=str(root), **overrides)
    return FleetContext(cfg)


def test_fleet_context_iteration_lag_detection(tmp_path):
    c0, c1 = _mk_ctx(tmp_path, 0), _mk_ctx(tmp_path, 1)
    c0.heartbeat(0)
    c1.heartbeat(0)
    assert c0.poll_peers() == []
    c0.heartbeat(5)  # peer now >= patience iterations behind
    assert c0.poll_peers() == [1]  # never includes self


def test_fleet_context_wallclock_staleness(tmp_path):
    c0 = _mk_ctx(tmp_path, 0, dead_after_s=0.5)
    c1 = _mk_ctx(tmp_path, 1, dead_after_s=0.5)
    c0.heartbeat(0)
    c1.heartbeat(0)
    assert c0.poll_peers() == []
    time.sleep(0.7)
    c0.heartbeat(0)  # refresh self; same iteration, so no lag signal
    assert c0.poll_peers() == [1]


def test_membership_epoch_first_writer_wins(tmp_path):
    c0 = _mk_ctx(tmp_path, 0, hosts=3)
    c1 = _mk_ctx(tmp_path, 1, hosts=3)
    c0.declare_dead([2])
    assert (c0.epoch, c0.members, c0.dead_hosts) == (1, [0, 1], [2])
    c1.declare_dead([2])  # racing survivor adopts, does not re-publish
    assert (c1.epoch, c1.members) == (1, [0, 1])
    # dead host's slice ownership reassigns deterministically and totally
    assert sorted(s for ss in c0.partition().values() for s in ss) == [0, 1, 2]
    assert c0.partition()[2] == []
    assert c0.slice_owner() == c1.slice_owner()


def test_declare_self_dead_raises(tmp_path):
    c0 = _mk_ctx(tmp_path, 0)
    with pytest.raises(RuntimeError):
        c0.declare_dead([0])


def test_wait_files_raises_hosts_lost_on_stale_peer(tmp_path):
    from repro.distributed.fleet import HostsLost

    c0 = _mk_ctx(tmp_path, 0, dead_after_s=0.4)
    c1 = _mk_ctx(tmp_path, 1, dead_after_s=0.4)
    c0.heartbeat(0)
    c1.heartbeat(0)
    time.sleep(0.6)
    with pytest.raises(HostsLost) as exc:
        c0.wait_files([str(tmp_path / "never")], timeout=10.0)
    assert exc.value.hosts == [1]


def test_wait_files_adopts_published_epoch(tmp_path):
    from repro.distributed.fleet import HostsLost

    c0 = _mk_ctx(tmp_path, 0, hosts=3)
    c1 = _mk_ctx(tmp_path, 1, hosts=3)
    c0.heartbeat(0)
    c1.heartbeat(0)
    c1.declare_dead([2])  # another survivor publishes the transition
    with pytest.raises(HostsLost) as exc:
        c0.wait_files([str(tmp_path / "never")], timeout=10.0)
    assert exc.value.hosts == [2]
    assert (c0.epoch, c0.members) == (1, [0, 1])


def test_wait_files_timeout_without_detection(tmp_path):
    c0 = _mk_ctx(tmp_path, 0)
    with pytest.raises(TimeoutError):
        c0.wait_files([str(tmp_path / "never")], timeout=0.2, detect=False)


def test_barrier_rendezvous(tmp_path):
    c0, c1 = _mk_ctx(tmp_path, 0), _mk_ctx(tmp_path, 1)
    errs = []

    def arrive(c):
        try:
            c.barrier("startup", timeout=30.0)
        except Exception as e:  # pragma: no cover - failure reporting
            errs.append(e)

    ts = [threading.Thread(target=arrive, args=(c,)) for c in (c0, c1)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert not errs and not any(t.is_alive() for t in ts)


def test_ensure_context_reuse_and_replacement(tmp_path):
    from repro.configs.base import DistributedConfig
    from repro.distributed import fleet

    prev = fleet.get_context()
    try:
        cfg = DistributedConfig(num_hosts=2, process_id=0,
                                coordinator=str(tmp_path / "a"))
        a = fleet.ensure_context(cfg)
        assert fleet.ensure_context(cfg) is a  # epoch state survives rebuilds
        other = DistributedConfig(num_hosts=2, process_id=0,
                                  coordinator=str(tmp_path / "b"))
        assert fleet.ensure_context(other) is not a
    finally:
        fleet.set_context(prev)


def _run_exchange_pair(tmp_path, mode, grads_by_host, rounds=1):
    """Drive both hosts' GradExchange concurrently (publish-then-wait makes
    this deadlock-free single-process); returns per-host results per round."""
    from repro.distributed.fleet import GradExchange

    ctxs = [_mk_ctx(tmp_path, h) for h in range(2)]
    for c in ctxs:
        c.heartbeat(0)  # bring-up contract: never-beat peers look dead
    exs = [GradExchange(c, mode) for c in ctxs]
    results = {0: [], 1: []}
    errors = []

    def drive(h):
        try:
            for _ in range(rounds):
                out, metrics = exs[h](grads_by_host[h])
                results[h].append((out, metrics))
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append((h, e))

    ts = [threading.Thread(target=drive, args=(h,)) for h in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=120)
    assert not errors, errors
    assert not any(t.is_alive() for t in ts)
    return exs, results


def test_grad_exchange_exact_slice_mixing(tmp_path):
    """Host h owns slice h of the flat vector: the reconstruction every host
    returns is slice 0 from host 0's gradient + slice 1 from host 1's —
    bitwise, with the pytree structure and leaf dtypes preserved."""
    import jax.numpy as jnp

    g0 = {"w": jnp.full((30,), 1.0, jnp.float32),
          "b": jnp.full((10,), 3.0, jnp.float32)}
    g1 = {"w": jnp.full((30,), 2.0, jnp.float32),
          "b": jnp.full((10,), 4.0, jnp.float32)}
    exs, results = _run_exchange_pair(tmp_path, "none", {0: g0, 1: g1})
    out0 = results[0][0][0]
    out1 = results[1][0][0]
    # dict leaves flatten alphabetically (b then w): 40-element vector with
    # slice [0:20) from host 0 (b + first 10 of w), [20:40) from host 1
    expect_b = np.full(10, 3.0)
    expect_w = np.concatenate([np.full(10, 1.0), np.full(20, 2.0)])
    for out in (out0, out1):
        np.testing.assert_array_equal(np.asarray(out["w"]), expect_w)
        np.testing.assert_array_equal(np.asarray(out["b"]), expect_b)
    for ex in exs:
        assert ex.stats["wire_bytes"] == ex.stats["exact_bytes"] == 40 * 4


def test_grad_exchange_int8_ef_bounded_and_cheaper(tmp_path):
    import jax

    key = jax.random.PRNGKey(7)
    g = jax.random.normal(key, (600,), dtype=np.float32)
    exs, results = _run_exchange_pair(tmp_path, "int8_ef", {0: g, 1: g},
                                      rounds=2)
    vec = np.asarray(g)
    # per-block int8: elementwise error bounded by the block scale (the EF
    # round's scale can grow by half an lsb, hence 126 not 127)
    bound = np.abs(vec).max() / 126.0
    for h in range(2):
        for out, _ in results[h]:
            assert np.abs(np.asarray(out) - vec).max() <= bound
    # both hosts decode the same bytes -> identical reconstructions
    np.testing.assert_array_equal(np.asarray(results[0][0][0]),
                                  np.asarray(results[1][0][0]))
    np.testing.assert_array_equal(np.asarray(results[0][1][0]),
                                  np.asarray(results[1][1][0]))
    for ex in exs:
        assert 0 < ex.stats["wire_bytes"] < ex.stats["exact_bytes"]
        assert ex.stats["wire_saved_bytes"] > 0


def test_grad_exchange_rejects_unknown_mode(tmp_path):
    from repro.distributed.fleet import GradExchange

    with pytest.raises(ValueError):
        GradExchange(_mk_ctx(tmp_path, 0), "fp4_magic")
