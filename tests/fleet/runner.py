"""FleetRunner: spawn N host processes against one coordinator directory.

Each host is a fresh ``python tests/fleet/train_host.py`` subprocess with:

* ``XLA_FLAGS=--xla_force_host_platform_device_count=<num_hosts * devices
  _per_host>`` — the whole fleet's devices exist in every process, so the
  global mesh (and hence the SPMD program) is identical everywhere
  (SNIPPETS.md snippet 1; same isolation pattern as tests/test_multidevice).
* ``FLEET_*`` env describing its rank, the shared coordinator dir, iteration
  count, gradient compression, and (optionally) an iteration at which to
  SIGKILL itself mid-run (elastic-recovery tests).

Artifacts are one JSON file per host (params digest, per-iteration metric
history, membership/epoch view, exchange + buffer stats); tests assert the
cross-host invariants on those.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import time
from typing import Dict, List, Optional

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
HOST_PROGRAM = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "train_host.py")


class FleetRunner:
    def __init__(
        self,
        workdir: str,
        *,
        num_hosts: int = 2,
        devices_per_host: int = 4,
        iters: int = 3,
        compression: str = "none",
        seed: int = 0,
        dead_after_s: float = 8.0,
        extra_env: Optional[Dict[str, str]] = None,
    ):
        self.workdir = str(workdir)
        self.num_hosts = num_hosts
        self.devices_per_host = devices_per_host
        self.iters = iters
        self.compression = compression
        self.seed = seed
        self.dead_after_s = dead_after_s
        self.extra_env = dict(extra_env or {})
        self.coordinator = os.path.join(self.workdir, "coord")
        os.makedirs(self.coordinator, exist_ok=True)
        self.procs: Dict[int, subprocess.Popen] = {}
        self._logs: Dict[int, str] = {}

    # -------------------------------------------------------------- #
    def artifact_path(self, host: int) -> str:
        return os.path.join(self.workdir, f"artifact.host{host}.json")

    def _env(self, host: int, solo: bool, die_at: int) -> Dict[str, str]:
        n = self.num_hosts * self.devices_per_host
        env = dict(os.environ)
        env.update(self.extra_env)
        env.update({
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={n}",
            "JAX_PLATFORMS": "cpu",
            "PYTHONPATH": os.path.join(REPO, "src"),
            "FLEET_COORD": self.coordinator,
            "FLEET_NUM_HOSTS": str(self.num_hosts),
            "FLEET_PROCESS_ID": str(host),
            "FLEET_ITERS": str(self.iters),
            "FLEET_COMPRESSION": self.compression,
            "FLEET_SEED": str(self.seed),
            "FLEET_DIE_AT": str(die_at),
            "FLEET_DEAD_AFTER_S": str(self.dead_after_s),
            "FLEET_SOLO": "1" if solo else "0",
            "FLEET_ARTIFACT": self.artifact_path(host),
            "FLEET_WORKDIR": self.workdir,
        })
        return env

    def launch(self, *, die_at: Optional[Dict[int, int]] = None) -> None:
        """Start every host process (die_at: host -> iteration to SIGKILL
        itself at, for recovery tests)."""
        die_at = die_at or {}
        for h in range(self.num_hosts):
            self.launch_host(h, die_at=die_at.get(h, -1))

    def launch_host(self, host: int, *, die_at: int = -1,
                    solo: bool = False) -> subprocess.Popen:
        log = os.path.join(self.workdir, f"host{host}.log")
        self._logs[host] = log
        with open(log, "wb") as f:
            proc = subprocess.Popen(
                [sys.executable, HOST_PROGRAM],
                env=self._env(host, solo, die_at),
                stdout=f, stderr=subprocess.STDOUT, cwd=REPO,
            )
        self.procs[host] = proc
        return proc

    def run_solo_reference(self, *, timeout: float = 600.0) -> dict:
        """Single-host reference on the flat (data, model) mesh over the
        same device count — the parity baseline. Runs host id ``num_hosts``
        so its artifact never collides with fleet hosts'."""
        h = self.num_hosts  # out-of-band id
        self.launch_host(h, solo=True)
        self.wait(hosts=[h], timeout=timeout)
        return self.artifact(h)

    # -------------------------------------------------------------- #
    def kill(self, host: int) -> None:
        """SIGKILL a host (no cleanup, no goodbye — the failure under test)."""
        self.procs[host].send_signal(signal.SIGKILL)

    def wait(self, *, hosts: Optional[List[int]] = None,
             timeout: float = 600.0, expect_failure: tuple = ()) -> None:
        """Join host processes; raise (with the host's log tail) if any exits
        nonzero, except hosts listed in ``expect_failure`` (the killed ones)."""
        hosts = list(self.procs) if hosts is None else hosts
        deadline = time.monotonic() + timeout
        for h in hosts:
            left = max(deadline - time.monotonic(), 1.0)
            try:
                rc = self.procs[h].wait(timeout=left)
            except subprocess.TimeoutExpired:
                self.procs[h].kill()
                raise AssertionError(
                    f"host {h} timed out\n{self.log_tail(h)}")
            if rc != 0 and h not in expect_failure:
                raise AssertionError(
                    f"host {h} exited {rc}\n{self.log_tail(h)}")

    def log_tail(self, host: int, lines: int = 40) -> str:
        try:
            with open(self._logs[host], errors="replace") as f:
                return "".join(f.readlines()[-lines:])
        except OSError:
            return "<no log>"

    def artifact(self, host: int) -> dict:
        path = self.artifact_path(host)
        assert os.path.exists(path), (
            f"host {host} wrote no artifact\n{self.log_tail(host)}")
        with open(path) as f:
            return json.load(f)

    def artifacts(self, hosts: Optional[List[int]] = None) -> Dict[int, dict]:
        hosts = hosts if hosts is not None else list(range(self.num_hosts))
        return {h: self.artifact(h) for h in hosts}
