"""Simulated-fleet test harness (docs/multihost.md).

``runner.FleetRunner`` spawns one ``train_host.py`` subprocess per host —
each forcing the full fleet's device count via ``XLA_FLAGS`` so every
process holds the identical global ``(pod, data, model)`` mesh — wires them
to a shared coordinator directory, and collects per-host JSON artifacts for
cross-host invariant assertions (tests/test_fleet.py).
"""
