"""One fleet host: the program FleetRunner spawns per process.

Runs a tiny GRPO pipeline (same reduced config as tests/test_multidevice)
for FLEET_ITERS iterations on the global fleet mesh, exchanging DP
gradients through the shared coordinator directory, checkpointing
(actor_state, rng key) every iteration, and writing a JSON artifact with
the params digest + metric history for cross-host assertions.

Env contract (all set by tests/fleet/runner.FleetRunner):
  FLEET_COORD         shared coordinator directory
  FLEET_NUM_HOSTS     fleet size H
  FLEET_PROCESS_ID    this host's rank in [0, H)
  FLEET_ITERS         training iterations
  FLEET_COMPRESSION   none | int8_ef
  FLEET_SEED          pipeline seed
  FLEET_DIE_AT        iteration at which to SIGKILL self (-1 = never)
  FLEET_DEAD_AFTER_S  wall-clock heartbeat staleness for failure detection
  FLEET_SOLO          "1" = single-host parity reference: flat (data, model)
                      mesh over the same devices, fused actor step, no fleet
  FLEET_ARTIFACT      output JSON path
  FLEET_BALANCE       "1" = enable the Data Coordinator's length-aware
                      load balancing (hierarchical on pod meshes)
  FLEET_OBS           "1" = enable telemetry: span tracing (per-host Chrome
                      trace exported to the workdir), and — fleet hosts
                      only — per-iteration metrics snapshots over the file
                      plane for launch/obs_report.py aggregation
  FLEET_WORKDIR       scratch dir (per-host checkpoint dirs live here)

Elastic recovery: when a peer dies mid-run, the blocked exchange raises
HostsLost; this driver declares the hosts dead (membership epoch bump),
restores the last checkpoint, rebuilds the pipeline (fresh engines +
exchange under the new epoch), rewinds the dataloader, and resumes — the
post-recovery trajectory is bitwise-identical to an undisturbed run because
batch content is a pure function of the step index and the exact-mode
exchange reconstructs gradients bit-for-bit.
"""
import hashlib
import json
import os
import signal
import sys


def main() -> None:
    coord = os.environ["FLEET_COORD"]
    H = int(os.environ.get("FLEET_NUM_HOSTS", "1"))
    pid = int(os.environ.get("FLEET_PROCESS_ID", "0"))
    iters = int(os.environ.get("FLEET_ITERS", "3"))
    comp = os.environ.get("FLEET_COMPRESSION", "none")
    seed = int(os.environ.get("FLEET_SEED", "0"))
    die_at = int(os.environ.get("FLEET_DIE_AT", "-1"))
    dead_after = float(os.environ.get("FLEET_DEAD_AFTER_S", "8"))
    solo = os.environ.get("FLEET_SOLO") == "1"
    obs_on = os.environ.get("FLEET_OBS") == "1"
    artifact_path = os.environ["FLEET_ARTIFACT"]
    workdir = os.environ.get("FLEET_WORKDIR", os.path.dirname(artifact_path))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import ARCHS, reduced
    from repro.configs.base import DataCoordinatorConfig
    from repro.core import build_pipeline
    from repro.distributed import fleet
    from repro.ft import checkpoint
    from repro.launch.mesh import init_distributed, make_fleet_mesh
    from repro.rl import RLConfig
    from repro.utils.jax_compat import make_compat_mesh, use_mesh

    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260, num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=4, head_dim=16)
    # entropy bonus keeps the gradient non-zero even when the synthetic
    # rewards tie within every GRPO group (zero advantages at random init) —
    # without it the parity assertion would be vacuous (params never move)
    rl = RLConfig(algorithm="grpo", group_size=4, max_new_tokens=8, lr=1e-3,
                  entropy_coef=0.01)
    # defaults: no load-balance repack, no prefetch — the parity baseline.
    # FLEET_BALANCE=1 turns on the Data Coordinator's length-aware repack
    # (hierarchical on pod meshes) for the balanced-token-bins fleet arm.
    coordinator_cfg = DataCoordinatorConfig(
        load_balance=os.environ.get("FLEET_BALANCE") == "1")

    # scale the prompt batch with the fleet's device count so the DP sharding
    # always divides it; fleet and solo processes force the same count, so
    # both arms of a parity pair agree
    prompts_per_iter = max(8, len(jax.devices()))

    fleet_ctx = None
    dist_cfg = None
    if solo:
        n = len(jax.devices())
        mesh = make_compat_mesh((n, 1), ("data", "model"))
    else:
        fleet_ctx = init_distributed(
            coord, H, pid,
            grad_compression=comp,
            dead_after_s=dead_after,
            exchange_timeout_s=240.0,
        )
        dist_cfg = fleet_ctx.cfg
        mesh = make_fleet_mesh(H)
        fleet_ctx.start_heartbeats()
        fleet_ctx.barrier("startup", timeout=300.0)

    ckpt_dir = os.path.join(workdir, f"ckpt.host{pid}{'.solo' if solo else ''}")

    from repro.configs.base import ObsConfig

    obs_cfg = ObsConfig(enabled=True) if obs_on else None

    def build():
        return build_pipeline(
            cfg, rl, mesh=mesh, prompts_per_iter=prompts_per_iter,
            coordinator=coordinator_cfg, distributed=dist_cfg, seed=seed,
            obs=obs_cfg,
        )

    with use_mesh(mesh):
        pipe = build()
        history = {}
        recoveries = 0
        flagged_dead: set = set()
        it = 0
        while it < iters:
            if fleet_ctx is not None:
                fleet_ctx.heartbeat(it)
            if it == die_at and not solo:
                os.kill(os.getpid(), signal.SIGKILL)
            try:
                metrics = pipe.worker.run_iteration()
            except fleet.HostsLost as exc:
                print(f"[host{pid}] lost {exc.hosts} at it={it}; recovering",
                      flush=True)
                flagged_dead.update(exc.hosts)
                fleet_ctx.declare_dead(exc.hosts)
                template = {"actor": pipe.ctx.actor_state, "key": pipe.ctx.key}
                restored, step = checkpoint.restore(ckpt_dir, template)
                pipe = build()  # fresh engines + exchange under the new epoch
                # uncommitted device arrays, like a fresh model.init — jit
                # re-places them against the sharded batch exactly as the
                # original compilation did
                pipe.ctx.actor_state = jax.tree.map(
                    lambda r, t: jnp.asarray(r, dtype=t.dtype),
                    restored["actor"], pipe.ctx.actor_state)
                pipe.ctx.key = jnp.asarray(restored["key"])
                pipe.ctx.dataloader.step = step
                pipe.ctx.dataloader._built_step = step
                it = step
                recoveries += 1
                continue
            history[str(it)] = {k: float(v) for k, v in metrics.items()}
            if obs_on and fleet_ctx is not None:
                fleet_ctx.publish_metrics(it, metrics)
            checkpoint.save(
                ckpt_dir,
                {"actor": pipe.ctx.actor_state, "key": pipe.ctx.key},
                step=it + 1,
            )
            it += 1

        params = pipe.ctx.actor_state.params
        flat = np.concatenate([
            np.asarray(leaf, np.float32).ravel()
            for leaf in jax.tree_util.tree_leaves(params)
        ])
        stats = pipe.buffer.stats
        art = {
            "process_id": pid,
            "solo": solo,
            "devices": len(jax.devices()),
            "compression": comp,
            "iters": iters,
            "params_sha256": hashlib.sha256(flat.tobytes()).hexdigest(),
            "history": history,
            "steps": sorted(int(k) for k in history),
            "recoveries": recoveries,
            "epoch": fleet_ctx.epoch if fleet_ctx else 0,
            "members": fleet_ctx.members if fleet_ctx else [0],
            "dead_hosts": fleet_ctx.dead_hosts if fleet_ctx else [],
            # hosts the monitor flagged DURING training (the HostsLost path),
            # not a post-exit poll: a peer that already finished cleanly has
            # stopped heartbeating and would look wall-clock stale here.
            "monitor_dead": sorted(flagged_dead),
            "exchange": (
                dict(pipe.ctx.grad_exchange.stats)
                if fleet_ctx is not None else None
            ),
            "buffer": {
                "bytes_through_controller": stats.bytes_through_controller,
                "max_host_inbound_bytes": stats.max_host_inbound_bytes,
                "redistributions": stats.redistributions,
            },
        }
        if obs_on:
            trace_path = os.path.join(
                workdir, f"trace.host{pid}{'.solo' if solo else ''}.json")
            pipe.ctx.obs.tracer.export_chrome(trace_path)
            art["obs"] = {
                "trace": trace_path,
                "snapshots_root": coord if fleet_ctx is not None else None,
                "num_events": pipe.ctx.obs.tracer.num_events,
            }
    tmp = artifact_path + f".tmp{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(art, f, indent=1)
    os.replace(tmp, artifact_path)
    if fleet_ctx is not None:
        fleet_ctx.stop_heartbeats()
    print(f"[host{pid}] done: {art['params_sha256'][:12]}", flush=True)


if __name__ == "__main__":
    main()
