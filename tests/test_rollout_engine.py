"""Continuous-batching rollout engine tests: token-for-token lockstep
equivalence under a fixed slot schedule, slot refill, early-exit decode,
length bucketing / chunked prefill, and the pipeline/ExperimentSpec wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec
from repro.configs import ARCHS, RolloutEngineConfig, reduced
from repro.core import build_pipeline
from repro.models import get_model
from repro.rl import RLConfig
from repro.rl.rollout import generate
from repro.rl.rollout_engine import (
    ContinuousRolloutEngine,
    PromptQueue,
    lockstep_waste,
)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260, num_layers=2)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(B, Lp, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, Lp), 3, 200)


# --------------------------------------------------------------------------- #
# equivalence contract
# --------------------------------------------------------------------------- #
def test_token_identical_to_lockstep_fixed_schedule(tiny_model):
    """Under a fixed slot schedule (num_slots >= batch, single bucket) the
    engine consumes lockstep's exact key schedule and must produce the same
    tokens, masks, and lengths — the acceptance criterion of the engine."""
    cfg, model, params = tiny_model
    B, Lp, T = 8, 6, 12
    prompt = _prompts(B, Lp)
    key = jax.random.PRNGKey(6)
    # eos_id=3 at temperature 2.0 gets sampled naturally -> varied lengths
    ref = generate(model, params, prompt, key, max_new=T, temperature=2.0,
                   eos_id=3, pad_id=0)
    eng = ContinuousRolloutEngine(model, max_new=T, temperature=2.0,
                                  eos_id=3, pad_id=0)
    got = eng(params, prompt, key)
    np.testing.assert_array_equal(np.asarray(got.tokens), np.asarray(ref.tokens))
    np.testing.assert_array_equal(
        np.asarray(got.response_mask), np.asarray(ref.response_mask))
    np.testing.assert_array_equal(
        np.asarray(got.lengths), np.asarray(ref.lengths))
    # behaviour logprobs agree up to float reassociation (the engine's
    # refill prefill compiles as its own executable)
    np.testing.assert_allclose(
        np.asarray(got.old_logprob), np.asarray(ref.old_logprob), atol=5e-3)
    assert not np.all(np.asarray(ref.lengths) == T), "want some early EOS"


def test_token_identical_with_budgets(tiny_model):
    """Per-sequence response budgets: lockstep and the engine implement the
    same cap semantics, token-for-token, under the fixed schedule."""
    cfg, model, params = tiny_model
    B, Lp, T = 8, 6, 10
    prompt = _prompts(B, Lp, seed=4)
    budgets = jnp.asarray([1, 3, 10, 5, 2, 10, 7, 4], jnp.int32)
    key = jax.random.PRNGKey(12)
    ref = generate(model, params, prompt, key, max_new=T, temperature=1.0,
                   pad_id=0, budgets=budgets)
    np.testing.assert_array_equal(np.asarray(ref.lengths),
                                  np.asarray(budgets))  # cap binds (no EOS)
    eng = ContinuousRolloutEngine(model, max_new=T, temperature=1.0, pad_id=0)
    got = eng(params, prompt, key, budgets=np.asarray(budgets))
    np.testing.assert_array_equal(np.asarray(got.tokens), np.asarray(ref.tokens))
    np.testing.assert_array_equal(
        np.asarray(got.lengths), np.asarray(ref.lengths))


def test_token_identical_greedy(tiny_model):
    cfg, model, params = tiny_model
    prompt = _prompts(4, 5, seed=2)
    ref = generate(model, params, prompt, jax.random.PRNGKey(3), max_new=6,
                   temperature=0.0)
    eng = ContinuousRolloutEngine(model, max_new=6, temperature=0.0)
    got = eng(params, prompt, jax.random.PRNGKey(99))  # key-free when greedy
    np.testing.assert_array_equal(np.asarray(got.tokens), np.asarray(ref.tokens))


# --------------------------------------------------------------------------- #
# slot refill / early exit
# --------------------------------------------------------------------------- #
def test_slot_refill_completes_all_sequences(tiny_model):
    """4 slots over 16 prompts: every sequence completes, outputs are
    teacher-forcing consistent, and the queue actually refilled."""
    cfg, model, params = tiny_model
    B, Lp, T = 16, 6, 12
    prompt = _prompts(B, Lp)
    eng = ContinuousRolloutEngine(model, max_new=T, temperature=2.0,
                                  eos_id=3, pad_id=0, num_slots=4)
    got = eng(params, prompt, jax.random.PRNGKey(7))
    lens = np.asarray(got.lengths)
    assert np.all(lens >= 1) and np.all(lens <= T)
    np.testing.assert_array_equal(
        np.asarray(got.tokens[:, :Lp]), np.asarray(prompt))
    lp, _ = model.logprobs(params, got.tokens)
    m = np.asarray(got.response_mask)
    np.testing.assert_allclose(
        np.asarray(got.old_logprob)[m], np.asarray(lp)[m], atol=5e-2)
    s = eng.last_stats
    assert s["refills"] > 1, "16 prompts over 4 slots must refill"
    assert s["num_slots"] == 4
    assert 0.0 < s["slot_occupancy"] <= 1.0
    assert s["tokens"] == float(lens.sum())


def test_early_exit_all_eos_at_step_0(tiny_model):
    """Zeroed params make logits constant -> argmax is token 0; with
    eos_id=0 every sequence finishes at its first sampled token, and the
    while_loop must exit without a single decode step (lockstep would still
    scan all max_new-1 steps)."""
    cfg, model, params = tiny_model
    zeroed = jax.tree.map(jnp.zeros_like, params)
    eng = ContinuousRolloutEngine(model, max_new=16, temperature=0.0,
                                  eos_id=0, pad_id=0)
    got = eng(zeroed, _prompts(4, 6), jax.random.PRNGKey(0))
    assert np.all(np.asarray(got.lengths) == 1)
    assert eng.last_stats["decode_steps"] == 0.0
    assert eng.last_stats["padding_waste"] == 0.0


def test_early_exit_beats_lockstep_schedule(tiny_model):
    """With natural early EOS the engine must run fewer decode steps than
    lockstep's unconditional max_new-1."""
    cfg, model, params = tiny_model
    T = 48
    eng = ContinuousRolloutEngine(model, max_new=T, temperature=2.0,
                                  eos_id=3, pad_id=0)
    got = eng(params, _prompts(8, 6), jax.random.PRNGKey(11))
    lens = np.asarray(got.lengths)
    # slot s runs lens[s]-1 decode steps (token 1 comes from prefill); the
    # while_loop exits at the slowest slot instead of scanning to T-1
    assert eng.last_stats["decode_steps"] == max(lens) - 1


def test_refill_regression_mixed_budgets_no_starvation(tiny_model):
    """Queue drained mid-refill under a mixed-length budget set: every
    sequence must complete at exactly its budget (no slot starvation when
    late refills race the early-exit), and the occupancy metric must stay
    consistent with the token accounting — active lane-steps equal the
    decode-produced tokens, i.e. occupancy * slots * steps == sum(len - 1)
    over all sequences (each sequence's first token comes from prefill)."""
    cfg, model, params = tiny_model
    B, Lp, T, S = 12, 6, 16, 4
    prompt = _prompts(B, Lp, seed=21)
    # mixed budgets: several 1-token bursts (immediate-done refills), some
    # mid-length, a few full-budget stragglers — the drain pattern that
    # exercises pop() on a shrinking queue while slots free in bursts
    budgets = np.array([1, 16, 2, 1, 7, 16, 3, 1, 5, 2, 16, 4], np.int32)
    eng = ContinuousRolloutEngine(model, max_new=T, temperature=1.0,
                                  pad_id=0, num_slots=S)
    got = eng(params, prompt, jax.random.PRNGKey(17), budgets=budgets)
    lens = np.asarray(got.lengths)
    # no starvation: every sequence ran to its cap (no EOS id configured)
    np.testing.assert_array_equal(lens, budgets)
    s = eng.last_stats
    assert s["refills"] >= 2, "12 prompts over 4 slots must refill"
    # occupancy consistency: active lane-steps == decode-produced tokens
    active_steps = s["slot_occupancy"] * s["num_slots"] * s["decode_steps"]
    assert active_steps == pytest.approx(int((budgets - 1).sum()))
    assert 0.0 < s["slot_occupancy"] <= 1.0


# --------------------------------------------------------------------------- #
# bucketing / chunked prefill
# --------------------------------------------------------------------------- #
def test_prompt_queue_buckets_and_fifo():
    pad = 0
    prompts = np.zeros((6, 8), np.int32)
    for i, n in enumerate([3, 8, 2, 8, 5, 1]):
        prompts[i, :n] = 7  # n true tokens, rest pad
    q = PromptQueue(prompts, pad_id=pad, bucket=4)
    assert len(q) == 6
    # buckets: ceil(len/4)*4 -> {4: [0,2,5], 8: [1,3,4]}
    np.testing.assert_array_equal(q.bucket_len, [4, 8, 4, 8, 8, 4])
    lb, idxs = q.pop(2)
    assert lb in (4, 8) and len(idxs) == 2
    assert idxs == sorted(idxs), "FIFO within a bucket preserves order"
    total = len(idxs)
    while len(q):
        _, got = q.pop(3)
        total += len(got)
    assert total == 6


def test_prompt_queue_single_bucket_is_lockstep_schedule():
    prompts = np.full((4, 6), 9, np.int32)
    q = PromptQueue(prompts, pad_id=0, bucket=0)
    lb, idxs = q.pop(4)
    assert lb == 6 and idxs == [0, 1, 2, 3]


def test_prompt_queue_no_fresh_starvation_under_cont_pressure():
    """Regression: continuations used to be served unconditionally first,
    so an env re-queueing one continuation per finished turn — i.e. refill
    pressure exactly matching the pop rate — deferred fresh prompts
    forever. The streak bound must serve a fresh bucket within
    STARVATION_LIMIT + 1 pops no matter how fast continuations re-arrive."""
    from repro.rl.rollout_engine import _Continuation

    prompts = np.full((4, 8), 7, np.int32)
    q = PromptQueue(prompts, pad_id=0, bucket=4)
    q.push(_Continuation(0, np.array([5, 6]), None, 8))
    served_fresh_at = None
    for i in range(2 * PromptQueue.STARVATION_LIMIT + 2):
        kind, _, items = q.pop_work(2)
        if kind == "prefill":
            served_fresh_at = i
            break
        # adversary: replace every popped continuation immediately
        for c in items:
            q.push(_Continuation(c.row, c.feed, None, c.cache_len))
    assert served_fresh_at is not None, "fresh prompts starved"
    assert served_fresh_at <= PromptQueue.STARVATION_LIMIT


def test_prompt_queue_small_bucket_not_deferred_indefinitely():
    """Regression for the other starvation mode: fullest-bucket-first let a
    small bucket's head wait out every larger bucket. With aging, the lone
    short prompt must be served within a bounded number of pops even while
    the big bucket still holds work; FIFO within each bucket throughout."""
    prompts = np.zeros((12, 16), np.int32)
    prompts[0, :2] = 7  # row 0: the lone 4-bucket prompt
    for i in range(1, 12):
        prompts[i, :14] = 7  # rows 1..11: one deep 16-bucket
    q = PromptQueue(prompts, pad_id=0, bucket=4)
    popped = []
    for i in range(12):
        if not len(q):
            break
        lb, idxs = q.pop(1)
        popped.extend(idxs)
        if 0 in idxs:
            break
    assert 0 in popped, "short-bucket prompt starved"
    # the big bucket won the first STARVATION_LIMIT pops (fullest-first),
    # then aging forced the short bucket through
    assert popped.index(0) <= PromptQueue.STARVATION_LIMIT
    big = [r for r in popped if r != 0]
    assert big == sorted(big), "FIFO within a bucket must be preserved"


def test_bucketed_prefill_trims_padding(tiny_model):
    """Variable-length prompts through length-bucketed prefill: every
    sequence completes in dataset order and the refill batches prefill
    fewer lane-tokens than the padded maximum would."""
    cfg, model, params = tiny_model
    B, Lp, T = 8, 12, 8
    rng = np.random.default_rng(0)
    prompts = np.zeros((B, Lp), np.int32)
    for i in range(B):
        n = int(rng.integers(2, Lp + 1))
        prompts[i, :n] = rng.integers(3, 200, n)
    eng = ContinuousRolloutEngine(
        model, max_new=T, temperature=2.0, eos_id=3, pad_id=0,
        num_slots=4, prefill_bucket=4,
    )
    got = eng(params, jnp.asarray(prompts), jax.random.PRNGKey(5))
    s = eng.last_stats
    assert s["prefill_lane_tokens"] < B * Lp, "bucketing must trim padding"
    assert s["prefill_true_tokens"] <= s["prefill_lane_tokens"]
    lens = np.asarray(got.lengths)
    assert np.all(lens >= 1) and np.all(lens <= T)
    np.testing.assert_array_equal(
        np.asarray(got.tokens[:, :Lp]), np.asarray(prompts))


def test_chunked_prefill_token_match(tiny_model):
    """Chunked prefill (single bucket, greedy) produces the same tokens as
    the whole-prompt engine — the chunk boundary only reassociates floats."""
    cfg, model, params = tiny_model
    prompt = _prompts(4, 8, seed=9)
    whole = ContinuousRolloutEngine(model, max_new=6, temperature=0.0)
    chunked = ContinuousRolloutEngine(model, max_new=6, temperature=0.0,
                                      prefill_chunk=4)
    assert chunked.prefill_chunk == 4
    r1 = whole(params, prompt, jax.random.PRNGKey(0))
    r2 = chunked(params, prompt, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(r1.tokens), np.asarray(r2.tokens))


def test_chunked_prefill_gated_for_ssm_and_quant():
    import dataclasses

    cfg = reduced(ARCHS["mamba2-2.7b"], vocab_size=260)
    eng = ContinuousRolloutEngine(get_model(cfg), max_new=4, prefill_chunk=2)
    assert eng.prefill_chunk == 0, "SSM archs fall back to whole-prompt"
    # int8 caches too: a chunk would attend its prefix's quantize->
    # dequantized K/V, diverging from whole-prompt prefill well beyond
    # float reassociation
    qcfg = dataclasses.replace(
        reduced(ARCHS["qwen2.5-7b"], vocab_size=260), kv_quant=True)
    eng = ContinuousRolloutEngine(get_model(qcfg), max_new=4, prefill_chunk=2)
    assert eng.prefill_chunk == 0, "kv_quant falls back to whole-prompt"


# --------------------------------------------------------------------------- #
# config / pipeline wiring
# --------------------------------------------------------------------------- #
def test_rollout_engine_config_validation():
    with pytest.raises(ValueError, match="lockstep"):
        RolloutEngineConfig(engine="vllm")
    with pytest.raises(ValueError, match="num_slots"):
        RolloutEngineConfig(num_slots=-1)
    assert RolloutEngineConfig().engine == "lockstep"


def test_experiment_spec_rollout_round_trip():
    exp = ExperimentSpec(
        model=reduced(ARCHS["qwen2.5-7b"], vocab_size=260),
        rl=RLConfig(algorithm="grpo", group_size=2, max_new_tokens=8),
        rollout=RolloutEngineConfig(engine="continuous", num_slots=4,
                                    prefill_bucket=2),
    )
    assert ExperimentSpec.from_json(exp.to_json()) == exp
    # back-compat: dicts without the rollout key default to lockstep
    d = exp.to_dict()
    del d["rollout"]
    assert ExperimentSpec.from_dict(d).rollout.engine == "lockstep"


def test_continuous_engine_through_pipeline():
    """GENERATE stage drives the engine: full iterations run, slot metrics
    surface as rollout/*, and training consumes the trajectories."""
    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260)
    rl = RLConfig(algorithm="grpo", group_size=2, max_new_tokens=8, lr=1e-4)
    pipe = build_pipeline(
        cfg, rl, prompts_per_iter=4,
        rollout=RolloutEngineConfig(engine="continuous", num_slots=4),
    )
    hist = pipe.run(2)
    for m in hist:
        assert m["rollout/tokens"] > 0
        assert 0.0 < m["rollout/slot_occupancy"] <= 1.0
        assert 0.0 <= m["rollout/padding_waste"] < 1.0
        assert m["rollout/num_slots"] == 4
        assert any(k.startswith("actor/") for k in m)


def test_prompt_source_handoff():
    """The worker hands the GENERATE stage its prompt iterator: the bound
    PromptSource group-expands, and a swapped source is what the stage
    consumes."""
    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260)
    rl = RLConfig(algorithm="grpo", group_size=3, max_new_tokens=4)
    pipe = build_pipeline(cfg, rl, prompts_per_iter=2)
    assert pipe.ctx.prompt_source is not None
    assert pipe.ctx.prompt_source.group_size == 3
    prompts, answers = pipe.ctx.prompt_source.next_prompts()
    assert prompts.shape[0] == 6 and answers.shape[0] == 6  # 2 prompts x 3


def test_lockstep_waste_helper():
    assert lockstep_waste(np.array([8, 8]), 8) == 0.0
    # 2 sequences, lengths 1 and 8, max_new 8: decode produced 7 of 14 slots
    assert lockstep_waste(np.array([1, 8]), 8) == pytest.approx(0.5)
