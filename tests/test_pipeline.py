"""End-to-end pipeline integration tests (single device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import build_pipeline, grpo_dag, ppo_dag
from repro.ft import checkpoint
from repro.rl import RLConfig


def small_cfg(**kw):
    base = dict(vocab_size=260, num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, head_dim=16, d_ff=128)
    base.update(kw)
    return reduced(ARCHS["qwen2.5-7b"], **base)


def test_grpo_iteration_metrics_sane():
    pipe = build_pipeline(small_cfg(),
                          RLConfig(algorithm="grpo", group_size=4,
                                   max_new_tokens=6, lr=1e-4),
                          prompts_per_iter=4)
    m = pipe.run(2)[-1]
    assert abs(m["actor/ratio_mean"] - 1.0) < 0.05  # engines agree
    assert m["actor/entropy"] > 0
    assert m["rollout/tokens"] > 0
    assert pipe.buffer.stats.bytes_through_controller == 0


def test_ppo_iteration_with_critic():
    pipe = build_pipeline(small_cfg(),
                          RLConfig(algorithm="ppo", max_new_tokens=6,
                                   lr=1e-4, critic_lr=1e-4),
                          prompts_per_iter=8)
    m = pipe.run(2)[-1]
    assert "critic/loss" in m
    assert np.isfinite(m["critic/loss"])
    assert "actor/loss" in m


def test_centralized_and_distributed_same_math():
    """Fig. 14 invariant at unit scale: buffer arm changes no numbers."""
    rl = RLConfig(algorithm="grpo", group_size=4, max_new_tokens=4, lr=3e-4)
    cfg = small_cfg()
    h_d = build_pipeline(cfg, rl, prompts_per_iter=4, seed=11).run(3)
    h_c = build_pipeline(cfg, rl, prompts_per_iter=4, seed=11,
                         centralized=True).run(3)
    for a, b in zip(h_d, h_c):
        for k in ("reward/mean", "actor/entropy", "actor/loss"):
            # replicated vs sharded inputs re-jit with different fusion ->
            # float reduction order differs at ~1e-4; trajectories coincide
            assert a[k] == pytest.approx(b[k], rel=1e-3, abs=1e-3), k


def test_checkpoint_roundtrip_bf16(tmp_path):
    pipe = build_pipeline(small_cfg(),
                          RLConfig(algorithm="grpo", group_size=2,
                                   max_new_tokens=4, lr=1e-4),
                          prompts_per_iter=2)
    pipe.run(1)
    checkpoint.save(str(tmp_path), pipe.ctx.actor_state, step=1)
    restored, step = checkpoint.restore(str(tmp_path), pipe.ctx.actor_state)
    assert step == 1
    for a, b in zip(jax.tree.leaves(pipe.ctx.actor_state),
                    jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_learning_improves_reward():
    """A short real GRPO run on single-digit sums must lift the reward above
    the random-policy floor (the convergence benchmark does the long run)."""
    from repro.data.dataset import SyntheticMathDataset

    cfg = small_cfg(num_layers=2, d_model=128, d_ff=256)
    rl = RLConfig(algorithm="grpo", group_size=8, max_new_tokens=3,
                  lr=1e-3, kl_coef=0.0)
    ds = SyntheticMathDataset(4096, seed=1234, max_operand=4)
    pipe = build_pipeline(cfg, rl, prompts_per_iter=8, seed=1234, dataset=ds)
    # 90 iterations: the entropy collapse that precedes the reward lift takes
    # ~60 iterations at this scale (older jax releases land on a slightly
    # different but equally valid trajectory than the one 40 was tuned for)
    hist = pipe.run(90)
    early = np.mean([h["reward/mean"] for h in hist[:8]])
    late = np.mean([h["reward/mean"] for h in hist[-8:]])
    assert late > early + 0.05, (early, late)  # genuine improvement
    assert hist[-1]["actor/entropy"] < hist[0]["actor/entropy"]
