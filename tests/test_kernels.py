"""Per-kernel correctness sweeps: Pallas (interpret=True) vs ref.py oracles.

Shapes/dtypes swept per the deliverable: every kernel is exercised across
block-divisible and ragged shapes, GQA group sizes, fp32/bf16, and the
masking variants (causal / sliding-window / partial cache fill).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as fa_pallas
from repro.kernels.decode_attention import (
    _pick_block_s,
    _ragged_block_index,
    decode_attention as da_pallas,
    decode_attention_quant as daq_pallas,
    paged_decode_attention as pda_pallas,
)
from repro.kernels.sampling import fused_sample as fs_pallas
from repro.kernels.ssd import ssd as ssd_pallas
from repro.kernels.rmsnorm import rmsnorm as rn_pallas

jax.config.update("jax_default_matmul_precision", "highest")


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("S,H,KVH,D,block", [
    (128, 4, 4, 32, 64),    # MHA
    (256, 4, 2, 64, 64),    # GQA group 2
    (256, 8, 1, 32, 128),   # MQA
    (192, 4, 4, 64, 64),    # ragged seq vs block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 96])
def test_flash_attention(S, H, KVH, D, block, dtype, window):
    if S % block != 0:
        pytest.skip("pallas path requires block-divisible seq (wrapper asserts)")
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    B = 2
    q = jax.random.normal(k[0], (B, S, H, D), dtype)
    kk = jax.random.normal(k[1], (B, S, KVH, D), dtype)
    vv = jax.random.normal(k[2], (B, S, KVH, D), dtype)
    o_ref = ref.flash_attention(q, kk, vv, causal=True, window=window)
    o_pal = fa_pallas(q, kk, vv, causal=True, window=window,
                      block_q=block, block_k=block, interpret=True)
    np.testing.assert_allclose(np.array(o_pal, np.float32),
                               np.array(o_ref, np.float32), **tol(dtype))


def test_flash_attention_q_offset():
    """Chunked prefill: queries are a suffix of the kv sequence."""
    k = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, D = 1, 128, 2, 32
    q = jax.random.normal(k[0], (B, 64, H, D))
    kk = jax.random.normal(k[1], (B, S, H, D))
    vv = jax.random.normal(k[2], (B, S, H, D))
    o_ref = ref.flash_attention(q, kk, vv, causal=True, q_offset=64)
    o_pal = fa_pallas(q, kk, vv, causal=True, q_offset=64,
                      block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.array(o_pal), np.array(o_ref), atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# decode attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("S,H,KVH,D,block", [
    (256, 4, 4, 32, 64),
    (512, 8, 2, 64, 128),
    (256, 16, 1, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(S, H, KVH, D, block, dtype):
    k = jax.random.split(jax.random.PRNGKey(2), 3)
    B = 3
    q = jax.random.normal(k[0], (B, H, D), dtype)
    kk = jax.random.normal(k[1], (B, S, KVH, D), dtype)
    vv = jax.random.normal(k[2], (B, S, KVH, D), dtype)
    cl = jnp.array([S // 3, S, 1], jnp.int32)  # partial / full / single-slot
    o_r, l_r = ref.decode_attention(q, kk, vv, cl, return_lse=True)
    o_p, l_p = da_pallas(q, kk, vv, cl, block_s=block, interpret=True)
    np.testing.assert_allclose(np.array(o_p, np.float32),
                               np.array(o_r, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.array(l_p), np.array(l_r), atol=1e-3, rtol=1e-3)


def test_decode_attention_sharded_combine():
    """Sequence-sharded cache: per-shard (o,lse) must combine exactly."""
    k = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, H, KVH, D, P = 2, 256, 4, 2, 32, 4
    q = jax.random.normal(k[0], (B, H, D))
    kk = jax.random.normal(k[1], (B, S, KVH, D))
    vv = jax.random.normal(k[2], (B, S, KVH, D))
    cl = jnp.array([S - 10, S // 2], jnp.int32)
    o_full, _ = da_pallas(q, kk, vv, cl, block_s=64, interpret=True)
    shard = S // P
    os_, ls_ = [], []
    for i in range(P):
        o_i, l_i = da_pallas(q, kk[:, i * shard:(i + 1) * shard],
                             vv[:, i * shard:(i + 1) * shard], cl,
                             pos_offset=i * shard, block_s=64, interpret=True)
        os_.append(o_i)
        ls_.append(l_i)
    o_comb = ref.combine_decode_shards(jnp.stack(os_), jnp.stack(ls_))
    np.testing.assert_allclose(np.array(o_comb), np.array(o_full), atol=2e-5, rtol=2e-5)


def test_pick_block_s_largest_divisor():
    assert _pick_block_s(256, 64) == 64
    assert _pick_block_s(160, 64) == 40   # non-power-of-two arena width
    assert _pick_block_s(160, 512) == 160
    assert _pick_block_s(7, 4) == 1       # prime: falls to 1, grid still exact
    assert _pick_block_s(96, 64) == 48
    for S in (96, 160, 192, 250):
        bs = _pick_block_s(S, 64)
        assert S % bs == 0 and bs <= 64


def test_ragged_block_index_clamps():
    """Dead grid steps must repeat a live block index (so Pallas elides the
    copy) and live steps must map to themselves."""
    f = functools.partial(_ragged_block_index, block_s=64, num_blocks=4,
                          pos_offset=0, window=None)
    lens = jnp.int32(130)  # needs blocks 0..2
    got = [int(f(jnp.int32(si), lens)) for si in range(4)]
    assert got == [0, 1, 2, 2]  # step 3 re-fetches block 2: copy elided
    # kv_len=1 needs only block 0
    assert [int(f(jnp.int32(si), jnp.int32(1))) for si in range(4)] == [0] * 4
    # full cache: identity
    assert [int(f(jnp.int32(si), jnp.int32(256))) for si in range(4)] == [0, 1, 2, 3]
    # SWA clamps the head too: window=64, kv_len=256 -> live kpos 192..255,
    # exactly block 3 (first = (256-64)//64 = 3); blocks 0-2 are dead steps
    fw = functools.partial(_ragged_block_index, block_s=64, num_blocks=4,
                           pos_offset=0, window=64)
    assert [int(fw(jnp.int32(si), jnp.int32(256))) for si in range(4)] == [3] * 4
    # window=96 straddles a block boundary: live kpos 160..255 -> blocks 2..3
    fw2 = functools.partial(_ragged_block_index, block_s=64, num_blocks=4,
                            pos_offset=0, window=96)
    assert [int(fw2(jnp.int32(si), jnp.int32(256))) for si in range(4)] == [2, 2, 2, 3]
    # sharded: pos_offset shifts the live range
    fo = functools.partial(_ragged_block_index, block_s=64, num_blocks=4,
                           pos_offset=256, window=None)
    assert [int(fo(jnp.int32(si), jnp.int32(300))) for si in range(4)] == [0, 0, 0, 0]


def test_decode_attention_non_power_of_two_seq():
    """Regression: S=160 used to trip ``assert S % block_s == 0`` with the
    default block; the wrapper now auto-picks the largest divisor (40)."""
    k = jax.random.split(jax.random.PRNGKey(9), 3)
    B, S, H, KVH, D = 2, 160, 4, 2, 32
    q = jax.random.normal(k[0], (B, H, D))
    kk = jax.random.normal(k[1], (B, S, KVH, D))
    vv = jax.random.normal(k[2], (B, S, KVH, D))
    cl = jnp.array([97, 160], jnp.int32)
    o_r, l_r = ref.decode_attention(q, kk, vv, cl, return_lse=True)
    o_p, l_p = da_pallas(q, kk, vv, cl, block_s=64, interpret=True)
    np.testing.assert_allclose(np.array(o_p), np.array(o_r), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.array(l_p), np.array(l_r), atol=1e-3, rtol=1e-3)


def test_decode_attention_ragged_edges():
    """kv_len = 1 (single live slot) and kv_len = S (no dead tiles) are the
    fetch-skip clamp's boundary cases."""
    k = jax.random.split(jax.random.PRNGKey(10), 3)
    B, S, H, KVH, D = 2, 256, 4, 2, 32
    q = jax.random.normal(k[0], (B, H, D))
    kk = jax.random.normal(k[1], (B, S, KVH, D))
    vv = jax.random.normal(k[2], (B, S, KVH, D))
    cl = jnp.array([1, S], jnp.int32)
    o_r, l_r = ref.decode_attention(q, kk, vv, cl, return_lse=True)
    o_p, l_p = da_pallas(q, kk, vv, cl, block_s=64, interpret=True)
    np.testing.assert_allclose(np.array(o_p), np.array(o_r), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.array(l_p), np.array(l_r), atol=1e-3, rtol=1e-3)


def test_decode_attention_sliding_window():
    k = jax.random.split(jax.random.PRNGKey(4), 3)
    B, S, H, KVH, D = 2, 256, 4, 2, 32
    q = jax.random.normal(k[0], (B, H, D))
    kk = jax.random.normal(k[1], (B, S, KVH, D))
    vv = jax.random.normal(k[2], (B, S, KVH, D))
    cl = jnp.array([200, 256], jnp.int32)
    o_r, _ = ref.decode_attention(q, kk, vv, cl, window=64, return_lse=True)
    o_p, _ = da_pallas(q, kk, vv, cl, window=64, block_s=64, interpret=True)
    np.testing.assert_allclose(np.array(o_p), np.array(o_r), atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# paged decode attention (block-table gather through the serving page pool)
# --------------------------------------------------------------------------- #
def _paged_pool(key, B, S, KVH, D, ps, extra_pages=5, dtype=jnp.float32):
    """A contiguous cache plus the same KV scattered into a scrambled page
    pool with per-sequence block tables (plus unowned garbage pages)."""
    k1, k2, k3 = jax.random.split(key, 3)
    kk = jax.random.normal(k1, (B, S, KVH, D), dtype)
    vv = jax.random.normal(k2, (B, S, KVH, D), dtype)
    T = S // ps
    P = B * T + extra_pages
    perm = np.random.default_rng(0).permutation(P)[: B * T]
    tables = perm.reshape(B, T).astype(np.int32)
    pool_k = jax.random.normal(k3, (P, ps, KVH, D), dtype)  # garbage base
    pool_v = jax.random.normal(jax.random.fold_in(k3, 1), (P, ps, KVH, D), dtype)
    kp = kk.reshape(B * T, ps, KVH, D)
    vp = vv.reshape(B * T, ps, KVH, D)
    pool_k = pool_k.at[perm].set(kp)
    pool_v = pool_v.at[perm].set(vp)
    return kk, vv, pool_k, pool_v, jnp.asarray(tables)


@pytest.mark.parametrize("S,H,KVH,D,ps", [
    (64, 4, 4, 32, 8),     # MHA, small pages
    (128, 8, 2, 64, 16),   # GQA
    (64, 8, 1, 32, 8),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(S, H, KVH, D, ps, dtype):
    k = jax.random.split(jax.random.PRNGKey(11), 2)
    B = 3
    q = jax.random.normal(k[0], (B, H, D), dtype)
    kk, vv, pool_k, pool_v, tables = _paged_pool(k[1], B, S, KVH, D, ps,
                                                 dtype=dtype)
    cl = jnp.array([S // 3, S, 1], jnp.int32)
    o_r, l_r = ref.decode_attention(q, kk, vv, cl, return_lse=True)
    o_p, l_p = pda_pallas(q, pool_k, pool_v, tables, cl, interpret=True)
    np.testing.assert_allclose(np.array(o_p, np.float32),
                               np.array(o_r, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.array(l_p), np.array(l_r), atol=1e-3, rtol=1e-3)


def test_paged_decode_matches_ref_paged_oracle():
    """The ref paged oracle (gather pages -> contiguous -> ref decode) and
    the Pallas table-gather kernel agree; garbage in unowned pool pages and
    in owned-but-dead table tails must not leak into either."""
    k = jax.random.split(jax.random.PRNGKey(12), 2)
    B, S, H, KVH, D, ps = 2, 64, 4, 2, 32, 8
    q = jax.random.normal(k[0], (B, H, D))
    _, _, pool_k, pool_v, tables = _paged_pool(k[1], B, S, KVH, D, ps)
    cl = jnp.array([13, 50], jnp.int32)  # mid-page raggedness
    o_r, l_r = ref.paged_decode_attention(q, pool_k, pool_v, tables, cl,
                                          return_lse=True)
    o_p, l_p = pda_pallas(q, pool_k, pool_v, tables, cl, interpret=True)
    np.testing.assert_allclose(np.array(o_p), np.array(o_r), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.array(l_p), np.array(l_r), atol=1e-3, rtol=1e-3)


# --------------------------------------------------------------------------- #
# fused sampling (hidden @ head -> temperature -> sample, no HBM logits)
# --------------------------------------------------------------------------- #
def _sampler_inputs(key, B, d, V, Vp=None):
    k1, k2 = jax.random.split(key)
    h = jax.random.normal(k1, (B, d), jnp.float32)
    w = jax.random.normal(k2, (d, Vp or V), jnp.float32) * 0.3
    return h, w


def test_fused_sample_greedy_bitwise():
    """inv_temp == 0 must reduce to exact argmax over the true logits,
    including jnp.argmax's first-max tie-breaking, and the returned logprob
    is the untempered log_softmax at that token."""
    h, w = _sampler_inputs(jax.random.PRNGKey(13), 4, 32, 384)
    # manufacture ties: duplicate a column block
    w = w.at[:, 100].set(w[:, 300])
    logits = h @ w
    seeds = jnp.arange(4, dtype=jnp.int32)
    tok, lp = fs_pallas(h, w, seeds, jnp.zeros(4), interpret=True)
    want = jnp.argmax(logits, axis=-1)
    assert np.array_equal(np.array(tok), np.array(want))
    want_lp = jax.nn.log_softmax(logits, axis=-1)[jnp.arange(4), want]
    np.testing.assert_allclose(np.array(lp), np.array(want_lp),
                               atol=1e-5, rtol=1e-5)


def test_fused_sample_logprob_is_untempered():
    """Sampled under temperature != 1, the logprob is still the UNTEMPERED
    distribution's log_softmax at the sampled token (the behaviour-policy
    contract of rl.rollout)."""
    h, w = _sampler_inputs(jax.random.PRNGKey(14), 8, 32, 256)
    logits = h @ w
    seeds = jnp.arange(8, dtype=jnp.int32)
    tok, lp = fs_pallas(h, w, seeds, jnp.full((8,), 1.0 / 0.7), interpret=True)
    want_lp = jax.nn.log_softmax(logits, axis=-1)[jnp.arange(8), tok]
    np.testing.assert_allclose(np.array(lp), np.array(want_lp),
                               atol=1e-5, rtol=1e-5)


def test_fused_sample_vocab_mask_never_sampled():
    """Padded vocab columns (vocab_size < padded width) must have zero
    sampling probability at any temperature."""
    V, Vp = 250, 256
    h, w = _sampler_inputs(jax.random.PRNGKey(15), 16, 32, V, Vp)
    # make the padded tail maximally attractive
    w = w.at[:, V:].set(10.0)
    for it in (0.0, 1.0, 2.0):
        for s in range(8):
            seeds = jnp.arange(16, dtype=jnp.int32) + 16 * s
            tok, _ = fs_pallas(h, w, seeds, jnp.full((16,), it),
                               vocab_size=V, interpret=True)
            assert int(jnp.max(tok)) < V


def test_fused_sample_statistics_match_softmax():
    """Empirical draw frequencies track softmax(logits/T) within 4 sigma —
    the hash-Gumbel stream is a different RNG than jax.random.categorical,
    so equivalence is distributional, not bitwise."""
    d, V, N, temp = 16, 8, 4000, 0.9
    h, w = _sampler_inputs(jax.random.PRNGKey(16), 1, d, V)
    logits = (h @ w)[0]
    p = np.array(jax.nn.softmax(logits / temp))
    h_rep = jnp.broadcast_to(h, (N, d))
    seeds = jnp.arange(N, dtype=jnp.int32)
    tok, _ = fs_pallas(h_rep, w, seeds, jnp.full((N,), 1.0 / temp),
                       interpret=True)
    counts = np.bincount(np.array(tok), minlength=V)
    for t in range(V):
        sigma = max((N * p[t] * (1 - p[t])) ** 0.5, 1.0)
        assert abs(counts[t] - N * p[t]) < 4 * sigma, (t, counts[t], N * p[t])


def test_fused_sample_block_v_invariance():
    """The online max/lse/winner accumulation must not depend on the vocab
    tiling (512-wide vs full-width single tile)."""
    h, w = _sampler_inputs(jax.random.PRNGKey(17), 4, 32, 1024)
    seeds = jnp.arange(4, dtype=jnp.int32)
    it = jnp.full((4,), 1.25)
    tok_a, lp_a = fs_pallas(h, w, seeds, it, block_v=256, interpret=True)
    tok_b, lp_b = fs_pallas(h, w, seeds, it, block_v=1024, interpret=True)
    assert np.array_equal(np.array(tok_a), np.array(tok_b))
    np.testing.assert_allclose(np.array(lp_a), np.array(lp_b),
                               atol=1e-5, rtol=1e-5)


def test_ref_fused_sample_matches_op_sequence():
    """The ref oracle is bitwise the historical decode-path op sequence
    (sample_token + untempered log_softmax gather) — the anchor the engines'
    ref dispatch mode relies on."""
    from repro.kernels import ref as kref
    from repro.rl.rollout import sample_token

    h, w = _sampler_inputs(jax.random.PRNGKey(18), 4, 32, 256)
    logits = h @ w
    key = jax.random.PRNGKey(99)
    for temp in (0.0, 0.7, 1.0):
        tok, lp = kref.fused_sample(h, w, key, temp)
        want = sample_token(logits, key, temp)
        assert np.array_equal(np.array(tok), np.array(want))
        want_lp = jax.nn.log_softmax(logits, axis=-1)[jnp.arange(4), want]
        assert np.array_equal(np.array(lp), np.array(want_lp))


def test_top_p_filter():
    from repro.kernels import ref as kref

    logits = jnp.log(jnp.array([[0.5, 0.3, 0.15, 0.05]]))
    # top_p >= 1 is the identity OBJECT (python-level skip stays bitwise)
    assert kref.top_p_filter(logits, 1.0) is logits

    def kept(top_p):  # NEG_INF is a finite sentinel (-1e30), not -inf
        return (np.array(kref.top_p_filter(logits, top_p))[0] > -1e29).tolist()

    assert kept(0.75) == [True, True, False, False]
    # the top-1 token always survives, even for tiny top_p
    assert kept(1e-9) == [True, False, False, False]
def _quantized_cache(key, B, S, KVH, D):
    from repro.models.lm import quant_kv

    k1, k2 = jax.random.split(key)
    kk = jax.random.normal(k1, (B, S, KVH, D), jnp.bfloat16)
    vv = jax.random.normal(k2, (B, S, KVH, D), jnp.bfloat16)
    kq, ks = quant_kv(kk)
    vq, vs = quant_kv(vv)
    return kq, vq, ks, vs


@pytest.mark.parametrize("S,H,KVH,D,block", [
    (128, 4, 2, 32, 64),   # GQA, per-slot varied fills
    (256, 4, 4, 32, 64),   # MHA
    (512, 8, 2, 64, 128),  # larger cache
    (256, 16, 1, 32, 64),  # MQA
])
@pytest.mark.parametrize("window", [None, 96])
def test_decode_attention_quant(S, H, KVH, D, block, window):
    """Fused-dequant Pallas kernel vs the dequantize-up-front oracle, across
    per-slot variable cache_len (the continuous engine's slot fills)."""
    k = jax.random.split(jax.random.PRNGKey(5), 2)
    B = 3
    q = jax.random.normal(k[0], (B, H, D), jnp.float32)
    kq, vq, ks, vs = _quantized_cache(k[1], B, S, KVH, D)
    cl = jnp.array([S // 3, S, 1], jnp.int32)
    o_r, l_r = ref.decode_attention_quant(
        q, kq, vq, ks, vs, cl, window=window, return_lse=True)
    o_p, l_p = daq_pallas(q, kq, vq, ks, vs, cl, window=window,
                          block_s=block, interpret=True)
    np.testing.assert_allclose(np.array(o_p, np.float32),
                               np.array(o_r, np.float32), atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.array(l_p), np.array(l_r),
                               atol=1e-2, rtol=1e-2)


def test_decode_attention_quant_matches_unfused():
    """The fused kernel must agree with dequantizing the whole cache and
    running the plain kernel — the exact computation it replaces in
    ``lm._decode_quant``."""
    from repro.models.lm import dequant_kv

    k = jax.random.split(jax.random.PRNGKey(6), 2)
    B, S, H, KVH, D = 2, 256, 4, 2, 32
    q = jax.random.normal(k[0], (B, H, D), jnp.float32)
    kq, vq, ks, vs = _quantized_cache(k[1], B, S, KVH, D)
    cl = jnp.array([77, 200], jnp.int32)
    o_fused, l_fused = daq_pallas(q, kq, vq, ks, vs, cl, block_s=64,
                                  interpret=True)
    o_unf, l_unf = da_pallas(q, dequant_kv(kq, ks), dequant_kv(vq, vs), cl,
                             block_s=64, interpret=True)
    np.testing.assert_allclose(np.array(o_fused, np.float32),
                               np.array(o_unf, np.float32),
                               atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.array(l_fused), np.array(l_unf),
                               atol=1e-2, rtol=1e-2)


# --------------------------------------------------------------------------- #
# SSD
# --------------------------------------------------------------------------- #
def _ssd_inputs(key, b, s, nh, p, g, n, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (b, s, nh, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (b, s, g, n), dtype)
    Cm = jax.random.normal(ks[4], (b, s, g, n), dtype)
    D = jax.random.normal(ks[5], (nh,))
    return x, dt, A, Bm, Cm, D


@pytest.mark.parametrize("s,nh,p,g,n,chunk", [
    (64, 2, 16, 1, 16, 16),
    (128, 4, 32, 2, 16, 32),
    (256, 4, 64, 4, 32, 64),
    (128, 8, 64, 1, 128, 128),  # mamba2-like (ngroups=1, N=128)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_scan(s, nh, p, g, n, chunk, dtype):
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(5), 2, s, nh, p, g, n, dtype)
    y_r, h_r = ref.ssd_scan(x, dt, A, Bm, Cm, D, return_state=True)
    y_p, h_p = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    # fp32 tol: chunked recurrence vs sequential scan accumulate in different
    # orders; the worst observed element error varies with the jax/XLA version
    # (~3e-4 on CPU jax 0.4.x), so leave headroom above it
    t = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.array(y_p, np.float32), np.array(y_r, np.float32), **t)
    np.testing.assert_allclose(np.array(h_p), np.array(h_r), atol=1e-3, rtol=1e-3)


def test_ssd_chunked_ref_matches_scan():
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(6), 2, 96, 4, 8, 2, 8)
    y1 = ref.ssd_scan(x, dt, A, Bm, Cm, D)
    y2 = ref.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=32)
    np.testing.assert_allclose(np.array(y1), np.array(y2), atol=1e-4, rtol=1e-4)


def test_ssd_decode_matches_scan_prefix():
    b, s, nh, p, g, n = 2, 16, 4, 8, 2, 8
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(7), b, s, nh, p, g, n)
    y_scan = ref.ssd_scan(x, dt, A, Bm, Cm, D)
    h = jnp.zeros((b, nh, p, n))
    for t in range(s):
        y_t, h = ref.ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D, h)
        np.testing.assert_allclose(np.array(y_t), np.array(y_scan[:, t]),
                                   atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------- #
# rmsnorm
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("shape", [(4, 64), (3, 100, 64), (7, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    k = jax.random.split(jax.random.PRNGKey(8), 2)
    x = jax.random.normal(k[0], shape, dtype)
    w = (jax.random.normal(k[1], (shape[-1],)) * 0.1).astype(dtype)
    y_r = ref.rmsnorm(x, w)
    y_p = rn_pallas(x, w, block_rows=32, interpret=True)
    np.testing.assert_allclose(np.array(y_p, np.float32),
                               np.array(y_r, np.float32), **tol(dtype))
