"""Per-kernel correctness sweeps: Pallas (interpret=True) vs ref.py oracles.

Shapes/dtypes swept per the deliverable: every kernel is exercised across
block-divisible and ragged shapes, GQA group sizes, fp32/bf16, and the
masking variants (causal / sliding-window / partial cache fill).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as fa_pallas
from repro.kernels.decode_attention import decode_attention as da_pallas
from repro.kernels.decode_attention import decode_attention_quant as daq_pallas
from repro.kernels.ssd import ssd as ssd_pallas
from repro.kernels.rmsnorm import rmsnorm as rn_pallas

jax.config.update("jax_default_matmul_precision", "highest")


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("S,H,KVH,D,block", [
    (128, 4, 4, 32, 64),    # MHA
    (256, 4, 2, 64, 64),    # GQA group 2
    (256, 8, 1, 32, 128),   # MQA
    (192, 4, 4, 64, 64),    # ragged seq vs block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 96])
def test_flash_attention(S, H, KVH, D, block, dtype, window):
    if S % block != 0:
        pytest.skip("pallas path requires block-divisible seq (wrapper asserts)")
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    B = 2
    q = jax.random.normal(k[0], (B, S, H, D), dtype)
    kk = jax.random.normal(k[1], (B, S, KVH, D), dtype)
    vv = jax.random.normal(k[2], (B, S, KVH, D), dtype)
    o_ref = ref.flash_attention(q, kk, vv, causal=True, window=window)
    o_pal = fa_pallas(q, kk, vv, causal=True, window=window,
                      block_q=block, block_k=block, interpret=True)
    np.testing.assert_allclose(np.array(o_pal, np.float32),
                               np.array(o_ref, np.float32), **tol(dtype))


def test_flash_attention_q_offset():
    """Chunked prefill: queries are a suffix of the kv sequence."""
    k = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, D = 1, 128, 2, 32
    q = jax.random.normal(k[0], (B, 64, H, D))
    kk = jax.random.normal(k[1], (B, S, H, D))
    vv = jax.random.normal(k[2], (B, S, H, D))
    o_ref = ref.flash_attention(q, kk, vv, causal=True, q_offset=64)
    o_pal = fa_pallas(q, kk, vv, causal=True, q_offset=64,
                      block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.array(o_pal), np.array(o_ref), atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# decode attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("S,H,KVH,D,block", [
    (256, 4, 4, 32, 64),
    (512, 8, 2, 64, 128),
    (256, 16, 1, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(S, H, KVH, D, block, dtype):
    k = jax.random.split(jax.random.PRNGKey(2), 3)
    B = 3
    q = jax.random.normal(k[0], (B, H, D), dtype)
    kk = jax.random.normal(k[1], (B, S, KVH, D), dtype)
    vv = jax.random.normal(k[2], (B, S, KVH, D), dtype)
    cl = jnp.array([S // 3, S, 1], jnp.int32)  # partial / full / single-slot
    o_r, l_r = ref.decode_attention(q, kk, vv, cl, return_lse=True)
    o_p, l_p = da_pallas(q, kk, vv, cl, block_s=block, interpret=True)
    np.testing.assert_allclose(np.array(o_p, np.float32),
                               np.array(o_r, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.array(l_p), np.array(l_r), atol=1e-3, rtol=1e-3)


def test_decode_attention_sharded_combine():
    """Sequence-sharded cache: per-shard (o,lse) must combine exactly."""
    k = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, H, KVH, D, P = 2, 256, 4, 2, 32, 4
    q = jax.random.normal(k[0], (B, H, D))
    kk = jax.random.normal(k[1], (B, S, KVH, D))
    vv = jax.random.normal(k[2], (B, S, KVH, D))
    cl = jnp.array([S - 10, S // 2], jnp.int32)
    o_full, _ = da_pallas(q, kk, vv, cl, block_s=64, interpret=True)
    shard = S // P
    os_, ls_ = [], []
    for i in range(P):
        o_i, l_i = da_pallas(q, kk[:, i * shard:(i + 1) * shard],
                             vv[:, i * shard:(i + 1) * shard], cl,
                             pos_offset=i * shard, block_s=64, interpret=True)
        os_.append(o_i)
        ls_.append(l_i)
    o_comb = ref.combine_decode_shards(jnp.stack(os_), jnp.stack(ls_))
    np.testing.assert_allclose(np.array(o_comb), np.array(o_full), atol=2e-5, rtol=2e-5)


def test_decode_attention_sliding_window():
    k = jax.random.split(jax.random.PRNGKey(4), 3)
    B, S, H, KVH, D = 2, 256, 4, 2, 32
    q = jax.random.normal(k[0], (B, H, D))
    kk = jax.random.normal(k[1], (B, S, KVH, D))
    vv = jax.random.normal(k[2], (B, S, KVH, D))
    cl = jnp.array([200, 256], jnp.int32)
    o_r, _ = ref.decode_attention(q, kk, vv, cl, window=64, return_lse=True)
    o_p, _ = da_pallas(q, kk, vv, cl, window=64, block_s=64, interpret=True)
    np.testing.assert_allclose(np.array(o_p), np.array(o_r), atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# decode attention, fused int8 dequant (kv_quant cache path)
# --------------------------------------------------------------------------- #
def _quantized_cache(key, B, S, KVH, D):
    from repro.models.lm import quant_kv

    k1, k2 = jax.random.split(key)
    kk = jax.random.normal(k1, (B, S, KVH, D), jnp.bfloat16)
    vv = jax.random.normal(k2, (B, S, KVH, D), jnp.bfloat16)
    kq, ks = quant_kv(kk)
    vq, vs = quant_kv(vv)
    return kq, vq, ks, vs


@pytest.mark.parametrize("S,H,KVH,D,block", [
    (128, 4, 2, 32, 64),   # GQA, per-slot varied fills
    (256, 4, 4, 32, 64),   # MHA
    (512, 8, 2, 64, 128),  # larger cache
    (256, 16, 1, 32, 64),  # MQA
])
@pytest.mark.parametrize("window", [None, 96])
def test_decode_attention_quant(S, H, KVH, D, block, window):
    """Fused-dequant Pallas kernel vs the dequantize-up-front oracle, across
    per-slot variable cache_len (the continuous engine's slot fills)."""
    k = jax.random.split(jax.random.PRNGKey(5), 2)
    B = 3
    q = jax.random.normal(k[0], (B, H, D), jnp.float32)
    kq, vq, ks, vs = _quantized_cache(k[1], B, S, KVH, D)
    cl = jnp.array([S // 3, S, 1], jnp.int32)
    o_r, l_r = ref.decode_attention_quant(
        q, kq, vq, ks, vs, cl, window=window, return_lse=True)
    o_p, l_p = daq_pallas(q, kq, vq, ks, vs, cl, window=window,
                          block_s=block, interpret=True)
    np.testing.assert_allclose(np.array(o_p, np.float32),
                               np.array(o_r, np.float32), atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.array(l_p), np.array(l_r),
                               atol=1e-2, rtol=1e-2)


def test_decode_attention_quant_matches_unfused():
    """The fused kernel must agree with dequantizing the whole cache and
    running the plain kernel — the exact computation it replaces in
    ``lm._decode_quant``."""
    from repro.models.lm import dequant_kv

    k = jax.random.split(jax.random.PRNGKey(6), 2)
    B, S, H, KVH, D = 2, 256, 4, 2, 32
    q = jax.random.normal(k[0], (B, H, D), jnp.float32)
    kq, vq, ks, vs = _quantized_cache(k[1], B, S, KVH, D)
    cl = jnp.array([77, 200], jnp.int32)
    o_fused, l_fused = daq_pallas(q, kq, vq, ks, vs, cl, block_s=64,
                                  interpret=True)
    o_unf, l_unf = da_pallas(q, dequant_kv(kq, ks), dequant_kv(vq, vs), cl,
                             block_s=64, interpret=True)
    np.testing.assert_allclose(np.array(o_fused, np.float32),
                               np.array(o_unf, np.float32),
                               atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.array(l_fused), np.array(l_unf),
                               atol=1e-2, rtol=1e-2)


# --------------------------------------------------------------------------- #
# SSD
# --------------------------------------------------------------------------- #
def _ssd_inputs(key, b, s, nh, p, g, n, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (b, s, nh, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (b, s, g, n), dtype)
    Cm = jax.random.normal(ks[4], (b, s, g, n), dtype)
    D = jax.random.normal(ks[5], (nh,))
    return x, dt, A, Bm, Cm, D


@pytest.mark.parametrize("s,nh,p,g,n,chunk", [
    (64, 2, 16, 1, 16, 16),
    (128, 4, 32, 2, 16, 32),
    (256, 4, 64, 4, 32, 64),
    (128, 8, 64, 1, 128, 128),  # mamba2-like (ngroups=1, N=128)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_scan(s, nh, p, g, n, chunk, dtype):
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(5), 2, s, nh, p, g, n, dtype)
    y_r, h_r = ref.ssd_scan(x, dt, A, Bm, Cm, D, return_state=True)
    y_p, h_p = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    # fp32 tol: chunked recurrence vs sequential scan accumulate in different
    # orders; the worst observed element error varies with the jax/XLA version
    # (~3e-4 on CPU jax 0.4.x), so leave headroom above it
    t = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.array(y_p, np.float32), np.array(y_r, np.float32), **t)
    np.testing.assert_allclose(np.array(h_p), np.array(h_r), atol=1e-3, rtol=1e-3)


def test_ssd_chunked_ref_matches_scan():
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(6), 2, 96, 4, 8, 2, 8)
    y1 = ref.ssd_scan(x, dt, A, Bm, Cm, D)
    y2 = ref.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=32)
    np.testing.assert_allclose(np.array(y1), np.array(y2), atol=1e-4, rtol=1e-4)


def test_ssd_decode_matches_scan_prefix():
    b, s, nh, p, g, n = 2, 16, 4, 8, 2, 8
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(7), b, s, nh, p, g, n)
    y_scan = ref.ssd_scan(x, dt, A, Bm, Cm, D)
    h = jnp.zeros((b, nh, p, n))
    for t in range(s):
        y_t, h = ref.ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D, h)
        np.testing.assert_allclose(np.array(y_t), np.array(y_scan[:, t]),
                                   atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------- #
# rmsnorm
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("shape", [(4, 64), (3, 100, 64), (7, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    k = jax.random.split(jax.random.PRNGKey(8), 2)
    x = jax.random.normal(k[0], shape, dtype)
    w = (jax.random.normal(k[1], (shape[-1],)) * 0.1).astype(dtype)
    y_r = ref.rmsnorm(x, w)
    y_p = rn_pallas(x, w, block_rows=32, interpret=True)
    np.testing.assert_allclose(np.array(y_p, np.float32),
                               np.array(y_r, np.float32), **tol(dtype))
