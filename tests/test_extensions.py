"""Tests for the beyond-paper extensions: int8 KV cache, gradient
accumulation, train<->serve weight switching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import get_model
from repro.models.lm import dequant_kv, quant_kv
from repro.utils.jax_compat import make_compat_mesh


# --------------------------------------------------------------------------- #
# int8 KV cache
# --------------------------------------------------------------------------- #
def test_quant_kv_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 3, 16), jnp.bfloat16) * 4
    q, s = quant_kv(x)
    y = dequant_kv(q, s)
    err = np.abs(np.asarray(y, np.float32) - np.asarray(x, np.float32))
    bound = np.asarray(s)[..., None] * 0.5 + 0.05  # half-step + bf16 slack
    assert np.all(err <= bound)


@pytest.mark.parametrize("arch", ["deepseek-67b", "mixtral-8x7b", "gemma-2b"])
def test_int8_cache_decode_close_to_fp(arch):
    cfg = reduced(ARCHS[arch])
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    m, mq = get_model(cfg), get_model(cfg_q)
    params = m.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 1, 250)
    lg, c, cl = m.prefill(params, tok, smax=20)
    lgq, cq, clq = mq.prefill(params, tok, smax=20)
    # prefill attention runs on unquantized k/v -> identical logits
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lgq))
    assert cq[0]["k"].dtype == jnp.int8
    nxt = jnp.argmax(lg, -1)
    l1, c, cl = m.decode_step(params, nxt, c, cl)
    l2, cq, clq = mq.decode_step(params, nxt, cq, clq)
    valid = np.asarray(l1) > -1e29
    err = np.abs((np.asarray(l1) - np.asarray(l2))[valid]).max()
    assert err < 0.25, err  # int8 cache tolerance

    # cache bytes halve (int8 + f32 scales vs bf16)
    def nbytes(cc):
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cc))

    attn_fp = nbytes({k: v for k, v in c[0].items()} if isinstance(c[0], dict) else c[0])
    attn_q = nbytes(cq[0])
    assert attn_q < 0.7 * attn_fp


# --------------------------------------------------------------------------- #
# gradient accumulation
# --------------------------------------------------------------------------- #
def test_accumulated_actor_step_matches_full_batch():
    from repro.rl import RLConfig
    from repro.rl.trainer import (init_state, make_actor_step,
                                  make_actor_step_accumulated)

    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260, num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, head_dim=16)
    model = get_model(cfg)
    rl = RLConfig(algorithm="grpo", lr=1e-3, group_size=4)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 8, 16
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, T), 3, 250)
    mask = jnp.concatenate(
        [jnp.zeros((B, 6), bool), jnp.ones((B, T - 6), bool)], 1)
    lp, _ = model.logprobs(params, tokens)
    batch = {
        "tokens": tokens,
        "response_mask": mask,
        "old_logprob": lp * mask,
        "ref_logprob": lp * mask,
        "advantages": jax.random.normal(key, (B, T)) * mask,
    }
    s1, m1 = jax.jit(make_actor_step(model, rl))(init_state(params), batch)
    s2, m2 = jax.jit(make_actor_step_accumulated(model, rl, num_microbatches=4))(
        init_state(params), batch)
    # GRPO loss is a token-mean; microbatch token counts are equal here, so
    # the averaged-grad update matches the full-batch one closely
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


# --------------------------------------------------------------------------- #
# weight switching
# --------------------------------------------------------------------------- #
def test_weight_switch_preserves_values_and_prices_bytes():
    from repro.distributed import weight_sync
    from repro.launch.workloads import state_shapes

    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=256, num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=4, head_dim=16)
    mesh = make_compat_mesh((1, 1), ("data", "model"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dst = weight_sync.specs_for(cfg, mesh, params, "serve")
    switched = weight_sync.switch(mesh, params, dst)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(switched)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # analytic bytes on the production-mesh shapes: train->serve must move
    # roughly the destination-resident bytes (weights weren't resident before)
    class FakeMesh:
        def __init__(self, m):
            self.shape = dict(m)
            self.axis_names = tuple(m)

    big = ARCHS["deepseek-67b"]
    state = state_shapes(big)
    out = weight_sync.switch_bytes(big, FakeMesh({"data": 16, "model": 16}),
                                   state.params)
    resident = out["resident_bytes_per_device_dst"]
    assert 7e9 < resident < 10e9  # ~67B bf16 / 16-way TP
    assert 0.5 * resident < out["recv_bytes_per_device"] <= resident
    assert out["switch_seconds"] < 0.1  # amortized per iteration: negligible


# --------------------------------------------------------------------------- #
# one-step-off-policy pipelined worker
# --------------------------------------------------------------------------- #
def test_pipelined_worker_learns_off_policy():
    from repro.core import build_pipeline
    from repro.core.async_worker import PipelinedDAGWorker
    from repro.data.dataset import SyntheticMathDataset
    from repro.rl import RLConfig

    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260, num_layers=2,
                  d_model=128, d_ff=256, num_heads=4, num_kv_heads=2,
                  head_dim=16)
    rl = RLConfig(algorithm="grpo", group_size=8, max_new_tokens=3,
                  lr=1e-3, kl_coef=0.0)
    ds = SyntheticMathDataset(4096, seed=7, max_operand=4)
    pipe = build_pipeline(cfg, rl, prompts_per_iter=8, seed=7, dataset=ds)
    pipe.worker = PipelinedDAGWorker(pipe.ctx, pipe.plan,
                                     pipe.worker.registry, pipe.buffer)
    hist = [pipe.worker.run_iteration() for _ in range(30)]
    # first iteration has no pending batch -> no train metrics
    assert "actor/loss" not in hist[0]
    assert "actor/loss" in hist[2]
    # off-policy signature: behaviour policy is one step stale, so the ratio
    # deviates from exactly-1 once updates start moving params
    rewards = np.array([h.get("reward/mean", 0.0) for h in hist])
    assert rewards[-8:].mean() > rewards[:8].mean()  # still learns
    assert np.isfinite(rewards).all()
