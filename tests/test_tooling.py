"""CI tooling tests: scripts/ci.sh must propagate chunk failures and print
per-chunk timing; scripts/check_docs.py must execute doc fences and catch
API drift."""
import os
import pathlib
import subprocess
import sys
import textwrap

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run_ci(chunks: str):
    env = dict(os.environ, CI_CHUNKS=chunks)
    env.pop("PYTHONPATH", None)  # ci.sh must set it itself
    return subprocess.run(
        ["bash", str(REPO / "scripts" / "ci.sh")],
        capture_output=True, text=True, env=env, cwd=REPO,
    )


def test_ci_sh_propagates_chunk_failure(tmp_path):
    """Acceptance: a failing parallel chunk fails the whole run (verified
    with an intentionally failing chunk) and timings are printed."""
    good = tmp_path / "test_good.py"
    good.write_text("def test_ok():\n    assert True\n")
    bad = tmp_path / "test_bad.py"
    bad.write_text("def test_nope():\n    assert False\n")
    res = _run_ci(f"{good};{bad}")
    assert res.returncode != 0, res.stdout + res.stderr
    assert "chunk2 FAILED" in res.stdout
    assert "chunk1 ok in " in res.stdout  # per-chunk timing is visible
    assert "s:" in res.stdout


def test_ci_sh_green_run_exits_zero(tmp_path):
    good = tmp_path / "test_good.py"
    good.write_text("def test_ok():\n    assert True\n")
    res = _run_ci(str(good))
    assert res.returncode == 0, res.stdout + res.stderr
    assert "chunk1 ok in " in res.stdout


def _run_check_docs(*paths):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_docs.py"),
         *map(str, paths)],
        capture_output=True, text=True, cwd=REPO,
    )


def test_check_docs_runs_good_fences_cumulatively(tmp_path):
    md = tmp_path / "good.md"
    md.write_text(textwrap.dedent("""\
        # sample
        ```python
        from repro.configs import AsyncPipelineConfig
        cfg = AsyncPipelineConfig(enabled=True, max_staleness=1)
        ```
        Later fences share the namespace:
        ```python
        assert cfg.max_staleness == 1
        ```
        Non-python fences are ignored:
        ```json
        {"not": "executed"}
        ```
        ```python no-check
        this_would_raise(
        ```
        """))
    res = _run_check_docs(md)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "all python fences pass" in res.stdout


def test_check_docs_catches_api_drift(tmp_path):
    md = tmp_path / "drift.md"
    md.write_text(textwrap.dedent("""\
        ```python
        from repro.configs import AsyncPipelineConfig
        AsyncPipelineConfig(max_staleness_typo=1)
        ```
        """))
    res = _run_check_docs(md)
    assert res.returncode != 0
    assert "FAIL" in res.stdout
    assert "drift.md:1" in res.stdout  # failure names file and fence line
